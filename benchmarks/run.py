"""Benchmark harness: one module per paper table/figure + system tables.

Prints ``name,value,derived`` CSV. Modules:
  upload_time      — paper Fig. 8 (upload seconds vs model size/bandwidth)
  bandwidth_model  — paper SPIC cost claim (50 MB/s video vs <1 MB/s updates)
  convergence      — paper efficiency claim (federated vs centralized)
  kernel_bench     — kernel reference micro-benchmarks
  kernel_bench_detect — detection IoU/NMS: Pallas vs NumPy oracle
  kernel_bench_agg — packed-vs-tree aggregation transport
  round_sweep      — per-round work vs participation fraction, tree (PR 3,
                     DESIGN.md §8) and flat (DESIGN.md §11) engines timed
                     with paired samples
  eq6_guard        — packed eq6 must beat tree eq6 at 256k (regression gate)
  async_equiv      — full-buffer async == flat sync bit-for-bit (DESIGN.md §12)
  async_sweep      — async vs sync time-to-loss on the simulated wall clock,
                     straggler fractions {0.125, 0.25, 0.5} (async must win
                     at 0.25 or the module fails)
  client_scaling   — flat vs hier vs sharded-hier aggregation at
                     C ∈ {8, 64, 256, 1024} + the C=1024 streaming async
                     flush (DESIGN.md §13); writes BENCH_scaling_sweep.csv
  wire_bench       — socket-transport payload bytes per codec + measured
                     localhost DISPATCH/UPDATE round-trip (DESIGN.md §14)
  pareto_bench     — communication-frontier Pareto sweep (DESIGN.md §15):
                     loss vs uplink bytes for dense/quant8/quant4/topk_ef/
                     topk_ef+quant4/secure-int4
  serve_bench      — serving plane (DESIGN.md §17): served QPS + p50/p99 at
                     batch occupancy 1/4/8 (batched-8 must beat sequential)
                     and the hot-swap-under-load row (zero dropped requests,
                     post-swap responses carry the new round version);
                     writes BENCH_serve_rows.csv
  roofline_table   — per (arch x shape x mesh) roofline from the dry-run

``--smoke`` runs the cheap analytic tables, a 1-iteration flat-round sweep,
the eq6 tiling guard (packed eq6 must beat the tree path at 256k — the
module FAILS if the packed reducer regresses), the async-vs-sync
equivalence guard (full-buffer async must reproduce the sync round
bit-for-bit), the hier scaling guard (the two-level reduce must not
lose to flat at C=64, with the C ∈ {8, 64} curves written to
BENCH_scaling_sweep.csv), and the frontier guard (topk_ef at k/N=0.1 must
stay within 10% of the dense round-20 loss at a >4x payload cut vs
quant8) — the CI gate (scripts/check.sh) that proves the
harness imports, both round engines run, and the re-tiled reducers still
win, in a few minutes of compute.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast CI subset: analytic tables + tiny participation sweep")
    args = ap.parse_args()

    from benchmarks import async_bench, bandwidth_model, convergence, kernel_bench, pareto_bench, roofline_table, scale_bench, serve_bench, upload_time, wire_bench

    if args.smoke:
        modules = [
            ("upload_time", upload_time.rows),
            ("bandwidth_model", bandwidth_model.rows),
            ("flat_round", lambda: kernel_bench.flat_round_rows(iters=1)),
            ("eq6_guard", kernel_bench.eq6_guard_rows),
            ("async_equiv", async_bench.equivalence_rows),
            ("client_scaling", scale_bench.smoke_rows),
            ("wire_bench", wire_bench.rows),
            ("pareto_smoke", pareto_bench.smoke_rows),
            ("serve_bench", serve_bench.smoke_rows),
        ]
    else:
        modules = [
            ("upload_time", upload_time.rows),
            ("bandwidth_model", bandwidth_model.rows),
            ("convergence", convergence.rows),
            ("kernel_bench", kernel_bench.rows),
            ("kernel_bench_detect", kernel_bench.detect_rows),
            ("kernel_bench_agg", kernel_bench.agg_rows),
            ("round_sweep", kernel_bench.round_sweep_rows),
            ("eq6_guard", kernel_bench.eq6_guard_rows),
            ("async_equiv", async_bench.equivalence_rows),
            ("async_sweep", async_bench.async_sweep_rows),
            ("client_scaling", scale_bench.full_rows),
            ("wire_bench", wire_bench.rows),
            ("pareto_bench", pareto_bench.rows),
            ("serve_bench", serve_bench.rows),
            ("roofline_table", roofline_table.rows),
        ]
    failed = 0
    for name, rows_fn in modules:
        try:
            for row_name, val, extra in rows_fn():
                print(f"{row_name},{val},{extra}")
        except Exception:  # noqa: BLE001
            failed += 1
            traceback.print_exc()
            print(f"{name},ERROR,", file=sys.stderr)
    if failed:
        raise SystemExit(f"{failed} benchmark modules failed")


if __name__ == "__main__":
    main()
