"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json and emits one CSV row per (arch x shape x
mesh): the three roofline terms, dominant bottleneck, memory/device, and the
MODEL_FLOPS/HLO_FLOPs useful ratio.
"""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def load(tag: str | None = None):
    recs = []
    for f in sorted(DRYRUN.glob("*.json")):
        d = json.loads(f.read_text())
        name = d.get("name", f.stem)
        has_tag = len(name.split("--")) > 3
        if (tag is None) == has_tag:
            continue
        if tag and not name.endswith("--" + tag):
            continue
        recs.append(d)
    return recs


def rows(tag: str | None = None):
    out = []
    for d in load(tag):
        stem = d["name"]
        if "skipped" in d:
            out.append((f"roofline/{stem}", 0.0, f"SKIP:{d['skipped'][:60]}"))
            continue
        if "error" in d:
            out.append((f"roofline/{stem}", -1.0, "ERROR"))
            continue
        r = d["roofline"]
        mem = d["memory"]["total_per_device"] / 2**30
        dom_val = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append((
            f"roofline/{stem}",
            dom_val,
            f"dom={r['dominant']};c={r['compute_s']:.3g};m={r['memory_s']:.3g};"
            f"x={r['collective_s']:.3g};mem_GiB={mem:.2f};useful={r['useful_ratio']:.3f}",
        ))
    return out


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val:.4g},{extra}")
