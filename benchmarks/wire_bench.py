"""Wire-transport benchmark rows (DESIGN.md §14).

Two tables:

  wire/payload_*      — analytic UPDATE-payload bytes per codec
                        (`transport.codec.payload_bytes`) at representative
                        packed-row widths, plus the quant8 compression ratio
                        (the FedVision uplink claim, now with real wire
                        framing overhead included).
  wire/roundtrip_*    — measured localhost round-trip latency of one
                        DISPATCH -> UPDATE exchange over a real TCP socket
                        pair: full frames, `FrameParser` on both ends,
                        encode/decode included — everything but the training
                        step, so the row isolates transport cost from JAX.

Both are cheap (no jit, no subprocess) so they belong in the ``--smoke``
CI subset: they prove the framing + codec path imports and moves real
bytes without spending the minutes a full `wire_run` federation costs.
"""
from __future__ import annotations

import socket
import threading
import time

import numpy as np

from repro.core.transport import codec, wire

# representative packed-row widths: the test harness's tiny arch (~0.4M),
# a 16M mid-size row, and the paper-scale FedYOLOv3 row (~62M params)
WIDTHS = {"tiny": 1 << 19, "mid": 1 << 24, "fedyolov3": 61_949_149}
RT_WIDTH = 1 << 20  # round-trip measurement payload (1M f32 = 4 MB dense)
RT_ITERS = 5


def payload_rows():
    out = []
    for name, n in WIDTHS.items():
        dense = codec.payload_bytes(n, "dense")
        out.append((f"wire/payload_{name}_dense_MB", dense / 1e6, f"n={n}"))
        for cname in ("quant8", "quant4", "topk"):
            b = codec.payload_bytes(n, cname)
            out.append((f"wire/payload_{name}_{cname}_MB", b / 1e6,
                        f"ratio={dense / b:.2f}x"))
    return out


def _echo_server(listener: socket.socket, n: int):
    """Server half: send a DISPATCH, parse the UPDATE that comes back."""
    sock, _ = listener.accept()
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    parser = wire.FrameParser()
    row = np.zeros(n, np.float32)
    payload = codec.encode_row(row, "dense")
    for _ in range(RT_ITERS):
        sock.sendall(wire.pack_dispatch(1, payload))
        frames = []
        while not frames:
            data = sock.recv(1 << 20)
            if not data:
                return
            frames.extend(parser.feed(data))
    sock.close()


def roundtrip_rows():
    out = []
    for name in codec.CODECS:
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.bind(("127.0.0.1", 0))
        listener.listen(1)
        t = threading.Thread(target=_echo_server, args=(listener, RT_WIDTH), daemon=True)
        t.start()
        sock = socket.create_connection(listener.getsockname()[:2], timeout=30.0)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        parser = wire.FrameParser()
        times = []
        for i in range(RT_ITERS):
            t0 = time.perf_counter()
            frames = []
            while not frames:
                frames.extend(parser.feed(sock.recv(1 << 20)))
            _v, row_buf = wire.parse_dispatch(frames[0][1])
            base = codec.decode_row(row_buf).astype(np.float32)
            buf = codec.encode_update(base, base, name, 1024)
            sock.sendall(wire.pack_update(0, i, 1, 0.0, buf))
            times.append(time.perf_counter() - t0)
        sock.close()
        t.join(timeout=10.0)
        listener.close()
        # first iteration pays connection warmup; report the rest
        ms = 1e3 * float(np.median(times[1:] or times))
        out.append((f"wire/roundtrip_{name}_ms", ms,
                    f"n={RT_WIDTH};iters={RT_ITERS}"))
    return out


def rows():
    return payload_rows() + roundtrip_rows()


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val:.4f},{extra}")
