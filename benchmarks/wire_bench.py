"""Wire-transport benchmark rows (DESIGN.md §14).

Three tables:

  wire/payload_*      — analytic UPDATE-payload bytes per codec
                        (`transport.codec.payload_bytes`) at representative
                        packed-row widths, plus the quant8 compression ratio
                        (the FedVision uplink claim, now with real wire
                        framing overhead included).
  wire/roundtrip_*    — measured localhost round-trip latency of one
                        DISPATCH -> UPDATE exchange over a real TCP socket
                        pair: full frames, `FrameParser` on both ends,
                        encode/decode included — everything but the training
                        step, so the row isolates transport cost from JAX.
  wire/snapshot_* /   — durability cost (DESIGN.md §16): full-engine
  wire/wal_*            snapshot write/verify wall time at representative
                        state sizes, WAL append cost per landing event in
                        both durability modes (flush-per-event vs
                        fsync-per-event), and the headline guard row
                        ``wire/wal_overhead_vs_roundtrip_pct`` — WAL-on
                        landing throughput must stay within 15% of WAL-off
                        even at the transport's own floor cadence (a dense
                        roundtrip with zero training time). `rows()` ASSERTS
                        the guard, so a WAL regression fails the CI
                        bench-smoke step, not just a dashboard.

All are cheap (no jit, no subprocess) so they belong in the ``--smoke``
CI subset: they prove the framing + codec + durability path imports and
moves real bytes without spending the minutes a full `wire_run` costs.
"""
from __future__ import annotations

import socket
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

from repro.checkpoint import durable as dr
from repro.core.transport import codec, wire
from repro.core.transport.replay import WireEvent

# representative packed-row widths: the test harness's tiny arch (~0.4M),
# a 16M mid-size row, and the paper-scale FedYOLOv3 row (~62M params)
WIDTHS = {"tiny": 1 << 19, "mid": 1 << 24, "fedyolov3": 61_949_149}
RT_WIDTH = 1 << 20  # round-trip measurement payload (1M f32 = 4 MB dense)
RT_ITERS = 5


def payload_rows():
    out = []
    for name, n in WIDTHS.items():
        dense = codec.payload_bytes(n, "dense")
        out.append((f"wire/payload_{name}_dense_MB", dense / 1e6, f"n={n}"))
        for cname in ("quant8", "quant4", "topk"):
            b = codec.payload_bytes(n, cname)
            out.append((f"wire/payload_{name}_{cname}_MB", b / 1e6,
                        f"ratio={dense / b:.2f}x"))
    return out


def _echo_server(listener: socket.socket, n: int):
    """Server half: send a DISPATCH, parse the UPDATE that comes back."""
    sock, _ = listener.accept()
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    parser = wire.FrameParser()
    row = np.zeros(n, np.float32)
    payload = codec.encode_row(row, "dense")
    for _ in range(RT_ITERS):
        sock.sendall(wire.pack_dispatch(1, payload))
        frames = []
        while not frames:
            data = sock.recv(1 << 20)
            if not data:
                return
            frames.extend(parser.feed(data))
    sock.close()


def roundtrip_rows():
    out = []
    for name in codec.CODECS:
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.bind(("127.0.0.1", 0))
        listener.listen(1)
        t = threading.Thread(target=_echo_server, args=(listener, RT_WIDTH), daemon=True)
        t.start()
        sock = socket.create_connection(listener.getsockname()[:2], timeout=30.0)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        parser = wire.FrameParser()
        times = []
        for i in range(RT_ITERS):
            t0 = time.perf_counter()
            frames = []
            while not frames:
                frames.extend(parser.feed(sock.recv(1 << 20)))
            _v, row_buf = wire.parse_dispatch(frames[0][1])
            base = codec.decode_row(row_buf).astype(np.float32)
            buf = codec.encode_update(base, base, name, 1024)
            sock.sendall(wire.pack_update(0, i, 1, 0.0, buf))
            times.append(time.perf_counter() - t0)
        sock.close()
        t.join(timeout=10.0)
        listener.close()
        # first iteration pays connection warmup; report the rest
        ms = 1e3 * float(np.median(times[1:] or times))
        out.append((f"wire/roundtrip_{name}_ms", ms,
                    f"n={RT_WIDTH};iters={RT_ITERS}"))
    return out


# durable-state sizes: the harness tiny arch's (C=2, 1<<19) buffer and a
# mid-size (C=2, 1<<22) one — 4 MB / 32 MB snapshots, real disk I/O but
# well under a second each so the smoke subset stays fast
SNAP_WIDTHS = {"tiny": 1 << 19, "mid": 1 << 22}
SNAP_CLIENTS = 2
WAL_EVENTS = 2000       # flush-per-event appends to time
WAL_FSYNC_EVENTS = 100  # fsync-per-event appends (each pays a disk sync)
WAL_GUARD_PCT = 15.0


def _fake_state(n: int) -> dict:
    rng = np.random.default_rng(0)
    return {
        "arrays": {
            "params": rng.normal(size=(SNAP_CLIENTS, n)).astype(np.float32),
            "global": rng.normal(size=n).astype(np.float32),
            "dispatch_version": np.zeros(SNAP_CLIENTS, np.int64),
        },
        "scalars": {"round": 3, "version": 3},
    }


def durable_rows():
    """Snapshot + WAL cost rows (and the raw ingredients of the guard)."""
    out = []
    with tempfile.TemporaryDirectory(prefix="wirebench_durable_") as td:
        root = Path(td)
        for name, n in SNAP_WIDTHS.items():
            snap = _fake_state(n)
            p = root / f"{name}.ckpt"
            t0 = time.perf_counter()
            nbytes = dr.write_snapshot(p, snap)
            w_ms = 1e3 * (time.perf_counter() - t0)
            t0 = time.perf_counter()
            dr.read_snapshot(p)  # includes the CRC verify recovery pays
            r_ms = 1e3 * (time.perf_counter() - t0)
            out.append((f"wire/snapshot_{name}_write_ms", w_ms,
                        f"bytes={nbytes};C={SNAP_CLIENTS};n={n}"))
            out.append((f"wire/snapshot_{name}_verify_ms", r_ms,
                        f"bytes={nbytes}"))
        ev = WireEvent("land", 1.0, 0, 1, seq=0, dropped=False, flush=-1)
        for mode, fsync, iters in (("flush", False, WAL_EVENTS),
                                   ("fsync", True, WAL_FSYNC_EVENTS)):
            run = dr.DurableRun(root / f"wal_{mode}", {"bench": mode},
                                fsync_every_event=fsync)
            t0 = time.perf_counter()
            for _ in range(iters):
                run.append_event(ev)
            us = 1e6 * (time.perf_counter() - t0) / iters
            run.close()
            out.append((f"wire/wal_append_{mode}_us", us, f"iters={iters}"))
    return out


def rows():
    rt = roundtrip_rows()
    du = durable_rows()
    # the guard: one WAL append (the per-landing durability cost in the
    # default flush-per-event mode) against the dense roundtrip — the
    # fastest landing cadence the transport itself can sustain. Staying
    # under 15% *here* means any real run (which also trains) sees far less.
    rt_dense_ms = next(v for n, v, _ in rt if n == "wire/roundtrip_dense_ms")
    wal_us = next(v for n, v, _ in du if n == "wire/wal_append_flush_us")
    pct = 100.0 * (wal_us / 1e3) / rt_dense_ms
    assert pct < WAL_GUARD_PCT, (
        f"WAL-on landing overhead {pct:.2f}% exceeds the {WAL_GUARD_PCT}% "
        f"guard (append {wal_us:.1f}us vs dense roundtrip {rt_dense_ms:.2f}ms)"
    )
    du.append(("wire/wal_overhead_vs_roundtrip_pct", pct,
               f"guard<{WAL_GUARD_PCT:.0f};append_us={wal_us:.1f}"))
    return payload_rows() + rt + du


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val:.4f},{extra}")
