"""Communication-frontier Pareto sweep (DESIGN.md §15).

One curve per uplink budget: dense / quant8 / quant4 / topk_ef /
topk_ef+quant4 / secure-int4 federated runs on the same non-IID token
stream, reporting final loss against analytic uplink payload bytes per
element. The frontier claim the sweep records: topk_ef at k/N = 0.1
composed with 4-bit values cuts the uplink >= 16x under dense while
staying within 10% of the dense round-20 loss.

``smoke_rows`` is the CI guard (benchmarks/run.py --smoke): dense vs
topk_ef only, FAILING if the sparsified run regresses past 10% or the
payload drops under 4x vs quant8. Running as a script appends the full
sweep to ``BENCH_kernel_bench.json`` via kernel_bench.emit_trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.core.transport import codec
from repro.data.pipeline import fed_batches
from repro.optim import adamw

CFG = get_arch("qwen3-1.7b").reduced()
ROUNDS = 20
CLIENTS = 4
BATCH = 4
SEQ = 32
BLOCK = 1024  # FedConfig.quant_block default — scales amortize to 4/BLOCK

VARIANTS = {
    "dense": {"aggregation": "dense"},
    "quant8": {"aggregation": "quant8"},
    "quant4": {"aggregation": "quant4", "quant4_mode": "stochastic"},
    "topk_ef": {"aggregation": "topk_ef", "topk_frac": 0.1},
    "topk_ef_quant4": {
        "aggregation": "topk_ef", "topk_frac": 0.1, "topk_quant": "quant4",
    },
    "secure_int4": {"aggregation": "secure", "secure_domain": "int4"},
}


def payload_per_elt(name: str) -> float:
    """Analytic uplink bytes per packed element (codec.py framing, headers
    amortized away): what each variant's UPDATE would weigh on the wire."""
    f = 0.1
    if name == "dense":
        return 4.0
    if name == "quant8":
        return 1.0 + 4.0 / BLOCK
    if name == "quant4":
        return 0.5 + 4.0 / BLOCK  # two values per byte + f32 scales
    if name == "topk_ef":
        return 1.0 / 8 + f * (1.0 + 4.0 / BLOCK)  # bitmap + int8 values
    if name == "topk_ef_quant4":
        return 1.0 / 8 + f * (0.5 + 4.0 / BLOCK)  # bitmap + nibble values
    if name == "secure_int4":
        # the pairwise masks occupy the FULL uint32 ring: exact cancellation
        # costs the wire its dense width (the privacy/bandwidth trade)
        return 4.0
    raise ValueError(name)


def run(name: str, rounds: int = ROUNDS) -> tuple[float, float]:
    fed = FedConfig(
        n_clients=CLIENTS,
        local_steps=2,
        topn=2,
        client_axis="data",
        data_axis=None,
        quant_block=BLOCK,
        **VARIANTS[name],
    )
    opt = adamw(3e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        t0 = time.time()
        loss = float("nan")
        src = fed_batches(CFG, fed, batch=BATCH, seq=SEQ, seed=0)
        for _, b in zip(range(rounds), src):
            state, m = fr(state, jax.tree.map(jnp.asarray, b), R.uniform_weights(CLIENTS))
            loss = float(m["loss"])
        return loss, time.time() - t0


def _guard(dense_loss: float, sparse_loss: float) -> None:
    if not sparse_loss <= dense_loss * 1.10:
        raise AssertionError(
            f"topk_ef(k/N=0.1) final loss {sparse_loss:.4f} regressed >10% past "
            f"dense {dense_loss:.4f}"
        )
    # payload guard pinned to the REAL codec framing, not the analytic model
    n = 10**6
    topk_b = codec.payload_bytes(n, "topk", BLOCK)
    quant8_b = codec.payload_bytes(n, "quant8", BLOCK)
    if not quant8_b > 4 * topk_b:
        raise AssertionError(
            f"topk payload {topk_b} not >4x under quant8 {quant8_b} at n={n}"
        )


def smoke_rows():
    """CI gate: the sparsified frontier must stay on the dense curve."""
    dense_loss, dense_dt = run("dense")
    topk_loss, topk_dt = run("topk_ef")
    _guard(dense_loss, topk_loss)
    ratio = payload_per_elt("quant8") / payload_per_elt("topk_ef")
    return [
        ("pareto/dense_round20_loss", dense_loss, f"wall_s={dense_dt:.1f}"),
        ("pareto/topk_ef_round20_loss", topk_loss,
         f"loss={topk_loss:.4f};dense={dense_loss:.4f};payload_vs_quant8={ratio:.2f}x;guard=pass"),
    ]


def rows(rounds: int = ROUNDS):
    out = []
    losses = {}
    for name in VARIANTS:
        loss, dt = run(name, rounds)
        losses[name] = loss
        bpe = payload_per_elt(name)
        out.append((
            f"pareto/{name}_round{rounds}_loss",
            loss,
            f"loss={loss:.4f};payload_bytes_per_elt={bpe:.4f};"
            f"uplink_vs_dense={4.0 / bpe:.1f}x;wall_s={dt:.1f}",
        ))
    _guard(losses["dense"], losses["topk_ef"])
    # the acceptance pin: >=16x uplink cut at <=10% loss regression
    comp = payload_per_elt("topk_ef_quant4")
    assert 4.0 / comp >= 16.0, comp
    out.append((
        "pareto/topk_ef_quant4_uplink_reduction_x",
        4.0 / comp,
        f"loss={losses['topk_ef_quant4']:.4f};dense_loss={losses['dense']:.4f};"
        f"regression={(losses['topk_ef_quant4'] / losses['dense'] - 1) * 100:.2f}pct",
    ))
    return out


if __name__ == "__main__":
    from benchmarks import kernel_bench

    all_rows = rows()
    for name, val, extra in all_rows:
        print(f"{name},{val:.4f},{extra}")
    kernel_bench.emit_trajectory(all_rows)
    print(f"# trajectory appended to {kernel_bench.BENCH_JSON}")
