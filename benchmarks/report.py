"""Render EXPERIMENTS.md tables from experiments/dryrun artifacts."""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
ARCH_ORDER = [
    "granite-3-8b", "qwen3-1.7b", "hubert-xlarge", "grok-1-314b",
    "granite-moe-1b-a400m", "gemma3-27b", "llava-next-34b", "minitron-8b",
    "mamba2-1.3b", "zamba2-2.7b",
]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load(tag: str = ""):
    recs = {}
    for f in sorted(DRYRUN.glob("*.json")):
        d = json.loads(f.read_text())
        parts = f.stem.split("--")
        if tag:
            if len(parts) != 4 or parts[3] != tag:
                continue
        elif len(parts) != 3:
            continue
        recs[(parts[0], parts[1], parts[2])] = d
    return recs


def fmt_s(x):
    if x == 0:
        return "0"
    if x >= 0.01:
        return f"{x:.2f}"
    return f"{x:.1e}"


def roofline_table(mesh: str, tag: str = "") -> str:
    recs = load(tag)
    lines = [
        "| arch | shape | kind | compute s | memory s | collective s | dominant | mem/dev GiB | useful ratio |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = recs.get((a, s, mesh))
            if d is None:
                continue
            if "skipped" in d:
                lines.append(f"| {a} | {s} | — | — | — | — | *skipped* | — | — |")
                continue
            r = d["roofline"]
            mem = d["memory"]["total_per_device"] / 2**30
            lines.append(
                f"| {a} | {s} | {d['kind']} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
                f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** | {mem:.2f} | {r['useful_ratio']:.2f} |"
            )
    return "\n".join(lines)


def dryrun_table(mesh: str) -> str:
    recs = load()
    lines = [
        "| arch | shape | compile s | args GiB | temp GiB | HLO GFLOPs/dev | collective bytes/dev (by op) |",
        "|---|---|---|---|---|---|---|",
    ]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            d = recs.get((a, s, mesh))
            if d is None or "skipped" in d:
                continue
            h = d["hlo_costs"]
            colls = ", ".join(
                f"{k}:{v/2**20:.0f}MiB" for k, v in sorted(h["collective_bytes"].items(), key=lambda kv: -kv[1])
            ) or "none"
            lines.append(
                f"| {a} | {s} | {d['compile_s']} | {d['memory']['argument_bytes']/2**30:.2f} "
                f"| {d['memory']['temp_bytes']/2**30:.2f} | {h['flops_per_device']/1e9:.1f} | {colls} |"
            )
    return "\n".join(lines)


if __name__ == "__main__":
    import sys

    kind = sys.argv[1] if len(sys.argv) > 1 else "roofline"
    mesh = sys.argv[2] if len(sys.argv) > 2 else "singlepod"
    tag = sys.argv[3] if len(sys.argv) > 3 else ""
    print((roofline_table if kind == "roofline" else dryrun_table)(mesh, *((tag,) if kind == "roofline" else ())))
