"""Async-vs-sync convergence on the simulated wall clock (DESIGN.md §12).

The paper's deployment regime: camera-edge clients whose upload times and
load spikes — not FLOPs — set the round period. The sync engine waits for
the slowest selected client every round, so time-to-loss degrades with the
straggler fraction; the buffered async engine flushes after ``buffer_size``
landed updates and discounts stale ones, so its flush period tracks the
*fast* clients. `async_sweep_rows` measures both engines' simulated
time-to-target-loss under the same `ClientLoadModel` + bandwidth terms at
straggler fractions {0.125, 0.25, 0.5}; async must win at 0.25 (the row
carries the speedup and the bench FAILS otherwise, like the eq6 guard).

`equivalence_rows` is the cheap CI tripwire (`benchmarks/run.py --smoke`):
async with ``buffer_size == C``, a zero-variance load model, and alpha=0
must reproduce the flat sync round BIT-FOR-BIT after two rounds.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import packing
from repro.core import rounds as R
from repro.core.async_engine import (
    BufferedAsyncEngine,
    TimingModel,
    default_upload_terms,
    sync_round_seconds,
)
from repro.core.explorer import ClientLoadModel, LoadModelConfig
from repro.core.rounds import FedConfig
from repro.core.simclock import SimClock
from repro.data.pipeline import fed_batches
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()
CLIENTS = 8
BATCH = 4
SEQ = 32
LR = 0.05
SYNC_ROUNDS = 8
ASYNC_BUDGET = 4 * SYNC_ROUNDS  # flushes; sim time, not flush count, is the metric
BUFFER = CLIENTS // 2
ALPHA = 0.5
# straggler compute dominates: healthy round ~ tens of seconds, a spiked or
# chronically hot client ~ minutes — the paper's camera-edge regime
TIMING = TimingModel(base_compute_s=20.0, uplink_spread=0.3)


def _fed(mode: str, **kw) -> FedConfig:
    return FedConfig(
        n_clients=CLIENTS, local_steps=1, aggregation="dense",
        client_axis="data", data_axis=None, mode=mode, **kw,
    )


def _load_model(frac: float, seed: int) -> ClientLoadModel:
    return ClientLoadModel(
        CLIENTS, seed=seed, config=LoadModelConfig(straggler_frac=frac)
    )


def _batches(fed: FedConfig, seed: int = 0):
    return (
        jax.tree.map(jnp.asarray, b)
        for b in fed_batches(CFG, fed, batch=BATCH, seq=SEQ, seed=seed)
    )


def _run_sync(frac: float, seed: int = 0) -> list[tuple[float, float]]:
    """(sim_time, loss) per round: the server waits for the slowest client."""
    fed = _fed("sync")
    opt = sgd(LR)
    clock = SimClock()
    lm = _load_model(frac, seed)
    spec = packing.build_pack_spec(CFG, R.make_template(CFG))
    # the ONE derivation the async engine uses too: same seed, same uplinks
    upload = default_upload_terms(TIMING, CLIENTS, spec.n_total, seed)
    state = R.make_state(CFG, fed, opt, jax.random.key(seed))
    fr = R.jit_fed_round(R.build_fed_round(CFG, fed, opt))
    w = R.uniform_weights(CLIENTS)
    src = _batches(fed, seed)
    trace = []
    for _ in range(SYNC_ROUNDS):
        dur = sync_round_seconds(TIMING, lm.loads, upload, fed.local_steps)
        state, m = fr(state, next(src), w)
        clock.advance(dur)
        lm.step(dur)
        trace.append((clock.now(), float(m["loss"])))
    return trace


def _run_async(frac: float, seed: int = 0) -> list[tuple[float, float]]:
    """(sim_time, loss) per flush of the buffered engine."""
    fed = _fed("async", buffer_size=BUFFER, staleness_alpha=ALPHA)
    eng = BufferedAsyncEngine(
        CFG, fed, sgd(LR), seed=seed,
        load_model=_load_model(frac, seed), timing=TIMING,
    )
    src = _batches(fed, seed)
    trace = []
    for _ in range(ASYNC_BUDGET):
        rec = eng.step_round(next(src))
        trace.append((rec.sim_time, rec.loss))
    return trace


def _time_to(trace: list[tuple[float, float]], target: float) -> float:
    for t, loss in trace:
        if loss <= target:
            return t
    return float("inf")


def async_sweep_rows(fracs=(0.125, 0.25, 0.5)):
    """Time-to-target-loss, sync vs async, per straggler fraction.

    The target is the sync trace's best loss, so the sync time is exactly
    the simulated time sync needed to get there; the async engine must
    reach the same loss sooner at the 0.25 fraction (the load model's
    default regime) or the module fails.
    """
    out = []
    for frac in fracs:
        sync_trace = _run_sync(frac)
        target = min(loss for _, loss in sync_trace)
        t_sync = _time_to(sync_trace, target)
        async_trace = _run_async(frac)
        t_async = _time_to(async_trace, target)
        speedup = t_sync / t_async if np.isfinite(t_async) else 0.0
        out.append((
            f"async/ttl_frac{frac}_sync_s", t_sync,
            f"target_loss={target:.4f};rounds={SYNC_ROUNDS};wait_for_slowest",
        ))
        out.append((
            f"async/ttl_frac{frac}_async_s", t_async,
            f"target_loss={target:.4f};buffer={BUFFER};alpha={ALPHA};"
            f"speedup_vs_sync={speedup:.2f}x;async_wins={t_async < t_sync}",
        ))
        if frac == 0.25 and not t_async < t_sync:
            raise RuntimeError(
                f"async lost at the 0.25-straggler regime: {t_async:.0f}s vs "
                f"sync {t_sync:.0f}s to loss {target:.4f} — the buffered "
                "engine must beat wait-for-slowest here"
            )
    return out


def equivalence_rows():
    """CI guard: full-buffer async == flat sync, bit for bit, 2 rounds."""
    C = 4
    fed_a = dataclasses.replace(
        _fed("async", buffer_size=C, staleness_alpha=0.0), n_clients=C
    )
    zero_var = LoadModelConfig(
        straggler_frac=0.0, base_spread=0.0, jitter=0.0, spike_prob=0.0
    )
    eng = BufferedAsyncEngine(
        CFG, fed_a, sgd(LR), seed=0,
        load_model=ClientLoadModel(C, seed=0, config=zero_var),
        timing=TimingModel(),
    )
    fed_s = dataclasses.replace(fed_a, mode="sync")
    opt = sgd(LR)
    state = R.make_state(CFG, fed_s, opt, jax.random.key(0))
    fr = R.jit_fed_round(R.build_fed_round(CFG, fed_s, opt))
    src_a, src_s = _batches(fed_a, seed=7), _batches(fed_s, seed=7)
    for _ in range(2):
        rec = eng.step_round(next(src_a))
        state, m = fr(state, next(src_s), R.uniform_weights(C))
    if not np.array_equal(np.asarray(state["params"]), np.asarray(eng.state["params"])):
        raise RuntimeError(
            "async (buffer_size == C, zero variance, alpha=0) diverged from "
            "the flat sync round — the sync-equivalence contract is broken"
        )
    if float(m["loss"]) != rec.loss:
        raise RuntimeError(
            f"async round loss {rec.loss} != sync round loss {float(m['loss'])}"
        )
    return [(
        "async/sync_equiv_bitwise", 1.0,
        f"buffer=C;alpha=0;zero_variance;rounds=2;staleness={rec.staleness}",
    )]


if __name__ == "__main__":
    from benchmarks.kernel_bench import emit_trajectory

    all_rows = equivalence_rows() + async_sweep_rows()
    for name, val, extra in all_rows:
        print(f"{name},{val:.1f},{extra}")
    emit_trajectory(all_rows)
    print("# trajectory appended to BENCH_kernel_bench.json")
