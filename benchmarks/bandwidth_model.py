"""Paper 'Application Use and Payoff' cost claim (SPIC case):

"100 channels of surveillance video ... require at least 50 MB/sec of
network bandwidth if image data need to be sent. With FedVision, the network
bandwidth required for model update is significantly reduced to less than
1 MB/sec."

We reproduce both sides with our system's real numbers: raw-video upload
bandwidth for 100 channels at the paper's 512 KB/s per channel, vs the
amortized model-update bandwidth of FedYOLOv3 rounds (payload / round
period), under each compression transport.
"""
from __future__ import annotations

from benchmarks.upload_time import payload_bytes

CHANNELS = 100
PER_CHANNEL_B_S = 512e3  # paper: 512 KB/s per channel
ROUND_PERIOD_S = 600.0  # one federated round every 10 minutes


def upload_seconds(payload_bytes: float, uplink_b_s: float = PER_CHANNEL_B_S) -> float:
    """Seconds to push one model update over a client uplink.

    The bandwidth term of the async engine's completion-time model
    (core/async_engine.py, DESIGN.md §12): the paper's 512 KB/s camera-edge
    uplink is the default, so upload time — not FLOPs — dominates round
    latency for real payload sizes, exactly the regime FedVision targets.
    """
    return float(payload_bytes) / max(float(uplink_b_s), 1.0)


def client_uplink_scales(n_clients: int, rng, spread: float = 0.5):
    """Per-client uplink multipliers in [1-spread, 1+spread] (uniform).

    Stable per-client heterogeneity: sampled once at engine build, not per
    round — a camera on a bad link stays on a bad link. spread=0 gives the
    homogeneous fleet the sync-equivalence contract needs.
    """
    import numpy as np

    if not 0.0 <= spread < 1.0:
        raise ValueError(f"uplink spread must be in [0, 1), got {spread}")
    if spread == 0.0:
        return np.ones(n_clients)
    return rng.uniform(1.0 - spread, 1.0 + spread, n_clients)


def rows():
    video = CHANNELS * PER_CHANNEL_B_S
    out = [("spic/video_upload_MB_s", video / 1e6, f"paper_claim>=50MB_s:{video >= 50e6}")]
    for mode in ["full", "eq6_topn", "quant8", "eq6+quant8"]:
        b = payload_bytes("fedyolov3", mode)
        bw = b / ROUND_PERIOD_S
        out.append((
            f"spic/fedvision_update_{mode}_MB_s",
            bw / 1e6,
            f"paper_claim<1MB_s:{bw < 1e6}",
        ))
    return out


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val:.4f},{extra}")
