"""Paper efficiency claim (CRC case): federated training matches the
centralized workflow without moving data.

Benchmarks federated (dense / eq6-compressed / quant8) vs centralized
training of the same model on the same total token budget, with non-IID
client data. Reports final losses; federated should land within a small gap
of centralized while uploading a fraction of the bytes.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.data.pipeline import fed_batches
from repro.optim import adamw

CFG = get_arch("qwen3-1.7b").reduced()
ROUNDS = 12
CLIENTS = 4
BATCH = 4
SEQ = 32


def run(mode: str) -> tuple[float, float]:
    fed = FedConfig(
        n_clients=CLIENTS if mode != "central" else 1,
        local_steps=2,
        aggregation="dense" if mode == "central" else mode,
        topn=2,
        client_axis="data",
        data_axis=None,
    )
    opt = adamw(3e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    # central sees ALL clients' data pooled into one "client"
    batch_size = BATCH if mode != "central" else BATCH * CLIENTS
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        t0 = time.time()
        loss = float("nan")
        src = fed_batches(CFG, fed, batch=batch_size, seq=SEQ, seed=0)
        for _, b in zip(range(ROUNDS), src):
            state, m = fr(state, jax.tree.map(jnp.asarray, b), R.uniform_weights(fed.n_clients))
            loss = float(m["loss"])
        return loss, time.time() - t0


def run_local_steps(E: int) -> float:
    """FedAvg's knob: E local steps per round = 1/E the sync traffic.

    Fixed total token budget: rounds x E is constant."""
    fed = FedConfig(n_clients=CLIENTS, local_steps=E, aggregation="dense", client_axis="data", data_axis=None)
    opt = adamw(3e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        src = fed_batches(CFG, fed, batch=BATCH, seq=SEQ, seed=0)
        loss = float("nan")
        for _, b in zip(range(24 // E), src):
            state, m = fr(state, jax.tree.map(jnp.asarray, b), R.uniform_weights(CLIENTS))
            loss = float(m["loss"])
    return loss


def rows():
    out = []
    for mode in ["central", "dense", "eq6", "quant8"]:
        loss, dt = run(mode)
        out.append((f"convergence/{mode}_final_loss", loss, f"wall_s={dt:.1f}"))
    # ablation: E local steps at fixed token budget (sync traffic = 1/E)
    for E in [1, 2, 4]:
        out.append((f"convergence/local_steps_E{E}_final_loss", run_local_steps(E), f"syncs={24 // E}"))
    return out


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val:.4f},{extra}")
