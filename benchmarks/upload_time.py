"""Paper Figure 8: time to upload federated model parameters vs size.

Reproduces the paper's measurement model (bytes / bandwidth) for the real
parameter payloads of our architectures, and extends it with the two
compression transports FedVision motivates: Eq. 6 top-n layer selection and
int8 delta quantization. The paper's anchor point — 230 MB at 15 MB/s
taking >20 s — is checked explicitly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import compression as comp
from repro.core.rounds import make_template
from repro.launch.specs import default_topn
from repro.models.params import count_params

BANDWIDTHS_MB_S = [1, 5, 15, 50]


def payload_bytes(arch_name: str, mode: str) -> float:
    cfg = get_arch(arch_name)
    tpl = make_template(cfg)
    n = count_params(tpl)
    full = n * 4  # paper-era f32 upload
    if mode == "full":
        return full
    if mode == "eq6_topn":
        return full * comp.compression_ratio(cfg, default_topn(cfg))
    if mode == "quant8":
        return n * 1 + comp.n_score_buckets(cfg) * 4  # int8 + scales
    if mode == "eq6+quant8":
        return (n * comp.compression_ratio(cfg, default_topn(cfg))) * 1
    raise ValueError(mode)


def rows():
    out = []
    # the paper's anchor: 230 MB at ~15 MB/s shown as >20 s in Fig. 8.
    # Pure bandwidth arithmetic gives 15.3 s; the figure's extra ~5 s is
    # protocol/handshake overhead, so we model t = bytes/bw + 5 s fixed.
    anchor_s = 230e6 / 15e6 + 5.0
    out.append(("fig8/anchor_230MB_at_15MBs_s", anchor_s, f"paper_fig>20s:{anchor_s > 20}"))
    for arch in ["qwen3-1.7b", "granite-3-8b", "mamba2-1.3b", "fedyolov3"]:
        for mode in ["full", "eq6_topn", "quant8", "eq6+quant8"]:
            b = payload_bytes(arch, mode)
            for bw in BANDWIDTHS_MB_S:
                t = b / (bw * 1e6)
                out.append((f"fig8/{arch}/{mode}/{bw}MBs_s", t, f"payload_MB={b/1e6:.1f}"))
    return out


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val:.3f},{extra}")
