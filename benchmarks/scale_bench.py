"""Client-axis scaling benches (PR 6, DESIGN.md §13).

Three tables over the packed (C, N) buffer at C ∈ {8, 64, 256, 1024}:

  client_scaling_rows — flat vs hierarchical aggregation (the eq6-style
      masked bucket reduce, the engine's most general hot loop). Flat runs
      one C-row reduce; hier runs the grouped inner mean (fused chains /
      batched contraction under the per-group renormalization) plus the
      same outer reduce over C/G group rows. Above the CHAIN_MAX_CLIENTS
      cutover the flat path is one big contraction while hier's two small
      levels stay chain-shaped — that is where the hierarchy wins.
  sharded_hier_rows — the same hier reduce with the inner level running
      shard-local under shard_map on a forced-2-device CPU mesh
      (subprocess: the bench process itself runs on one device).
  async_stream_rows — the C=1024 streaming async flush: state bytes of
      the dispatch-ring + running-accumulator discipline vs the analytic
      (C, N) buffered footprint, and one measured flush.

hier_guard_rows is the CI gate: hier must not lose to flat at C=64 (the
first federation size where the flat chain's unroll starts to hurt).
"""
from __future__ import annotations

import os
import subprocess
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.kernel_bench import _bench_spec, _timeit, _timeit_paired
from repro.core import packing

N_BENCH = 262_144
N_LEAVES = 32
GROUPS = {8: 4, 64: 8, 256: 16, 1024: 32}  # G ~ sqrt(C): both levels stay small
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _setup(C: int, N: int = N_BENCH, n_leaves: int = N_LEAVES):
    rng = np.random.default_rng(3)
    spec = _bench_spec(C, N, n_leaves)
    packed = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    w = jnp.full((C,), 1 / C, jnp.float32)
    bmask = jnp.asarray(np.random.default_rng(7).integers(0, 2, (C, n_leaves)), jnp.float32)
    return spec, packed, w, bmask * w[:, None]


def _hier_fn(spec, G: int, w, n_leaves: int = N_LEAVES):
    ngroups = w.shape[0] // G
    gbmask = jnp.asarray(
        np.random.default_rng(11).integers(0, 2, (ngroups, n_leaves)), jnp.float32
    )

    def f(p):
        rows, den = packing.grouped_weighted_mean(p, w, G)
        return packing.masked_bucket_mean(rows, gbmask * den[:, None], spec)

    return jax.jit(f)


def _flat_hier_pair(C: int, G: int, iters: int):
    spec, packed, w, wmask = _setup(C)
    flat = jax.jit(lambda p: packing.masked_bucket_mean(p, wmask, spec))
    hier = _hier_fn(spec, G, w)
    return _timeit_paired(
        lambda p: flat(p), (packed,), lambda p: hier(p), (packed,), iters=iters
    )


def client_scaling_rows(Cs=(8, 64, 256, 1024), iters: int = 5, sharded: bool = True):
    out = []
    for C in Cs:
        G = GROUPS[C]
        us_flat, us_hier = _flat_hier_pair(C, G, iters)
        out.append((
            f"scale/agg_flat_C{C}", us_flat,
            f"N={N_BENCH};mode=eq6_masked_bucket;iters={iters}",
        ))
        out.append((
            f"scale/agg_hier_C{C}_G{G}", us_hier,
            f"N={N_BENCH};inner=grouped_mean;outer=masked_bucket;"
            f"speedup_vs_flat={us_flat / max(us_hier, 1e-9):.2f}x;iters={iters}",
        ))
    if sharded:
        out.extend(sharded_hier_rows(Cs, iters=min(iters, 3)))
    return out


def hier_guard_rows(iters: int = 5):
    """CI gate: the hierarchy must not lose to the flat reduce at C>=64."""
    C, G = 64, GROUPS[64]
    us_flat, us_hier = _flat_hier_pair(C, G, iters)
    if us_hier > us_flat:
        raise RuntimeError(
            f"hier aggregation lost to flat at C={C}: {us_hier:.1f}us vs "
            f"{us_flat:.1f}us — the two-level reduce regressed "
            f"(grouped inner chains or the {G}-row outer reduce)"
        )
    return [(
        f"scale/hier_guard_C{C}", us_hier,
        f"flat={us_flat:.1f}us;speedup={us_flat / max(us_hier, 1e-9):.2f}x;"
        f"guard=hier_must_not_lose_at_C>=64;iters={iters}",
    )]


_SHARDED_SCRIPT = r"""
import sys
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from benchmarks.kernel_bench import _timeit
from benchmarks.scale_bench import GROUPS, _setup, N_BENCH
from repro.core import packing

assert jax.device_count() == 2, jax.device_count()
mesh = jax.make_mesh((2, 1), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
iters = int(sys.argv[2])
for C in [int(c) for c in sys.argv[1].split(",")]:
    G = GROUPS[C]
    spec, packed, w, _ = _setup(C)
    ngroups = C // G
    gbmask = jnp.asarray(np.random.default_rng(11).integers(0, 2, (ngroups, spec.n_buckets)), jnp.float32)

    def f(p, w=w, G=G, gbmask=gbmask, spec=spec):
        rows, den = jax.shard_map(
            lambda pl, wl: packing.grouped_weighted_mean(pl, wl, G),
            mesh=mesh,
            in_specs=(P("data", None), P("data")),
            out_specs=(P("data", None), P("data")),
            check_vma=False,
        )(p, w)
        return packing.masked_bucket_mean(rows, gbmask * den[:, None], spec)

    sharding = jax.NamedSharding(mesh, P("data", None))
    p_sh = jax.device_put(packed, sharding)
    fj = jax.jit(f)
    us = _timeit(lambda p: fj(p), p_sh, iters=iters)
    print(f"SHARDROW,scale/agg_hier_sharded_C{C}_G{G},{us},"
          f"N={N_BENCH};shards=2;inner=shard_local_grouped_mean;iters={iters}")
"""


def sharded_hier_rows(Cs=(8, 64, 256, 1024), iters: int = 3):
    """Times the shard-local hier reduce on 2 forced host devices. A
    subprocess because this process already initialized jax on one."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=2"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [_ROOT, os.path.join(_ROOT, "src"), env.get("PYTHONPATH", "")]
    )
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT, ",".join(str(c) for c in Cs), str(iters)],
        env=env, capture_output=True, text=True, timeout=1200, cwd=_ROOT,
    )
    if out.returncode != 0:
        raise RuntimeError(f"sharded hier bench failed:\n{out.stdout}\n{out.stderr}")
    rows = []
    for line in out.stdout.splitlines():
        if line.startswith("SHARDROW,"):
            _, name, us, extra = line.split(",", 3)
            rows.append((name, float(us), extra))
    return rows


def async_stream_rows():
    """The C=1024 streaming flush: O(buffer_size·N) accumulator state vs
    the (C, N) buffered footprint, plus one measured flush."""
    from repro.configs import get_arch
    from repro.core.async_engine import StreamingAsyncEngine
    from repro.core.rounds import FedConfig
    from repro.optim import sgd

    cfg = get_arch("qwen3-1.7b").reduced()
    C, k_buf = 1024, 16
    fed = FedConfig(
        n_clients=C, local_steps=1, aggregation="dense", client_axis="data",
        data_axis=None, state_layout="flat", mode="async", buffer_size=k_buf,
        max_staleness=4, stream=True,
    )
    eng = StreamingAsyncEngine(cfg, fed, sgd(lr=0.05, momentum=0.0), seed=0)
    n = eng.agg.ctx.spec.n_total
    for leaf in jax.tree.leaves(eng.state):
        assert not (leaf.ndim and leaf.shape[0] == C), (
            f"streaming state materialized a client-dim leaf {leaf.shape}"
        )
    state_mb = sum(leaf.nbytes for leaf in jax.tree.leaves(eng.state)) / 1e6
    # the buffered engine at the same size: (C, N) params + (C, N) sgd
    # momentum rows, before counting the flush's own temporaries
    buffered_mb = 2 * C * n * 4 / 1e6
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (C, 1, 2, 16)), jnp.int32)}
    t0 = time.perf_counter()
    eng.step_round(batch)  # compile + first flush
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    eng.step_round(batch)
    flush_us = (time.perf_counter() - t0) * 1e6
    return [
        (
            "scale/async_stream_state_C1024", round(state_mb, 2),
            f"unit=MB;ring={fed.max_staleness + 1}xN;acc=1xN;"
            f"buffered_analytic={buffered_mb:.0f}MB;"
            f"ratio={buffered_mb / state_mb:.0f}x;no_CxN_leaf=checked",
        ),
        (
            "scale/async_stream_flush_C1024", round(flush_us, 1),
            f"unit=us;buffer={k_buf};cohort={eng._cohort};"
            f"compile_s={compile_s:.1f};mode=dense;opt=sgd_m0",
        ),
    ]


def write_csv(rows, path: str = None) -> None:
    path = path or os.path.join(_ROOT, "BENCH_scaling_sweep.csv")
    with open(path, "w") as f:
        f.write("name,value,extra\n")
        for name, val, extra in rows:
            f.write(f"{name},{val},{extra}\n")


def smoke_rows():
    """CI subset: the C=64 guard + the C ∈ {8, 64} flat/hier/sharded
    curves, written to BENCH_scaling_sweep.csv for the CI artifact."""
    rows = hier_guard_rows(iters=3) + client_scaling_rows(Cs=(8, 64), iters=3)
    write_csv(rows)
    return rows


def full_rows():
    rows = (
        hier_guard_rows()
        + client_scaling_rows(Cs=(8, 64, 256, 1024))
        + async_stream_rows()
    )
    write_csv(rows)
    return rows


if __name__ == "__main__":
    all_rows = full_rows()
    for name, val, extra in all_rows:
        print(f"{name},{val},{extra}")
    from benchmarks.kernel_bench import emit_trajectory

    emit_trajectory(all_rows)
