"""Serving-plane benchmark rows (DESIGN.md §17).

Two tables, written to BENCH_serve_rows.csv for the CI artifact:

  serve/qps_occ{1,4,8}        — served QPS + p50/p99 request latency at
                                batch occupancy 1/4/8: the same total
                                request count driven by 1/4/8 concurrent
                                consumers through ONE InferenceService.
                                Every batch runs the same fixed-slot jitted
                                program, so per-batch cost is flat and QPS
                                should scale with occupancy — the guard
                                asserts batched-8 beats sequential
                                single-request serving (``rows()`` FAILS on
                                regression, so the CI bench-smoke step
                                gates it, not a dashboard).
  serve/hotswap_*             — hot swap under load: a publisher thread
                                lands new model versions mid-traffic while
                                4 consumers stream requests. Asserts ZERO
                                dropped requests (every INFER answered),
                                that responses span both the pre-swap and
                                post-swap versions, and that the last
                                response carries the final published
                                version — the ModelSlot swap protocol's
                                acceptance row.

Cheap enough for the ``--smoke`` subset: tiny fedyolov3 arch at 32px, one
jit compile, a few hundred socket round-trips.
"""
from __future__ import annotations

import os
import threading
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import rounds as R
from repro.core import serving
from repro.data import synthetic
from repro.models import params as P
from repro.models import yolov3

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

IMG_SIZE = 32
TOTAL_REQUESTS = 32  # per occupancy point (split across the consumers)
SWAP_PUBLISHES = 4  # hot-swap row: versions published mid-traffic


def _setup(serve_batch: int = 8):
    cfg = get_arch("fedyolov3").reduced()
    fed = R.FedConfig(n_clients=4, serve_batch=serve_batch)
    params = P.init_params(yolov3.template(cfg), jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(7)
    imgs, _ = synthetic.scene_images(rng, TOTAL_REQUESTS, IMG_SIZE, cfg.vocab_size)
    return cfg, fed, params, imgs


def _drive(svc, imgs, n_consumers: int, per_consumer: int):
    """n_consumers concurrent blocking-infer loops -> (qps, p50_ms, p99_ms,
    versions seen in response order)."""
    lats: list[float] = []
    versions: list[int] = []
    lock = threading.Lock()

    def consumer(ci: int):
        with serving.InferenceClient(svc.host, svc.port) as c:
            got = []
            for i in range(per_consumer):
                t0 = time.perf_counter()
                res = c.infer(imgs[(ci * per_consumer + i) % len(imgs)])
                got.append((time.perf_counter() - t0, res.version))
        with lock:
            for dt, v in got:
                lats.append(dt)
                versions.append(v)

    threads = [threading.Thread(target=consumer, args=(ci,)) for ci in range(n_consumers)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    total = time.perf_counter() - t0
    lats.sort()
    n = len(lats)
    return (
        n / total,
        1e3 * lats[n // 2],
        1e3 * lats[min(n - 1, int(n * 0.99))],
        versions,
    )


def occupancy_rows():
    cfg, fed, params, imgs = _setup()
    slot = serving.ModelSlot()
    slot.publish(1, params)
    svc = serving.InferenceService(cfg, fed, slot, img_size=IMG_SIZE).start()
    try:
        with serving.InferenceClient(svc.host, svc.port) as warm:
            warm.infer(imgs[0])  # pay the jit compile outside the timings
        out, qps_by_occ = [], {}
        for occ in (1, 4, 8):
            qps, p50, p99, _ = _drive(svc, imgs, occ, TOTAL_REQUESTS // occ)
            qps_by_occ[occ] = qps
            out.append((
                f"serve/qps_occ{occ}", round(qps, 2),
                f"p50_ms={p50:.2f};p99_ms={p99:.2f};"
                f"requests={TOTAL_REQUESTS};batch={fed.serve_batch}",
            ))
        assert svc.stats.in_flight == 0, (
            f"{svc.stats.in_flight} requests accepted but never answered"
        )
    finally:
        svc.stop()
    # the guard: 8 concurrent consumers through the fixed-slot batch must
    # beat the same requests served one at a time — if batching buys
    # nothing, the whole serving design regressed to sequential decode
    speedup = qps_by_occ[8] / qps_by_occ[1]
    assert speedup > 1.0, (
        f"batched-8 serving ({qps_by_occ[8]:.1f} QPS) does not beat "
        f"sequential single-request serving ({qps_by_occ[1]:.1f} QPS)"
    )
    out.append(("serve/batch8_vs_seq_speedup", round(speedup, 2),
                f"guard>1.0;avg_occupancy={svc.stats.avg_occupancy:.2f}"))
    return out


def hotswap_rows():
    """Hot swap under load: zero dropped requests, post-swap responses
    carry the new round version."""
    cfg, fed, params, imgs = _setup()
    slot = serving.ModelSlot()
    slot.publish(1, params)
    svc = serving.InferenceService(cfg, fed, slot, img_size=IMG_SIZE).start()
    stop_pub = threading.Event()
    published = [1]

    # publish thresholds: a new version lands each time another 1/(K+1) of
    # the traffic has been served, so every swap happens with requests in
    # flight AND the final version still has a tail of traffic to serve
    thresholds = [
        TOTAL_REQUESTS * (i + 1) // (SWAP_PUBLISHES + 1)
        for i in range(SWAP_PUBLISHES)
    ]

    def publisher():
        # a stand-in for the training loop landing rounds: republish the
        # model at successive versions while traffic is in flight
        for i, at in enumerate(thresholds):
            while not stop_pub.is_set() and svc.stats.results < at:
                time.sleep(0.0005)
            if stop_pub.is_set():
                return
            slot.publish(2 + i, params)
            published.append(2 + i)

    try:
        with serving.InferenceClient(svc.host, svc.port) as warm:
            warm.infer(imgs[0])
        pub = threading.Thread(target=publisher)
        pub.start()
        qps, p50, p99, versions = _drive(svc, imgs, 4, TOTAL_REQUESTS // 4)
        stop_pub.set()
        pub.join()
        # drain check: every accepted INFER was answered — a swap can never
        # drop a request because no lock spans the jit and the batcher
        # snapshots the slot per batch
        dropped = svc.stats.in_flight
        assert dropped == 0, f"{dropped} requests dropped across the hot swap"
        assert len(versions) == TOTAL_REQUESTS, (len(versions), TOTAL_REQUESTS)
        assert max(versions) == max(published), (
            f"no response carried the final published version "
            f"{max(published)} (saw {sorted(set(versions))})"
        )
        assert min(versions) < max(versions), (
            f"traffic never observed a swap (all responses v{versions[0]}; "
            f"published {published})"
        )
    finally:
        stop_pub.set()
        svc.stop()
    return [
        ("serve/hotswap_dropped", dropped,
         f"guard=0;requests={TOTAL_REQUESTS};swaps={slot.swaps}"),
        ("serve/hotswap_qps", round(qps, 2),
         f"p50_ms={p50:.2f};p99_ms={p99:.2f};publishes={len(published)}"),
        ("serve/hotswap_versions_served", len(set(versions)),
         f"first=v{min(versions)};final=v{max(versions)};"
         f"published_final=v{max(published)}"),
    ]


def write_csv(rows, path: str = None) -> None:
    path = path or os.path.join(_ROOT, "BENCH_serve_rows.csv")
    with open(path, "w") as f:
        f.write("name,value,extra\n")
        for name, val, extra in rows:
            f.write(f"{name},{val},{extra}\n")


def rows():
    all_rows = occupancy_rows() + hotswap_rows()
    write_csv(all_rows)
    return all_rows


# the full and smoke subsets run the same table: the serving plane is cheap
# (tiny arch, one compile) and the guards are exactly what CI must gate
smoke_rows = rows


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val},{extra}")
