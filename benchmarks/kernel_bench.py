"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(interpret-mode Pallas timing is not meaningful) plus derived bytes/FLOPs
per call for the roofline narrative."""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.models.mamba2 import ssd_chunked


def _timeit(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def rows():
    out = []
    rng = np.random.default_rng(0)
    # fedavg: C=8 clients x 4M params
    C, N = 8, 4_194_304  # block-aligned 4M
    x = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    w = jnp.full((C,), 1 / C, jnp.float32)
    m = jnp.ones((C,), jnp.float32)
    f = jax.jit(ref.fedavg_masked_mean)
    us = _timeit(lambda a, b, c: (f(a, b, c),), x, w, m)
    out.append(("kernel/fedavg_8x4M", us, f"bytes={C*N*4/1e6:.0f}MB"))
    # quant roundtrip
    v = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    g = jax.jit(lambda v: ref.dequantize_blocks(*ref.quantize_blocks(v, 1024), 1024))
    us = _timeit(lambda a: (g(a),), v)
    out.append(("kernel/quant_roundtrip_4M", us, f"compression=4x"))
    # attention: 1x8 heads x 1k x 64
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v, causal=True))
    us = _timeit(lambda a, b, c: (fa(a, b, c),), q, k, k)
    flops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2
    out.append(("kernel/attention_1k", us, f"gflops_per_call={flops/1e9:.2f}"))
    # ssd: B1 S1024 H8 P64 N64
    xdt = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)) * 0.1, jnp.float32)
    dA = -jnp.abs(jnp.asarray(rng.normal(size=(1, 1024, 8)) * 0.1, jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(1, 1024, 64)), jnp.float32)
    ss = jax.jit(lambda a, b, c, d: ssd_chunked(a, b, c, d, 128))
    us = _timeit(lambda a, b, c, d: ss(a, b, c, d), xdt, dA, Bm, Bm)
    out.append(("kernel/ssd_1k", us, "chunk=128"))
    return out


if __name__ == "__main__":
    for name, val, extra in rows():
        print(f"{name},{val:.1f},{extra}")
