"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(interpret-mode Pallas timing is not meaningful) plus derived bytes/FLOPs
per call for the roofline narrative.

`agg_rows` benchmarks the packed aggregation engine against the legacy
per-leaf tree path (dense / eq6 / quant8 at three sizes): wall time, kernel
launches per round (packed = 1 vs one per leaf), and collective payload
bytes. The packed columns time the flat engine's actual entry points —
merged-run fused chains (`packing.masked_bucket_mean` / `weighted_mean`)
and the fused quant8 encode->reduce (`packing.quant8_mean_ref`). The
`agg/pack_*` rows survive as EDGE costs only (make_state / checkpoint /
serve): the flat round engine (DESIGN.md §11) carries the packed buffer as
its state, so no pack/unpack copy appears in the per-round path — the
`agg/unpack_view` row pins that (reading the buffer through all slot views
costs the same as reading it flat).

`round_sweep_rows` sweeps the participation fraction C_active/C of the
compact round engine with PAIRED samples: the PR 3 tree layout
(`fed/round_participation_*`, the "before" column, DESIGN.md §8) and the
flat engine with the donated round jit (`fed/round_flat_*`, DESIGN.md §11)
alternate inside one timing loop. `flat_round_rows` is the flat-only sweep
the CI smoke uses.

Running this module as a script appends one timestamped record to
``BENCH_kernel_bench.json`` at the repo root — the cross-PR trajectory of
these numbers.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels import ref
from repro.models.mamba2 import ssd_chunked

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_kernel_bench.json"


def _timeit(fn, *args, iters=5):
    """Median of `iters` timed runs, AFTER one untimed warmup call: compile
    and first-dispatch cost never lands in the row, and the median resists
    the 2x run-to-run swings this shared-CPU container produces. Rows record
    the iteration count in their info string (";iters=N")."""
    jax.block_until_ready(fn(*args))  # warmup: compile + first dispatch
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6  # us


def _timeit_paired(fn_a, args_a, fn_b, args_b, iters=7):
    """PAIRED medians for an A-vs-B row: samples alternate A,B,A,B,... so
    both sides see the same machine state (this container's effective core
    count drifts, which otherwise flips A-vs-B orderings between rows that
    were measured minutes apart). Both get an untimed warmup first."""
    jax.block_until_ready(fn_a(*args_a))
    jax.block_until_ready(fn_b(*args_b))
    ta, tb = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args_a))
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args_b))
        tb.append(time.perf_counter() - t0)
    return float(np.median(ta)) * 1e6, float(np.median(tb)) * 1e6


def rows(iters: int = 5):
    out = []
    rng = np.random.default_rng(0)
    # fedavg: C=8 clients x 4M params
    C, N = 8, 4_194_304  # block-aligned 4M
    x = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    w = jnp.full((C,), 1 / C, jnp.float32)
    m = jnp.ones((C,), jnp.float32)
    f = jax.jit(ref.fedavg_masked_mean)
    us = _timeit(lambda a, b, c: (f(a, b, c),), x, w, m, iters=iters)
    out.append(("kernel/fedavg_8x4M", us, f"bytes={C*N*4/1e6:.0f}MB;iters={iters}"))
    # quant roundtrip
    v = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    g = jax.jit(lambda v: ref.dequantize_blocks(*ref.quantize_blocks(v, 1024), 1024))
    us = _timeit(lambda a: (g(a),), v, iters=iters)
    out.append(("kernel/quant_roundtrip_4M", us, f"compression=4x;iters={iters}"))
    # attention: 1x8 heads x 1k x 64
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v, causal=True))
    us = _timeit(lambda a, b, c: (fa(a, b, c),), q, k, k, iters=iters)
    flops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2
    out.append(("kernel/attention_1k", us, f"gflops_per_call={flops/1e9:.2f};iters={iters}"))
    # ssd: B1 S1024 H8 P64 N64
    xdt = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)) * 0.1, jnp.float32)
    dA = -jnp.abs(jnp.asarray(rng.normal(size=(1, 1024, 8)) * 0.1, jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(1, 1024, 64)), jnp.float32)
    ss = jax.jit(lambda a, b, c, d: ssd_chunked(a, b, c, d, 128))
    us = _timeit(lambda a, b, c, d: ss(a, b, c, d), xdt, dA, Bm, Bm, iters=iters)
    out.append(("kernel/ssd_1k", us, f"chunk=128;iters={iters}"))
    return out


def detect_rows(iters: int = 5):
    """Detection eval kernels: Pallas IoU/NMS (interpret mode on this CPU
    container — wall time measures the traced jnp body, not real kernel
    perf) vs the host-side NumPy oracles, plus the O(pairs) Python-loop
    IoU the seed's eval would have needed, at matched shapes."""
    from repro.kernels import detect

    rng = np.random.default_rng(5)

    def boxes(*shape):
        xy = rng.uniform(0, 1, shape + (2,)).astype(np.float32)
        wh = rng.uniform(0.02, 0.4, shape + (2,)).astype(np.float32)
        return np.concatenate([xy, wh], -1)

    out = []
    B, N, M = 4, 256, 256
    a_np, b_np = boxes(B, N), boxes(B, M)
    a, b = jnp.asarray(a_np), jnp.asarray(b_np)
    pairs = B * N * M
    us = _timeit(lambda x, y: (detect.pairwise_iou(x, y),), a, b, iters=iters)
    out.append((f"detect/iou_pallas_{B}x{N}x{M}", us, f"pairs={pairs};impl=interpret"))
    t0 = time.time()
    for _ in range(iters):
        ref.pairwise_iou_np(a_np, b_np)
    out.append((f"detect/iou_numpy_{B}x{N}x{M}", (time.time() - t0) / iters * 1e6, f"pairs={pairs}"))
    # the replaced per-pair Python loop, one image's worth (N*M scalar calls)
    t0 = time.time()
    for i in range(64):
        for j in range(64):
            ref.pairwise_iou_np(a_np[0, i : i + 1], b_np[0, j : j + 1])
    per_pair_us = (time.time() - t0) / (64 * 64) * 1e6
    out.append((f"detect/iou_python_pairs_{B}x{N}x{M}", per_pair_us * pairs, "extrapolated;launches=pairs"))
    K = 128
    nb, ns = boxes(B, K), rng.uniform(0, 1, (B, K)).astype(np.float32)
    nbj, nsj = jnp.asarray(nb), jnp.asarray(ns)
    us = _timeit(lambda x, y: (detect.nms(x, y),), nbj, nsj, iters=iters)
    out.append((f"detect/nms_pallas_{B}x{K}", us, "impl=interpret;fixed_shape"))
    t0 = time.time()
    for _ in range(iters):
        ref.nms_np(nb, ns)
    out.append((f"detect/nms_numpy_{B}x{K}", (time.time() - t0) / iters * 1e6, "python_loop"))
    return out


def _tree_of(C: int, N: int, n_leaves: int) -> dict:
    """Synthetic client-stacked param tree: n_leaves equal (C, N/n_leaves).

    Keys are zero-padded so jax.tree.leaves order == slot order."""
    rng = np.random.default_rng(3)
    per = N // n_leaves
    return {f"leaf{i:02d}": jnp.asarray(rng.normal(size=(C, per)), jnp.float32) for i in range(n_leaves)}


def _bench_spec(C: int, N: int, n_leaves: int):
    per = N // n_leaves
    # one score bucket per leaf, like scan-stacked layers
    return packing.PackSpec(
        N, n_leaves,
        tuple(
            packing.LeafSlot(f"leaf{i}", (per,), i * per, per, i, 1)
            for i in range(n_leaves)
        ),
    )


def _eq6_pair(C, N, n_leaves, tree, packed, spec, w, iters):
    """(tree us, packed us) for the eq6-style masked mean at one size —
    PAIRED samples (interleaved), so the comparison is apples-to-apples."""
    masks = {k: jnp.asarray(np.random.default_rng(i).integers(0, 2, C), jnp.float32) for i, k in enumerate(tree)}
    wmask = jnp.stack([masks[k] for k in tree], axis=1) * w[:, None]  # (C, B)
    tree_fn6 = jax.jit(lambda t: [ref.fedavg_masked_mean(x, w, masks[k]) for k, x in t.items()])
    packed_fn6 = jax.jit(lambda p: packing.masked_bucket_mean(p, wmask, spec))
    return _timeit_paired(
        lambda t: tree_fn6(t), (tree,), lambda p: packed_fn6(p), (packed,), iters=iters
    )


def agg_rows(iters: int = 7):
    """Packed-vs-tree aggregation: dense / eq6-style masked / quant8.

    The packed side times the flat engine's actual entry points — the
    merged-run fused chains and the fused quant8 encode->reduce — against
    the seed's per-leaf tree walk. `agg/pack_*` is reported as an EDGE cost
    (make_state/checkpoint/serve); it is no longer on the per-round path,
    which `agg/unpack_view` pins: one pass over the buffer through all slot
    views costs what one flat pass costs (slices fuse, nothing copies).
    """
    out = []
    C, n_leaves, block = 8, 32, 1024
    w = jnp.full((C,), 1 / C, jnp.float32)
    for N in (262_144, 1_048_576, 4_194_304):
        tree = _tree_of(C, N, n_leaves)
        spec = _bench_spec(C, N, n_leaves)
        packed = packing.pack(spec, tree)
        nb = N // block
        bytes_dense = C * N * 4
        bytes_q_payload = C * N  # int8 operand: exactly 4x fewer than f32
        bytes_q_scales = C * nb * 4
        ones = jnp.ones((C,), jnp.float32)

        # pack: an edge cost (init/checkpoint/serve) — the flat round state
        # IS the packed buffer, so no round pays this
        pack_fn = jax.jit(lambda t: packing.pack(spec, t))
        out.append((
            f"agg/pack_{C}x{N>>10}k", _timeit(lambda t: pack_fn(t), tree, iters=iters),
            f"bytes={bytes_dense/1e6:.1f}MB;edge=make_state/checkpoint/serve;not_in_round_path;iters={iters}",
        ))

        # dense (tree and packed interleaved: same machine state per row)
        tree_fn = jax.jit(lambda t: [ref.fedavg_masked_mean(x, w, ones) for x in t.values()])
        packed_fn = jax.jit(lambda p: packing.weighted_mean(p, w))
        us_tree, us_packed = _timeit_paired(
            lambda t: tree_fn(t), (tree,), lambda p: packed_fn(p), (packed,), iters=iters
        )
        out.append((
            f"agg/dense_{C}x{N>>10}k_tree", us_tree,
            f"launches={n_leaves};bytes={bytes_dense/1e6:.1f}MB;iters={iters}",
        ))
        out.append((
            f"agg/dense_{C}x{N>>10}k_packed", us_packed,
            f"launches=1;bytes={bytes_dense/1e6:.1f}MB;iters={iters}",
        ))

        # eq6-style masked mean (per-bucket weight mask)
        us_tree, us_packed = _eq6_pair(C, N, n_leaves, tree, packed, spec, w, iters)
        out.append((f"agg/eq6_{C}x{N>>10}k_tree", us_tree, f"launches={n_leaves};iters={iters}"))
        out.append((f"agg/eq6_{C}x{N>>10}k_packed", us_packed, f"launches=1;fused_chain=merged_runs;iters={iters}"))

        # quant8 transport: tree = per-leaf encode->decode->reduce; packed =
        # the fused engine path (encode+reduce in one pass, no int8
        # materialization — the collective-free transport of quant8)
        def tree_q(t):
            outs = []
            for x in t.values():
                q, s = ref.quantize_blocks(x.reshape(-1), block)
                d = ref.dequantize_blocks(q, s, block).reshape(x.shape)
                outs.append(jnp.einsum("c,cn->n", w, d))
            return outs

        tree_qj = jax.jit(tree_q)
        packed_qj = jax.jit(lambda p: packing.quant8_mean_ref(p, w, block))
        us_tree, us_packed = _timeit_paired(
            lambda t: tree_qj(t), (tree,), lambda p: (packed_qj(p),), (packed,), iters=iters
        )
        ratio = bytes_dense / bytes_q_payload
        out.append((
            f"agg/quant8_{C}x{N>>10}k_tree", us_tree,
            f"launches={2*n_leaves};payload={bytes_q_payload/1e6:.1f}MB;iters={iters}",
        ))
        out.append((
            f"agg/quant8_{C}x{N>>10}k_packed", us_packed,
            f"launches=1;fused=encode+reduce;payload={bytes_q_payload/1e6:.1f}MB;scales={bytes_q_scales/1e6:.2f}MB;payload_ratio_vs_dense={ratio:.1f}x;iters={iters}",
        ))

        if N == 4_194_304:
            # copy-free slot views, proved structurally: the reconstruction
            # lowers to slice+reshape ONLY — the row's value is the count of
            # data-moving primitives in its jaxpr (0), vs pack's concatenate.
            # The wall-clock effect of dropping the boundary copies is the
            # fed/round_flat_* vs fed/round_participation_* sweep below.
            tpl = {k: jax.ShapeDtypeStruct(v.shape[1:], v.dtype) for k, v in tree.items()}
            abs_p = jax.ShapeDtypeStruct((C, N), jnp.float32)
            jaxpr = jax.make_jaxpr(lambda p: packing.unpack_views(spec, p, tpl))(abs_p)
            prims = sorted({e.primitive.name for e in jaxpr.jaxpr.eqns})
            moving = [q for q in prims if q not in ("slice", "reshape", "squeeze")]
            pack_jaxpr = jax.make_jaxpr(lambda t: packing.pack(spec, t))(tree)
            pack_prims = sorted({e.primitive.name for e in pack_jaxpr.jaxpr.eqns})
            out.append((
                f"agg/unpack_view_{C}x{N>>10}k", float(len(moving)),
                f"data_moving_ops_in_jaxpr;view_prims={'+'.join(prims)};pack_prims={'+'.join(pack_prims)};copies=0",
            ))
    return out


def eq6_guard_rows(iters: int = 9):
    """CI guard (benchmarks/run.py --smoke): packed eq6 must beat the tree
    path at the 256k size — a cheap tripwire against re-introducing the
    mis-tiled reducers this bench caught at PR 3 (packed 2-4x slower)."""
    C, n_leaves, N = 8, 32, 262_144
    w = jnp.full((C,), 1 / C, jnp.float32)
    tree = _tree_of(C, N, n_leaves)
    spec = _bench_spec(C, N, n_leaves)
    packed = packing.pack(spec, tree)
    us_tree, us_packed = _eq6_pair(C, N, n_leaves, tree, packed, spec, w, iters)
    if us_packed > us_tree:
        raise RuntimeError(
            f"packed eq6 regressed: {us_packed:.0f}us > tree {us_tree:.0f}us "
            f"at 8x256k (median of {iters}) — the packed reducer must win"
        )
    return [(
        "agg/eq6_guard_256k", us_packed,
        f"tree={us_tree:.0f}us;packed_must_win;iters={iters}",
    )]


def _round_sweep_setup(K: int, C: int = 8):
    from repro.configs import get_arch
    from repro.core import rounds as R
    from repro.optim import sgd

    cfg = get_arch("qwen3-1.7b").reduced()
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (C, 1, 2, 32)), jnp.int32)
    mask = np.zeros(C, np.float32)
    mask[:K] = 1.0
    return cfg, R, sgd(lr=0.05), {"tokens": toks}, mask


def flat_round_rows(iters: int = 3):
    """The flat engine's round sweep (DESIGN.md §11): packed (C, N_total)
    round state, slot-view training, in-place write-back, donated jit — no
    per-round pack/unpack copy. Timed by THREADING the state (each call
    consumes the donated previous state), exactly how FLServer drives it.
    """
    C = 8
    out = []
    for K in (2, 4, 8):
        cfg, R, opt, batch, mask = _round_sweep_setup(K, C)
        fed = R.FedConfig(
            n_clients=C, local_steps=1, aggregation="dense", client_axis="data",
            data_axis=None, participation="compact", max_participants=K,
        )
        state = R.make_state(cfg, fed, opt, jax.random.key(0))
        fr = R.jit_fed_round(R.build_fed_round(cfg, fed, opt))
        part = R.participation_input(fed, mask, mask / K, np.arange(K))
        state, _ = fr(state, batch, part)  # warmup: compile + first dispatch
        jax.block_until_ready(state)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            state, m = fr(state, batch, part)
            jax.block_until_ready((state["params"], m["loss"]))
            ts.append(time.perf_counter() - t0)
        out.append((
            f"fed/round_flat_{K}of{C}", float(np.median(ts)) * 1e6,
            f"frac={K / C:.2f};mode=compact;layout=flat;donated=1;no_round_pack=1;iters={iters}",
        ))
    return out


def round_sweep_rows(iters: int = 3):
    """Before/after round sweep with PAIRED samples: at each fraction the
    tree round (PR 3 engine) and the flat round (DESIGN.md §11) alternate
    within one timing loop, so both see the same machine state. The flat
    engine threads its donated state; the tree engine replays one state
    (donation would invalidate the replayed buffer)."""
    C = 8
    out_tree, out_flat = [], []
    for K in (2, 4, 8):
        cfg, R, opt, batch, mask = _round_sweep_setup(K, C)
        base = dict(
            n_clients=C, local_steps=1, aggregation="dense", client_axis="data",
            data_axis=None, participation="compact", max_participants=K,
        )
        fed_t = R.FedConfig(**base, state_layout="tree")
        fed_f = R.FedConfig(**base)
        st_t = R.make_state(cfg, fed_t, opt, jax.random.key(0))
        fr_t = jax.jit(R.build_fed_round(cfg, fed_t, opt))
        st_f = R.make_state(cfg, fed_f, opt, jax.random.key(0))
        fr_f = R.jit_fed_round(R.build_fed_round(cfg, fed_f, opt))
        part = R.participation_input(fed_t, mask, mask / K, np.arange(K))
        jax.block_until_ready(fr_t(st_t, batch, part)[1]["loss"])  # warmups
        st_f, m = fr_f(st_f, batch, part)
        jax.block_until_ready((st_f["params"], m["loss"]))
        tt, tf = [], []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fr_t(st_t, batch, part)[1]["loss"])
            tt.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            st_f, m = fr_f(st_f, batch, part)
            jax.block_until_ready((st_f["params"], m["loss"]))
            tf.append(time.perf_counter() - t0)
        out_tree.append((
            f"fed/round_participation_{K}of{C}", float(np.median(tt)) * 1e6,
            f"frac={K / C:.2f};mode=compact;layout=tree;train_work=K/C;iters={iters};paired=1",
        ))
        out_flat.append((
            f"fed/round_flat_{K}of{C}", float(np.median(tf)) * 1e6,
            f"frac={K / C:.2f};mode=compact;layout=flat;donated=1;no_round_pack=1;iters={iters};paired=1",
        ))
    return out_tree + out_flat


def emit_trajectory(all_rows) -> None:
    """Append one timestamped record to the BENCH_*.json trajectory."""
    traj = []
    if BENCH_JSON.exists():
        traj = json.loads(BENCH_JSON.read_text())
    traj.append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": [[n, round(float(v), 1), e] for n, v, e in all_rows],
    })
    BENCH_JSON.write_text(json.dumps(traj, indent=1))


if __name__ == "__main__":
    all_rows = rows() + detect_rows() + agg_rows() + round_sweep_rows()
    for name, val, extra in all_rows:
        print(f"{name},{val:.1f},{extra}")
    emit_trajectory(all_rows)
    print(f"# trajectory appended to {BENCH_JSON}")
