"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(interpret-mode Pallas timing is not meaningful) plus derived bytes/FLOPs
per call for the roofline narrative.

`agg_rows` benchmarks the packed aggregation transport against the legacy
per-leaf tree path (dense / eq6 / quant8 at three sizes): wall time, kernel
launches per round (packed = 1 vs one per leaf), and collective payload
bytes (quant8's int8 operand moves 4x fewer bytes than dense f32 at equal
shapes; the per-block f32 scale sideband is reported separately).

`participation_rows` sweeps the participation fraction C_active/C of the
compact round engine (DESIGN.md §8): local training gathers only the K
selected clients, so per-round wall time drops with the fraction while the
aggregation still spans the full (C, N_total) buffer.

Running this module as a script appends one timestamped record to
``BENCH_kernel_bench.json`` at the repo root — the cross-PR trajectory of
these numbers.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels import ref
from repro.models.mamba2 import ssd_chunked

BENCH_JSON = Path(__file__).resolve().parents[1] / "BENCH_kernel_bench.json"


def _timeit(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6  # us


def rows():
    out = []
    rng = np.random.default_rng(0)
    # fedavg: C=8 clients x 4M params
    C, N = 8, 4_194_304  # block-aligned 4M
    x = jnp.asarray(rng.normal(size=(C, N)), jnp.float32)
    w = jnp.full((C,), 1 / C, jnp.float32)
    m = jnp.ones((C,), jnp.float32)
    f = jax.jit(ref.fedavg_masked_mean)
    us = _timeit(lambda a, b, c: (f(a, b, c),), x, w, m)
    out.append(("kernel/fedavg_8x4M", us, f"bytes={C*N*4/1e6:.0f}MB"))
    # quant roundtrip
    v = jnp.asarray(rng.normal(size=(N,)), jnp.float32)
    g = jax.jit(lambda v: ref.dequantize_blocks(*ref.quantize_blocks(v, 1024), 1024))
    us = _timeit(lambda a: (g(a),), v)
    out.append(("kernel/quant_roundtrip_4M", us, f"compression=4x"))
    # attention: 1x8 heads x 1k x 64
    q = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 8, 1024, 64)), jnp.bfloat16)
    fa = jax.jit(lambda q, k, v: ref.flash_attention(q, k, v, causal=True))
    us = _timeit(lambda a, b, c: (fa(a, b, c),), q, k, k)
    flops = 4 * 1 * 8 * 1024 * 1024 * 64 / 2
    out.append(("kernel/attention_1k", us, f"gflops_per_call={flops/1e9:.2f}"))
    # ssd: B1 S1024 H8 P64 N64
    xdt = jnp.asarray(rng.normal(size=(1, 1024, 8, 64)) * 0.1, jnp.float32)
    dA = -jnp.abs(jnp.asarray(rng.normal(size=(1, 1024, 8)) * 0.1, jnp.float32))
    Bm = jnp.asarray(rng.normal(size=(1, 1024, 64)), jnp.float32)
    ss = jax.jit(lambda a, b, c, d: ssd_chunked(a, b, c, d, 128))
    us = _timeit(lambda a, b, c, d: ss(a, b, c, d), xdt, dA, Bm, Bm)
    out.append(("kernel/ssd_1k", us, "chunk=128"))
    return out


def detect_rows(iters: int = 5):
    """Detection eval kernels: Pallas IoU/NMS (interpret mode on this CPU
    container — wall time measures the traced jnp body, not real kernel
    perf) vs the host-side NumPy oracles, plus the O(pairs) Python-loop
    IoU the seed's eval would have needed, at matched shapes."""
    from repro.kernels import detect

    rng = np.random.default_rng(5)

    def boxes(*shape):
        xy = rng.uniform(0, 1, shape + (2,)).astype(np.float32)
        wh = rng.uniform(0.02, 0.4, shape + (2,)).astype(np.float32)
        return np.concatenate([xy, wh], -1)

    out = []
    B, N, M = 4, 256, 256
    a_np, b_np = boxes(B, N), boxes(B, M)
    a, b = jnp.asarray(a_np), jnp.asarray(b_np)
    pairs = B * N * M
    us = _timeit(lambda x, y: (detect.pairwise_iou(x, y),), a, b, iters=iters)
    out.append((f"detect/iou_pallas_{B}x{N}x{M}", us, f"pairs={pairs};impl=interpret"))
    t0 = time.time()
    for _ in range(iters):
        ref.pairwise_iou_np(a_np, b_np)
    out.append((f"detect/iou_numpy_{B}x{N}x{M}", (time.time() - t0) / iters * 1e6, f"pairs={pairs}"))
    # the replaced per-pair Python loop, one image's worth (N*M scalar calls)
    t0 = time.time()
    for i in range(64):
        for j in range(64):
            ref.pairwise_iou_np(a_np[0, i : i + 1], b_np[0, j : j + 1])
    per_pair_us = (time.time() - t0) / (64 * 64) * 1e6
    out.append((f"detect/iou_python_pairs_{B}x{N}x{M}", per_pair_us * pairs, "extrapolated;launches=pairs"))
    K = 128
    nb, ns = boxes(B, K), rng.uniform(0, 1, (B, K)).astype(np.float32)
    nbj, nsj = jnp.asarray(nb), jnp.asarray(ns)
    us = _timeit(lambda x, y: (detect.nms(x, y),), nbj, nsj, iters=iters)
    out.append((f"detect/nms_pallas_{B}x{K}", us, "impl=interpret;fixed_shape"))
    t0 = time.time()
    for _ in range(iters):
        ref.nms_np(nb, ns)
    out.append((f"detect/nms_numpy_{B}x{K}", (time.time() - t0) / iters * 1e6, "python_loop"))
    return out


def _tree_of(C: int, N: int, n_leaves: int) -> dict:
    """Synthetic client-stacked param tree: n_leaves equal (C, N/n_leaves).

    Keys are zero-padded so jax.tree.leaves order == slot order."""
    rng = np.random.default_rng(3)
    per = N // n_leaves
    return {f"leaf{i:02d}": jnp.asarray(rng.normal(size=(C, per)), jnp.float32) for i in range(n_leaves)}


def agg_rows():
    """Packed-vs-tree aggregation: dense / eq6-style masked / quant8.

    The packed side times the actual engine entry point
    (`packing.masked_bucket_mean` over a real PackSpec) — one fused
    reduction per round — against the seed's per-leaf tree walk.
    """
    out = []
    C, n_leaves, block = 8, 32, 1024
    w = jnp.full((C,), 1 / C, jnp.float32)
    for N in (262_144, 1_048_576, 4_194_304):
        tree = _tree_of(C, N, n_leaves)
        per = N // n_leaves
        # one score bucket per leaf, like scan-stacked layers
        spec = packing.PackSpec(
            N, n_leaves,
            tuple(
                packing.LeafSlot(f"leaf{i}", (per,), i * per, per, i, 1)
                for i in range(n_leaves)
            ),
        )
        packed = packing.pack(spec, tree)
        nb = N // block
        bytes_dense = C * N * 4
        bytes_q_payload = C * N  # int8 operand: exactly 4x fewer than f32
        bytes_q_scales = C * nb * 4
        wmask = jnp.asarray(np.random.default_rng(0).integers(0, 2, (C, n_leaves)), jnp.float32) * w[:, None]
        ones = jnp.ones((C,), jnp.float32)

        # pack itself (once per round on the packed path, absent on tree's)
        pack_fn = jax.jit(lambda t: packing.pack(spec, t))
        out.append((f"agg/pack_{C}x{N>>10}k", _timeit(lambda t: pack_fn(t), tree), f"bytes={bytes_dense/1e6:.1f}MB"))

        # dense
        tree_fn = jax.jit(lambda t: [ref.fedavg_masked_mean(x, w, ones) for x in t.values()])
        us_tree = _timeit(lambda t: tree_fn(t), tree)
        packed_fn = jax.jit(lambda p: packing.weighted_mean(p, w))
        us_packed = _timeit(lambda p: packed_fn(p), packed)
        out.append((
            f"agg/dense_{C}x{N>>10}k_tree", us_tree,
            f"launches={n_leaves};bytes={bytes_dense/1e6:.1f}MB",
        ))
        out.append((
            f"agg/dense_{C}x{N>>10}k_packed", us_packed,
            f"launches=1;bytes={bytes_dense/1e6:.1f}MB",
        ))

        # eq6-style masked mean (per-bucket weight mask)
        masks = {k: jnp.asarray(np.random.default_rng(i).integers(0, 2, C), jnp.float32) for i, k in enumerate(tree)}
        tree_fn6 = jax.jit(lambda t: [ref.fedavg_masked_mean(x, w, masks[k]) for k, x in t.items()])
        us_tree = _timeit(lambda t: tree_fn6(t), tree)
        packed_fn6 = jax.jit(lambda p: packing.masked_bucket_mean(p, wmask, spec))
        us_packed = _timeit(lambda p: packed_fn6(p), packed)
        out.append((f"agg/eq6_{C}x{N>>10}k_tree", us_tree, f"launches={n_leaves}"))
        out.append((f"agg/eq6_{C}x{N>>10}k_packed", us_packed, "launches=1"))

        # quant8 transport (quantize + dequantize + reduce)
        def tree_q(t):
            outs = []
            for x in t.values():
                q, s = ref.quantize_blocks(x.reshape(-1), block)
                d = ref.dequantize_blocks(q, s, block).reshape(x.shape)
                outs.append(jnp.einsum("c,cn->n", w, d))
            return outs

        def packed_q(p):
            q, s = packing.quantize_rows_ref(p, block)
            d = packing.dequantize_rows_ref(q, s, block)
            return jnp.einsum("c,cn->n", w, d)

        tree_qj, packed_qj = jax.jit(tree_q), jax.jit(packed_q)
        us_tree = _timeit(lambda t: tree_qj(t), tree)
        us_packed = _timeit(lambda p: (packed_qj(p),), packed)
        ratio = bytes_dense / bytes_q_payload
        out.append((
            f"agg/quant8_{C}x{N>>10}k_tree", us_tree,
            f"launches={2*n_leaves};payload={bytes_q_payload/1e6:.1f}MB",
        ))
        out.append((
            f"agg/quant8_{C}x{N>>10}k_packed", us_packed,
            f"launches=2;payload={bytes_q_payload/1e6:.1f}MB;scales={bytes_q_scales/1e6:.2f}MB;payload_ratio_vs_dense={ratio:.1f}x",
        ))
    return out


def participation_rows(iters: int = 3):
    """Per-round wall time vs participation fraction (compact engine).

    C_active/C in {0.25, 0.5, 1.0} on the reduced qwen3 arch: K of 8
    clients train per round, the rest keep their rows; aggregation weights/
    mask flow in as traced inputs (one compile per static K only).
    """
    from repro.configs import get_arch
    from repro.core import rounds as R
    from repro.optim import sgd

    cfg = get_arch("qwen3-1.7b").reduced()
    C = 8
    opt = sgd(lr=0.05)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (C, 1, 2, 32)), jnp.int32)
    out = []
    for K in (2, 4, 8):
        fed = R.FedConfig(
            n_clients=C, local_steps=1, aggregation="dense", client_axis="data",
            data_axis=None, participation="compact", max_participants=K,
        )
        state = R.make_state(cfg, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(cfg, fed, opt))
        mask = np.zeros(C, np.float32)
        mask[:K] = 1.0
        part = R.participation_input(fed, mask, mask / K, np.arange(K))
        batch = {"tokens": toks}
        us = _timeit(lambda s: fr(s, batch, part)[1]["loss"], state, iters=iters)
        out.append((
            f"fed/round_participation_{K}of{C}", us,
            f"frac={K / C:.2f};mode=compact;train_work=K/C",
        ))
    return out


def emit_trajectory(all_rows) -> None:
    """Append one timestamped record to the BENCH_*.json trajectory."""
    traj = []
    if BENCH_JSON.exists():
        traj = json.loads(BENCH_JSON.read_text())
    traj.append({
        "ts": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": [[n, round(float(v), 1), e] for n, v, e in all_rows],
    })
    BENCH_JSON.write_text(json.dumps(traj, indent=1))


if __name__ == "__main__":
    all_rows = rows() + detect_rows() + agg_rows() + participation_rows()
    for name, val, extra in all_rows:
        print(f"{name},{val:.1f},{extra}")
    emit_trajectory(all_rows)
    print(f"# trajectory appended to {BENCH_JSON}")
