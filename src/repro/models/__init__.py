from repro.models import attention, layers, mamba2, moe, params, serving, transformer, yolov3

__all__ = ["attention", "layers", "mamba2", "moe", "params", "serving", "transformer", "yolov3"]
