"""Model zoo orchestrator: templates, forward, loss, prefill, decode.

Families:
- dense / vlm / audio: pre-norm transformer (GQA + SwiGLU), scan over layers.
- dense with local:global pattern (gemma3): scan over period-groups; local
  layers use structural sliding-window attention and ring-buffer KV caches.
- moe: dense attention + GShard top-k MoE FFN (aux loss threaded via scan).
- ssm (mamba2): attention-free SSD blocks.
- hybrid (zamba2): mamba2 groups + one *shared* attention+MLP block applied
  between groups (single weight set, per-application KV caches).

All full-size dry-runs lower these with `lax.scan` so HLO stays compact.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.layers import rms_norm, softmax_cross_entropy, swiglu
from repro.models.params import ParamInfo
from repro.models.shard_ctx import constrain

PyTree = Any


# ---------------------------------------------------------------------------
# Templates
# ---------------------------------------------------------------------------

def _mlp_template(cfg, pa, ns):
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamInfo(ns + (d, f), pa + ("embed", "ffn")),
        "w_up": ParamInfo(ns + (d, f), pa + ("embed", "ffn")),
        "w_down": ParamInfo(ns + (f, d), pa + ("ffn", "embed")),
    }


def _dense_layer_template(cfg, pa=("layer",), ns=()):
    d = cfg.d_model
    t = {
        "norm1": ParamInfo(ns + (d,), pa + ("embed",), init="zeros"),
        "attn": attn.attention_template(cfg, pa, ns),
        "norm2": ParamInfo(ns + (d,), pa + ("embed",), init="zeros"),
    }
    if cfg.family == "moe":
        t["moe"] = moe_mod.moe_template(cfg, pa, ns)
    else:
        t["mlp"] = _mlp_template(cfg, pa, ns)
    return t


def _ssm_layer_template(cfg, pa=("layer",), ns=()):
    d = cfg.d_model
    return {
        "norm1": ParamInfo(ns + (d,), pa + ("embed",), init="zeros"),
        "ssm": m2.mamba2_template(cfg, pa, ns),
    }


def gemma_pattern(cfg) -> tuple[int, int]:
    """(n_groups, n_tail) for the local:global period pattern."""
    period = cfg.local_global_period
    return cfg.n_layers // period, cfg.n_layers % period


VOCAB_PAD = 16  # pad vocab to the model-axis width; padded logits masked


def padded_vocab(cfg: ArchConfig) -> int:
    return -(-cfg.vocab_size // VOCAB_PAD) * VOCAB_PAD


def template(cfg: ArchConfig) -> PyTree:
    d, v = cfg.d_model, padded_vocab(cfg)
    t: dict = {
        "embed": ParamInfo((v, d), ("vocab", "embed"), init="small_normal"),
        "final_norm": ParamInfo((d,), ("embed",), init="zeros"),
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamInfo((d, v), ("embed", "vocab"))
    if cfg.modality == "vlm":
        t["img_proj"] = ParamInfo((d, d), ("embed", None))
    if cfg.family in ("dense", "vlm", "audio", "moe") and not cfg.local_global_period:
        t["layers"] = _dense_layer_template(cfg, ("layer",), (cfg.n_layers,))
    elif cfg.local_global_period:  # gemma3
        ng, nt = gemma_pattern(cfg)
        t["groups"] = _dense_layer_template(
            cfg, ("group", "layer"), (ng, cfg.local_global_period)
        )
        if nt:
            t["tail"] = _dense_layer_template(cfg, ("layer",), (nt,))
    elif cfg.family == "ssm":
        t["layers"] = _ssm_layer_template(cfg, ("layer",), (cfg.n_layers,))
    elif cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.shared_attn_period
        t["mamba_groups"] = _ssm_layer_template(
            cfg, ("group", "layer"), (ng, cfg.shared_attn_period)
        )
        t["shared"] = {
            "norm1": ParamInfo((d,), ("embed",), init="zeros"),
            "attn": attn.attention_template(cfg, (), ()),
            "norm2": ParamInfo((d,), ("embed",), init="zeros"),
            "mlp": _mlp_template(cfg, (), ()),
        }
    else:
        raise ValueError(f"unsupported family {cfg.family}")
    return t


def layer_window(cfg, group_pos: int) -> int:
    """Window for position-in-period: gemma3 = [W]*(p-1) + [0 (global)]."""
    return cfg.window if group_pos != cfg.local_global_period - 1 else 0


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill trunk)
# ---------------------------------------------------------------------------

def _dense_block(cfg, p, x, window: int):
    x = x + attn.attention_block(p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, window=window)
    h = rms_norm(x, p["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, aux = moe_mod.moe_block(p["moe"], h, cfg)
        return x + y, aux
    return x + swiglu(h, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"]), jnp.float32(0)


def _ssm_block(cfg, p, x):
    return x + m2.mamba2_block(p["ssm"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg)


def embed_inputs(cfg, params, batch) -> jax.Array:
    if cfg.modality == "audio":
        return batch["frames"].astype(_dtype(cfg))
    if cfg.modality == "vlm":
        img = jnp.einsum("bnd,de->bne", batch["images"].astype(_dtype(cfg)), params["img_proj"])
        txt = params["embed"][batch["tokens"]]
        return jnp.concatenate([img, txt], axis=1)
    return params["embed"][batch["tokens"]]


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def trunk(cfg: ArchConfig, params: PyTree, x: jax.Array):
    """Hidden states (B,S,D) -> (B,S,D) after all layers + final norm.

    Returns (hidden, aux_loss).
    """
    x = constrain(x)
    aux_total = jnp.float32(0)
    # remat each scanned block: the backward pass recomputes activations, so
    # the saved residency is one (B,S,D) carry per layer instead of every
    # intermediate — required for the full-size train_4k memory budget.
    if "layers" in params and cfg.family in ("dense", "vlm", "audio", "moe"):
        @jax.checkpoint
        def body(carry, layer_p):
            h, aux = carry
            h, a = _dense_block(cfg, layer_p, h, cfg.window)
            return (constrain(h), aux + a), None

        (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["layers"])
    elif "groups" in params:  # gemma3 pattern
        period = cfg.local_global_period

        @jax.checkpoint
        def gbody(carry, group_p):
            h, aux = carry
            for i in range(period):
                sub = jax.tree.map(lambda w: w[i], group_p)
                h, a = _dense_block(cfg, sub, h, layer_window(cfg, i))
                aux = aux + a
            return (constrain(h), aux), None

        (x, aux_total), _ = jax.lax.scan(gbody, (x, aux_total), params["groups"])
        if "tail" in params:
            @jax.checkpoint
            def tbody(carry, layer_p):
                h, aux = carry
                h, a = _dense_block(cfg, layer_p, h, cfg.window)
                return (constrain(h), aux + a), None

            (x, aux_total), _ = jax.lax.scan(tbody, (x, aux_total), params["tail"])
    elif cfg.family == "ssm":
        @jax.checkpoint
        def sbody(h, layer_p):
            return constrain(_ssm_block(cfg, layer_p, h)), None

        x, _ = jax.lax.scan(sbody, x, params["layers"])
    elif cfg.family == "hybrid":
        period = cfg.shared_attn_period
        shared = params["shared"]

        @jax.checkpoint
        def hbody(h, group_p):
            for i in range(period):
                sub = jax.tree.map(lambda w: w[i], group_p)
                h = _ssm_block(cfg, sub, h)
            h, _ = _dense_block(cfg, shared, h, 0)
            return constrain(h), None

        x, _ = jax.lax.scan(hbody, x, params["mamba_groups"])
    else:
        raise ValueError(cfg.family)
    return rms_norm(x, params["final_norm"], cfg.norm_eps), aux_total


def logits_fn(cfg, params, hidden: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", hidden, params["embed"])
    else:
        logits = jnp.einsum("bsd,dv->bsv", hidden, params["lm_head"])
    if logits.shape[-1] != cfg.vocab_size:  # mask sharding-padding columns
        pad = logits.shape[-1] - cfg.vocab_size
        neg = jnp.full((pad,), -1e30, logits.dtype)
        logits = logits + jnp.concatenate([jnp.zeros((cfg.vocab_size,), logits.dtype), neg])
    return logits


CE_CHUNK = 512  # sequence-chunked loss: never materialize (B,S,V) logits


def chunked_ce(cfg, params, hidden: jax.Array, labels: jax.Array, mask: jax.Array | None) -> jax.Array:
    """CE via lax.scan over sequence chunks (remat'd): peak logits memory is
    (B, CE_CHUNK, V/shards) instead of (B, S, V/shards)."""
    B, S, _ = hidden.shape
    if S % CE_CHUNK or S <= CE_CHUNK:
        logits = logits_fn(cfg, params, hidden)
        return softmax_cross_entropy(logits, labels, mask)
    nc = S // CE_CHUNK
    h = hidden.reshape(B, nc, CE_CHUNK, -1).transpose(1, 0, 2, 3)
    l = labels.reshape(B, nc, CE_CHUNK).transpose(1, 0, 2)
    m = (
        mask.reshape(B, nc, CE_CHUNK).transpose(1, 0, 2)
        if mask is not None
        else jnp.ones((nc, B, CE_CHUNK), jnp.float32)
    )

    @jax.checkpoint
    def body(carry, xs):
        hc, lc, mc = xs
        logits = logits_fn(cfg, params, hc)
        logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
        gold = jnp.sum(
            logits.astype(jnp.float32) * (iota == lc[..., None]).astype(jnp.float32), axis=-1
        )
        mc = mc.astype(jnp.float32)
        return (carry[0] + jnp.sum((logz - gold) * mc), carry[1] + jnp.sum(mc)), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0), jnp.float32(0)), (h, l, m))
    return tot / jnp.maximum(cnt, 1.0)


def _next_token_ce(cfg, params, hidden: jax.Array, tokens: jax.Array) -> jax.Array:
    """Next-token CE keeping the full (chunk-divisible) sequence: labels are
    tokens shifted left, the final position masked out."""
    S = hidden.shape[1]
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.broadcast_to((jnp.arange(S) < S - 1)[None], labels.shape)
    return chunked_ce(cfg, params, hidden, labels, mask)


def loss_fn(cfg: ArchConfig, params: PyTree, batch: dict) -> tuple[jax.Array, dict]:
    """Training objective per modality. Returns (loss, metrics)."""
    x = embed_inputs(cfg, params, batch)
    hidden, aux = trunk(cfg, params, x)
    if cfg.modality == "audio":
        # HuBERT masked cluster prediction: CE at masked frames only.
        ce = chunked_ce(cfg, params, hidden, batch["labels"], batch["mask"])
    elif cfg.modality == "vlm":
        n_img = batch["images"].shape[1]
        ce = _next_token_ce(cfg, params, hidden[:, n_img:], batch["tokens"])
    else:
        ce = _next_token_ce(cfg, params, hidden, batch["tokens"])
    loss = ce + cfg.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}
