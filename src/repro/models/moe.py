"""GShard-style top-k Mixture-of-Experts FFN (TPU-native dispatch einsums).

Capacity-based one-hot dispatch/combine — the canonical TPU MoE formulation
(GShard / Switch). Router aux load-balance loss included. Baseline sharding
puts d_ff over the "model" axis; the expert-parallel variant (experts over
"model", see core/rounds EP rules) is the §Perf hillclimb.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamInfo


def moe_template(cfg, prefix_axes=("layer",), n_stack=()):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    pa, ns = prefix_axes, n_stack
    return {
        "router": ParamInfo(ns + (d, e), pa + ("embed", "expert"), init="small_normal"),
        "w_gate": ParamInfo(ns + (e, d, f), pa + ("expert", "embed", "ffn")),
        "w_up": ParamInfo(ns + (e, d, f), pa + ("expert", "embed", "ffn")),
        "w_down": ParamInfo(ns + (e, f, d), pa + ("expert", "ffn", "embed")),
    }


def capacity(cfg, group_size: int) -> int:
    cap = int(group_size * cfg.experts_per_token / cfg.n_experts * cfg.capacity_factor)
    return max(cap, cfg.experts_per_token)


def route(cfg, logits: jax.Array):
    """logits (G, S, E) -> dispatch (G,S,E,C) bool, combine (G,S,E,C), aux loss.

    Top-k per token, capacity-limited per expert within each group.
    """
    G, S, E = logits.shape
    C = capacity(cfg, S)
    k = cfg.experts_per_token
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (G,S,k)
    # one-hot per choice: (G, S, k, E)
    choice_oh = jax.nn.one_hot(expert_idx, E, dtype=jnp.float32)
    # position of each (token, choice) within its expert queue, flattened over (S,k)
    flat = choice_oh.reshape(G, S * k, E)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat  # (G, S*k, E)
    pos = jnp.sum(pos_in_expert * flat, axis=-1).reshape(G, S, k)  # (G,S,k)
    fits = pos < C
    gate_vals = gate_vals * fits
    pos_oh = jax.nn.one_hot(pos, C, dtype=jnp.float32) * fits[..., None]  # (G,S,k,C)
    # dispatch (G,S,E,C): token s goes to expert e at slot c
    dispatch = jnp.einsum("gske,gskc->gsec", choice_oh, pos_oh)
    combine = jnp.einsum("gsk,gske,gskc->gsec", gate_vals, choice_oh, pos_oh)
    # aux load-balance loss (Switch): E * mean(fraction_tokens_e * mean_prob_e)
    frac = jnp.mean(choice_oh[:, :, 0, :], axis=1)  # top-1 assignment fraction (G,E)
    mean_prob = jnp.mean(probs, axis=1)  # (G,E)
    aux = E * jnp.mean(jnp.sum(frac * mean_prob, axis=-1))
    return dispatch, combine, aux


def moe_block(p: dict, x: jax.Array, cfg):
    """x: (B, S, D) -> (B, S, D), aux_loss.

    Routing groups are `moe_group_size` token windows (GShard): capacity —
    and therefore the one-hot dispatch tensors — stay bounded regardless of
    sequence length. moe_impl="sort" switches to the gather/scatter dispatch
    (no dispatch-einsum FLOPs; see EXPERIMENTS.md §Perf hillclimb #1).
    """
    if cfg.moe_impl == "sort":
        return moe_block_sort(p, x, cfg)
    B, S, D = x.shape
    gs = min(cfg.moe_group_size, S)
    ng = S // gs if S % gs == 0 else 1
    if S % gs:
        gs, ng = S, 1
    # keep the batch dim separate (reshaping it into the group dim loses
    # batch sharding through the dispatch tensors: measured 40 GiB/device
    # f32 combine buffers on grok prefill)
    xg = x.reshape(B, ng, gs, D)
    logits = jnp.einsum("bgsd,de->bgse", xg, p["router"])
    dispatch, combine, aux = jax.vmap(lambda lg: route(cfg, lg))(logits)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)
    xe = jnp.einsum("bgsec,bgsd->bgecd", dispatch, xg)
    g = jnp.einsum("bgecd,edf->bgecf", xe, p["w_gate"])
    u = jnp.einsum("bgecd,edf->bgecf", xe, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    ye = jnp.einsum("bgecf,efd->bgecd", h, p["w_down"])
    y = jnp.einsum("bgsec,bgecd->bgsd", combine, ye)
    return y.reshape(B, S, D), jnp.mean(aux).astype(jnp.float32)


def moe_block_sort(p: dict, x: jax.Array, cfg):
    """Sort-based (gather/scatter) top-k dispatch: no one-hot einsum FLOPs.

    Per batch row: flatten (token, choice) pairs, argsort by expert, rank
    within expert -> capacity slot, gather rows into (E, C, D), run the
    expert FFN, scale by gates and scatter-add back. Dispatch/combine are
    pure data movement (gather/scatter), so HLO FLOPs ~= expert FFN FLOPs.
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.experts_per_token
    C = capacity(cfg, S)
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)  # (B,S,k)
    flat_e = expert_idx.reshape(B, S * k)
    flat_tok = jnp.broadcast_to(jnp.arange(S)[:, None], (S, k)).reshape(S * k)
    flat_g = gate_vals.reshape(B, S * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # (B, S*k)
    se = jnp.take_along_axis(flat_e, order, axis=-1)
    stok = flat_tok[order]  # (B, S*k) token index per sorted entry
    sgate = jnp.take_along_axis(flat_g, order, axis=-1)
    # rank within expert = position - first position of that expert
    starts = jax.vmap(lambda row: jnp.searchsorted(row, jnp.arange(E)))(se)  # (B,E)
    rank = jnp.arange(S * k)[None] - jnp.take_along_axis(starts, se, axis=-1)
    keep = rank < C
    slot = jnp.where(keep, se * C + rank, E * C)  # OOB -> dropped by scatter

    def per_row(xrow, slot_r, stok_r, sgate_r):
        dix = jnp.full((E * C,), S, jnp.int32).at[slot_r].set(stok_r, mode="drop")
        gec = jnp.zeros((E * C,), jnp.float32).at[slot_r].set(sgate_r, mode="drop")
        xpad = jnp.concatenate([xrow, jnp.zeros((1, D), xrow.dtype)], axis=0)
        xe = xpad[dix].reshape(E, C, D)
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(xrow.dtype) * u
        ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, D)
        ye = ye * gec[:, None].astype(ye.dtype)
        y = jnp.zeros((S + 1, D), xrow.dtype).at[dix].add(ye)
        return y[:S]

    y = jax.vmap(per_row)(x, slot, stok.astype(jnp.int32), sgate)
    frac = jnp.mean(jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32), axis=1)
    aux = E * jnp.mean(jnp.sum(frac * jnp.mean(probs, axis=1), axis=-1))
    return y, aux.astype(jnp.float32)
