"""GQA attention: full, structurally-windowed (chunked), and decode paths.

Supports RoPE, qk-norm (qwen3/gemma3), grouped KV heads, causal or
bidirectional masking, and per-layer sliding windows. The windowed
train/prefill path is *structural* (two-chunk local attention), so local
layers really cost O(S*W), not O(S^2) — this is what makes gemma3's 5:1
pattern and the long-context dry-runs honest.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, rms_norm, rope_freqs
from repro.models.params import ParamInfo

NEG_INF = -1e30


def eff_heads(cfg) -> int:
    """q heads incl. per-group sharding padding (llava: 8 groups of 7 -> 8)."""
    if cfg.q_group_pad:
        return cfg.n_kv_heads * cfg.q_group_pad
    return cfg.n_heads


def head_mask(cfg) -> jax.Array | None:
    """(H_eff,) 0/1 mask killing padded dead heads; None when unpadded."""
    if not cfg.q_group_pad:
        return None
    real = cfg.n_heads // cfg.n_kv_heads
    idx = jnp.arange(eff_heads(cfg))
    return (idx % cfg.q_group_pad < real).astype(jnp.float32)


def attention_template(cfg, prefix_axes: tuple[str, ...] = ("layer",), n_stack: tuple[int, ...] = ()) -> dict:
    """ParamInfo tree for one (optionally layer-stacked) attention block."""
    d, h, kv, hd = cfg.d_model, eff_heads(cfg), cfg.n_kv_heads, cfg.resolved_head_dim
    pa, ns = prefix_axes, n_stack
    t = {
        "wq": ParamInfo(ns + (d, h, hd), pa + ("embed", "heads", "head_dim")),
        "wk": ParamInfo(ns + (d, kv, hd), pa + ("embed", "kv_heads", "head_dim")),
        "wv": ParamInfo(ns + (d, kv, hd), pa + ("embed", "kv_heads", "head_dim")),
        "wo": ParamInfo(ns + (h, hd, d), pa + ("heads", "head_dim", "embed"), scale=1.0),
    }
    if cfg.qk_norm:
        t["q_norm"] = ParamInfo(ns + (hd,), pa + ("head_dim",), init="zeros")
        t["k_norm"] = ParamInfo(ns + (hd,), pa + ("head_dim",), init="zeros")
    return t


def _project_qkv(p: dict, x: jax.Array, cfg, positions: jax.Array):
    """x: (B, S, D) -> q (B,S,H,hd), k/v (B,S,Hkv,hd), with qk-norm + RoPE."""
    hd = cfg.resolved_head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(q, k, v, mask):
    """q (B,Sq,H,hd), k/v (B,Sk,Hkv,hd), mask broadcastable to (B,1,1,Sq,Sk)."""
    B, Sq, H, hd = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, Sq, H, hd)


Q_CHUNK_THRESHOLD = 8192  # above this, chunk queries to avoid S^2 scores
Q_CHUNK = 1024


def full_attention(q, k, v, *, causal: bool) -> jax.Array:
    Sq, Sk = q.shape[1], k.shape[1]
    if Sq >= Q_CHUNK_THRESHOLD and Sq % Q_CHUNK == 0:
        return _q_chunked_attention(q, k, v, causal=causal)
    if causal:
        qp = jnp.arange(Sq)[:, None]
        kp = jnp.arange(Sk)[None, :]
        mask = (qp >= kp)[None, None, None]
    else:
        mask = jnp.ones((1, 1, 1, Sq, Sk), bool)
    return _sdpa(q, k, v, mask)


def _q_chunked_attention(q, k, v, *, causal: bool, q_chunk: int = Q_CHUNK) -> jax.Array:
    """Query-chunked attention: softmax per q-chunk against full K/V, so the
    peak score buffer is (B, H, Q_CHUNK, S) instead of (B, H, S, S). Memory
    drops 32x at 32k prefill; FLOPs unchanged (the Pallas flash kernel is
    the TPU-side answer for the causal-half saving)."""
    B, S, H, hd = q.shape
    qc_size = min(q_chunk, S)
    nc = S // qc_size
    qc = jnp.moveaxis(q.reshape(B, nc, qc_size, H, hd), 1, 0)  # (nc,B,QC,H,hd)
    kp = jnp.arange(S)

    def body(_, args):
        qi, idx = args
        qpos = idx * qc_size + jnp.arange(qc_size)
        if causal:
            mask = (qpos[:, None] >= kp[None, :])[None, None, None]
        else:
            mask = jnp.ones((1, 1, 1, qc_size, S), bool)
        return None, _sdpa(qi, k, v, mask)

    _, out = jax.lax.scan(body, None, (qc, jnp.arange(nc)))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def windowed_attention(q, k, v, *, window: int) -> jax.Array:
    """Structural causal sliding-window attention (two-chunk local).

    Requires S % window == 0. Each query chunk attends its own and the
    previous key chunk -> exact window-W causal attention at O(S*W) cost.
    """
    B, S, H, hd = q.shape
    Hkv = k.shape[2]
    W = window
    assert S % W == 0, f"seq {S} not a multiple of window {W}"
    nc = S // W
    G = H // Hkv
    qc = q.reshape(B, nc, W, Hkv, G, hd)
    kc = k.reshape(B, nc, W, Hkv, hd)
    vc = v.reshape(B, nc, W, Hkv, hd)
    zeros = jnp.zeros_like(kc[:, :1])
    kprev = jnp.concatenate([zeros, kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kcat = jnp.concatenate([kprev, kc], axis=2)  # (B, nc, 2W, Hkv, hd)
    vcat = jnp.concatenate([vprev, vc], axis=2)
    scores = jnp.einsum("bnskgh,bntkh->bnkgst", qc, kcat).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    s_idx = jnp.arange(W)[:, None]  # query offset in chunk
    t_idx = jnp.arange(2 * W)[None, :]  # key offset in [prev, cur]
    rel = s_idx + W - t_idx  # qpos - kpos
    valid = (rel >= 0) & (rel < W)
    # the first chunk has no previous keys: only the [W, 2W) half is real
    mask = valid[None] & ((jnp.arange(nc)[:, None, None] > 0) | (t_idx >= W)[None])
    scores = jnp.where(mask[None, :, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnkgst,bntkh->bnskgh", probs, vcat)
    return out.reshape(B, S, H, hd)


def attention_block(p: dict, x: jax.Array, cfg, *, window: int = 0, positions=None, return_kv: bool = False):
    """Full train/prefill attention block (no cache). window=0 -> full.

    With return_kv=True also returns cache-ready (k, v): full-length for
    global layers, the trailing `window` positions (in ring order, which for
    S % window == 0 equals slot order) for windowed layers.
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)
    q, k, v = _project_qkv(p, x, cfg, positions)
    if getattr(cfg, "attention_impl", "ref") == "pallas" and cfg.causal and S % 128 == 0:
        from repro.kernels import ops as kops

        out = kops.flash_attention_trainable(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
            causal=True, window=window,
        ).transpose(0, 2, 1, 3)
    elif window and cfg.causal and S % window == 0 and S > window:
        out = windowed_attention(q, k, v, window=window)
    elif window and cfg.causal:
        # fallback: masked full attention with window (small/smoke shapes)
        qp = jnp.arange(S)[:, None]
        kp = jnp.arange(S)[None, :]
        mask = ((qp >= kp) & (qp - kp < window))[None, None, None]
        out = _sdpa(q, k, v, mask)
    else:
        out = full_attention(q, k, v, causal=cfg.causal)
    hm = head_mask(cfg)
    if hm is not None:
        out = out * hm[None, None, :, None].astype(out.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    if return_kv:
        if window and S >= window:
            kc, vc = k[:, -window:], v[:, -window:]
        elif window:
            pad = window - S
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        else:
            kc, vc = k, v
        return out, (kc, vc)
    return out


# ---------------------------------------------------------------------------
# KV-cache decode path
# ---------------------------------------------------------------------------

def cache_template(cfg, n_layers: int, batch: int, max_len: int, window: int = 0):
    """Abstract KV cache for a homogeneous stack. window>0 -> ring buffer."""
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    S = min(window, max_len) if window else max_len
    shape = (n_layers, batch, S, kv, hd)
    return {
        "k": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
        "v": jax.ShapeDtypeStruct(shape, jnp.bfloat16),
    }


def init_cache(cfg, n_layers: int, batch: int, max_len: int, window: int = 0, dtype=jnp.bfloat16):
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    S = min(window, max_len) if window else max_len
    shape = (n_layers, batch, S, kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_attention(p: dict, x: jax.Array, layer_cache: dict, cfg, pos: jax.Array, *, window: int = 0):
    """One-token attention against a cache slice.

    x: (B, 1, D); layer_cache {"k","v"}: (B, S_cache, Hkv, hd); pos: scalar
    current position. Returns (out (B,1,D), updated layer_cache).
    Windowed layers use a ring buffer of size `window`.
    """
    B = x.shape[0]
    positions = jnp.full((1,), pos, jnp.int32)
    q, k, v = _project_qkv(p, x, cfg, positions)
    S_cache = layer_cache["k"].shape[1]
    slot = pos % S_cache if window else pos
    ck = jax.lax.dynamic_update_slice(layer_cache["k"], k.astype(layer_cache["k"].dtype), (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(layer_cache["v"], v.astype(layer_cache["v"].dtype), (0, slot, 0, 0))
    # key positions: ring buffer -> reconstruct absolute positions per slot
    idx = jnp.arange(S_cache)
    if window:
        # slot i holds absolute position: largest p <= pos with p % S_cache == i
        kpos = pos - ((pos - idx) % S_cache)
    else:
        kpos = idx
    valid = (kpos <= pos) & (kpos >= 0)
    if window:
        valid &= pos - kpos < window
    mask = valid[None, None, None, None, :]  # (1,1,1,1,S_cache)
    out = _sdpa(q, ck, cv, mask)
    hm = head_mask(cfg)
    if hm is not None:
        out = out * hm[None, None, :, None].astype(out.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, {"k": ck, "v": cv}
