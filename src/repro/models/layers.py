"""Shared neural-net building blocks (pure jnp)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    """RMSNorm with f32 statistics but no f32 materialization of x.

    The variance is an f32-accumulated contraction (XLA keeps the (B,S,D)
    stream in bf16); only the (B,S,1) statistics live in f32. Saving an f32
    copy of every layer input cost 14 GiB/device on the train_4k dry-run.
    """
    var = jnp.einsum(
        "...d,...d->...", x, x, preferred_element_type=jnp.float32
    ) / x.shape[-1]
    inv = jax.lax.rsqrt(var + eps)[..., None]  # (..., 1) f32
    return x * ((1.0 + weight.astype(jnp.float32)) * inv).astype(x.dtype)


def rope_freqs(positions: jax.Array, head_dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions: (...,) int -> cos/sin of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, D); cos/sin: (S, D/2) or (B, S, D/2)."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:  # (S, half) -> broadcast over batch & heads
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:  # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


def softmax_cross_entropy(logits: jax.Array, labels: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Mean CE over valid positions; logits (..., V), labels (...,) int.

    The gold logit is extracted with a masked reduction instead of
    take_along_axis so a vocab-sharded logits tensor never gets gathered
    (vocab-parallel heads stay sharded through the loss).
    """
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    sel = (vocab_iota == labels[..., None]).astype(jnp.float32)
    gold = jnp.sum(logits.astype(jnp.float32) * sel, axis=-1)
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
