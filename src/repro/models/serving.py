"""Serving paths: prefill (build caches) and decode_step (one token).

Cache layout per family (all layer-stacked for lax.scan):
- dense/moe/vlm/audio: {"k","v"}: (L, B, S, kv, hd)   (ring of W if windowed)
- gemma3 pattern:      {"g_local": {k,v} (ng, p-1, B, W, ...),
                        "g_global": {k,v} (ng, B, S, ...),
                        "tail": {k,v} (nt, B, W, ...)}
- ssm:                 {"ssm": (L,B,h,p,n) f32, "conv": (L,B,k-1,C)}
- hybrid:              {"ssm","conv" (ng, period, ...), "shared": {k,v} (ng,B,S,...)}
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import mamba2 as m2
from repro.models import moe as moe_mod
from repro.models.layers import rms_norm, swiglu
from repro.models.shard_ctx import constrain
from repro.models.transformer import (
    _dtype,
    embed_inputs,
    gemma_pattern,
    layer_window,
    logits_fn,
)

PyTree = Any


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def _kv(shape, dtype, make):
    return {"k": make(shape, dtype), "v": make(shape, dtype)}


def cache_spec(cfg: ArchConfig, batch: int, max_len: int, abstract: bool = True) -> PyTree:
    """Abstract (ShapeDtypeStruct) or zero-initialized cache pytree."""
    make = (lambda s, d: jax.ShapeDtypeStruct(s, d)) if abstract else (lambda s, d: jnp.zeros(s, d))
    dt = _dtype(cfg)
    kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if cfg.family in ("dense", "vlm", "audio", "moe") and not cfg.local_global_period:
        S = min(cfg.window, max_len) if cfg.window else max_len
        return _kv((cfg.n_layers, batch, S, kv, hd), dt, make)
    if cfg.local_global_period:
        ng, nt = gemma_pattern(cfg)
        p = cfg.local_global_period
        W = min(cfg.window, max_len)
        out = {
            "g_local": _kv((ng, p - 1, batch, W, kv, hd), dt, make),
            "g_global": _kv((ng, batch, max_len, kv, hd), dt, make),
        }
        if nt:
            out["tail"] = _kv((nt, batch, W, kv, hd), dt, make)
        return out
    if cfg.family == "ssm":
        di, h, n = m2.dims(cfg)
        conv_ch = di + 2 * n
        return {
            "ssm": make((cfg.n_layers, batch, h, cfg.ssm_headdim, n), jnp.float32),
            "conv": make((cfg.n_layers, batch, cfg.ssm_conv - 1, conv_ch), dt),
        }
    if cfg.family == "hybrid":
        ng = cfg.n_layers // cfg.shared_attn_period
        di, h, n = m2.dims(cfg)
        conv_ch = di + 2 * n
        per = cfg.shared_attn_period
        return {
            "ssm": make((ng, per, batch, h, cfg.ssm_headdim, n), jnp.float32),
            "conv": make((ng, per, batch, cfg.ssm_conv - 1, conv_ch), dt),
            "shared": _kv((ng, batch, max_len, kv, hd), dt, make),
        }
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# Decode blocks
# ---------------------------------------------------------------------------

def _dense_decode_block(cfg, p, h, ck, cv, pos, window: int):
    a, newc = attn.decode_attention(
        p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps), {"k": ck, "v": cv}, cfg, pos, window=window
    )
    h = h + a
    g = rms_norm(h, p["norm2"], cfg.norm_eps)
    if cfg.family == "moe":
        y, _ = moe_mod.moe_block(p["moe"], g, cfg)
    else:
        y = swiglu(g, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
    return h + y, newc["k"], newc["v"]


def _ssm_decode_block(cfg, p, h, st, pos):
    y, new = m2.mamba2_decode(p["ssm"], rms_norm(h, p["norm1"], cfg.norm_eps), st, cfg)
    return h + y, new


def decode_step(cfg: ArchConfig, params: PyTree, cache: PyTree, tokens: jax.Array, pos: jax.Array):
    """One-token decode. tokens (B,1) int32, pos scalar int32 (cache length).

    Returns (logits (B,1,V), new_cache).
    """
    x = params["embed"][tokens].astype(_dtype(cfg))
    if cfg.family in ("dense", "vlm", "audio", "moe") and not cfg.local_global_period:
        def body(h, xs):
            layer_p, ck, cv = xs
            h, nk, nv = _dense_decode_block(cfg, layer_p, h, ck, cv, pos, cfg.window)
            return h, (nk, nv)

        x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv}
    elif cfg.local_global_period:
        p_ = cfg.local_global_period

        def gbody(h, xs):
            gp, lk, lv, gk, gv = xs
            nlk, nlv = [], []
            for i in range(p_):
                sub = jax.tree.map(lambda w: w[i], gp)
                w = layer_window(cfg, i)
                if w:
                    h, k2, v2 = _dense_decode_block(cfg, sub, h, lk[i], lv[i], pos, w)
                    nlk.append(k2)
                    nlv.append(v2)
                else:
                    h, gk, gv = _dense_decode_block(cfg, sub, h, gk, gv, pos, 0)
            return h, (jnp.stack(nlk), jnp.stack(nlv), gk, gv)

        c = cache
        x, (nlk, nlv, ngk, ngv) = jax.lax.scan(
            gbody,
            x,
            (params["groups"], c["g_local"]["k"], c["g_local"]["v"], c["g_global"]["k"], c["g_global"]["v"]),
        )
        new_cache = {"g_local": {"k": nlk, "v": nlv}, "g_global": {"k": ngk, "v": ngv}}
        if "tail" in params:
            def tbody(h, xs):
                layer_p, ck, cv = xs
                h, nk, nv = _dense_decode_block(cfg, layer_p, h, ck, cv, pos, cfg.window)
                return h, (nk, nv)

            x, (tk, tv) = jax.lax.scan(tbody, x, (params["tail"], c["tail"]["k"], c["tail"]["v"]))
            new_cache["tail"] = {"k": tk, "v": tv}
    elif cfg.family == "ssm":
        def sbody(h, xs):
            layer_p, ssm, conv = xs
            h, new = _ssm_decode_block(cfg, layer_p, h, {"ssm": ssm, "conv": conv}, pos)
            return h, (new["ssm"], new["conv"])

        x, (ns, nc) = jax.lax.scan(sbody, x, (params["layers"], cache["ssm"], cache["conv"]))
        new_cache = {"ssm": ns, "conv": nc}
    elif cfg.family == "hybrid":
        per = cfg.shared_attn_period
        shared = params["shared"]

        def hbody(h, xs):
            gp, ssm, conv, sk, sv = xs
            nss, ncv = [], []
            for i in range(per):
                sub = jax.tree.map(lambda w: w[i], gp)
                h, new = _ssm_decode_block(cfg, sub, h, {"ssm": ssm[i], "conv": conv[i]}, pos)
                nss.append(new["ssm"])
                ncv.append(new["conv"])
            h, nk, nv = _dense_decode_block(cfg, shared, h, sk, sv, pos, 0)
            return h, (jnp.stack(nss), jnp.stack(ncv), nk, nv)

        c = cache
        x, (ns, ncv, nk, nv) = jax.lax.scan(
            hbody, x, (params["mamba_groups"], c["ssm"], c["conv"], c["shared"]["k"], c["shared"]["v"])
        )
        new_cache = {"ssm": ns, "conv": ncv, "shared": {"k": nk, "v": nv}}
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_fn(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# Prefill: full forward emitting caches, last-position logits only
# ---------------------------------------------------------------------------

def prefill(cfg: ArchConfig, params: PyTree, batch: dict, max_len: int = 0):
    """Returns (last-token logits (B,1,V), cache).

    max_len > S pads the global KV caches so subsequent decode_step calls
    have slots to write into (windowed/SSM caches are fixed-size already).
    """
    x = embed_inputs(cfg, params, batch)
    S_in = x.shape[1]

    def grow(kv):
        if not max_len or max_len <= S_in:
            return kv
        pad = max_len - S_in
        return jax.tree.map(
            lambda a: jnp.pad(a, [(0, 0)] * (a.ndim - 3) + [(0, pad), (0, 0), (0, 0)]), kv
        )

    x = constrain(x)

    def dense_block_kv(p, h, window):
        a, (kc, vc) = attn.attention_block(
            p["attn"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg, window=window, return_kv=True
        )
        h = h + a
        g = rms_norm(h, p["norm2"], cfg.norm_eps)
        if cfg.family == "moe":
            y, _ = moe_mod.moe_block(p["moe"], g, cfg)
        else:
            y = swiglu(g, p["mlp"]["w_gate"], p["mlp"]["w_up"], p["mlp"]["w_down"])
        return constrain(h + y), kc, vc

    def ssm_block_state(p, h):
        y, st = m2.mamba2_block(p["ssm"], rms_norm(h, p["norm1"], cfg.norm_eps), cfg, return_state=True)
        return constrain(h + y), st

    if cfg.family in ("dense", "vlm", "audio", "moe") and not cfg.local_global_period:
        def body(h, layer_p):
            h, kc, vc = dense_block_kv(layer_p, h, cfg.window)
            return h, (kc.astype(_dtype(cfg)), vc.astype(_dtype(cfg)))

        x, (k, v) = jax.lax.scan(body, x, params["layers"])
        cache = grow({"k": k, "v": v}) if not cfg.window else {"k": k, "v": v}
    elif cfg.local_global_period:
        p_ = cfg.local_global_period

        def gbody(h, gp):
            lk, lv = [], []
            gk = gv = None
            for i in range(p_):
                sub = jax.tree.map(lambda w: w[i], gp)
                w = layer_window(cfg, i)
                h, kc, vc = dense_block_kv(sub, h, w)
                if w:
                    lk.append(kc)
                    lv.append(vc)
                else:
                    gk, gv = kc, vc
            return h, (jnp.stack(lk), jnp.stack(lv), gk, gv)

        x, (lk, lv, gk, gv) = jax.lax.scan(gbody, x, params["groups"])
        cache = {"g_local": {"k": lk, "v": lv}, "g_global": grow({"k": gk, "v": gv})}
        if "tail" in params:
            def tbody(h, layer_p):
                h, kc, vc = dense_block_kv(layer_p, h, cfg.window)
                return h, (kc, vc)

            x, (tk, tv) = jax.lax.scan(tbody, x, params["tail"])
            cache["tail"] = {"k": tk, "v": tv}
    elif cfg.family == "ssm":
        def sbody(h, layer_p):
            h, st = ssm_block_state(layer_p, h)
            return h, (st["ssm"], st["conv"])

        x, (ssm, conv) = jax.lax.scan(sbody, x, params["layers"])
        cache = {"ssm": ssm, "conv": conv}
    elif cfg.family == "hybrid":
        per = cfg.shared_attn_period
        shared = params["shared"]

        def hbody(h, gp):
            ss, cc = [], []
            for i in range(per):
                sub = jax.tree.map(lambda w: w[i], gp)
                h, st = ssm_block_state(sub, h)
                ss.append(st["ssm"])
                cc.append(st["conv"])
            h, kc, vc = dense_block_kv(shared, h, 0)
            return h, (jnp.stack(ss), jnp.stack(cc), kc, vc)

        x, (ssm, conv, sk, sv) = jax.lax.scan(hbody, x, params["mamba_groups"])
        cache = {"ssm": ssm, "conv": conv, "shared": grow({"k": sk, "v": sv})}
    else:
        raise ValueError(cfg.family)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return logits_fn(cfg, params, x), cache
