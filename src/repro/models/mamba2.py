"""Mamba2 SSD (state-space duality) block — chunked scan, pure JAX reference.

Follows the minimal SSD formulation of arXiv:2405.21060: intra-chunk
quadratic attention-like term + inter-chunk recurrent state, with the
inter-chunk recurrence carried by ``lax.scan`` (so 500k-token sequences never
materialize an (n_chunks x n_chunks) decay matrix). The intra-chunk einsums
are mirrored by the Pallas kernel in ``repro.kernels.ssd_scan``.

Projections are kept separate (wz/wx/wB/wC/wdt instead of one fused in_proj)
so each output dimension carries a clean sharding axis (d_inner and heads on
"model", the small B/C/state tensors replicated).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm
from repro.models.params import ParamInfo


def dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return d_inner, n_heads, cfg.ssm_state


def mamba2_template(cfg, prefix_axes=("layer",), n_stack=()):
    d = cfg.d_model
    di, h, n = dims(cfg)
    k = cfg.ssm_conv
    pa, ns = prefix_axes, n_stack
    return {
        "wz": ParamInfo(ns + (d, di), pa + ("embed", "ssm_inner")),
        "wx": ParamInfo(ns + (d, di), pa + ("embed", "ssm_inner")),
        "wB": ParamInfo(ns + (d, n), pa + ("embed", "ssm_state")),
        "wC": ParamInfo(ns + (d, n), pa + ("embed", "ssm_state")),
        "wdt": ParamInfo(ns + (d, h), pa + ("embed", "heads")),
        "conv_x": ParamInfo(ns + (k, di), pa + ("conv", "ssm_inner"), init="small_normal"),
        "conv_B": ParamInfo(ns + (k, n), pa + ("conv", "ssm_state"), init="small_normal"),
        "conv_C": ParamInfo(ns + (k, n), pa + ("conv", "ssm_state"), init="small_normal"),
        "A_log": ParamInfo(ns + (h,), pa + ("heads",), init="zeros"),
        "D": ParamInfo(ns + (h,), pa + ("heads",), init="ones"),
        "dt_bias": ParamInfo(ns + (h,), pa + ("heads",), init="zeros"),
        "gate_norm": ParamInfo(ns + (di,), pa + ("ssm_inner",), init="zeros"),
        "wo": ParamInfo(ns + (di, d), pa + ("ssm_inner", "embed")),
    }


def causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (B,S,C), w (k,C) -> (B,S,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : xp.shape[1] - (k - 1 - i), :] * w[i] for i in range(k))
    return out


def ssd_chunked(xdt, dA, Bm, Cm, chunk: int, init_state=None):
    """Chunked SSD. xdt (b,s,h,p) [x*dt folded], dA (b,s,h), Bm/Cm (b,s,n).

    Returns y (b,s,h,p) and final state (b,h,p,n). f32 decay math.
    """
    b, s, h, p = xdt.shape
    n = Bm.shape[-1]
    s_orig = s
    if s % chunk:  # right-pad to a chunk multiple (dA=0 -> decay 1, xdt=0)
        pad = chunk - s % chunk
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dA = jnp.pad(dA, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    xdt_c = xdt.reshape(b, nc, chunk, h, p)
    dA_c = dA.reshape(b, nc, chunk, h).astype(jnp.float32)
    B_c = Bm.reshape(b, nc, chunk, n)
    C_c = Cm.reshape(b, nc, chunk, n)
    cum = jnp.cumsum(dA_c, axis=2)  # (b,nc,Q,h)
    # intra-chunk decay L[q,t] = exp(cum[q]-cum[t]), q >= t
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,Q,h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0).astype(xdt.dtype)
    scores = jnp.einsum("bcqn,bctn->bcqt", C_c, B_c)
    y_diag = jnp.einsum("bcqt,bcqth,bcthp->bcqhp", scores, L, xdt_c)
    # per-chunk state contribution and total chunk decay
    decay_states = jnp.exp(cum[:, :, -1:, :] - cum).astype(xdt.dtype)  # (b,nc,Q,h)
    states = jnp.einsum("bctn,bcth,bcthp->bchpn", B_c, decay_states, xdt_c)
    chunk_decay = jnp.exp(cum[:, :, -1, :]).astype(xdt.dtype)  # (b,nc,h)

    def scan_fn(carry, inp):
        st, cd = inp  # (b,h,p,n), (b,h)
        new = carry * cd[:, :, None, None] + st
        return new, carry  # emit the state *entering* this chunk

    init = (
        jnp.zeros((b, h, p, n), xdt.dtype)
        if init_state is None
        else init_state.astype(xdt.dtype)
    )
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)  # (b,nc,h,p,n)
    y_off = jnp.einsum(
        "bcqn,bchpn,bcqh->bcqhp",
        C_c,
        prev_states,
        jnp.exp(cum).astype(xdt.dtype),
    )
    y = (y_diag + y_off).reshape(b, s, h, p)[:, :s_orig]
    return y, final_state


def mamba2_block(p: dict, x: jax.Array, cfg, return_state: bool = False):
    """Full-sequence Mamba2 block. x (B,S,D) -> (B,S,D).

    With return_state=True also returns the decode-ready layer state
    {"ssm" (B,h,p,n) f32, "conv" (B,k-1,C) pre-activation tail}.
    """
    di, h, n = dims(cfg)
    pdim = cfg.ssm_headdim
    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    x_pre = jnp.einsum("bsd,de->bse", x, p["wx"])
    B_pre = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    C_pre = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    xin = jax.nn.silu(causal_conv(x_pre, p["conv_x"]).astype(jnp.float32)).astype(x.dtype)
    Bm = jax.nn.silu(causal_conv(B_pre, p["conv_B"]).astype(jnp.float32)).astype(x.dtype)
    Cm = jax.nn.silu(causal_conv(C_pre, p["conv_C"]).astype(jnp.float32)).astype(x.dtype)
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (h,)
    dA = dt * A  # (B,S,h)
    xh = xin.reshape(*xin.shape[:2], h, pdim)
    xdt = xh * dt[..., None].astype(x.dtype)
    if getattr(cfg, "ssm_impl", "ref") == "pallas" and x.shape[1] % cfg.ssm_chunk == 0:
        from repro.kernels import ops as kops

        y, final_state = kops.ssd_full_trainable(xdt, dA, Bm, Cm, chunk=cfg.ssm_chunk)
    else:
        y, final_state = ssd_chunked(xdt, dA, Bm, Cm, cfg.ssm_chunk)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], di)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["wo"])
    if return_state:
        k = cfg.ssm_conv
        pre = jnp.concatenate([x_pre, B_pre, C_pre], axis=-1)  # (B,S,C)
        conv_cache = pre[:, -(k - 1):, :]
        S = x.shape[1]
        if S < k - 1:
            conv_cache = jnp.pad(pre, ((0, 0), (k - 1 - S, 0), (0, 0)))
        return out, {"ssm": final_state.astype(jnp.float32), "conv": conv_cache}
    return out


# ---------------------------------------------------------------------------
# Decode path: O(1)-in-seq recurrent state
# ---------------------------------------------------------------------------

def state_template(cfg, n_layers: int, batch: int):
    di, h, n = dims(cfg)
    k = cfg.ssm_conv
    conv_ch = di + 2 * n  # x, B, C conv caches concatenated
    return {
        "ssm": jax.ShapeDtypeStruct((n_layers, batch, h, cfg.ssm_headdim, n), jnp.float32),
        "conv": jax.ShapeDtypeStruct((n_layers, batch, k - 1, conv_ch), jnp.bfloat16),
    }


def init_state(cfg, n_layers: int, batch: int, dtype=jnp.bfloat16):
    di, h, n = dims(cfg)
    k = cfg.ssm_conv
    return {
        "ssm": jnp.zeros((n_layers, batch, h, cfg.ssm_headdim, n), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, k - 1, di + 2 * n), dtype),
    }


def _conv_step(cache: jax.Array, new: jax.Array, w: jax.Array):
    """cache (B,k-1,C), new (B,C), w (k,C) -> out (B,C), cache'."""
    k = w.shape[0]
    full = jnp.concatenate([cache, new[:, None, :]], axis=1)  # (B,k,C)
    out = jnp.sum(full * w[None], axis=1)
    return out, full[:, 1:]


def mamba2_decode(p: dict, x: jax.Array, layer_state: dict, cfg):
    """One-token step. x (B,1,D); layer_state {ssm (B,h,p,n), conv (B,k-1,C)}."""
    di, h, n = dims(cfg)
    pdim = cfg.ssm_headdim
    xt = x[:, 0]  # (B,D)
    z = jnp.einsum("bd,de->be", xt, p["wz"])
    pre = jnp.concatenate(
        [
            jnp.einsum("bd,de->be", xt, p["wx"]),
            jnp.einsum("bd,dn->bn", xt, p["wB"]),
            jnp.einsum("bd,dn->bn", xt, p["wC"]),
        ],
        axis=-1,
    )
    w_all = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    conv_out, conv_cache = _conv_step(layer_state["conv"], pre.astype(layer_state["conv"].dtype), w_all)
    xin, Bm, Cm = jnp.split(conv_out, [di, di + n], axis=-1)
    xin = jax.nn.silu(xin.astype(jnp.float32)).astype(x.dtype)
    Bm = jax.nn.silu(Bm.astype(jnp.float32))
    Cm = jax.nn.silu(Cm.astype(jnp.float32))
    dt = jnp.einsum("bd,dh->bh", xt, p["wdt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    decay = jnp.exp(dt * A)  # (B,h)
    xh = xin.reshape(-1, h, pdim).astype(jnp.float32)
    ssm = layer_state["ssm"]
    contrib = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, Bm)
    ssm = ssm * decay[:, :, None, None] + contrib
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cm)
    y = y + xh * p["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(-1, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("be,ed->bd", y, p["wo"])[:, None, :]
    return out, {"ssm": ssm, "conv": conv_cache}
