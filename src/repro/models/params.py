"""Parameter templates: one source of truth for shapes, logical axes, init.

Every model module builds a pytree of :class:`ParamInfo` leaves. From it we
derive (a) real initialized arrays for training/smoke tests, (b)
``jax.ShapeDtypeStruct`` stand-ins for the multi-pod dry-run, and (c)
``PartitionSpec`` shardings via logical-axis rules.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ParamInfo:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim
    init: str = "normal"  # normal | zeros | ones | small_normal
    scale: float = 1.0  # multiplier on the fan-in init std

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_info(x) -> bool:
    return isinstance(x, ParamInfo)


# Default logical-axis -> mesh-axis rules (tensor parallel over "model").
# The leading federated-client axis is added by core.rounds, not here.
DEFAULT_RULES: dict[str | None, str | None] = {
    None: None,
    "layer": None,  # scan-stacked layer dim
    "group": None,  # layer-pattern group dim (gemma3/zamba2)
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ffn": "model",
    "expert": None,  # baseline: experts replicated, ffn sharded
    "ssm_inner": "model",
    "ssm_state": None,
    "conv": None,
}


# Production-mesh axis sizes (launch.mesh). Examples on host meshes pass
# their own sizes.
PROD_AXIS_SIZES: dict[str, int] = {"pod": 2, "data": 16, "model": 16}

# dims never sharded by fallback placement: scan/stack dims, and head_dim
# (RoPE splits it in half, so sharding it forces pathological reshards).
_NO_FALLBACK = {"layer", "group", "conv", "expert", "head_dim"}


def spec_for(info: ParamInfo, rules: dict | None = None, axis_sizes: dict | None = None) -> P:
    """Shape-aware sharding: honor rules where the dim is divisible by the
    mesh axis, otherwise leave the dim replicated. Non-divisible cases are
    handled structurally instead (vocab padding, per-group q-head padding —
    DESIGN.md §4): a measured fallback experiment (EXPERIMENTS.md §Perf)
    showed row-parallel/head_dim fallbacks trade memory for per-layer
    activation collectives and RoPE reshards."""
    rules = DEFAULT_RULES if rules is None else rules
    sizes = PROD_AXIS_SIZES if axis_sizes is None else axis_sizes
    n = len(info.shape)
    assigned: list[str | None] = [None] * n
    used: set[str] = set()
    for i in range(n):
        mesh_ax = rules.get(info.axes[i])
        if not mesh_ax or mesh_ax in used:
            continue
        if info.shape[i] > 0 and info.shape[i] % sizes.get(mesh_ax, 1) == 0:
            assigned[i] = mesh_ax
            used.add(mesh_ax)
    return P(*assigned)


def shardings(template: PyTree, mesh, rules: dict | None = None, axis_sizes: dict | None = None) -> PyTree:
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda i: NamedSharding(mesh, spec_for(i, rules, axis_sizes)), template, is_leaf=is_info
    )


def pspecs(template: PyTree, rules: dict | None = None, axis_sizes: dict | None = None) -> PyTree:
    return jax.tree.map(lambda i: spec_for(i, rules, axis_sizes), template, is_leaf=is_info)


def abstract(template: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda i: jax.ShapeDtypeStruct(i.shape, dtype), template, is_leaf=is_info
    )


def _fan_in(info: ParamInfo) -> int:
    # fan-in heuristic: product of all dims except the last
    if len(info.shape) <= 1:
        return max(info.shape[-1] if info.shape else 1, 1)
    return max(math.prod(info.shape[:-1]) // (info.shape[0] if info.axes and info.axes[0] in ("layer", "group", "expert") and len(info.shape) > 2 else 1), 1)


def init_params(template: PyTree, rng: jax.Array, dtype=jnp.float32) -> PyTree:
    """Initialize real arrays from a template (deterministic per-leaf keys)."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_info)
    keys = jax.random.split(rng, len(leaves))

    def make(info: ParamInfo, key):
        if info.init == "zeros":
            return jnp.zeros(info.shape, dtype)
        if info.init == "ones":
            return jnp.ones(info.shape, dtype)
        std = info.scale / math.sqrt(_fan_in(info))
        if info.init == "small_normal":
            std = 0.02 * info.scale
        return (jax.random.normal(key, info.shape, jnp.float32) * std).astype(dtype)

    return jax.tree.unflatten(treedef, [make(i, k) for i, k in zip(leaves, keys)])


def count_params(template: PyTree) -> int:
    leaves = jax.tree.leaves(template, is_leaf=is_info)
    return sum(math.prod(l.shape) for l in leaves)


def map_with_path(fn: Callable, template: PyTree) -> PyTree:
    return jax.tree_util.tree_map_with_path(fn, template, is_leaf=is_info)
