"""Activation-sharding context.

GSPMD's cost model sometimes resolves the weights-over-data (FSDP) vs
batch-over-data conflict by replicating the batch — catastrophic for the
saved-carry stack (measured: grok multipod 237 GiB/device temp). Step
builders set an activation PartitionSpec here; the model applies it at
block boundaries. Under `jax.vmap(..., spmd_axis_name=client_axis)` the
client axis is prepended automatically.
"""
from __future__ import annotations

import contextlib
from contextvars import ContextVar

import jax
from jax.sharding import PartitionSpec as P

_ACT_BATCH: ContextVar[tuple | None] = ContextVar("act_batch_axes", default=None)
_ACT_SEQ: ContextVar[str | None] = ContextVar("act_seq_axis", default=None)


@contextlib.contextmanager
def activation_sharding(batch_axes: tuple | None, seq_axis: str | None = None):
    """batch_axes: mesh axes for the leading batch dim of (B, S, D) acts.
    seq_axis: optional sequence-parallel axis (Megatron-SP): the residual
    stream between blocks is sharded over S, trading the per-block TP
    all-reduce for all-gather/reduce-scatter pairs."""
    tok = _ACT_BATCH.set(batch_axes)
    tok2 = _ACT_SEQ.set(seq_axis)
    try:
        yield
    finally:
        _ACT_BATCH.reset(tok)
        _ACT_SEQ.reset(tok2)


def constrain(x: jax.Array) -> jax.Array:
    """Constrain an activation whose dim 0 is the batch dim.

    batch_axes=() emits an all-None constraint: useless alone, but under
    vmap(spmd_axis_name=client_axis) the client axis is prepended, which is
    exactly the per-client sharding the stacked single-pod plan needs.
    """
    axes = _ACT_BATCH.get()
    if axes is None:
        return x
    lead = axes if axes else None
    seq = _ACT_SEQ.get()
    spec = P(lead, seq, *(None,) * (x.ndim - 2))
    return jax.lax.with_sharding_constraint(x, spec)
