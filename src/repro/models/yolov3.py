"""FedYOLOv3 — the paper's object detector (YOLOv3-lite in pure JAX).

Darknet-style residual backbone (scaled to be CPU-trainable) with 3-scale
detection heads. The loss implements the paper's Eqs 2-4 exactly as written:
squared-error class loss on object cells, lambda_coord-weighted box
coordinate loss, and confidence loss theta = p(obj) * IOU with
lambda_noobj down-weighting of empty cells.

Targets are grid tensors produced by repro.data.darknet from the paper's
``{label x y w h}`` annotation rows.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamInfo

LAMBDA_COORD = 5.0  # well-studied hyper-parameters, pre-configured (paper)
LAMBDA_NOOBJ = 0.5

# anchor (w, h) priors per scale, normalized to image size
ANCHORS = (
    ((0.05, 0.06), (0.10, 0.12), (0.16, 0.20)),  # stride 8
    ((0.22, 0.28), (0.35, 0.40), (0.45, 0.55)),  # stride 16
    ((0.55, 0.70), (0.75, 0.85), (0.90, 0.95)),  # stride 32
)


def _conv_info(kh, kw, cin, cout, init="normal"):
    return ParamInfo((kh, kw, cin, cout), (None, None, None, None), init=init)


def template(cfg):
    """cfg.d_model = base width, cfg.n_layers = stages, cfg.vocab_size = C."""
    c = cfg.d_model
    n_stages = max(cfg.n_layers, 3)  # three detection scales need >=3 stages
    A = cfg.n_heads
    C = cfg.vocab_size
    t = {"stem": _conv_info(3, 3, 3, c)}
    widths = [c * 2 ** min(i + 1, 5) for i in range(n_stages)]
    stages = []
    cin = c
    for w in widths:
        stages.append(
            {
                "down": _conv_info(3, 3, cin, w),
                "res1": _conv_info(1, 1, w, w // 2),
                "res2": _conv_info(3, 3, w // 2, w),
            }
        )
        cin = w
    t["stages"] = tuple(stages)
    # heads on the last three stages
    t["heads"] = tuple(
        _conv_info(1, 1, widths[-3 + i], A * (5 + C), init="small_normal") for i in range(3)
    )
    return t


def grid_sizes(cfg, img_size: int) -> list[int]:
    """Detection-head grid sizes for an image size, largest scale first.

    Each darknet stage halves the resolution and heads sit on the last
    three stages, so with n stages the strides are 2^(n-2), 2^(n-1), 2^n
    (the classic 8/16/32 at the full 5-stage config). Target builders must
    use this rather than hardcoding //8 //16 //32, or reduced configs
    (fewer stages) silently mis-shape the loss targets.
    """
    n = max(cfg.n_layers, 3)  # template forces >= 3 stages
    return [img_size // (1 << (n - 2)), img_size // (1 << (n - 1)), img_size // (1 << n)]


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def forward(params, images, cfg):
    """images (B, H, W, 3) -> list of 3 raw head outputs (B, S, S, A, 5+C)."""
    A, C = cfg.n_heads, cfg.vocab_size
    x = jax.nn.leaky_relu(_conv(images, params["stem"]), 0.1)
    feats = []
    for st in params["stages"]:
        x = jax.nn.leaky_relu(_conv(x, st["down"], stride=2), 0.1)
        h = jax.nn.leaky_relu(_conv(x, st["res1"]), 0.1)
        x = x + jax.nn.leaky_relu(_conv(h, st["res2"]), 0.1)
        feats.append(x)
    outs = []
    for f, head in zip(feats[-3:], params["heads"]):
        o = _conv(f, head)
        B, S1, S2, _ = o.shape
        outs.append(o.reshape(B, S1, S2, A, 5 + C))
    return outs


def decode_boxes(raw, anchors):
    """raw (B,S,S,A,5+C) -> boxes (x,y,w,h) normalized, conf, class probs."""
    B, S, _, A, _ = raw.shape
    gy, gx = jnp.meshgrid(jnp.arange(S), jnp.arange(S), indexing="ij")
    anc = jnp.asarray(anchors)  # (A, 2)
    xy = (jax.nn.sigmoid(raw[..., 0:2]) + jnp.stack([gx, gy], -1)[:, :, None, :]) / S
    wh = anc[None, None, None] * jnp.exp(jnp.clip(raw[..., 2:4], -6, 6))
    conf = jax.nn.sigmoid(raw[..., 4])
    cls = jax.nn.sigmoid(raw[..., 5:])
    return jnp.concatenate([xy, wh], -1), conf, cls


def iou(box_a, box_b):
    """Broadcasting IOU of (..., 4) center-format (x, y, w, h) boxes.

    Leading dims broadcast like any jnp op — same-shape arrays give the
    element-wise IOU the Eq. 4 loss needs; (..., N, 1, 4) against
    (..., 1, M, 4) gives the (..., N, M) pairwise matrix (see
    :func:`pairwise_iou`). Zero/negative-area degenerate boxes score 0
    against everything. This is the one IOU definition in the repo: the
    loss, the eval engine (core.detection), and the Pallas kernels
    (kernels.detect / kernels.ref) all share its corner math.
    """
    ax1, ay1 = box_a[..., 0] - box_a[..., 2] * 0.5, box_a[..., 1] - box_a[..., 3] * 0.5
    ax2, ay2 = box_a[..., 0] + box_a[..., 2] * 0.5, box_a[..., 1] + box_a[..., 3] * 0.5
    bx1, by1 = box_b[..., 0] - box_b[..., 2] * 0.5, box_b[..., 1] - box_b[..., 3] * 0.5
    bx2, by2 = box_b[..., 0] + box_b[..., 2] * 0.5, box_b[..., 1] + box_b[..., 3] * 0.5
    ix = jnp.maximum(jnp.minimum(ax2, bx2) - jnp.maximum(ax1, bx1), 0.0)
    iy = jnp.maximum(jnp.minimum(ay2, by2) - jnp.maximum(ay1, by1), 0.0)
    inter = jnp.maximum(ix * iy, 0.0)
    area_a = jnp.maximum((ax2 - ax1) * (ay2 - ay1), 0.0)
    area_b = jnp.maximum((bx2 - bx1) * (by2 - by1), 0.0)
    union = area_a + area_b - inter
    return inter / jnp.maximum(union, 1e-9)


def pairwise_iou(boxes_a, boxes_b):
    """(..., N, 4) x (..., M, 4) -> (..., N, M) via the shared :func:`iou`.

    The jnp formulation of kernels.detect.pairwise_iou — small-shape
    call sites (loss-side anchor matching, tests) that don't warrant a
    kernel launch use this one.
    """
    return iou(boxes_a[..., :, None, :], boxes_b[..., None, :, :])


def yolo_loss(params, batch, cfg):
    """Paper Eqs 2-4. batch: images + per-scale targets.

    targets[s]: {"obj" (B,S,S,A), "box" (B,S,S,A,4), "cls" (B,S,S,A,C)}.
    """
    outs = forward(params, batch["images"], cfg)
    total = jnp.float32(0)
    metrics = {}
    for s, (raw, anchors) in enumerate(zip(outs, ANCHORS)):
        tgt = batch["targets"][s]
        obj = tgt["obj"].astype(jnp.float32)
        noobj = 1.0 - obj
        boxes, conf, cls = decode_boxes(raw.astype(jnp.float32), anchors)
        # Eq. 2: class prediction loss on object cells
        l_cls = jnp.sum(obj[..., None] * (tgt["cls"] - cls) ** 2)
        # Eq. 3: bounding-box coordinate loss
        d = (tgt["box"] - boxes) ** 2
        l_box = LAMBDA_COORD * jnp.sum(obj * (d[..., 0] + d[..., 1])) + LAMBDA_COORD * jnp.sum(
            obj * (d[..., 2] + d[..., 3])
        )
        # Eq. 4: confidence; theta = p(obj) * IOU(pred, gt)
        theta = obj * jax.lax.stop_gradient(iou(boxes, tgt["box"]))
        l_conf = jnp.sum(obj * (theta - conf) ** 2) + LAMBDA_NOOBJ * jnp.sum(
            noobj * (theta - conf) ** 2
        )
        total = total + l_cls + l_box + l_conf
        metrics[f"scale{s}/cls"] = l_cls
        metrics[f"scale{s}/box"] = l_box
        metrics[f"scale{s}/conf"] = l_conf
    n = batch["images"].shape[0]
    return total / n, metrics
