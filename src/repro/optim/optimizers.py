"""Pure-JAX optimizers (optax-style init/update pairs).

SGD+momentum is the paper's local trainer (YOLOv3/Darknet convention);
AdamW is used for the LM architectures. Optimizer state trees mirror the
parameter tree, so the federated client-stacking and sharding rules apply
to them unchanged.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]
    name: str = "opt"


def clip_by_global_norm(grads: PyTree, max_norm: float) -> PyTree:
    norm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads)


def sgd(lr: float = 1e-2, momentum: float = 0.9, clip_norm: float = 10.0) -> Optimizer:
    if momentum == 0.0:
        # stateless: no velocity tree at all. The streaming async flush
        # (DESIGN.md §13) requires this — it keeps no per-client optimizer
        # rows, so the local trainer must carry nothing between rounds.
        def init0(params):
            return {}

        def update0(params, grads, state):
            if clip_norm:
                grads = clip_by_global_norm(grads, clip_norm)
            params = jax.tree.map(
                lambda p, g: p - (lr * g.astype(p.dtype)).astype(p.dtype), params, grads
            )
            return params, {}

        return Optimizer(init0, update0, "sgd")

    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params)}

    def update(params, grads, state):
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        mu = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), state["mu"], grads)
        params = jax.tree.map(lambda p, m: p - (lr * m).astype(p.dtype), params, mu)
        return params, {"mu": mu}

    return Optimizer(init, update, "sgd")


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.0, clip_norm: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(params, grads, state):
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        t = state["t"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd(p, m_, v_):
            step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                step = step + lr * weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - step).astype(p.dtype)

        params = jax.tree.map(upd, params, m, v)
        return params, {"m": m, "v": v, "t": t}

    return Optimizer(init, update, "adamw")
