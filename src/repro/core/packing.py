"""Packed-buffer transport for the aggregation engine (DESIGN.md §7, §11).

The packed ``(C, N_total)`` buffer is the *canonical round state* of the
flat engine (DESIGN.md §11): ``state["params"]`` IS this buffer, clients
train on per-leaf views reconstructed from the :class:`PackSpec` slots
(`unpack_views` — reshape-of-slice, fused into consumers under jit), and
trained leaves are written back in place with `write_slots` (donated-buffer
dynamic-update-slices). ``pack`` / ``unpack`` survive only at the edges:
``make_state``, checkpoint PUT, and model dispatch to serving.

Layer buckets reuse `compression.leaf_layer_ids`: each slot of the buffer
spans a contiguous range of Eq. 6 score buckets (scan-stacked layers map to
one bucket per layer; all unstacked tensors share the final "misc" bucket).
The bucket structure is kept *slot-wise* (offset + bucket count per leaf)
rather than as a materialized per-element id vector, so building a
``PackSpec`` for a 314B-param arch costs nothing; the explicit ``(N,)`` id
vector is only materialized for the Pallas kernel path and benchmarks.

Reduction tiling (the CPU-reference side of the §11 re-tile): XLA CPU runs
ONE whole-buffer elementwise fusion multi-threaded, but serializes a
concat of many small per-slot fusions, and batched/sliced dot_generals
transpose-copy their operands. The reducers below therefore lower to a
small number of fused multiply-add chains over *maximal merged runs* of
slots (`merged_runs`), with the 1/den division folded into the per-bucket
weights so no (C, N) weight or intermediate buffer ever materializes.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import compression as comp
from repro.models.params import is_info

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    name: str  # keystr path, for debugging/benchmarks
    shape: tuple[int, ...]  # per-client leaf shape (no leading C)
    offset: int  # element offset into the packed buffer
    size: int  # number of elements
    bucket_off: int  # first Eq.6 score bucket this slot touches
    n_buckets: int  # contiguous buckets spanned (layers, or 1 for misc)

    @property
    def per_bucket(self) -> int:
        return self.size // self.n_buckets


@dataclasses.dataclass(frozen=True)
class PackSpec:
    n_total: int
    n_buckets: int  # total score buckets (cfg.n_layers + 1)
    slots: tuple[LeafSlot, ...]


def build_pack_spec(cfg, template: PyTree) -> PackSpec:
    """Flatten the param template into slot metadata (trace-time, cheap)."""
    leaves = jax.tree_util.tree_flatten_with_path(template, is_leaf=is_info)[0]
    slots: list[LeafSlot] = []
    off = 0
    for path, info in leaves:
        size = max(math.prod(info.shape), 1)
        kind, boff = comp.leaf_layer_ids(path, info, cfg)
        if kind == "stack2":
            nb = info.shape[0] * info.shape[1]
        elif kind == "stack1":
            nb = info.shape[0]
        else:
            nb = 1
        slots.append(LeafSlot(jax.tree_util.keystr(path), tuple(info.shape), off, size, boff, nb))
        off += size
    return PackSpec(off, comp.n_score_buckets(cfg), tuple(slots))


def packed_pspec(spec: PackSpec, client_axis: str, mesh=None, axis_sizes: dict | None = None):
    """PartitionSpec for the (C, N_total) buffer: client dim on the client
    axis, flat dim sharded over the "model" axis when it exists and divides
    N_total (restores per-device memory scaling for the persistent packed
    state of quant8 at FSDP scale), else replicated."""
    from jax.sharding import PartitionSpec as P

    from repro.models.params import PROD_AXIS_SIZES

    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    else:
        sizes = PROD_AXIS_SIZES if axis_sizes is None else axis_sizes
    if "model" in sizes and spec.n_total % sizes["model"] == 0:
        return P(client_axis, "model")
    return P(client_axis, None)


@functools.lru_cache(maxsize=16)
def bucket_ids(spec: PackSpec) -> np.ndarray:
    """Explicit (N_total,) int32 bucket id per element — Pallas/bench path
    only; the jnp reference path never materializes it."""
    return np.concatenate(
        [
            np.repeat(np.arange(s.n_buckets, dtype=np.int32) + s.bucket_off, s.per_bucket)
            for s in spec.slots
        ]
    )


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack(spec: PackSpec, stacked: PyTree, dtype=None) -> jax.Array:
    """Client-stacked pytree -> one (C, N_total) buffer (one concat/round).

    With dtype=None the buffer takes the promoted dtype of all leaves, so a
    mixed-precision tree (bf16 weights + f32 norms) packs without rounding
    any leaf; unpack casts each slot back to its own dtype.
    """
    leaves = jax.tree.leaves(stacked)
    C = leaves[0].shape[0]
    if dtype is None:
        dtype = functools.reduce(jnp.promote_types, (x.dtype for x in leaves))
    return jnp.concatenate([x.reshape(C, -1).astype(dtype) for x in leaves], axis=1)


def unpack(spec: PackSpec, packed: jax.Array, like: PyTree) -> PyTree:
    """(C, N_total) buffer -> pytree shaped/dtyped like `like`."""
    leaves, treedef = jax.tree.flatten(like)
    C = packed.shape[0]
    out = [
        packed[:, s.offset : s.offset + s.size].reshape((C,) + s.shape).astype(l.dtype)
        for s, l in zip(spec.slots, leaves)
    ]
    return jax.tree.unflatten(treedef, out)


def unpack_views(spec: PackSpec, packed: jax.Array, like: PyTree) -> PyTree:
    """Per-leaf *views* of the packed round state: reshape-of-slice only.

    The flat engine's replacement for `unpack` inside the jitted round: each
    leaf is ``packed[:, off:off+size].reshape((C,) + shape)`` in the buffer's
    own dtype, so XLA fuses the slice into whatever consumes the leaf — no
    (C, N_total) copy materializes on the round boundary. `like` supplies
    only the tree structure (a ParamInfo template or any matching pytree);
    dtype-converting reconstruction is `unpack`'s job and stays at the edges.
    """
    from repro.models.params import is_info

    treedef = jax.tree.structure(like, is_leaf=is_info)
    C = packed.shape[0]
    out = [
        jax.lax.slice_in_dim(packed, s.offset, s.offset + s.size, axis=1).reshape((C,) + s.shape)
        for s in spec.slots
    ]
    return jax.tree.unflatten(treedef, out)


def write_slots(spec: PackSpec, packed: jax.Array, stacked: PyTree) -> jax.Array:
    """Write trained leaves back into the packed buffer (unpack_views'
    inverse). One dynamic-update-slice per slot; under the donated round jit
    XLA aliases these into the incoming buffer, so the write-back is the
    only data movement on the round boundary — there is no pack concat."""
    C = packed.shape[0]
    for s, leaf in zip(spec.slots, jax.tree.leaves(stacked)):
        packed = jax.lax.dynamic_update_slice(
            packed, leaf.reshape(C, s.size).astype(packed.dtype), (0, s.offset)
        )
    return packed


# ---------------------------------------------------------------------------
# reduction tiling: maximal merged runs of uniform-width buckets
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=16)
def merged_runs(spec: PackSpec) -> tuple[tuple[int, int, int, int], ...]:
    """Maximal contiguous (column, bucket) runs with one per-bucket width.

    Each run ``(col0, bucket0, n_buckets, per)`` satisfies
    ``bucket(col0 + i) == bucket0 + i // per``: adjacent slots merge when
    both their columns and their bucket ranges continue the run (scan-stacked
    leaves of one tensor; same-shape misc tensors do NOT merge — they share
    one bucket). The fused reducers iterate runs, not slots, so a uniform
    32-leaf buffer is ONE multiply-add chain XLA can thread across.
    """
    runs: list[tuple[int, int, int, int]] = []
    for s in spec.slots:
        if runs:
            col0, b0, nb, per = runs[-1]
            if (
                per == s.per_bucket
                and s.offset == col0 + nb * per
                and s.bucket_off == b0 + nb
            ):
                runs[-1] = (col0, b0, nb + s.n_buckets, per)
                continue
        runs.append((s.offset, s.bucket_off, s.n_buckets, s.per_bucket))
    return tuple(runs)


# clients beyond this fall back to contraction ops: the fused chains unroll
# one multiply-add per client. Measured on the CPU reference (N=262k, B=32):
# the chain's RUNTIME still wins to C~128 (97ms vs 181ms einsum at C=128),
# but its compile time grows with the unroll (6s at C=512, 16s at C=1024 vs
# a flat 1.5s for the contraction) — 64 is where the remaining runtime edge
# stops paying for the trace/compile blow-up at federation scale.
CHAIN_MAX_CLIENTS = 64


# ---------------------------------------------------------------------------
# bucket <-> element maps (no N-sized constants: slot-wise broadcasts)
# ---------------------------------------------------------------------------

def expand_bucket_vec(spec: PackSpec, vec: jax.Array) -> jax.Array:
    """(..., n_buckets) bucket vector -> (..., N_total) per-element vector.

    Iterates `merged_runs`, not slots: a uniform buffer expands as ONE
    broadcast instead of one slice/broadcast/concat triple per leaf."""
    parts = []
    for (_, b0, nb, per) in merged_runs(spec):
        v = jax.lax.slice_in_dim(vec, b0, b0 + nb, axis=-1)
        v = jnp.broadcast_to(v[..., None], v.shape + (per,))
        parts.append(v.reshape(v.shape[:-2] + (nb * per,)))
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=-1)


def bucket_sums(spec: PackSpec, packed: jax.Array) -> jax.Array:
    """Per-bucket signed element sums: (C, N_total) -> (C, n_buckets) f32.

    Packed-buffer equivalent of `compression.layer_sums` (Eq. 6 inner sums),
    vectorized over the client dim.
    """
    C = packed.shape[0]
    out = jnp.zeros((C, spec.n_buckets), jnp.float32)
    for s in spec.slots:
        x = packed[:, s.offset : s.offset + s.size].astype(jnp.float32)
        sums = x.reshape(C, s.n_buckets, s.per_bucket).sum(axis=-1)
        out = out.at[:, s.bucket_off : s.bucket_off + s.n_buckets].add(sums)
    return out


# ---------------------------------------------------------------------------
# the one masked/weighted reduction every mode lowers to
# ---------------------------------------------------------------------------

def weighted_mean(packed: jax.Array, weights: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Unmasked Eq. 5 over the flat buffer: (C, N), (C,) -> (N,) f32.

    The fast path for modes whose upload mask is uniform across buckets
    (dense, server-optimizer). `mask` is the optional (C,) 0/1 participation
    vector from the scheduler — masked-out client rows drop from both
    numerator and denominator. The 1/sum(w) normalization is folded into the
    per-client weights, so the reduction is a single whole-buffer fused
    multiply-add chain (one threaded XLA fusion; see module docstring) for
    small C, or one contraction beyond CHAIN_MAX_CLIENTS.
    """
    C = packed.shape[0]
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    wn = w / jnp.maximum(jnp.sum(w), 1e-12)
    if C > CHAIN_MAX_CLIENTS:
        return jnp.einsum("c,cn->n", wn, packed.astype(jnp.float32))
    acc = packed[0].astype(jnp.float32) * wn[0]
    for c in range(1, C):
        acc = acc + packed[c].astype(jnp.float32) * wn[c]
    return acc


def grouped_weighted_mean(
    packed: jax.Array,
    weights: jax.Array,
    group_size: int,
    mask: jax.Array | None = None,
    *,
    impl: str = "ref",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Per-group renormalized Eq. 5 — the hierarchical inner reduce.

    packed (C, N), weights (C,), C % group_size == 0 ->
    (rows (C/G, N) f32, den (C/G,) f32) with
    ``rows[g] = sum_i w[gG+i] x[gG+i] / den[g]`` and
    ``den[g] = sum_i w[gG+i]`` (mask folded in). A group nobody in
    participated has den 0 and a zero row — callers must mask it out of the
    outer reduce (`aggregators/hier.py` does). The 1/den renormalization is
    folded into the per-member weights exactly like `weighted_mean`, so each
    group is one fused multiply-add chain over its members (G <= cutover) or
    the whole buffer is ONE batched contraction (G above it).
    """
    C, N = packed.shape
    G = group_size
    if G < 1 or C % G:
        raise ValueError(f"group_size={G} must divide n_clients={C}")
    ngroups = C // G
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    wg = w.reshape(ngroups, G)
    den = jnp.sum(wg, axis=1)  # (C/G,)
    wn = wg / jnp.maximum(den, 1e-12)[:, None]
    if impl == "pallas":
        from repro.kernels import pack as _pk  # deferred: kernels are optional here

        return _pk.grouped_reduce(packed, wn, interpret=interpret), den
    xg = packed.astype(jnp.float32).reshape(ngroups, G, N)
    if G > CHAIN_MAX_CLIENTS:
        return jnp.einsum("gi,gin->gn", wn, xg), den
    acc = xg[:, 0] * wn[:, 0][:, None]
    for i in range(1, G):
        acc = acc + xg[:, i] * wn[:, i][:, None]
    return acc, den


def masked_bucket_mean(
    packed: jax.Array,
    wmask: jax.Array,
    spec: PackSpec,
    mask: jax.Array | None = None,
    *,
    impl: str = "ref",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Weighted mean over clients under a per-(client, bucket) mask.

    packed: (C, N); wmask: (C, B) — participation weight times the 0/1
    upload mask per score bucket; mask: optional (C,) 0/1 participation
    vector (None -> everyone). Returns (global (N,) f32, den (B,) f32):
    ``global[n] = sum_c mask[c] wmask[c, b(n)] x[c, n] / den[b(n)]`` with
    ``den[b] = sum_c mask[c] wmask[c, b]`` (0 where nobody uploaded). den is
    the per-BUCKET denominator — expand with `expand_bucket_vec` (consumers
    fuse the expansion into their own passes; a materialized (N,) den would
    cost the reduction an extra write pass for pure bookkeeping).

    The ref impl folds 1/den into the per-bucket weights and runs one fused
    multiply-add chain per `merged_runs` tile — no (C, N) weight expansion,
    no per-slot dot_generals (XLA CPU transpose-copies their operands), and
    the division costs no extra pass over the buffer.
    """
    C = packed.shape[0]
    wm = wmask.astype(jnp.float32)
    if mask is not None:
        wm = wm * mask.astype(jnp.float32)[:, None]
    den_b = jnp.sum(wm, axis=0)  # (B,)
    if impl == "pallas":
        from repro.kernels import pack as _pk  # deferred: kernels are optional here

        ids = jnp.asarray(bucket_ids(spec))
        # the tile bound MUST be computed for the kernel's actual N-block
        # width — a wider block spans more buckets than a narrower bound
        # and the out-of-window ids would silently one-hot to zero
        num, den = _pk.packed_bucket_reduce(
            packed, wmask, ids, mask,
            interpret=interpret, bucket_tile=bucket_tile_bound(spec, _pk.BLOCK_N),
        )
        return num / jnp.maximum(den, 1e-12), den_b
    wn = wm / jnp.maximum(den_b, 1e-12)[None, :]
    runs = merged_runs(spec)
    if C > CHAIN_MAX_CLIENTS:
        parts = [
            jnp.einsum(
                "cb,cbp->bp",
                jax.lax.slice_in_dim(wn, b0, b0 + nb, axis=1),
                packed[:, col0 : col0 + nb * per].astype(jnp.float32).reshape(C, nb, per),
            ).reshape(nb * per)
            for (col0, b0, nb, per) in runs
        ]
    else:
        parts = []
        for (col0, b0, nb, per) in runs:
            xs = jax.lax.slice_in_dim(packed, col0, col0 + nb * per, axis=1)
            xs = xs.astype(jnp.float32).reshape(C, nb, per)
            wt = jax.lax.slice_in_dim(wn, b0, b0 + nb, axis=1)  # (C, nb)
            acc = xs[0] * wt[0][:, None]
            for c in range(1, C):
                acc = acc + xs[c] * wt[c][:, None]
            parts.append(acc.reshape(nb * per))
    g = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    return g, den_b


@functools.lru_cache(maxsize=16)
def bucket_tile_bound(spec: PackSpec, block_n: int = 1024) -> int:
    """Max distinct buckets any block_n-aligned window of the packed buffer
    touches (padding id B included) — the Pallas kernel's bucket-tile width.
    Host-side and cached: derived from slot metadata via the id vector."""
    ids = bucket_ids(spec)
    pad = (-len(ids)) % block_n
    if pad:
        ids = np.concatenate([ids, np.full(pad, spec.n_buckets, np.int32)])
    win = ids.reshape(-1, block_n)
    # ids need not be monotonic across slot boundaries (a later slot can
    # restart at bucket 0), so the span is max - min per window
    return int((win.max(axis=1) - win.min(axis=1)).max()) + 1


# ---------------------------------------------------------------------------
# row-block int8 quantization of the packed buffer (quant8 transport)
# ---------------------------------------------------------------------------

def quantize_rows_ref(x: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """(C, N) f32 -> (q int8 (C, N), scales f32 (C, ceil(N/block)))."""
    C, N = x.shape
    pad = (-N) % block
    xb = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad))).reshape(C, -1, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(C, -1)[:, :N], scale


def dequantize_rows_ref(q: jax.Array, scales: jax.Array, block: int, dtype=jnp.float32) -> jax.Array:
    C, N = q.shape
    pad = (-N) % block
    qb = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, pad))).reshape(C, -1, block)
    return (qb * scales[..., None]).reshape(C, -1)[:, :N].astype(dtype)


def quant8_mean_ref(delta: jax.Array, weights: jax.Array, block: int) -> jax.Array:
    """Fused quant8 encode -> reduce: (C, N), (C,) -> (N,) f32 weighted sum
    of dequant(quant(delta)) with NO materialized int8 payload or (C, N)
    dequant buffer. ``clip(round(x/s), -127, 127)`` in f32 is bit-identical
    to the int8 round-trip (|q| <= 127 is exact in f32), so this is the
    collective-free transport path: per-client dequantized rows feed one
    fused multiply-add chain. Weights are used as-is (the scheduler
    normalizes them); fold the participation mask in before calling.
    """
    C, N = delta.shape
    pad = (-N) % block
    x = jnp.pad(delta.astype(jnp.float32), ((0, 0), (0, pad)))
    w = weights.astype(jnp.float32)

    def dq(row):  # (N+pad,) -> dequantized (N+pad,) f32
        xb = row.reshape(-1, block)
        amax = jnp.max(jnp.abs(xb), axis=-1)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127)
        return (q * scale[:, None]).reshape(-1)

    if C > CHAIN_MAX_CLIENTS:
        acc = jnp.einsum("c,cn->n", w, jax.vmap(dq)(x))
    else:
        acc = dq(x[0]) * w[0]
        for c in range(1, C):
            acc = acc + dq(x[c]) * w[c]
    return acc[:N] if pad else acc


def dequant_reduce_ref(q: jax.Array, scales: jax.Array, weights: jax.Array, block: int) -> jax.Array:
    """Fused decode -> reduce for the gathered int8 transport: (C, N) int8 +
    (C, ceil(N/block)) scales + (C,) weights -> (N,) f32 weighted sum,
    without materializing the (C, N) f32 dequant buffer."""
    C, N = q.shape
    pad = (-N) % block
    qp = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, pad)))
    w = weights.astype(jnp.float32)

    def dq(row, s):
        return (row.reshape(-1, block) * s[:, None]).reshape(-1)

    if C > CHAIN_MAX_CLIENTS:
        acc = jnp.einsum("c,cn->n", w, jax.vmap(dq)(qp, scales))
    else:
        acc = dq(qp[0], scales[0]) * w[0]
        for c in range(1, C):
            acc = acc + dq(qp[c], scales[c]) * w[c]
    return acc[:N] if pad else acc


# ---------------------------------------------------------------------------
# communication frontier (DESIGN.md §15): counter PRNG, 4-bit transport,
# pairwise integer masking — jnp twins of the kernels.ref NumPy oracles
# ---------------------------------------------------------------------------

# constants shared bit-for-bit with kernels.ref (the NumPy oracles) and the
# kernels.quant4 / kernels.mask Pallas bodies
FMIX_C1 = 0x85EBCA6B
FMIX_C2 = 0xC2B2AE35
GOLDEN = 0x9E3779B9
IDX_C = 0x9E3779B1
IDX_N = 0x85EBCA77
IDX_E = 0xC2B2AE3D


def fmix32(h: jax.Array) -> jax.Array:
    """murmur3 fmix32 over uint32 lanes (ref.fmix32_np's traced twin)."""
    h = jnp.asarray(h).astype(jnp.uint32)
    h = h ^ (h >> 16)
    h = h * jnp.uint32(FMIX_C1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(FMIX_C2)
    h = h ^ (h >> 16)
    return h


def round_key(seed, round_idx) -> jax.Array:
    """Per-round PRNG key from a static session seed and the TRACED round
    counter carried in agg_state — the key is a traced uint32 scalar, so
    per-round randomness never retraces the jitted round."""
    r = jnp.asarray(round_idx).astype(jnp.uint32)
    return fmix32(jnp.uint32(seed & 0xFFFFFFFF) ^ fmix32(r + jnp.uint32(GOLDEN)))


def counter_uniform(key, c_idx, n_idx) -> jax.Array:
    """u in [0, 1) f32 from the (client, element) counter hash; c_idx and
    n_idx broadcast (uint32)."""
    bits = fmix32(
        jnp.asarray(key).astype(jnp.uint32)
        + jnp.asarray(c_idx).astype(jnp.uint32) * jnp.uint32(IDX_C)
        + jnp.asarray(n_idx).astype(jnp.uint32) * jnp.uint32(IDX_N)
    )
    return (bits >> 8).astype(jnp.float32) * jnp.float32(2.0**-24)


def _quant4_dq_block(xb: jax.Array, u, mode: str) -> jax.Array:
    """(nb, block) f32 -> dequant(quant4) per block. u: matching uniforms
    for stochastic mode (ignored for nearest). Clip AFTER the floor: in f32
    7 + u can round to 8.0."""
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 7.0
    v = xb / scale[..., None]
    if mode == "nearest":
        q = jnp.clip(jnp.round(v), -7, 7)
    else:
        q = jnp.clip(jnp.floor(v + u), -7, 7)
    return q * scale[..., None]


def quant4_dequant_rows_ref(x: jax.Array, block: int, key=0, mode: str = "nearest") -> jax.Array:
    """(C, N) -> (C, N) f32 dequant(quant4(x)) per client row — the value a
    client uploads under 4-bit transport (topk_ef x quant4 composition)."""
    C, N = x.shape
    pad = (-N) % block
    xp = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad))).reshape(C, -1, block)
    if mode == "stochastic":
        u = counter_uniform(
            key,
            jnp.arange(C, dtype=jnp.uint32)[:, None],
            jnp.arange(N + pad, dtype=jnp.uint32)[None, :],
        ).reshape(C, -1, block)
    else:
        u = jnp.zeros_like(xp)
    return _quant4_dq_block(xp, u, mode).reshape(C, -1)[:, :N]


def quant4_mean_ref(delta: jax.Array, weights: jax.Array, block: int, key=0, mode: str = "nearest") -> jax.Array:
    """Fused 4-bit encode -> reduce (quant8_mean_ref's 4-bit sibling):
    (C, N), (C,) -> (N,) f32 weighted sum of dequant(quant4(delta)) with no
    materialized payload. Weights are used as-is; fold the participation
    mask in before calling. ref.quant4_reduce_np is the NumPy oracle."""
    C, N = delta.shape
    pad = (-N) % block
    x = jnp.pad(delta.astype(jnp.float32), ((0, 0), (0, pad)))
    w = weights.astype(jnp.float32)
    nidx = jnp.arange(N + pad, dtype=jnp.uint32)

    def dq(row, c):
        xb = row.reshape(-1, block)
        if mode == "stochastic":
            u = counter_uniform(key, c, nidx).reshape(-1, block)
        else:
            u = jnp.zeros_like(xb)
        return _quant4_dq_block(xb, u, mode).reshape(-1)

    if C > CHAIN_MAX_CLIENTS:
        acc = jnp.einsum(
            "c,cn->n", w, jax.vmap(dq)(x, jnp.arange(C, dtype=jnp.uint32))
        )
    else:
        acc = dq(x[0], jnp.uint32(0)) * w[0]
        for c in range(1, C):
            acc = acc + dq(x[c], jnp.uint32(c)) * w[c]
    return acc[:N] if pad else acc


def secure_client_masks(rk, participation: jax.Array, n: int) -> jax.Array:
    """(C,) 0/1 participation -> (C, n) uint32 pairwise-mask sums.

    Client c carries sum_{p>c} m_cp - sum_{p<c} m_pc over ACTIVE pairs
    (both endpoints selected), all mod 2^32, so the masks cancel EXACTLY in
    the active-row modular sum — not to float tolerance. A deselected
    client activates no pair, so it contributes no orphan mask. O(C^2 n)
    like any pairwise scheme; the secure aggregator bounds C at build time.
    ref.secure_masked_rows_np is the oracle twin."""
    act = participation.astype(jnp.float32) > 0
    C = act.shape[0]
    cidx = jnp.arange(C, dtype=jnp.uint32)
    nidx = jnp.arange(n, dtype=jnp.uint32)
    M = jnp.zeros((C, n), jnp.uint32)
    for p in range(C):
        pu = jnp.uint32(p)
        lo = jnp.minimum(cidx, pu)
        hi = jnp.maximum(cidx, pu)
        pk = fmix32(fmix32(jnp.asarray(rk).astype(jnp.uint32) + lo * jnp.uint32(IDX_C)) ^ (hi * jnp.uint32(IDX_N)))
        bits = fmix32(pk[:, None] + nidx[None, :] * jnp.uint32(IDX_E))  # (C, n)
        signed = jnp.where((cidx < pu)[:, None], bits, jnp.uint32(0) - bits)
        active = act & act[p] & (cidx != pu)
        M = M + jnp.where(active[:, None], signed, jnp.uint32(0))
    return M


def secure_sum_ref(q: jax.Array, participation: jax.Array, rk, *, use_masks: bool = True) -> jax.Array:
    """q (C, N) int32 -> (N,) int32 sum over participating rows, optionally
    through pairwise uint32 masking. Bitwise-equal either way: the masks
    cancel exactly in the modular sum (ref.secure_sum_np oracle)."""
    act = participation.astype(jnp.float32) > 0
    rows = jax.lax.bitcast_convert_type(q.astype(jnp.int32), jnp.uint32)
    if use_masks:
        rows = rows + secure_client_masks(rk, participation, q.shape[1])
    gated = jnp.where(act[:, None], rows, jnp.uint32(0))
    total = jnp.sum(gated, axis=0, dtype=jnp.uint32)
    return jax.lax.bitcast_convert_type(total, jnp.int32)
