"""Packed-buffer transport for the aggregation engine (DESIGN.md §7).

The server's hot loop used to aggregate a client-stacked param *pytree*:
every mode walked the tree with `tree_map`, launching one (padded) reduction
per leaf. This module packs the whole tree once per round into a single
contiguous ``(C, N_total)`` buffer with a precomputed layer-bucket map, so
every aggregation mode becomes one masked/weighted reduction over one flat
buffer — a single tiled kernel launch — and the int8 transport quantizes one
buffer instead of per-leaf fragments.

Layer buckets reuse `compression.leaf_layer_ids`: each slot of the buffer
spans a contiguous range of Eq. 6 score buckets (scan-stacked layers map to
one bucket per layer; all unstacked tensors share the final "misc" bucket).
The bucket structure is kept *slot-wise* (offset + bucket count per leaf)
rather than as a materialized per-element id vector, so building a
``PackSpec`` for a 314B-param arch costs nothing; the explicit ``(N,)`` id
vector is only materialized for the Pallas kernel path and benchmarks.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import compression as comp
from repro.models.params import is_info

PyTree = Any


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    name: str  # keystr path, for debugging/benchmarks
    shape: tuple[int, ...]  # per-client leaf shape (no leading C)
    offset: int  # element offset into the packed buffer
    size: int  # number of elements
    bucket_off: int  # first Eq.6 score bucket this slot touches
    n_buckets: int  # contiguous buckets spanned (layers, or 1 for misc)

    @property
    def per_bucket(self) -> int:
        return self.size // self.n_buckets


@dataclasses.dataclass(frozen=True)
class PackSpec:
    n_total: int
    n_buckets: int  # total score buckets (cfg.n_layers + 1)
    slots: tuple[LeafSlot, ...]


def build_pack_spec(cfg, template: PyTree) -> PackSpec:
    """Flatten the param template into slot metadata (trace-time, cheap)."""
    leaves = jax.tree_util.tree_flatten_with_path(template, is_leaf=is_info)[0]
    slots: list[LeafSlot] = []
    off = 0
    for path, info in leaves:
        size = max(math.prod(info.shape), 1)
        kind, boff = comp.leaf_layer_ids(path, info, cfg)
        if kind == "stack2":
            nb = info.shape[0] * info.shape[1]
        elif kind == "stack1":
            nb = info.shape[0]
        else:
            nb = 1
        slots.append(LeafSlot(jax.tree_util.keystr(path), tuple(info.shape), off, size, boff, nb))
        off += size
    return PackSpec(off, comp.n_score_buckets(cfg), tuple(slots))


def packed_pspec(spec: PackSpec, client_axis: str, mesh=None, axis_sizes: dict | None = None):
    """PartitionSpec for the (C, N_total) buffer: client dim on the client
    axis, flat dim sharded over the "model" axis when it exists and divides
    N_total (restores per-device memory scaling for the persistent packed
    state of quant8 at FSDP scale), else replicated."""
    from jax.sharding import PartitionSpec as P

    from repro.models.params import PROD_AXIS_SIZES

    if mesh is not None:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    else:
        sizes = PROD_AXIS_SIZES if axis_sizes is None else axis_sizes
    if "model" in sizes and spec.n_total % sizes["model"] == 0:
        return P(client_axis, "model")
    return P(client_axis, None)


@functools.lru_cache(maxsize=16)
def bucket_ids(spec: PackSpec) -> np.ndarray:
    """Explicit (N_total,) int32 bucket id per element — Pallas/bench path
    only; the jnp reference path never materializes it."""
    return np.concatenate(
        [
            np.repeat(np.arange(s.n_buckets, dtype=np.int32) + s.bucket_off, s.per_bucket)
            for s in spec.slots
        ]
    )


# ---------------------------------------------------------------------------
# pack / unpack
# ---------------------------------------------------------------------------

def pack(spec: PackSpec, stacked: PyTree, dtype=None) -> jax.Array:
    """Client-stacked pytree -> one (C, N_total) buffer (one concat/round).

    With dtype=None the buffer takes the promoted dtype of all leaves, so a
    mixed-precision tree (bf16 weights + f32 norms) packs without rounding
    any leaf; unpack casts each slot back to its own dtype.
    """
    leaves = jax.tree.leaves(stacked)
    C = leaves[0].shape[0]
    if dtype is None:
        dtype = functools.reduce(jnp.promote_types, (x.dtype for x in leaves))
    return jnp.concatenate([x.reshape(C, -1).astype(dtype) for x in leaves], axis=1)


def unpack(spec: PackSpec, packed: jax.Array, like: PyTree) -> PyTree:
    """(C, N_total) buffer -> pytree shaped/dtyped like `like`."""
    leaves, treedef = jax.tree.flatten(like)
    C = packed.shape[0]
    out = [
        packed[:, s.offset : s.offset + s.size].reshape((C,) + s.shape).astype(l.dtype)
        for s, l in zip(spec.slots, leaves)
    ]
    return jax.tree.unflatten(treedef, out)


# ---------------------------------------------------------------------------
# bucket <-> element maps (no N-sized constants: slot-wise broadcasts)
# ---------------------------------------------------------------------------

def expand_bucket_vec(spec: PackSpec, vec: jax.Array) -> jax.Array:
    """(..., n_buckets) bucket vector -> (..., N_total) per-element vector."""
    parts = []
    for s in spec.slots:
        v = jax.lax.slice_in_dim(vec, s.bucket_off, s.bucket_off + s.n_buckets, axis=-1)
        v = jnp.broadcast_to(v[..., None], v.shape + (s.per_bucket,))
        parts.append(v.reshape(v.shape[:-2] + (s.size,)))
    return jnp.concatenate(parts, axis=-1)


def bucket_sums(spec: PackSpec, packed: jax.Array) -> jax.Array:
    """Per-bucket signed element sums: (C, N_total) -> (C, n_buckets) f32.

    Packed-buffer equivalent of `compression.layer_sums` (Eq. 6 inner sums),
    vectorized over the client dim.
    """
    C = packed.shape[0]
    out = jnp.zeros((C, spec.n_buckets), jnp.float32)
    for s in spec.slots:
        x = packed[:, s.offset : s.offset + s.size].astype(jnp.float32)
        sums = x.reshape(C, s.n_buckets, s.per_bucket).sum(axis=-1)
        out = out.at[:, s.bucket_off : s.bucket_off + s.n_buckets].add(sums)
    return out


# ---------------------------------------------------------------------------
# the one masked/weighted reduction every mode lowers to
# ---------------------------------------------------------------------------

def weighted_mean(packed: jax.Array, weights: jax.Array, mask: jax.Array | None = None) -> jax.Array:
    """Unmasked Eq. 5 over the flat buffer: (C, N), (C,) -> (N,) f32.

    The fast path for modes whose upload mask is uniform across buckets
    (dense, server-optimizer): one flat contraction, no bucket machinery.
    `mask` is the optional (C,) 0/1 participation vector from the scheduler
    — masked-out client rows drop from both numerator and denominator.
    """
    w = weights.astype(jnp.float32)
    if mask is not None:
        w = w * mask.astype(jnp.float32)
    num = jnp.einsum("c,cn->n", w, packed.astype(jnp.float32))
    return num / jnp.maximum(jnp.sum(w), 1e-12)


def masked_bucket_mean(
    packed: jax.Array,
    wmask: jax.Array,
    spec: PackSpec,
    mask: jax.Array | None = None,
    *,
    impl: str = "ref",
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Weighted mean over clients under a per-(client, bucket) mask.

    packed: (C, N); wmask: (C, B) — participation weight times the 0/1
    upload mask per score bucket; mask: optional (C,) 0/1 participation
    vector (None -> everyone). Returns (global (N,) f32, den (N,) f32):
    ``global[n] = sum_c mask[c] wmask[c, bucket(n)] x[c, n] / den[n]`` with
    ``den[n] = sum_c mask[c] wmask[c, bucket(n)]`` (0 where nobody uploaded).
    """
    if impl == "pallas":
        from repro.kernels import pack as _pk  # deferred: kernels are optional here

        ids = jnp.asarray(bucket_ids(spec))
        num, den = _pk.packed_bucket_reduce(packed, wmask, ids, mask, interpret=interpret)
    else:
        # slot-wise einsum: reads `packed` once and never materializes a
        # (C, N) weight buffer — each slot's buckets are contiguous, so the
        # per-bucket weights contract directly against (C, nb, per) views
        C = packed.shape[0]
        wm = wmask.astype(jnp.float32)
        if mask is not None:
            wm = wm * mask.astype(jnp.float32)[:, None]
        parts = []
        for s in spec.slots:
            x = packed[:, s.offset : s.offset + s.size].astype(jnp.float32)
            x = x.reshape(C, s.n_buckets, s.per_bucket)
            w = jax.lax.slice_in_dim(wm, s.bucket_off, s.bucket_off + s.n_buckets, axis=1)
            parts.append(jnp.einsum("cb,cbp->bp", w, x).reshape(s.size))
        num = jnp.concatenate(parts)
        den = expand_bucket_vec(spec, jnp.sum(wm, axis=0))
    return num / jnp.maximum(den, 1e-12), den


# ---------------------------------------------------------------------------
# row-block int8 quantization of the packed buffer (quant8 transport)
# ---------------------------------------------------------------------------

def quantize_rows_ref(x: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """(C, N) f32 -> (q int8 (C, N), scales f32 (C, ceil(N/block)))."""
    C, N = x.shape
    pad = (-N) % block
    xb = jnp.pad(x.astype(jnp.float32), ((0, 0), (0, pad))).reshape(C, -1, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(C, -1)[:, :N], scale


def dequantize_rows_ref(q: jax.Array, scales: jax.Array, block: int, dtype=jnp.float32) -> jax.Array:
    C, N = q.shape
    pad = (-N) % block
    qb = jnp.pad(q.astype(jnp.float32), ((0, 0), (0, pad))).reshape(C, -1, block)
    return (qb * scales[..., None]).reshape(C, -1)[:, :N].astype(dtype)
