"""Task Manager — coordinates concurrent federated training tasks.

Paper component #3: "when multiple model algorithms are being trained
concurrently by the clients, this component coordinates the concurrent
federated model training processes." Round-robin fair-share over registered
tasks with per-task state and status tracking.

Shared-clock mode (DESIGN.md §12): construct the manager with the
platform's `core.simclock.SimClock` and give tasks a ``next_time``
callback — the simulated time their next round would complete (an async
task reports its earliest queued completion,
`BufferedAsyncEngine.next_completion_time`; a sync task reports
``clock.now() + round_duration``, see `async_engine.sync_round_seconds`).
`step_shared_clock` then advances the ONE runnable task that finishes
earliest, so an async task's flushes interleave with sync tasks' rounds in
simulated-time order instead of lockstep round-robin. Each task's
``run_round`` is responsible for advancing the shared clock by the time it
consumed (the async engine does this internally).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable

from repro.core.simclock import SimClock


class TaskStatus(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    PAUSED = "paused"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass
class FederatedTask:
    task_id: str
    arch: str
    total_rounds: int
    run_round: Callable[[int], dict]  # round_idx -> metrics
    rounds_done: int = 0
    status: TaskStatus = TaskStatus.PENDING
    history: list = dataclasses.field(default_factory=list)
    # shared-clock mode: simulated completion time of this task's next
    # round; required on every task once the manager carries a SimClock
    # (step_shared_clock rejects None rather than starve clocked tasks)
    next_time: Callable[[], float] | None = None


class TaskManager:
    def __init__(self, clock: SimClock | None = None):
        self.tasks: dict[str, FederatedTask] = {}
        self.clock = clock

    def register(self, task: FederatedTask) -> None:
        if task.task_id in self.tasks:
            raise ValueError(f"duplicate task id {task.task_id}")
        self.tasks[task.task_id] = task

    def runnable(self) -> list[FederatedTask]:
        return [
            t
            for t in self.tasks.values()
            if t.status in (TaskStatus.PENDING, TaskStatus.RUNNING) and t.rounds_done < t.total_rounds
        ]

    def _advance(self, t: FederatedTask) -> dict[str, dict]:
        """Run one round of one task with the shared status bookkeeping."""
        out = {}
        t.status = TaskStatus.RUNNING
        try:
            metrics = t.run_round(t.rounds_done)
        except Exception as e:  # noqa: BLE001 - platform surface
            t.status = TaskStatus.FAILED
            out[t.task_id] = {"error": str(e)}
            return out
        t.rounds_done += 1
        t.history.append(metrics)
        out[t.task_id] = metrics
        if t.rounds_done >= t.total_rounds:
            t.status = TaskStatus.DONE
        return out

    def step_all(self) -> dict[str, dict]:
        """One fair-share scheduling pass: each runnable task advances one round."""
        out = {}
        for t in self.runnable():
            out.update(self._advance(t))
        return out

    def step_shared_clock(self) -> dict[str, dict]:
        """Advance the one runnable task whose next round completes earliest
        on the shared simulated clock (ties break by task id — the same
        determinism contract as the async engine's event queue).

        Every task needs a ``next_time``: a task without one would report
        "ready now" forever, always undercut the clocked tasks' future
        completion times, and silently serialize the interleave — better to
        fail loudly than to starve the clocked tasks."""
        if self.clock is None:
            raise RuntimeError("step_shared_clock needs a TaskManager(clock=SimClock())")
        cands = self.runnable()
        if not cands:
            return {}
        missing = [t.task_id for t in cands if t.next_time is None]
        if missing:
            raise RuntimeError(
                f"shared-clock scheduling needs next_time on every task; "
                f"missing on {missing} (use step_all for untimed tasks)"
            )
        return self._advance(min(cands, key=lambda t: (t.next_time(), t.task_id)))

    def run_to_completion(self, max_passes: int = 10_000) -> None:
        step = self.step_shared_clock if self.clock is not None else self.step_all
        for _ in range(max_passes):
            if not self.runnable():
                return
            step()
