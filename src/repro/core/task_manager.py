"""Task Manager — coordinates concurrent federated training tasks.

Paper component #3: "when multiple model algorithms are being trained
concurrently by the clients, this component coordinates the concurrent
federated model training processes." Round-robin fair-share over registered
tasks with per-task state and status tracking.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable


class TaskStatus(enum.Enum):
    PENDING = "pending"
    RUNNING = "running"
    PAUSED = "paused"
    DONE = "done"
    FAILED = "failed"


@dataclasses.dataclass
class FederatedTask:
    task_id: str
    arch: str
    total_rounds: int
    run_round: Callable[[int], dict]  # round_idx -> metrics
    rounds_done: int = 0
    status: TaskStatus = TaskStatus.PENDING
    history: list = dataclasses.field(default_factory=list)


class TaskManager:
    def __init__(self):
        self.tasks: dict[str, FederatedTask] = {}

    def register(self, task: FederatedTask) -> None:
        if task.task_id in self.tasks:
            raise ValueError(f"duplicate task id {task.task_id}")
        self.tasks[task.task_id] = task

    def runnable(self) -> list[FederatedTask]:
        return [
            t
            for t in self.tasks.values()
            if t.status in (TaskStatus.PENDING, TaskStatus.RUNNING) and t.rounds_done < t.total_rounds
        ]

    def step_all(self) -> dict[str, dict]:
        """One fair-share scheduling pass: each runnable task advances one round."""
        out = {}
        for t in self.runnable():
            t.status = TaskStatus.RUNNING
            try:
                metrics = t.run_round(t.rounds_done)
            except Exception as e:  # noqa: BLE001 - platform surface
                t.status = TaskStatus.FAILED
                out[t.task_id] = {"error": str(e)}
                continue
            t.rounds_done += 1
            t.history.append(metrics)
            out[t.task_id] = metrics
            if t.rounds_done >= t.total_rounds:
                t.status = TaskStatus.DONE
        return out

    def run_to_completion(self, max_passes: int = 10_000) -> None:
        for _ in range(max_passes):
            if not self.runnable():
                return
            self.step_all()
