"""Buffered asynchronous aggregation engine on a simulated wall clock
(DESIGN.md §12).

FedVision's clients are camera-edge devices whose *upload times*, not
FLOPs, dominate round latency — the sync engine (core/rounds.py) waits for
the slowest selected client every round, so one straggler sets the round
period for the whole federation. This module is the second round-control
plane over the same aggregator/packing substrate: a FedBuff-style buffered
engine where clients run free, updates land whenever their simulated
completion time arrives, and the server flushes a staleness-weighted
aggregate every ``FedConfig.buffer_size`` landed updates.

How it maps onto the flat packed state (DESIGN.md §11):

- ``state["params"]`` row ``c`` holds the global version client ``c`` was
  *dispatched* with. Local training is deferred to flush time: an update's
  content is a pure function of (dispatch params, opt row, batch), so the
  event queue only decides *when* it lands and against which global
  version — the simulated clock never has to replay training.
- A flush is ONE jitted, donated program: gated local training of the
  staged rows (the masked trainer from core/rounds), in-place
  ``packing.write_slots`` write-back, then the registered aggregator over
  the packed buffer with the *staleness discount folded into the weights
  operand* — ``w_c * (1 + s_c)^-alpha`` — so the PR 4 reduction tiling
  (merged-run fused chains / `packed_bucket_reduce`) is reused verbatim;
  the discounted weights need not sum to 1 because every reducer
  normalizes by its own denominator. Staged rows leave the flush holding
  the fresh global (their redispatch); in-flight rows keep their dispatch
  version.
- Sync-equivalence contract: with ``buffer_size == C`` every client must
  complete before a flush, staleness is identically zero, and the flush
  program IS `rounds.build_fed_round`'s full-participation sync round —
  the same compiled program, so async reproduces the flat sync engine
  bit-for-bit by construction (pinned in tests/test_async_engine.py).

The host-side control plane is a deterministic discrete-event simulation:
a heap of ``(completion_time, client)`` events (ties break by client id),
a shared `core.simclock.SimClock`, and `explorer.ClientLoadModel.step(dt)`
advanced by the *simulated* gap between events — spikes and AR(1) drift
evolve in simulated seconds. Completion times are compute
(load-dependent, straggler-aware) plus the paper's bandwidth term
(`benchmarks/bandwidth_model.py`: payload / 512 KB/s camera uplink).
Updates staler than ``max_staleness`` are dropped — counted, never
silently lost — and the dropped client redispatches from the current
global.
"""
from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import explorer, packing
from repro.core import rounds as R
from repro.core.simclock import SimClock
from repro.models import params as mp

PyTree = Any


def _default_uplink_b_s() -> float:
    """The paper's per-camera uplink (benchmarks/bandwidth_model.py)."""
    try:
        from benchmarks.bandwidth_model import PER_CHANNEL_B_S

        return float(PER_CHANNEL_B_S)
    except ImportError:  # repro installed without the benchmarks tree
        return 512e3


@dataclasses.dataclass(frozen=True)
class TimingModel:
    """Per-client completion-time model: compute + upload, in sim seconds.

    ``compute`` scales the idle-client cost by the Explorer load (a client
    at load L runs at (1 - L) effective speed, floored at min_headroom —
    a spiked client is ~1/min_headroom slower, which is what makes the
    sync engine's wait-for-slowest hurt). ``upload`` is payload bytes over
    the paper's camera uplink, with optional stable per-client spread
    (`bandwidth_model.client_uplink_scales`). Zero spread + a zero-variance
    load model gives identical completion times for every client — the
    sync-equivalence regime.
    """

    base_compute_s: float = 10.0  # one local step on an idle client
    min_headroom: float = 0.05  # floor on (1 - load): max slowdown 20x
    uplink_b_s: float | None = None  # None -> bandwidth_model.PER_CHANNEL_B_S
    uplink_spread: float = 0.0  # per-client uplink spread in [0, 1)
    payload_bytes: float | None = None  # None -> n_total * 4 (f32 rows)

    def compute_seconds(self, load: float, local_steps: int = 1) -> float:
        return self.base_compute_s * local_steps / max(1.0 - load, self.min_headroom)


def default_upload_terms(timing: TimingModel, n_clients: int, n_total: int, seed: int) -> np.ndarray:
    """The per-client upload-seconds vector both round control planes use:
    payload (``timing.payload_bytes`` or f32 rows of the packed buffer)
    over per-client uplinks drawn from ``seed``. Sync FLServers and the
    async engine derive theirs through this ONE helper so the same seed
    gives the same uplink draws — the shared-clock interleave compares
    completion models, not sampling accidents."""
    payload = (
        timing.payload_bytes if timing.payload_bytes is not None else n_total * 4
    )
    return client_upload_seconds(
        timing, n_clients, payload, np.random.default_rng(seed + 1)
    )


def client_upload_seconds(timing: TimingModel, n_clients: int, payload: float, rng) -> np.ndarray:
    """Fixed per-client upload seconds (the bandwidth term) — shared by
    the engine and the sync side of the async-vs-sync benches."""
    base = timing.uplink_b_s if timing.uplink_b_s is not None else _default_uplink_b_s()
    try:
        from benchmarks import bandwidth_model as bw

        scales = np.asarray(bw.client_uplink_scales(n_clients, rng, timing.uplink_spread))
        return np.array([bw.upload_seconds(payload, base * s) for s in scales])
    except ImportError:
        scales = (
            np.ones(n_clients)
            if timing.uplink_spread == 0.0
            else rng.uniform(1.0 - timing.uplink_spread, 1.0 + timing.uplink_spread, n_clients)
        )
        return payload / np.maximum(base * scales, 1.0)


def sync_round_seconds(
    timing: TimingModel,
    loads: np.ndarray,
    upload_s: np.ndarray,
    local_steps: int = 1,
    mask: np.ndarray | None = None,
) -> float:
    """Simulated duration of ONE synchronous round: the server waits for
    the slowest participating client (compute under its load + upload).
    The sync side of the async-vs-sync time-to-loss benches and of the
    Task Manager's shared-clock interleaving."""
    loads = np.asarray(loads, float)
    per = np.array(
        [timing.compute_seconds(l, local_steps) for l in loads]
    ) + np.asarray(upload_s, float)
    if mask is not None:
        per = per[np.asarray(mask) > 0]
    return float(per.max())


@dataclasses.dataclass
class AsyncRoundRecord:
    """One flush of the buffered engine. Field names shared with
    `server.RoundRecord` (round_idx/loss/weights/seconds/participants/
    loads) so `core.monitor` renders either; the async-only fields are the
    simulated wall-clock and the per-update staleness the monitor adds."""

    round_idx: int
    loss: float
    weights: list[float]  # staleness-discounted, staged rows only
    seconds: float  # host wall (the simulation's own cost)
    participants: list[int]  # staged clients, completion order
    loads: list[float]
    version: int = 0  # global model version this flush produced
    sim_time: float = 0.0  # simulated wall-clock at flush
    staleness: list[int] = dataclasses.field(default_factory=list)
    dropped: int = 0  # stale completions discarded while filling the buffer


def _build_buffered_flush(cfg, fed: R.FedConfig, optimizer, agg):
    """The K_buf < C flush: gated training of the staged rows + the
    staleness-weighted aggregate, with in-flight rows carried through.

    Identical training/aggregation kernels to the sync masked round — the
    only async-specific steps are the discounted weights operand (computed
    host-side, staleness never enters the trace) and the final select that
    redispatches staged rows while in-flight rows keep their dispatch
    version (the sync round instead broadcasts to everyone).
    """
    spec = agg.ctx.spec
    tpl = agg.ctx.template
    fed_m = dataclasses.replace(fed, participation="masked")
    local_train, gated = R._local_training(cfg, fed_m, optimizer)
    train_clients = R._train_clients_fn(fed_m, local_train, gated)

    def flush(state, batch, part):
        mask = part["mask"].astype(jnp.float32)
        w_disc = part["weights"].astype(jnp.float32)  # w * (1+s)^-alpha
        packed = state["params"]
        new_p, new_o, loss = train_clients(
            packing.unpack_views(spec, packed, tpl), state["opt"], batch, mask
        )
        packed_new = packing.write_slots(spec, packed, new_p)
        packed_out, agg_state = agg.aggregate(packed_new, w_disc, state["agg"], mask)
        # staged rows redispatch with the fresh global; in-flight rows keep
        # the version they were dispatched with (sync broadcasts instead)
        params = jnp.where(mask[:, None] > 0, packed_out, packed_new)
        out = {
            **state,
            "params": params,
            "opt": new_o,
            "agg": agg_state,
            "round": state["round"] + 1,
        }
        return out, R._round_metrics(fed_m, loss, mask)

    return flush


class BufferedAsyncEngine:
    """Event-driven buffered-aggregation loop (FedBuff-style) over the flat
    packed round state. One ``step_round(batch)`` = pop completion events
    (advancing the shared SimClock and the load model in simulated time)
    until ``buffer_size`` updates stage, then apply one donated flush."""

    def __init__(
        self,
        cfg,
        fed: R.FedConfig,
        optimizer,
        *,
        mesh=None,
        rules: dict | None = None,
        seed: int = 0,
        dtype=jnp.float32,
        clock: SimClock | None = None,
        load_model: explorer.ClientLoadModel | None = None,
        timing: TimingModel | None = None,
        scheduler=None,
        aggregator=None,
    ):
        if fed.mode != "async":
            raise ValueError(
                f"BufferedAsyncEngine needs FedConfig(mode='async'), got {fed.mode!r}"
            )
        if fed.state_layout != "flat":
            raise ValueError(
                "the async engine runs on the flat packed round state "
                f"(state_layout='flat'), got {fed.state_layout!r}"
            )
        if fed.participation != "full":
            raise ValueError(
                "async mode owns its own participation plane (the event "
                f"queue); set participation='full', got {fed.participation!r}"
            )
        C = fed.n_clients
        self.k_buf = fed.buffer_size or C
        if not 1 <= self.k_buf <= C:
            raise ValueError(
                f"buffer_size={fed.buffer_size} must be in [1, n_clients={C}] (or 0 -> C)"
            )
        if fed.max_staleness < 0:
            raise ValueError(f"max_staleness={fed.max_staleness} must be >= 0")
        if fed.stream and type(self) is BufferedAsyncEngine:
            raise ValueError(
                "FedConfig(stream=True) selects the streaming flush; construct "
                "StreamingAsyncEngine (FLServer dispatches on fed.stream)"
            )
        self.cfg, self.fed, self.optimizer = cfg, fed, optimizer
        # a caller that already resolved the aggregator (FLServer) passes it
        # in — make_aggregator walks the whole param template for the
        # PackSpec, which need not run twice per construction
        self.agg = aggregator or R.make_aggregator(cfg, fed, mesh)
        if not self.agg.stacked:
            raise ValueError(
                f"async mode needs a client-stacked aggregator; {fed.aggregation!r} "
                "runs one shared model copy (fedsgd topology)"
            )
        self.clock = clock or SimClock()
        self.load_model = load_model or explorer.ClientLoadModel(C, seed=seed)
        self.scheduler = scheduler
        self.timing = timing or TimingModel()
        self.upload_s = default_upload_terms(
            self.timing, C, self.agg.ctx.spec.n_total, seed
        )
        self._mesh, self._rules, self._dtype, self._seed = mesh, rules, dtype, seed
        self._init_state_and_flush()
        self.version = 0
        self.dispatch_version = np.zeros(C, np.int64)
        self.completions = 0
        self.dropped_total = 0
        self.history: list[AsyncRoundRecord] = []
        # everyone starts in flight against version 0 at t=0; the heap's
        # (time, client) tuples make equal completion times pop in client-id
        # order — the deterministic tie-break the tests pin
        self._queue: list[tuple[float, int]] = []
        self.global_row = 0  # the state row currently holding the global dispatch
        for c in range(C):
            self._push(c)

    # -- state + flush program (overridden by StreamingAsyncEngine) ----------

    def _init_state_and_flush(self) -> None:
        cfg, fed, optimizer = self.cfg, self.fed, self.optimizer
        self.state = R.make_state(cfg, fed, optimizer, jax.random.key(self._seed), self._dtype)
        if self.k_buf == fed.n_clients:
            # the sync-equivalence contract, by construction: a full buffer
            # means every client completed (staleness == 0 everywhere), and
            # the flush IS the sync full-participation round program
            self._flush = R.jit_fed_round(
                R.build_fed_round(
                    cfg, dataclasses.replace(fed, mode="sync"), optimizer, self._mesh, self._rules
                )
            )
            self._full = True
        else:
            self._flush = jax.jit(
                _build_buffered_flush(cfg, fed, optimizer, self.agg), donate_argnums=(0,)
            )
            self._full = False

    def global_packed_row(self) -> jax.Array:
        """The (N_total,) packed row holding the current global dispatch —
        the one pack/unpack edge `server.global_params` reads through."""
        return self.state["params"][self.global_row]

    # -- event machinery -----------------------------------------------------

    def _client_seconds(self, c: int) -> float:
        load = float(self.load_model.loads[c])
        return self.timing.compute_seconds(load, self.fed.local_steps) + float(
            self.upload_s[c]
        )

    def _push(self, c: int) -> None:
        heapq.heappush(self._queue, (self.clock.now() + self._client_seconds(c), c))

    def next_completion_time(self) -> float | None:
        """Earliest queued completion — the Task Manager's interleave key."""
        return self._queue[0][0] if self._queue else None

    def _apply_pending_redispatch(self, pending: set[int]) -> None:
        """Write the current global row into every pending dropped client's
        row in ONE batched copy (a per-drop `.at[c].set` would materialize a
        fresh (C, N_total) buffer per dropped completion). Safe to defer
        within a collection window: the version — and with it global_row's
        contents — only changes at a flush, and no flush happens mid-window."""
        if not pending:
            return
        p = self.state["params"]
        idx = jnp.asarray(sorted(pending), jnp.int32)
        self.state["params"] = p.at[idx].set(p[self.global_row])
        pending.clear()

    # -- one flush -----------------------------------------------------------

    def _drop(self, c: int) -> None:
        """Dropped completion: counted, redispatched from the current global
        (its opt row persists — per-client optimizer memory is the client's
        own, exactly as in the sync flat engine); the row copy batches with
        other drops this window."""
        self._pending.add(c)

    def _pre_stage(self, c: int) -> None:
        if c in self._pending:
            # a dropped client completed again before its deferred row
            # copy landed — materialize the copies so it trains from
            # the global it was redispatched with
            self._apply_pending_redispatch(self._pending)

    def _post_collect(self) -> None:
        self._apply_pending_redispatch(self._pending)

    def _collect(self) -> tuple[list[int], list[int], int]:
        """Pop completion events (advancing the shared clock and the load
        model in simulated time) until ``buffer_size`` updates stage.
        Shared by both flush disciplines — the buffered/streaming split is
        only in what a drop and a flush do with the rows."""
        staged: list[int] = []
        stal: list[int] = []
        self._pending: set[int] = set()  # dropped rows awaiting the global copy
        dropped = 0
        while len(staged) < self.k_buf:
            t, c = heapq.heappop(self._queue)
            # a peer task on the shared clock may have advanced time past
            # this queued completion while we weren't scheduled — the
            # update then simply lands "now" (never move the clock back)
            dt = self.clock.advance_to(max(t, self.clock.now()))
            if dt > 0:
                self.load_model.step(dt)  # loads evolve in simulated time
            self.completions += 1
            s = self.version - int(self.dispatch_version[c])
            if self.fed.max_staleness and s > self.fed.max_staleness:
                dropped += 1
                self.dropped_total += 1
                self.dispatch_version[c] = self.version
                self._drop(c)
                self._push(c)
                continue
            self._pre_stage(c)
            staged.append(c)
            stal.append(s)
        self._post_collect()
        return staged, stal, dropped

    def step_round(self, batch: PyTree) -> AsyncRoundRecord:
        """Collect ``buffer_size`` completions, flush once.

        batch: the same (C, E, per-step...) pytree the sync round takes;
        only staged rows are consumed (the gated trainer carries the rest
        through untouched; the streaming flush gathers only staged rows).
        """
        t_host = time.time()
        staged, stal, dropped = self._collect()
        rec = self._do_flush(staged, stal, dropped, batch, t_host)
        self.history.append(rec)
        return rec

    def _do_flush(self, staged, stal, dropped, batch, t_host) -> AsyncRoundRecord:
        C = self.fed.n_clients
        mask = np.zeros(C, np.float32)
        mask[staged] = 1.0
        stal_vec = np.zeros(C, np.float32)
        stal_vec[staged] = stal
        # polynomial staleness discount folded into the weights operand —
        # the packed reducers renormalize by their own denominator, so the
        # discounted weights need not sum to 1. s == 0 gives exactly 1.0,
        # so a fresh buffer reproduces the undiscounted weights bit-for-bit.
        w = mask / np.float32(len(staged))
        w_disc = (w * (1.0 + stal_vec) ** np.float32(-self.fed.staleness_alpha)).astype(
            np.float32
        )
        if self._full:
            part = jnp.asarray(w_disc)  # bare weights: the sync full path
        else:
            part = {"mask": jnp.asarray(mask), "weights": jnp.asarray(w_disc)}
        self.state, metrics = self._flush(self.state, batch, part)
        self.version += 1
        if self.scheduler is not None:
            # async completions feed the same quality EMA sync rounds do
            client_loss = np.asarray(metrics["client_loss"], np.float32)
            for c in staged:
                self.scheduler.report_quality(c, float(client_loss[c]))
        for c in staged:
            self.dispatch_version[c] = self.version
            self._push(c)
        self.global_row = staged[0]  # its row now holds the fresh global
        rec = AsyncRoundRecord(
            round_idx=self.version - 1,
            loss=float(metrics["loss"]),
            weights=[float(x) for x in w_disc],
            seconds=time.time() - t_host,
            participants=[int(c) for c in staged],
            loads=[float(x) for x in self.load_model.loads],
            version=self.version,
            sim_time=self.clock.now(),
            staleness=[int(s) for s in stal],
            dropped=dropped,
        )
        return rec


def build_row_update(cfg, fed: R.FedConfig, optimizer, *, spec=None, template=None, dtype=jnp.float32):
    """The single-row jitted local update: (N_total,) dispatch row + one
    client's (E, per-step...) batch -> (trained row, mean loss).

    This is THE program federated workers run (DESIGN.md §14): the wire
    worker (`launch/worker.py`) and the SimClock replay harness
    (`core/transport/replay.py`) both train through this one jit, so the
    trained bytes a worker uploads and the rows the replay recomputes are
    the same deterministic function of (dispatch row, batch) — the
    replay-determinism contract rests on it. Training must be a pure
    function of the dispatch row, so the local optimizer must carry no
    cross-round state (``sgd(momentum=0.0)``), exactly the
    StreamingAsyncEngine rule."""
    if spec is None or template is None:
        agg = R.make_aggregator(cfg, fed)
        spec, template = agg.ctx.spec, agg.ctx.template
    pabs = mp.abstract(template, dtype)
    if jax.tree.leaves(jax.eval_shape(optimizer.init, pabs)):
        raise ValueError(
            "the row update is a pure function of (dispatch row, batch): use "
            f"a stateless local optimizer (sgd(momentum=0.0)), got "
            f"{optimizer.name!r} with persistent state"
        )
    local_train, _ = R._local_training(cfg, fed, optimizer)

    def update(row, batch_c):
        views = packing.unpack_views(spec, row[None], template)
        b = jax.tree.map(lambda x: x[None], batch_c)
        new_p, _, loss = jax.vmap(local_train)(views, {}, b)
        return packing.write_slots(spec, row[None], new_p)[0], loss[0]

    return jax.jit(update)


def _build_landing_flush(agg):
    """The arrival engine's flush: the buffered flush minus its training
    step — rows landed already trained (by the worker over the wire, or by
    the replay's row update), so the program is the registered aggregation
    over the packed buffer with the staleness discount folded into the
    weights operand, then the staged-redispatch select (staged rows leave
    holding the fresh global; in-flight rows keep their dispatch)."""

    def flush(state, part):
        mask = part["mask"].astype(jnp.float32)
        w_disc = part["weights"].astype(jnp.float32)
        packed = state["params"]
        packed_out, agg_state = agg.aggregate(packed, w_disc, state["agg"], mask)
        params = jnp.where(mask[:, None] > 0, packed_out, packed)
        return {
            **state,
            "params": params,
            "agg": agg_state,
            "round": state["round"] + 1,
        }

    return flush


@dataclasses.dataclass
class LandResult:
    """What one landed completion did to the engine."""

    client: int
    staleness: int
    dropped: bool  # True: staler than max_staleness — counted, redispatched
    version: int  # engine version after handling (the redispatch version)
    flush: AsyncRoundRecord | None = None  # set when this landing filled the buffer


class ArrivalAsyncEngine:
    """Buffered async engine driven by an external arrival stream
    (DESIGN.md §14): the wire server's socket landing loop, or a recorded
    arrival schedule replayed on the SimClock.

    Same packed ``(C, N_total)`` dispatch-row state, staleness accounting,
    polynomial discount, registered aggregation, and ``AsyncRoundRecord``
    history as :class:`BufferedAsyncEngine` — what changes is *when* and
    *whence* updates land: there is no simulated event heap, and updates
    arrive **already trained** (the worker ran :func:`build_row_update` on
    its dispatch row). Consequently there are no per-client optimizer rows:
    the local optimizer must be stateless (``sgd(momentum=0.0)``), the
    StreamingAsyncEngine rule.

    Row ``c`` of ``state["params"]`` always holds exactly what client ``c``
    was last dispatched (until its trained update lands in place) — the row
    IS the wire dispatch payload, which is what makes a recorded run
    replayable: replaying the same dispatch/land sequence reproduces the
    same rows, hence the same flushes, bit-for-bit for the dense codec.
    """

    def __init__(
        self,
        cfg,
        fed: R.FedConfig,
        optimizer,
        *,
        seed: int = 0,
        dtype=jnp.float32,
        clock: SimClock | None = None,
        aggregator=None,
    ):
        if fed.mode != "async":
            raise ValueError(
                f"ArrivalAsyncEngine needs FedConfig(mode='async'), got {fed.mode!r}"
            )
        if fed.state_layout != "flat":
            raise ValueError(
                "the arrival engine runs on the flat packed round state "
                f"(state_layout='flat'), got {fed.state_layout!r}"
            )
        if fed.stream:
            raise ValueError(
                "the arrival engine keeps the (C, N_total) dispatch buffer — "
                "its rows ARE the wire payloads; stream=True has no buffer to land into"
            )
        C = fed.n_clients
        self.k_buf = fed.buffer_size or C
        if not 1 <= self.k_buf <= C:
            raise ValueError(
                f"buffer_size={fed.buffer_size} must be in [1, n_clients={C}] (or 0 -> C)"
            )
        if fed.max_staleness < 0:
            raise ValueError(f"max_staleness={fed.max_staleness} must be >= 0")
        self.cfg, self.fed, self.optimizer = cfg, fed, optimizer
        self.agg = aggregator or R.make_aggregator(cfg, fed)
        if not self.agg.stacked:
            raise ValueError(
                f"async mode needs a client-stacked aggregator; {fed.aggregation!r} "
                "runs one shared model copy (fedsgd topology)"
            )
        spec, tpl = self.agg.ctx.spec, self.agg.ctx.template
        pabs = mp.abstract(tpl, dtype)
        if jax.tree.leaves(jax.eval_shape(optimizer.init, pabs)):
            raise ValueError(
                "the arrival engine keeps no per-client optimizer rows (updates "
                "arrive already trained); use a stateless local optimizer "
                f"(sgd(momentum=0.0)), got {optimizer.name!r} with persistent state"
            )
        self.clock = clock or SimClock()
        # same init draw as make_state row 0: every engine with this seed
        # starts from the identical global (the replay/equivalence anchor)
        keys = jax.random.split(jax.random.key(seed), C)
        row0 = packing.pack(
            spec,
            jax.tree.map(lambda x: x[None], mp.init_params(tpl, keys[0], dtype)),
            dtype,
        )[0]
        packed = jnp.tile(row0[None], (C, 1))
        self.state = {
            "params": packed,
            "agg": self.agg.init_state(packed),
            "round": jnp.int32(0),
        }
        self._flush = jax.jit(_build_landing_flush(self.agg), donate_argnums=(0,))
        self.version = 0
        self.global_row = 0
        # unlike the buffered engine, rows mutate on EVERY landing, so "the
        # row staged[0] holds the global" is only true until that client's
        # next update lands mid-window — the engine keeps its own copy of
        # the current global instead of trusting an index into the buffer
        self._global = row0
        self.dispatch_version = np.zeros(C, np.int64)
        self.completions = 0
        self.dropped_total = 0
        self.history: list[AsyncRoundRecord] = []
        self._staged: list[int] = []
        self._stal: list[int] = []
        self._losses: list[float] = []
        self._dropped_window = 0

    # -- durability (checkpoint/durable.py snapshots through these) ----------

    def export_state(self) -> dict:
        """Everything a crashed server needs to resume mid-window: the
        packed buffer, the COMPLETE ``state["agg"]`` substate (EF residual
        rows, fmix32 round counters — any aggregator-private leaf), the
        engine's own global copy, dispatch versions, and the host-side
        window/counter scalars. Returns ``{"arrays": {...}, "scalars":
        {...}}`` — plain numpy + JSON-able, ready for np.savez."""
        agg_leaves = jax.tree_util.tree_leaves(self.state["agg"])
        arrays = {
            "params": np.asarray(self.state["params"]),
            "global": np.asarray(self._global),
            "dispatch_version": np.asarray(self.dispatch_version),
        }
        for i, leaf in enumerate(agg_leaves):
            arrays[f"agg_{i}"] = np.asarray(leaf)
        scalars = {
            "round": int(self.state["round"]),
            "version": int(self.version),
            "global_row": int(self.global_row),
            "completions": int(self.completions),
            "dropped_total": int(self.dropped_total),
            "n_agg_leaves": len(agg_leaves),
            "staged": [int(c) for c in self._staged],
            "stal": [int(s) for s in self._stal],
            "losses": [float(x) for x in self._losses],
            "dropped_window": int(self._dropped_window),
            "clock_t": float(self.clock.now()),
            "n_history": len(self.history),
        }
        return {"arrays": arrays, "scalars": scalars}

    def import_state(self, snap: dict) -> None:
        """Inverse of :meth:`export_state` onto a freshly built engine (same
        meta => same agg tree structure, so the flattened leaves unflatten
        against this engine's own treedef). The clock is advanced to the
        snapshot time, never rewound."""
        arrays, scalars = snap["arrays"], snap["scalars"]
        leaves, treedef = jax.tree_util.tree_flatten(self.state["agg"])
        n = int(scalars["n_agg_leaves"])
        if n != len(leaves):
            raise ValueError(
                f"snapshot has {n} agg leaves, engine expects {len(leaves)} "
                "(aggregation mismatch between snapshot meta and engine?)"
            )
        agg = jax.tree_util.tree_unflatten(
            treedef,
            [jnp.asarray(arrays[f"agg_{i}"], leaves[i].dtype) for i in range(n)],
        )
        self.state = {
            "params": jnp.asarray(arrays["params"], self.state["params"].dtype),
            "agg": agg,
            "round": jnp.int32(scalars["round"]),
        }
        self._global = jnp.asarray(arrays["global"], self.state["params"].dtype)
        self.dispatch_version = np.asarray(arrays["dispatch_version"], np.int64).copy()
        self.version = int(scalars["version"])
        self.global_row = int(scalars["global_row"])
        self.completions = int(scalars["completions"])
        self.dropped_total = int(scalars["dropped_total"])
        self._staged = [int(c) for c in scalars["staged"]]
        self._stal = [int(s) for s in scalars["stal"]]
        self._losses = [float(x) for x in scalars["losses"]]
        self._dropped_window = int(scalars["dropped_window"])
        if float(scalars["clock_t"]) > self.clock.now():
            self.clock.advance_to(float(scalars["clock_t"]))

    # -- dispatch side -------------------------------------------------------

    def global_packed_row(self) -> jax.Array:
        """The (N_total,) packed row holding the current global dispatch.

        NOT ``state["params"][global_row]``: that row belongs to a client
        and may already hold the client's NEXT trained update (landed this
        window). Checkpoints and dispatches read the engine's own copy,
        which only changes at a flush."""
        return self._global

    def staged(self) -> tuple[int, ...]:
        """Clients landed-but-not-flushed this window (their rows hold
        trained updates and must not be redispatched over)."""
        return tuple(self._staged)

    def dispatch(self, c: int) -> int:
        """(Re)dispatch the current global into client ``c``'s row; returns
        the version dispatched. Refuses a staged client — its row holds a
        trained update awaiting the flush (the wire server defers such
        dispatches until the flush redispatches it anyway)."""
        if c in self._staged:
            raise RuntimeError(
                f"client {c} is staged for the pending flush; dispatching now "
                "would overwrite its landed update"
            )
        # copy from the engine's global, never from another client's row —
        # a row indexed by global_row may hold that client's newer landed
        # update (the mid-window staleness hazard global_params documents).
        # Skip only when row c provably holds the current global already:
        # dispatch_version[c] == version and c unstaged means c's last row
        # write was this version's flush or a dispatch of it.
        if int(self.dispatch_version[c]) != self.version:
            self.state["params"] = self.state["params"].at[c].set(self._global)
        self.dispatch_version[c] = self.version
        return self.version

    def dispatch_row(self, c: int) -> np.ndarray:
        """Host copy of client ``c``'s dispatch row — the wire payload."""
        return np.asarray(self.state["params"][c], np.float32)

    # -- landing side --------------------------------------------------------

    def land(self, c: int, row, *, loss: float = 0.0, t: float | None = None) -> LandResult:
        """One arrived update: advance the clock to its arrival time, apply
        the staleness gate, write the trained row in place, and flush once
        ``buffer_size`` updates have staged. Drops redispatch from the
        current global immediately (counted, never silent)."""
        if c in self._staged:
            raise RuntimeError(
                f"client {c} already staged this window — the dispatch protocol "
                "sends one update per dispatch"
            )
        if t is not None:
            self.clock.advance_to(max(float(t), self.clock.now()))
        self.completions += 1
        s = self.version - int(self.dispatch_version[c])
        if self.fed.max_staleness and s > self.fed.max_staleness:
            self.dropped_total += 1
            self._dropped_window += 1
            self.dispatch(c)  # redispatch from the current global
            return LandResult(client=c, staleness=s, dropped=True, version=self.version)
        self.state["params"] = self.state["params"].at[c].set(
            jnp.asarray(row, self.state["params"].dtype)
        )
        self._staged.append(c)
        self._stal.append(s)
        self._losses.append(float(loss))
        rec = self._flush_staged() if len(self._staged) >= self.k_buf else None
        return LandResult(client=c, staleness=s, dropped=False, version=self.version, flush=rec)

    def _flush_staged(self) -> AsyncRoundRecord:
        staged, stal, losses = self._staged, self._stal, self._losses
        C = self.fed.n_clients
        mask = np.zeros(C, np.float32)
        mask[staged] = 1.0
        stal_vec = np.zeros(C, np.float32)
        stal_vec[staged] = stal
        # identical discount arithmetic to BufferedAsyncEngine._do_flush —
        # the replay equivalence leans on the formulas matching exactly
        w = mask / np.float32(len(staged))
        w_disc = (w * (1.0 + stal_vec) ** np.float32(-self.fed.staleness_alpha)).astype(
            np.float32
        )
        part = {"mask": jnp.asarray(mask), "weights": jnp.asarray(w_disc)}
        self.state = self._flush(self.state, part)
        self.version += 1
        for c in staged:
            self.dispatch_version[c] = self.version
        self.global_row = staged[0]  # its row holds the fresh global (for now)
        self._global = self.state["params"][staged[0]]  # ...so snapshot it
        rec = AsyncRoundRecord(
            round_idx=self.version - 1,
            loss=float(np.mean(losses)) if losses else 0.0,
            weights=[float(x) for x in w_disc],
            seconds=0.0,
            participants=[int(c) for c in staged],
            loads=[0.0] * C,
            version=self.version,
            sim_time=self.clock.now(),
            staleness=[int(s) for s in stal],
            dropped=self._dropped_window,
        )
        self.history.append(rec)
        self._staged, self._stal, self._losses = [], [], []
        self._dropped_window = 0
        return rec


class StreamingAsyncEngine(BufferedAsyncEngine):
    """The O(buffer_size · N) flush discipline for large federations
    (DESIGN.md §13). Same event queue, clock, staleness accounting and
    record format as :class:`BufferedAsyncEngine`; what changes is the
    state the flush runs over:

    - No ``(C, N_total)`` buffer. A client's dispatch content is the global
      of the version it was dispatched with, so the engine keeps ONE ring
      of ``max_staleness + 1`` packed global rows — versions
      ``[version - max_staleness, version]``, exactly the versions a
      non-dropped completion can still reference. ``state["ring"]`` is
      ``(max_staleness + 1, N_total)``; a drop redispatches by writing
      ``dispatch_version[c]`` only (the ring already holds the row — the
      buffered engine instead copies a row per drop window).
    - Landed cohorts reduce into a running ``(N_total,)`` accumulator plus
      a weight scalar in ``state["agg"]`` (``acc``/``wsum``): each flush
      gathers at most ``_cohort`` dispatch rows from the ring, trains them,
      and folds ``sum_q w_q * trained_q`` into ``acc`` — peak extra memory
      is O(cohort · N), never O(C · N). The finalize step divides, writes
      the fresh global into ring slot ``(version+1) % R`` and zeroes the
      accumulator.
    - Training is stateless: no per-client optimizer rows exist, so the
      local optimizer must carry nothing between rounds
      (``sgd(momentum=0.0)``) — validated at build. Aggregation must be
      the linear ``dense`` reduce (the only mode a running sum can
      represent); both are build-time errors otherwise.

    With the same seed, batches and timing, streaming matches the buffered
    engine to reduction-order tolerance (the buffered flush reduces one
    masked C-length chain; streaming sums k_buf rows in cohorts)."""

    _cohort = 8  # max dispatch rows materialized per accumulate call

    def _init_state_and_flush(self) -> None:
        cfg, fed, optimizer = self.cfg, self.fed, self.optimizer
        if not fed.stream:
            raise ValueError("StreamingAsyncEngine needs FedConfig(stream=True)")
        if fed.max_staleness < 1:
            raise ValueError(
                "streaming flush needs max_staleness >= 1: the dispatch ring "
                "holds max_staleness+1 global versions in place of the (C, N) "
                f"buffer, got max_staleness={fed.max_staleness}"
            )
        if fed.aggregation != "dense":
            raise ValueError(
                "streaming flush folds aggregation into a running weighted "
                f"sum; only the linear 'dense' reduce streams, got "
                f"{fed.aggregation!r}"
            )
        tpl = self.agg.ctx.template
        spec = self.agg.ctx.spec
        pabs = mp.abstract(tpl, self._dtype)
        if jax.tree.leaves(jax.eval_shape(optimizer.init, pabs)):
            raise ValueError(
                "streaming flush keeps no per-client optimizer rows; use a "
                f"stateless local optimizer (sgd(momentum=0.0)), "
                f"got {optimizer.name!r} with persistent state"
            )
        self.ring_slots = fed.max_staleness + 1
        # same init draw as make_state row 0: every engine with this seed
        # starts from the identical global (the equivalence tests' anchor)
        keys = jax.random.split(jax.random.key(self._seed), fed.n_clients)
        row0 = packing.pack(
            spec,
            jax.tree.map(lambda x: x[None], mp.init_params(tpl, keys[0], self._dtype)),
            self._dtype,
        )[0]
        n = spec.n_total
        self.state = {
            "ring": jnp.broadcast_to(row0, (self.ring_slots, n)),
            "agg": {"acc": jnp.zeros((n,), jnp.float32), "wsum": jnp.zeros((), jnp.float32)},
            "round": jnp.int32(0),
        }
        local_train, _ = R._local_training(cfg, fed, optimizer)

        def accum(state, batch_q, slots, w_q):
            # (Q, N) gather from the ring — the only row materialization
            rows = jnp.take(state["ring"], slots, axis=0)
            new_p, _, loss = jax.vmap(local_train)(
                packing.unpack_views(spec, rows, tpl), {}, batch_q
            )
            trained = packing.write_slots(spec, rows, new_p).astype(jnp.float32)
            acc = state["agg"]["acc"] + jnp.einsum("q,qn->n", w_q, trained)
            wsum = state["agg"]["wsum"] + jnp.sum(w_q)
            return {**state, "agg": {"acc": acc, "wsum": wsum}}, loss

        def finalize(state, new_slot):
            g = state["agg"]["acc"] / jnp.maximum(state["agg"]["wsum"], 1e-12)
            ring = jax.lax.dynamic_update_index_in_dim(
                state["ring"], g.astype(state["ring"].dtype), new_slot, 0
            )
            return {
                "ring": ring,
                "agg": {
                    "acc": jnp.zeros_like(state["agg"]["acc"]),
                    "wsum": jnp.zeros_like(state["agg"]["wsum"]),
                },
                "round": state["round"] + 1,
            }

        self._accum = jax.jit(accum, donate_argnums=(0,))
        self._finalize = jax.jit(finalize, donate_argnums=(0,))
        self._full = False

    def global_packed_row(self) -> jax.Array:
        return self.state["ring"][self.version % self.ring_slots]

    # drops are version-only redispatches: the ring already holds the row
    def _drop(self, c: int) -> None:
        pass

    def _pre_stage(self, c: int) -> None:
        pass

    def _post_collect(self) -> None:
        pass

    def _do_flush(self, staged, stal, dropped, batch, t_host) -> AsyncRoundRecord:
        C = self.fed.n_clients
        k = len(staged)
        w_per = (
            (1.0 / np.float32(k))
            * (1.0 + np.asarray(stal, np.float32)) ** np.float32(-self.fed.staleness_alpha)
        ).astype(np.float32)
        Q = min(k, self._cohort)
        losses = np.zeros(k, np.float32)
        for i0 in range(0, k, Q):
            chunk = staged[i0 : i0 + Q]
            pad = Q - len(chunk)
            idx = np.asarray(chunk + [chunk[0]] * pad, np.int64)
            slots = jnp.asarray(
                (self.dispatch_version[idx] % self.ring_slots).astype(np.int32)
            )
            w_q = np.zeros(Q, np.float32)
            w_q[: len(chunk)] = w_per[i0 : i0 + Q]  # padding rows weigh 0
            batch_q = jax.tree.map(lambda x: x[jnp.asarray(idx)], batch)
            self.state, closs = self._accum(self.state, batch_q, slots, jnp.asarray(w_q))
            losses[i0 : i0 + len(chunk)] = np.asarray(closs, np.float32)[: len(chunk)]
        self.state = self._finalize(
            self.state, jnp.int32((self.version + 1) % self.ring_slots)
        )
        self.version += 1
        if self.scheduler is not None:
            for i, c in enumerate(staged):
                self.scheduler.report_quality(c, float(losses[i]))
        for c in staged:
            self.dispatch_version[c] = self.version
            self._push(c)
        w_disc = np.zeros(C, np.float32)
        w_disc[staged] = w_per
        return AsyncRoundRecord(
            round_idx=self.version - 1,
            loss=float(np.mean(losses)),
            weights=[float(x) for x in w_disc],
            seconds=time.time() - t_host,
            participants=[int(c) for c in staged],
            loads=[float(x) for x in self.load_model.loads],
            version=self.version,
            sim_time=self.clock.now(),
            staleness=[int(s) for s in stal],
            dropped=dropped,
        )
