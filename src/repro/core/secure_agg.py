"""Secure aggregation — pairwise additive masking (Bonawitz et al. 2017).

The paper: clients send "encrypted model parameters ... to the server in a
secure encrypted manner" and cite Bonawitz et al.'s system design. The
standard construction: every client pair (i, j) derives a shared mask
m_ij from a common seed; client i adds +m_ij for j > i and -m_ji for j < i
to its update. Masks cancel in the SUM, so the server learns only the
aggregate — individual updates stay hidden.

This is the real additive-masking algorithm (PRG = JAX threefry keyed by
the pair's shared seed), minus the dropout-recovery secret-sharing layer
(documented out of scope). Exact cancellation is tested to float tolerance
and the masked uploads are statistically indistinguishable from noise at
mask_scale >> update scale.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def _mix32(h: int) -> int:
    """murmur3 fmix32 finalizer on Python ints (masked to 32 bits)."""
    h &= 0xFFFFFFFF
    h ^= h >> 16
    h = (h * 0x85EBCA6B) & 0xFFFFFFFF
    h ^= h >> 13
    h = (h * 0xC2B2AE35) & 0xFFFFFFFF
    h ^= h >> 16
    return h


def pair_seed(i: int, j: int, round_idx: int, session: int = 0) -> int:
    """Shared seed for the (unordered) client pair at a given round.

    In deployment this comes from a Diffie-Hellman exchange; here both
    parties can derive it because they share the session key. A stable
    fmix32 chain, NOT `hash()`: tuple hashing is salted per process under
    PYTHONHASHSEED, so two worker processes would derive DIFFERENT masks
    for the same pair and nothing would cancel.
    `kernels.ref.pair_seed_np` is the bit-exact NumPy twin (regression pin).
    """
    a, b = (i, j) if i < j else (j, i)
    h = _mix32((session & 0xFFFFFFFF) + 0x9E3779B9)
    h = _mix32(h ^ _mix32((round_idx & 0xFFFFFFFF) + 0x9E3779B9))
    h = _mix32(h + (a & 0xFFFFFFFF) * 0x9E3779B1)
    h = _mix32(h ^ ((b & 0xFFFFFFFF) * 0x85EBCA77 & 0xFFFFFFFF))
    return h & 0x7FFFFFFF


def _mask_tree(template: PyTree, seed: int, scale: float) -> PyTree:
    leaves, treedef = jax.tree.flatten(template)
    keys = jax.random.split(jax.random.key(seed), len(leaves))
    masks = [
        scale * jax.random.normal(k, l.shape, jnp.float32) for k, l in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, masks)


def mask_update(update: PyTree, client: int, n_clients: int, round_idx: int, *, scale: float = 1.0, session: int = 0) -> PyTree:
    """Client-side: add pairwise masks (+ for higher peers, − for lower)."""
    out = jax.tree.map(lambda x: x.astype(jnp.float32), update)
    for peer in range(n_clients):
        if peer == client:
            continue
        m = _mask_tree(update, pair_seed(client, peer, round_idx, session), scale)
        sign = 1.0 if peer > client else -1.0
        out = jax.tree.map(lambda a, b: a + sign * b, out, m)
    return out


def aggregate_masked(masked_updates: list[PyTree]) -> PyTree:
    """Server-side: plain sum — the pairwise masks cancel exactly."""
    total = masked_updates[0]
    for u in masked_updates[1:]:
        total = jax.tree.map(jnp.add, total, u)
    return total


def secure_fedavg(updates: list[PyTree], round_idx: int, *, scale: float = 100.0, session: int = 0) -> PyTree:
    """End-to-end: mask every client's update, sum at the server, divide.

    The server never sees an unmasked individual update.
    """
    n = len(updates)
    masked = [
        mask_update(u, i, n, round_idx, scale=scale, session=session)
        for i, u in enumerate(updates)
    ]
    total = aggregate_masked(masked)
    return jax.tree.map(lambda x: x / n, total)
