"""Length-prefixed wire framing for the federation transport (DESIGN.md §14,
crash-tolerance + CRC in §16).

One frame on the socket is::

    u32 length (big-endian, of everything after the CRC field)
    u32 crc32  (of everything after itself: type byte + payload)
    u8  frame type
    ... type-specific payload

Frame types (client -> server unless noted):

    HELLO      client_id u32, protocol u16 — sent once per connection;
               repeating it on a new connection IS the reconnect path
               (the server re-registers the id and redispatches).
    DISPATCH   (server -> client) version u64, encoded row payload
               (`transport.codec`) — the global model the client trains on.
    UPDATE     client_id u32, seq u32 (client-local update index, the batch
               selector), version u64 (ECHO of the DISPATCH version this
               update was trained against — the server refuses an echo that
               does not match the client's current dispatch, which closes
               the superseded-dispatch race: a reconnect or redispatch can
               leave two processes holding dispatches for one client id,
               and an update trained on the older row must never be
               credited to the newer version), loss f32, encoded update
               payload (dense full row or quant8 delta vs the dispatch,
               `codec.encode_update`).
    HEARTBEAT  client_id u32 — liveness only, never touches the engine.
    BYE        (server -> client) empty — orderly shutdown.

Serving-plane frames (DESIGN.md §17; client here = an inference consumer,
not a federated trainer):

    INFER      request_id u32, height u16, width u16, raw little-endian
               f32 image bytes (H*W*3) — one detection request.
    RESULT     (server -> client) request_id u32 (echo), round_version u64
               (the landed training round the serving model was published
               from), freshness tier u8 (serving.TIER_CODES), n u16, then
               n detections of (label i32, score f32, box 4xf32 center
               format) — only valid (NMS-kept) slots ship.
    STATUS     empty payload = request; response = a UTF-8 JSON blob, the
               `serving.model_status` evaluation (version, rounds/seconds
               behind, freshness tier, occupancy counters).

The CRC is the corruption firewall (DESIGN.md §16): a flipped byte anywhere
in the body is *detected* — the parser counts it in ``crc_errors`` and
withholds the frame — instead of landing corrupt model bytes into the
engine and silently diverging from the replay. A mismatched frame is never
yielded; the endpoints treat a CRC error as a poisoned connection (drop it
and let the reconnect/redispatch path recover), because a stream that
corrupted one byte cannot be trusted to have framed the next one honestly.

`FrameParser` is an incremental decoder: feed it arbitrary byte chunks
(TCP gives no message boundaries — frames arrive split and coalesced) and
it yields complete frames in order. The hypothesis round-trip suite in
tests/test_packing_props.py pins encode->feed->parse identity under
adversarial chunkings, and corrupted-byte sweeps in tests/test_transport.py
pin that no corruption ever parses.
"""
from __future__ import annotations

import struct
import zlib

PROTOCOL_VERSION = 3  # v3: serving frames (INFER/RESULT/STATUS); v2: CRC32

HELLO = 1
DISPATCH = 2
UPDATE = 3
HEARTBEAT = 4
BYE = 5
INFER = 6
RESULT = 7
STATUS = 8

FRAME_TYPES = (HELLO, DISPATCH, UPDATE, HEARTBEAT, BYE, INFER, RESULT, STATUS)

_LEN = struct.Struct("!I")
_CRC = struct.Struct("!I")
_HELLO = struct.Struct("!IH")
_DISPATCH = struct.Struct("!Q")
_UPDATE = struct.Struct("!IIQf")
_HEARTBEAT = struct.Struct("!I")
_INFER = struct.Struct("!IHH")
_RESULT = struct.Struct("!IQBH")
_DET = struct.Struct("!ifffff")  # label, score, box (x, y, w, h)

HEADER_BYTES = _LEN.size + _CRC.size  # per-frame framing overhead before the body

# a frame larger than this is a protocol error, not a big model: the row
# payload of a 314B-param arch ships sharded, never as one frame
MAX_FRAME = 1 << 31


def encode_frame(ftype: int, payload: bytes = b"") -> bytes:
    """One wire frame: length prefix + CRC32 + type byte + payload."""
    if ftype not in FRAME_TYPES:
        raise ValueError(f"unknown frame type {ftype}")
    body = bytes([ftype]) + payload
    if len(body) > MAX_FRAME:
        raise ValueError(f"frame of {len(body)} bytes exceeds MAX_FRAME")
    return _LEN.pack(len(body)) + _CRC.pack(zlib.crc32(body)) + body


class FrameParser:
    """Incremental frame decoder over a TCP byte stream.

    `feed(chunk)` returns every frame completed by that chunk as a list of
    ``(ftype, payload)`` tuples; partial frames are buffered across calls.
    A frame whose CRC32 does not match is *withheld* — counted in
    ``crc_errors``, its bytes discarded, parsing continues at the next
    length prefix — so a corrupted frame is detected, never parsed.
    Structurally impossible streams (absurd lengths, an unknown type under
    a *valid* CRC) still raise ``ValueError``: those are protocol bugs, not
    line noise. The parser is transport-agnostic: the socket reader
    threads, the replay tooling, and the property tests all share it.
    """

    def __init__(self):
        self._buf = bytearray()
        self.crc_errors = 0  # frames withheld because their CRC mismatched

    @property
    def pending(self) -> int:
        """Bytes buffered awaiting a complete frame."""
        return len(self._buf)

    def feed(self, chunk: bytes) -> list[tuple[int, bytes]]:
        self._buf.extend(chunk)
        frames: list[tuple[int, bytes]] = []
        while True:
            if len(self._buf) < HEADER_BYTES:
                return frames
            (n,) = _LEN.unpack_from(self._buf, 0)
            if n < 1 or n > MAX_FRAME:
                raise ValueError(f"corrupt frame length {n}")
            if len(self._buf) < HEADER_BYTES + n:
                return frames
            (crc,) = _CRC.unpack_from(self._buf, _LEN.size)
            body = bytes(self._buf[HEADER_BYTES : HEADER_BYTES + n])
            del self._buf[: HEADER_BYTES + n]
            if zlib.crc32(body) != crc:
                # corruption detected: withhold the frame, keep the stream
                # position (the length prefix still told us where it ended)
                self.crc_errors += 1
                continue
            ftype = body[0]
            if ftype not in FRAME_TYPES:
                raise ValueError(f"unknown frame type {ftype}")
            frames.append((ftype, body[1:]))


# -- message payloads --------------------------------------------------------

def pack_hello(client_id: int) -> bytes:
    return encode_frame(HELLO, _HELLO.pack(client_id, PROTOCOL_VERSION))


def parse_hello(payload: bytes) -> int:
    client_id, proto = _HELLO.unpack(payload)
    if proto != PROTOCOL_VERSION:
        raise ValueError(f"protocol version {proto} != {PROTOCOL_VERSION}")
    return client_id


def pack_dispatch(version: int, row_payload: bytes) -> bytes:
    return encode_frame(DISPATCH, _DISPATCH.pack(version) + row_payload)


def parse_dispatch(payload: bytes) -> tuple[int, bytes]:
    (version,) = _DISPATCH.unpack_from(payload, 0)
    return version, payload[_DISPATCH.size :]


def pack_update(client_id: int, seq: int, version: int, loss: float,
                row_payload: bytes) -> bytes:
    return encode_frame(
        UPDATE, _UPDATE.pack(client_id, seq, version, loss) + row_payload
    )


def parse_update(payload: bytes) -> tuple[int, int, int, float, bytes]:
    client_id, seq, version, loss = _UPDATE.unpack_from(payload, 0)
    return client_id, seq, version, loss, payload[_UPDATE.size :]


def pack_heartbeat(client_id: int) -> bytes:
    return encode_frame(HEARTBEAT, _HEARTBEAT.pack(client_id))


def parse_heartbeat(payload: bytes) -> int:
    return _HEARTBEAT.unpack(payload)[0]


def pack_bye() -> bytes:
    return encode_frame(BYE)


# -- serving-plane payloads (DESIGN.md §17) ----------------------------------

def pack_infer(request_id: int, image) -> bytes:
    """INFER payload: one (H, W, 3) f32 image as raw little-endian bytes.
    NumPy-only on purpose — inference consumers need the codec, not JAX."""
    import numpy as np

    img = np.ascontiguousarray(np.asarray(image, np.float32))
    if img.ndim != 3 or img.shape[2] != 3:
        raise ValueError(f"INFER image must be (H, W, 3), got {img.shape}")
    h, w = img.shape[:2]
    if h > 0xFFFF or w > 0xFFFF:
        raise ValueError(f"image {h}x{w} exceeds the u16 frame dimensions")
    return encode_frame(
        INFER, _INFER.pack(request_id, h, w) + img.astype("<f4").tobytes()
    )


def parse_infer(payload: bytes):
    """-> (request_id, image (H, W, 3) f32)."""
    import numpy as np

    request_id, h, w = _INFER.unpack_from(payload, 0)
    body = payload[_INFER.size:]
    if len(body) != h * w * 3 * 4:
        raise ValueError(
            f"INFER body of {len(body)} bytes != {h}x{w}x3 f32 image"
        )
    img = np.frombuffer(body, "<f4").astype(np.float32).reshape(h, w, 3)
    return request_id, img


def pack_result(request_id: int, version: int, tier_code: int,
                detections) -> bytes:
    """RESULT payload: echo + round version + freshness tier + the kept
    detections, each a (label, score, (x, y, w, h)) tuple."""
    dets = list(detections)
    if len(dets) > 0xFFFF:
        raise ValueError(f"{len(dets)} detections exceed the u16 count field")
    body = _RESULT.pack(request_id, version, tier_code, len(dets))
    for label, score, box in dets:
        body += _DET.pack(int(label), float(score), *(float(v) for v in box))
    return encode_frame(RESULT, body)


def parse_result(payload: bytes):
    """-> (request_id, version, tier_code, [(label, score, (x,y,w,h)), ...])."""
    request_id, version, tier_code, n = _RESULT.unpack_from(payload, 0)
    off = _RESULT.size
    if len(payload) != off + n * _DET.size:
        raise ValueError(
            f"RESULT body of {len(payload) - off} bytes != {n} detections"
        )
    dets = []
    for _ in range(n):
        label, score, x, y, w, h = _DET.unpack_from(payload, off)
        off += _DET.size
        dets.append((label, score, (x, y, w, h)))
    return request_id, version, tier_code, dets


def pack_status_request() -> bytes:
    return encode_frame(STATUS)


def pack_status(status: dict) -> bytes:
    import json

    return encode_frame(STATUS, json.dumps(status).encode("utf-8"))


def parse_status(payload: bytes) -> dict | None:
    """None for the empty request form, the status dict for a response."""
    import json

    if not payload:
        return None
    return json.loads(payload.decode("utf-8"))
