"""Wire-run orchestration: meta construction, worker processes, one call
to run a whole multi-process federation (DESIGN.md §14).

`make_meta` builds the run's self-description — the single dict that the
server, every worker process, and the replay harness all derive their
config/engine/batches from (it is also what `ArrivalSchedule` persists).
`wire_run` is the one-call harness the scenario tests and
``launch/train.py --transport socket`` share: build the engine on a
WallClock, start the `WireServer`, spawn worker processes over real
sockets, serve until the flush target, tear everything down, and hand back
the schedule + stats + final global row.

Workers are real OS processes (``python -m repro.launch.worker``). One
process can host several client loops in threads (``client_ids``) — that
amortizes the JAX import/jit cost across clients — while scenario-specific
clients (the crasher, the straggler) get their own process so killing or
delaying them touches nobody else.
"""
from __future__ import annotations

import dataclasses
import errno
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path
from typing import Any

import numpy as np

from repro.checkpoint.durable import DurableRun
from repro.core.simclock import WallClock
from repro.core.transport import replay as rp
from repro.core.transport.faults import FaultPlan, ServerKilled
from repro.core.transport.server import WireRunStats, WireServer

# shrink the reduced arch further for multi-process tests: every worker
# process re-jits the row update, so the model should be as small as the
# transformer stack allows while still exercising real packed rows
TINY_OVERRIDES = {"d_model": 64, "n_heads": 2, "n_kv_heads": 1, "d_ff": 128, "vocab_size": 128}

_run_counter = 0  # distinguishes WIRE_SCHEDULE_DIR dumps within one process


def make_meta(
    arch: str = "qwen3-1.7b",
    *,
    reduced: bool = True,
    overrides: dict | None = None,
    n_clients: int = 4,
    buffer_size: int = 2,
    max_staleness: int = 2,
    staleness_alpha: float = 0.5,
    aggregation: str = "dense",
    local_steps: int = 1,
    batch: int = 2,
    seq: int = 16,
    seed: int = 0,
    lr: float = 0.05,
    wire_codec: str = "dense",
    quant_block: int = 1024,
    queue_cap: int = 0,
    heartbeat_s: float = 0.2,
    heartbeat_timeout_s: float = 2.0,
) -> dict[str, Any]:
    return {
        "arch": arch,
        "reduced": reduced,
        "overrides": dict(overrides) if overrides else {},
        "n_clients": n_clients,
        "buffer_size": buffer_size,
        "max_staleness": max_staleness,
        "staleness_alpha": staleness_alpha,
        "aggregation": aggregation,
        "local_steps": local_steps,
        "batch": batch,
        "seq": seq,
        "seed": seed,
        "lr": lr,
        "transport": "socket",
        "wire_codec": wire_codec,
        "quant_block": quant_block,
        "queue_cap": queue_cap,
        "heartbeat_s": heartbeat_s,
        "heartbeat_timeout_s": heartbeat_timeout_s,
    }


def worker_cmd(meta_path: str, host: str, port: int, client_ids: list[int],
               extra: list[str] | None = None) -> list[str]:
    return [
        sys.executable, "-m", "repro.launch.worker",
        "--host", host, "--port", str(port),
        "--meta", meta_path,
        "--client-ids", ",".join(str(c) for c in client_ids),
        *(extra or []),
    ]


def spawn_worker(meta_path: str, host: str, port: int, client_ids: list[int],
                 extra: list[str] | None = None) -> subprocess.Popen:
    src = Path(rp.__file__).resolve().parents[3]  # .../src
    env = {
        **os.environ,
        "PYTHONPATH": f"{src}{os.pathsep}{os.environ.get('PYTHONPATH', '')}".rstrip(os.pathsep),
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
    }
    return subprocess.Popen(
        worker_cmd(meta_path, host, port, client_ids, extra),
        env=env,
        stdout=subprocess.DEVNULL,
        stderr=subprocess.PIPE,
    )


@dataclasses.dataclass
class WireRunResult:
    meta: dict
    stats: WireRunStats
    schedule: rp.ArrivalSchedule
    history: list  # AsyncRoundRecord flushes, wall-clock arrival order
    global_row: np.ndarray  # final (N_total,) packed global
    dropped_total: int
    liveness_log: list[tuple[float, int, str]]
    worker_stderr: dict[str, str] = dataclasses.field(default_factory=dict)
    recovered: bool = False  # the run crossed a server kill + restore
    pre_crash_stats: WireRunStats | None = None  # first incarnation's counters


def _merge_stats(a: WireRunStats, b: WireRunStats) -> WireRunStats:
    """Whole-run counters across a crash: sums, maxes, ors as appropriate."""
    out = WireRunStats()
    for f in dataclasses.fields(WireRunStats):
        x, y = getattr(a, f.name), getattr(b, f.name)
        if f.name in ("queue_high_water", "faults_injected"):
            # high-water is a max by nature; faults_injected reads the ONE
            # shared plan's cumulative fire count on both sides of a crash
            setattr(out, f.name, max(x, y))
        elif isinstance(x, bool):
            setattr(out, f.name, x or y)
        else:
            setattr(out, f.name, x + y)
    return out


def wire_run(
    meta: dict,
    n_flushes: int,
    *,
    worker_groups: list[dict] | None = None,
    deadline_s: float = 180.0,
    land_delay_s: float = 0.0,
    port: int = 0,
    hooks=None,
    durable_root: str | Path | None = None,
    snapshot_every: int = 0,
    fault_plan: str = "",
    fault_seed: int = 0,
    recover: bool = True,
) -> WireRunResult:
    """One multi-process federation: engine + WireServer + worker processes.

    worker_groups: list of ``{"client_ids": [...], "extra": [cli flags]}``
    — one worker process per entry (default: all clients in one process).
    hooks: optional ``fn(server, workers)`` called right after workers
    spawn, before `serve` — scenario tests use it to kill a process mid-run.

    Durability + chaos (DESIGN.md §16): ``durable_root`` gives the run a
    `DurableRun` directory (landing WAL + snapshots every
    ``snapshot_every`` landings). ``fault_plan`` is a `faults.FaultPlan`
    spec applied on BOTH ends — the server wraps accepted sockets with its
    ``server.``-side ops (and honours ``kill@M``), worker processes get the
    same spec via ``--fault-plan`` for the ``client.``-side ops. When the
    plan kills the server and ``recover`` is set (and the run is durable),
    the harness rebuilds the engine from snapshot+WAL, rebinds the SAME
    port, and serves the remaining flushes — the still-running workers
    reconnect through their backoff loop. The result carries the COMBINED
    schedule (from the WAL — it spans the crash) and merged stats.

    With ``WIRE_SCHEDULE_DIR`` set in the environment, every run saves its
    recorded arrival schedule there (CI uploads the directory as an
    artifact on failure, so a red wire test can be replay-debugged locally
    via ``train.py --replay-schedule`` without rerunning the subprocesses).
    """
    faults = FaultPlan.parse(fault_plan, seed=fault_seed) if fault_plan else None
    durable = DurableRun(durable_root, meta) if durable_root else None
    engine = rp.make_engine(meta, clock=WallClock())
    server = WireServer(engine, port=port, land_delay_s=land_delay_s,
                        durable=durable, snapshot_every=snapshot_every,
                        faults=faults)
    server.schedule.meta = dict(meta)
    groups = worker_groups or [{"client_ids": list(range(meta["n_clients"]))}]
    workers: list[subprocess.Popen] = []
    stderrs: dict[str, str] = {}
    pre_crash: WireRunStats | None = None
    recovered = False
    with tempfile.TemporaryDirectory(prefix="fedwire_") as td:
        meta_path = str(Path(td) / "meta.json")
        Path(meta_path).write_text(json.dumps(meta))
        server.start()
        try:
            for g in groups:
                extra = list(g.get("extra") or [])
                if fault_plan and "--fault-plan" not in extra:
                    extra += ["--fault-plan", fault_plan,
                              "--fault-seed", str(fault_seed)]
                workers.append(
                    spawn_worker(meta_path, server.host, server.port,
                                 g["client_ids"], extra)
                )
            if hooks is not None:
                hooks(server, workers)
            try:
                server.serve(n_flushes, deadline_s=deadline_s)
            except ServerKilled:
                if not (recover and durable is not None):
                    raise
                # -- crash recovery (DESIGN.md §16) --------------------------
                # everything below reads ONLY what survived on disk: the
                # first server's in-memory engine is dead to us, exactly as
                # it would be after a real kill -9.
                pre_crash = server.stats
                old_port = server.port
                durable2 = DurableRun(durable_root)
                events = durable2.events()
                resume_t = events[-1].t if events else 0.0
                engine2, _ = durable2.recover_engine(clock=WallClock(start=resume_t))
                # the killed listener's port lingers until its blocked
                # accept() returns (kill() pops it, but a straggling
                # reconnect can re-arm the race) — retry the rebind
                for _ in range(40):
                    try:
                        server = WireServer(
                            engine2, port=old_port, land_delay_s=land_delay_s,
                            durable=durable2, snapshot_every=snapshot_every,
                            faults=faults, recovered=True,
                        )
                        break
                    except OSError as e:
                        if e.errno != errno.EADDRINUSE:
                            raise
                        time.sleep(0.25)
                else:
                    raise ConnectionError(
                        f"recovery could not rebind port {old_port}")
                server.schedule.meta = dict(meta)
                # splice histories: the recovered engine replayed flushes
                # since its snapshot; earlier rounds live in engine1's record
                cut = engine2.history[0].round_idx if engine2.history else engine2.version
                hist_prefix = [r for r in engine.history if r.round_idx < cut]
                engine2.history[:0] = hist_prefix
                engine = engine2
                recovered = True
                server.start()
                server.serve(n_flushes - engine2.version, deadline_s=deadline_s)
        finally:
            server.stop()
            deadline = time.monotonic() + 20.0
            for i, p in enumerate(workers):
                try:
                    _, err = p.communicate(timeout=max(0.1, deadline - time.monotonic()))
                except subprocess.TimeoutExpired:
                    p.kill()
                    _, err = p.communicate()
                if err:
                    stderrs[f"worker{i}"] = err.decode("utf-8", "replace")[-4000:]
    # the WAL spans the crash, so it — not either server's in-memory record
    # — is the run's full schedule once a recovery happened
    schedule = durable.schedule() if (durable is not None and recovered) else server.schedule
    if durable is not None:
        durable.close()
    dump_dir = os.environ.get("WIRE_SCHEDULE_DIR")
    if dump_dir:
        global _run_counter
        _run_counter += 1
        Path(dump_dir).mkdir(parents=True, exist_ok=True)
        schedule.save(
            Path(dump_dir) / f"schedule_{os.getpid()}_{_run_counter:03d}.json"
        )
    stats = _merge_stats(pre_crash, server.stats) if pre_crash else server.stats
    return WireRunResult(
        meta=meta,
        stats=stats,
        schedule=schedule,
        history=list(engine.history),
        global_row=np.asarray(engine.global_packed_row(), np.float32),
        dropped_total=engine.dropped_total,
        liveness_log=list(server.liveness_log),
        worker_stderr=stderrs,
        recovered=recovered,
        pre_crash_stats=pre_crash,
    )
