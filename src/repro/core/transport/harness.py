"""Wire-run orchestration: meta construction, worker processes, one call
to run a whole multi-process federation (DESIGN.md §14).

`make_meta` builds the run's self-description — the single dict that the
server, every worker process, and the replay harness all derive their
config/engine/batches from (it is also what `ArrivalSchedule` persists).
`wire_run` is the one-call harness the scenario tests and
``launch/train.py --transport socket`` share: build the engine on a
WallClock, start the `WireServer`, spawn worker processes over real
sockets, serve until the flush target, tear everything down, and hand back
the schedule + stats + final global row.

Workers are real OS processes (``python -m repro.launch.worker``). One
process can host several client loops in threads (``client_ids``) — that
amortizes the JAX import/jit cost across clients — while scenario-specific
clients (the crasher, the straggler) get their own process so killing or
delaying them touches nobody else.
"""
from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path
from typing import Any

import numpy as np

from repro.core.simclock import WallClock
from repro.core.transport import replay as rp
from repro.core.transport.server import WireRunStats, WireServer

# shrink the reduced arch further for multi-process tests: every worker
# process re-jits the row update, so the model should be as small as the
# transformer stack allows while still exercising real packed rows
TINY_OVERRIDES = {"d_model": 64, "n_heads": 2, "n_kv_heads": 1, "d_ff": 128, "vocab_size": 128}

_run_counter = 0  # distinguishes WIRE_SCHEDULE_DIR dumps within one process


def make_meta(
    arch: str = "qwen3-1.7b",
    *,
    reduced: bool = True,
    overrides: dict | None = None,
    n_clients: int = 4,
    buffer_size: int = 2,
    max_staleness: int = 2,
    staleness_alpha: float = 0.5,
    aggregation: str = "dense",
    local_steps: int = 1,
    batch: int = 2,
    seq: int = 16,
    seed: int = 0,
    lr: float = 0.05,
    wire_codec: str = "dense",
    quant_block: int = 1024,
    queue_cap: int = 0,
    heartbeat_s: float = 0.2,
    heartbeat_timeout_s: float = 2.0,
) -> dict[str, Any]:
    return {
        "arch": arch,
        "reduced": reduced,
        "overrides": dict(overrides) if overrides else {},
        "n_clients": n_clients,
        "buffer_size": buffer_size,
        "max_staleness": max_staleness,
        "staleness_alpha": staleness_alpha,
        "aggregation": aggregation,
        "local_steps": local_steps,
        "batch": batch,
        "seq": seq,
        "seed": seed,
        "lr": lr,
        "transport": "socket",
        "wire_codec": wire_codec,
        "quant_block": quant_block,
        "queue_cap": queue_cap,
        "heartbeat_s": heartbeat_s,
        "heartbeat_timeout_s": heartbeat_timeout_s,
    }


def worker_cmd(meta_path: str, host: str, port: int, client_ids: list[int],
               extra: list[str] | None = None) -> list[str]:
    return [
        sys.executable, "-m", "repro.launch.worker",
        "--host", host, "--port", str(port),
        "--meta", meta_path,
        "--client-ids", ",".join(str(c) for c in client_ids),
        *(extra or []),
    ]


def spawn_worker(meta_path: str, host: str, port: int, client_ids: list[int],
                 extra: list[str] | None = None) -> subprocess.Popen:
    src = Path(rp.__file__).resolve().parents[3]  # .../src
    env = {
        **os.environ,
        "PYTHONPATH": f"{src}{os.pathsep}{os.environ.get('PYTHONPATH', '')}".rstrip(os.pathsep),
        "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu"),
    }
    return subprocess.Popen(
        worker_cmd(meta_path, host, port, client_ids, extra),
        env=env,
        stdout=subprocess.DEVNULL,
        stderr=subprocess.PIPE,
    )


@dataclasses.dataclass
class WireRunResult:
    meta: dict
    stats: WireRunStats
    schedule: rp.ArrivalSchedule
    history: list  # AsyncRoundRecord flushes, wall-clock arrival order
    global_row: np.ndarray  # final (N_total,) packed global
    dropped_total: int
    liveness_log: list[tuple[float, int, str]]
    worker_stderr: dict[str, str] = dataclasses.field(default_factory=dict)


def wire_run(
    meta: dict,
    n_flushes: int,
    *,
    worker_groups: list[dict] | None = None,
    deadline_s: float = 180.0,
    land_delay_s: float = 0.0,
    port: int = 0,
    hooks=None,
) -> WireRunResult:
    """One multi-process federation: engine + WireServer + worker processes.

    worker_groups: list of ``{"client_ids": [...], "extra": [cli flags]}``
    — one worker process per entry (default: all clients in one process).
    hooks: optional ``fn(server, workers)`` called right after workers
    spawn, before `serve` — scenario tests use it to kill a process mid-run.

    With ``WIRE_SCHEDULE_DIR`` set in the environment, every run saves its
    recorded arrival schedule there (CI uploads the directory as an
    artifact on failure, so a red wire test can be replay-debugged locally
    via ``train.py --replay-schedule`` without rerunning the subprocesses).
    """
    engine = rp.make_engine(meta, clock=WallClock())
    server = WireServer(engine, port=port, land_delay_s=land_delay_s)
    server.schedule.meta = dict(meta)
    groups = worker_groups or [{"client_ids": list(range(meta["n_clients"]))}]
    workers: list[subprocess.Popen] = []
    stderrs: dict[str, str] = {}
    with tempfile.TemporaryDirectory(prefix="fedwire_") as td:
        meta_path = str(Path(td) / "meta.json")
        Path(meta_path).write_text(json.dumps(meta))
        server.start()
        try:
            for g in groups:
                workers.append(
                    spawn_worker(meta_path, server.host, server.port,
                                 g["client_ids"], g.get("extra"))
                )
            if hooks is not None:
                hooks(server, workers)
            server.serve(n_flushes, deadline_s=deadline_s)
        finally:
            server.stop()
            deadline = time.monotonic() + 20.0
            for i, p in enumerate(workers):
                try:
                    _, err = p.communicate(timeout=max(0.1, deadline - time.monotonic()))
                except subprocess.TimeoutExpired:
                    p.kill()
                    _, err = p.communicate()
                if err:
                    stderrs[f"worker{i}"] = err.decode("utf-8", "replace")[-4000:]
    dump_dir = os.environ.get("WIRE_SCHEDULE_DIR")
    if dump_dir:
        global _run_counter
        _run_counter += 1
        Path(dump_dir).mkdir(parents=True, exist_ok=True)
        server.schedule.save(
            Path(dump_dir) / f"schedule_{os.getpid()}_{_run_counter:03d}.json"
        )
    return WireRunResult(
        meta=meta,
        stats=server.stats,
        schedule=server.schedule,
        history=list(engine.history),
        global_row=np.asarray(engine.global_packed_row(), np.float32),
        dropped_total=engine.dropped_total,
        liveness_log=list(server.liveness_log),
        worker_stderr=stderrs,
    )
