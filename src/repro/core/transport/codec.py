"""Row payload codec: the bytes inside DISPATCH/UPDATE frames (DESIGN.md §14).

Two codecs, selected by ``FedConfig.wire_codec``:

    dense   — the full row as raw little-endian bytes in its own dtype.
              Lossless: encode -> decode is bit-identical, which is what
              lets a recorded dense wire run replay bit-for-bit.
    quant8  — the paper's 4x uplink cut finally carrying real wire bytes:
              the **delta** vs the dispatch row, int8-quantized with one
              f32 scale per ``block`` elements (symmetric, the
              `core.compression` / quant8-aggregator scheme). Deltas, not
              rows: a trained row's quantization step would be set by the
              weight magnitudes and destroy the (lr-sized) update signal;
              the delta's step is set by the update itself.

All arithmetic is NumPy in float32 — deterministic across processes, so
the replay harness reproduces a worker's encoded bytes exactly by running
the same codec on the same trained row.

Payload layout (after the 1-byte codec tag):

    dense:  u8 dtype code, u32 n, raw bytes
    quant8: u32 n, u32 block, ceil(n/block) f32 scales, n int8 values
"""
from __future__ import annotations

import struct

import numpy as np

DENSE = 0
QUANT8 = 1

CODECS = {"dense": DENSE, "quant8": QUANT8}
CODEC_NAMES = {v: k for k, v in CODECS.items()}

_DTYPES = {0: np.float32, 1: np.float16, 2: np.float64}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}

_DENSE_HDR = struct.Struct("!BI")
_QUANT_HDR = struct.Struct("!II")


def _as_row(x) -> np.ndarray:
    row = np.asarray(x)
    if row.ndim != 1:
        raise ValueError(f"codec rows are 1-D packed rows, got shape {row.shape}")
    return row


# -- dense -------------------------------------------------------------------

def encode_dense(row) -> bytes:
    row = _as_row(row)
    if row.dtype not in _DTYPE_CODES:
        raise ValueError(f"unsupported row dtype {row.dtype}")
    hdr = _DENSE_HDR.pack(_DTYPE_CODES[row.dtype], row.size)
    return bytes([DENSE]) + hdr + row.astype(row.dtype.newbyteorder("<")).tobytes()


def _decode_dense(buf: bytes) -> np.ndarray:
    code, n = _DENSE_HDR.unpack_from(buf, 0)
    if code not in _DTYPES:
        raise ValueError(f"unknown dtype code {code}")
    dt = np.dtype(_DTYPES[code]).newbyteorder("<")
    body = buf[_DENSE_HDR.size :]
    if len(body) != n * dt.itemsize:
        raise ValueError(f"dense payload of {len(body)} bytes != {n} x {dt.itemsize}")
    return np.frombuffer(body, dt, count=n).astype(_DTYPES[code])


# -- quant8 ------------------------------------------------------------------

def quantize_blocks(x: np.ndarray, block: int) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric blockwise int8: one f32 scale per `block` elements
    (amax/127, floored so an all-zero block stays exactly zero)."""
    if block < 1:
        raise ValueError(f"quant block must be >= 1, got {block}")
    x = np.asarray(x, np.float32)
    n = x.size
    nb = -(-n // block)
    padded = np.zeros(nb * block, np.float32)
    padded[:n] = x
    x2 = padded.reshape(nb, block)
    scale = (np.maximum(np.abs(x2).max(axis=1), 1e-12) / np.float32(127.0)).astype(
        np.float32
    )
    q = np.clip(np.rint(x2 / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize_blocks(q: np.ndarray, scale: np.ndarray, n: int) -> np.ndarray:
    return (q.astype(np.float32) * scale[:, None].astype(np.float32)).reshape(-1)[:n]


def encode_quant8(row, block: int) -> bytes:
    row = _as_row(row)
    q, scale = quantize_blocks(row, block)
    hdr = _QUANT_HDR.pack(row.size, block)
    return (
        bytes([QUANT8])
        + hdr
        + scale.astype("<f4").tobytes()
        + q.tobytes()
    )


def _decode_quant8(buf: bytes) -> np.ndarray:
    n, block = _QUANT_HDR.unpack_from(buf, 0)
    nb = -(-n // block)
    off = _QUANT_HDR.size
    scale = np.frombuffer(buf, "<f4", count=nb, offset=off).astype(np.float32)
    off += nb * 4
    q = np.frombuffer(buf, np.int8, count=nb * block, offset=off).reshape(nb, block)
    if len(buf) != off + nb * block:
        raise ValueError("quant8 payload size mismatch")
    return dequantize_blocks(q, scale, n)


# -- update/dispatch payloads ------------------------------------------------

def encode_row(row, codec: str = "dense", block: int = 1024) -> bytes:
    """DISPATCH payload: dense always (downlink is not the FL bottleneck —
    FedVision's asymmetry is camera uplink — and a lossless dispatch keeps
    the worker training on exactly the server's row)."""
    if codec not in CODECS:
        raise ValueError(f"unknown wire codec {codec!r}; expected {sorted(CODECS)}")
    return encode_dense(row)


def decode_row(buf: bytes) -> np.ndarray:
    if not buf:
        raise ValueError("empty row payload")
    tag = buf[0]
    if tag == DENSE:
        return _decode_dense(buf[1:])
    if tag == QUANT8:
        return _decode_quant8(buf[1:])
    raise ValueError(f"unknown codec tag {tag}")


def encode_update(row_new, row_base, codec: str = "dense", block: int = 1024) -> bytes:
    """UPDATE payload: the trained row (dense) or its int8 delta (quant8)."""
    if codec == "dense":
        return encode_dense(row_new)
    if codec == "quant8":
        delta = np.asarray(row_new, np.float32) - np.asarray(row_base, np.float32)
        return encode_quant8(delta, block)
    raise ValueError(f"unknown wire codec {codec!r}; expected {sorted(CODECS)}")


def decode_update(buf: bytes, row_base) -> np.ndarray:
    """Inverse of `encode_update`: quant8 payloads land as
    base + dequant(delta); dense payloads are the row itself."""
    if not buf:
        raise ValueError("empty update payload")
    if buf[0] == DENSE:
        return _decode_dense(buf[1:])
    if buf[0] == QUANT8:
        return np.asarray(row_base, np.float32) + _decode_quant8(buf[1:])
    raise ValueError(f"unknown codec tag {buf[0]}")


def payload_bytes(n: int, codec: str, block: int = 1024, itemsize: int = 4) -> int:
    """Analytic payload size (the BENCH payload-bytes rows)."""
    if codec == "dense":
        return 1 + _DENSE_HDR.size + n * itemsize
    if codec == "quant8":
        nb = -(-n // block)
        return 1 + _QUANT_HDR.size + nb * 4 + nb * block
    raise ValueError(f"unknown wire codec {codec!r}")
