"""Row payload codec: the bytes inside DISPATCH/UPDATE frames (DESIGN.md §14).

Four codecs, selected by ``FedConfig.wire_codec``:

    dense   — the full row as raw little-endian bytes in its own dtype.
              Lossless: encode -> decode is bit-identical, which is what
              lets a recorded dense wire run replay bit-for-bit.
    quant8  — the paper's 4x uplink cut finally carrying real wire bytes:
              the **delta** vs the dispatch row, int8-quantized with one
              f32 scale per ``block`` elements (symmetric, the
              `core.compression` / quant8-aggregator scheme). Deltas, not
              rows: a trained row's quantization step would be set by the
              weight magnitudes and destroy the (lr-sized) update signal;
              the delta's step is set by the update itself.
    quant4  — the DESIGN.md §15 frontier on the wire: the delta with one
              f32 scale per block and values in [-7, 7], packed two
              two's-complement nibbles per byte (~8x under dense).
              Nearest rounding: the wire has no shared per-round key, and
              a deterministic codec is what replay pins against.
    topk    — sparse delta: a selection bitmap (the top ceil(frac * n)
              magnitudes) + int8-quantized selected values. At frac = 0.1
              the payload is ~0.23 bytes/element — >4x under quant8.

All arithmetic is NumPy in float32 — deterministic across processes, so
the replay harness reproduces a worker's encoded bytes exactly by running
the same codec on the same trained row. The 4-bit/sparse primitives are
pinned bit-for-bit against the `kernels.ref` oracles.

Payload layout (after the 1-byte codec tag):

    dense:  u8 dtype code, u32 n, raw bytes
    quant8: u32 n, u32 block, ceil(n/block) f32 scales, n int8 values
    quant4: u32 n, u32 block, ceil(n/block) f32 scales, ceil(n/2) nibble bytes
    topk:   u32 n, u32 block, ceil(n/8) bitmap, ceil(k/block) f32 scales,
            k int8 values (k = popcount(bitmap); values in bitmap order)
"""
from __future__ import annotations

import struct

import numpy as np

DENSE = 0
QUANT8 = 1
QUANT4 = 2
TOPK = 3

CODECS = {"dense": DENSE, "quant8": QUANT8, "quant4": QUANT4, "topk": TOPK}
CODEC_NAMES = {v: k for k, v in CODECS.items()}

TOPK_FRAC = 0.1  # wire-codec upload fraction (the aggregator-side knob is
# FedConfig.topk_frac; the codec keeps one fixed ratio so both endpoints
# frame identically without negotiating)

_DTYPES = {0: np.float32, 1: np.float16, 2: np.float64}
_DTYPE_CODES = {np.dtype(v): k for k, v in _DTYPES.items()}

_DENSE_HDR = struct.Struct("!BI")
_QUANT_HDR = struct.Struct("!II")


def _as_row(x) -> np.ndarray:
    row = np.asarray(x)
    if row.ndim != 1:
        raise ValueError(f"codec rows are 1-D packed rows, got shape {row.shape}")
    return row


# -- dense -------------------------------------------------------------------

def encode_dense(row) -> bytes:
    row = _as_row(row)
    if row.dtype not in _DTYPE_CODES:
        raise ValueError(f"unsupported row dtype {row.dtype}")
    hdr = _DENSE_HDR.pack(_DTYPE_CODES[row.dtype], row.size)
    return bytes([DENSE]) + hdr + row.astype(row.dtype.newbyteorder("<")).tobytes()


def _decode_dense(buf: bytes) -> np.ndarray:
    code, n = _DENSE_HDR.unpack_from(buf, 0)
    if code not in _DTYPES:
        raise ValueError(f"unknown dtype code {code}")
    dt = np.dtype(_DTYPES[code]).newbyteorder("<")
    body = buf[_DENSE_HDR.size :]
    if len(body) != n * dt.itemsize:
        raise ValueError(f"dense payload of {len(body)} bytes != {n} x {dt.itemsize}")
    return np.frombuffer(body, dt, count=n).astype(_DTYPES[code])


# -- quant8 ------------------------------------------------------------------

def quantize_blocks(x: np.ndarray, block: int) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric blockwise int8: one f32 scale per `block` elements
    (amax/127, floored so an all-zero block stays exactly zero)."""
    if block < 1:
        raise ValueError(f"quant block must be >= 1, got {block}")
    x = np.asarray(x, np.float32)
    n = x.size
    nb = -(-n // block)
    padded = np.zeros(nb * block, np.float32)
    padded[:n] = x
    x2 = padded.reshape(nb, block)
    scale = (np.maximum(np.abs(x2).max(axis=1), 1e-12) / np.float32(127.0)).astype(
        np.float32
    )
    q = np.clip(np.rint(x2 / scale[:, None]), -127, 127).astype(np.int8)
    return q, scale


def dequantize_blocks(q: np.ndarray, scale: np.ndarray, n: int) -> np.ndarray:
    return (q.astype(np.float32) * scale[:, None].astype(np.float32)).reshape(-1)[:n]


def encode_quant8(row, block: int) -> bytes:
    row = _as_row(row)
    q, scale = quantize_blocks(row, block)
    hdr = _QUANT_HDR.pack(row.size, block)
    return (
        bytes([QUANT8])
        + hdr
        + scale.astype("<f4").tobytes()
        + q.tobytes()
    )


def _decode_quant8(buf: bytes) -> np.ndarray:
    n, block = _QUANT_HDR.unpack_from(buf, 0)
    nb = -(-n // block)
    off = _QUANT_HDR.size
    scale = np.frombuffer(buf, "<f4", count=nb, offset=off).astype(np.float32)
    off += nb * 4
    q = np.frombuffer(buf, np.int8, count=nb * block, offset=off).reshape(nb, block)
    if len(buf) != off + nb * block:
        raise ValueError("quant8 payload size mismatch")
    return dequantize_blocks(q, scale, n)


# -- quant4 ------------------------------------------------------------------

def quantize4_blocks(x: np.ndarray, block: int) -> tuple[np.ndarray, np.ndarray]:
    """Symmetric blockwise 4-bit (nearest): one f32 scale per `block`
    elements, amax/7 — the wire twin of `kernels.ref.quant4_blocks_np`."""
    if block < 1:
        raise ValueError(f"quant block must be >= 1, got {block}")
    x = np.asarray(x, np.float32)
    n = x.size
    nb = -(-n // block)
    padded = np.zeros(nb * block, np.float32)
    padded[:n] = x
    x2 = padded.reshape(nb, block)
    scale = (np.maximum(np.abs(x2).max(axis=1), 1e-12) / np.float32(7.0)).astype(np.float32)
    q = np.clip(np.rint(x2 / scale[:, None]), -7, 7).astype(np.int8)
    return q, scale


def pack_nibbles(q: np.ndarray) -> bytes:
    """int8 values in [-8, 7] -> two two's-complement nibbles per byte."""
    u = np.asarray(q, np.int8).reshape(-1).astype(np.uint8) & np.uint8(0xF)
    if len(u) % 2:
        u = np.append(u, np.uint8(0))
    return (u[0::2] | (u[1::2] << np.uint8(4))).astype(np.uint8).tobytes()


def unpack_nibbles(buf: bytes, n: int) -> np.ndarray:
    """Inverse of `pack_nibbles`: first n sign-extended int8 values."""
    b = np.frombuffer(buf, np.uint8)
    u = np.empty(len(b) * 2, np.uint8)
    u[0::2] = b & np.uint8(0xF)
    u[1::2] = b >> np.uint8(4)
    return ((u[:n].astype(np.int16) ^ 8) - 8).astype(np.int8)


def encode_quant4(row, block: int) -> bytes:
    row = _as_row(row)
    q, scale = quantize4_blocks(row, block)
    hdr = _QUANT_HDR.pack(row.size, block)
    return bytes([QUANT4]) + hdr + scale.astype("<f4").tobytes() + pack_nibbles(q)


def _decode_quant4(buf: bytes) -> np.ndarray:
    n, block = _QUANT_HDR.unpack_from(buf, 0)
    nb = -(-n // block)
    off = _QUANT_HDR.size
    scale = np.frombuffer(buf, "<f4", count=nb, offset=off).astype(np.float32)
    off += nb * 4
    nbytes = -(-(nb * block) // 2)
    if len(buf) != off + nbytes:
        raise ValueError("quant4 payload size mismatch")
    q = unpack_nibbles(buf[off:], nb * block).reshape(nb, block)
    return dequantize_blocks(q, scale, n)


# -- topk (sparse delta) -----------------------------------------------------

def topk_indices(delta: np.ndarray, frac: float = TOPK_FRAC) -> np.ndarray:
    """Sorted indices of the ceil(frac * n) largest-|value| entries.
    Deterministic tie-break (last index wins via argpartition on (|v|, i))."""
    delta = np.asarray(delta, np.float32)
    n = delta.size
    k = max(1, min(n, int(-(-frac * n // 1))))
    idx = np.argpartition(np.abs(delta), n - k)[n - k:]
    return np.sort(idx)


def encode_topk(delta, block: int, frac: float = TOPK_FRAC) -> bytes:
    """Bitmap of the selected positions + int8-quantized selected values
    (quantized as a dense k-vector, one scale per `block` of it)."""
    delta = _as_row(np.asarray(delta, np.float32))
    n = delta.size
    idx = topk_indices(delta, frac)
    bitmap = np.zeros(n, np.uint8)
    bitmap[idx] = 1
    q, scale = quantize_blocks(delta[idx], block)
    hdr = _QUANT_HDR.pack(n, block)
    return (
        bytes([TOPK])
        + hdr
        + np.packbits(bitmap).tobytes()
        + scale.astype("<f4").tobytes()
        + q.reshape(-1)[: idx.size].tobytes()
    )


def _decode_topk(buf: bytes) -> np.ndarray:
    n, block = _QUANT_HDR.unpack_from(buf, 0)
    off = _QUANT_HDR.size
    nbm = -(-n // 8)
    bitmap = np.unpackbits(np.frombuffer(buf, np.uint8, count=nbm, offset=off))[:n]
    off += nbm
    k = int(bitmap.sum())
    nb = -(-k // block)
    scale = np.frombuffer(buf, "<f4", count=nb, offset=off).astype(np.float32)
    off += nb * 4
    if len(buf) != off + k:
        raise ValueError("topk payload size mismatch")
    qv = np.frombuffer(buf, np.int8, count=k, offset=off)
    qp = np.zeros(nb * block, np.int8)
    qp[:k] = qv
    vals = dequantize_blocks(qp.reshape(nb, block), scale, k)
    delta = np.zeros(n, np.float32)
    delta[bitmap.astype(bool)] = vals
    return delta


# -- update/dispatch payloads ------------------------------------------------

def encode_row(row, codec: str = "dense", block: int = 1024) -> bytes:
    """DISPATCH payload: dense always (downlink is not the FL bottleneck —
    FedVision's asymmetry is camera uplink — and a lossless dispatch keeps
    the worker training on exactly the server's row)."""
    if codec not in CODECS:
        raise ValueError(f"unknown wire codec {codec!r}; expected {sorted(CODECS)}")
    return encode_dense(row)


def decode_row(buf: bytes) -> np.ndarray:
    if not buf:
        raise ValueError("empty row payload")
    tag = buf[0]
    if tag == DENSE:
        return _decode_dense(buf[1:])
    if tag == QUANT8:
        return _decode_quant8(buf[1:])
    if tag == QUANT4:
        return _decode_quant4(buf[1:])
    if tag == TOPK:
        return _decode_topk(buf[1:])
    raise ValueError(f"unknown codec tag {tag}")


def encode_update(row_new, row_base, codec: str = "dense", block: int = 1024) -> bytes:
    """UPDATE payload: the trained row (dense) or its int8 delta (quant8)."""
    if codec == "dense":
        return encode_dense(row_new)
    if codec in ("quant8", "quant4", "topk"):
        delta = np.asarray(row_new, np.float32) - np.asarray(row_base, np.float32)
        if codec == "quant8":
            return encode_quant8(delta, block)
        if codec == "quant4":
            return encode_quant4(delta, block)
        return encode_topk(delta, block)
    raise ValueError(f"unknown wire codec {codec!r}; expected {sorted(CODECS)}")


def decode_update(buf: bytes, row_base) -> np.ndarray:
    """Inverse of `encode_update`: quant8 payloads land as
    base + dequant(delta); dense payloads are the row itself."""
    if not buf:
        raise ValueError("empty update payload")
    if buf[0] == DENSE:
        return _decode_dense(buf[1:])
    if buf[0] == QUANT8:
        return np.asarray(row_base, np.float32) + _decode_quant8(buf[1:])
    if buf[0] == QUANT4:
        return np.asarray(row_base, np.float32) + _decode_quant4(buf[1:])
    if buf[0] == TOPK:
        return np.asarray(row_base, np.float32) + _decode_topk(buf[1:])
    raise ValueError(f"unknown codec tag {buf[0]}")


def payload_bytes(n: int, codec: str, block: int = 1024, itemsize: int = 4) -> int:
    """Analytic payload size (the BENCH payload-bytes rows)."""
    if codec == "dense":
        return 1 + _DENSE_HDR.size + n * itemsize
    if codec == "quant8":
        nb = -(-n // block)
        return 1 + _QUANT_HDR.size + nb * 4 + nb * block
    if codec == "quant4":
        nb = -(-n // block)
        return 1 + _QUANT_HDR.size + nb * 4 + -(-(nb * block) // 2)
    if codec == "topk":
        k = max(1, min(n, int(-(-TOPK_FRAC * n // 1))))
        nb = -(-k // block)
        return 1 + _QUANT_HDR.size + -(-n // 8) + nb * 4 + k
    raise ValueError(f"unknown wire codec {codec!r}")
