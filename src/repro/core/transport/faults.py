"""Deterministic fault injection for the wire transport (DESIGN.md §16).

A `FaultPlan` is parsed from a compact spec string — shippable through
``--fault-plan`` to worker subprocesses — and wraps either endpoint's
socket so the *same plan + same seed* injects the *same faults at the same
frames* on every run. That determinism is what lets the chaos suite pin
recovery behaviour (counters, convergence bounds) instead of flaking.

Grammar: ops separated by ``;`` (or ``,``), each::

    [side.]op@arg[:qualifier]*

    corrupt@K[:TYPE]   flip one seeded byte of the K-th (1-based) matching
                       outbound frame — the CRC firewall must detect it
    drop@K[:TYPE]      swallow the K-th matching outbound frame
    dup@K[:TYPE]       send the K-th matching outbound frame twice
    delay@K[:TYPE]:S   sleep S seconds before sending frame K
    sever@N            close the connection abruptly after N bytes sent
    kill@M             (server op) crash the landing loop after M landings
                       — no BYE, no cleanup: the kill -9 model

``side`` is ``client`` or ``server`` (default ``client``): which
endpoint's *outbound* frames the op watches. ``TYPE`` is a frame-type name
(``hello``/``dispatch``/``update``/``heartbeat``/``bye``); without it the
op counts every frame. Per-type counters are the determinism linchpin:
heartbeats interleave nondeterministically with updates, so "the 2nd
frame" is racy but "the 2nd UPDATE" is exact.

Counters live on the *plan*, not the socket wrapper, and survive
reconnects — otherwise ``drop@1:update`` would re-fire on every fresh
connection and the worker would retry forever. Every fault that fires is
counted in ``plan.fired`` (and surfaced into ``WireRunStats.faults_injected``
by the server) so the acceptance criterion "every injected fault is
counted" is checkable.
"""
from __future__ import annotations

import socket
import threading
import time

from repro.core.transport import wire

_TYPE_NAMES = {
    "hello": wire.HELLO,
    "dispatch": wire.DISPATCH,
    "update": wire.UPDATE,
    "heartbeat": wire.HEARTBEAT,
    "bye": wire.BYE,
}

CLIENT, SERVER = "client", "server"
_OPS = ("corrupt", "drop", "dup", "delay", "sever", "kill")


class _Op:
    """One parsed fault op with its own persistent match counter."""

    def __init__(self, side: str, kind: str, arg: int,
                 ftype: int | None = None, seconds: float = 0.0,
                 spec: str = ""):
        self.side, self.kind, self.arg = side, kind, arg
        self.ftype, self.seconds, self.spec = ftype, seconds, spec
        self.seen = 0  # matching frames (or bytes, for sever) so far
        self.done = False

    def matches_frame(self, ftype: int) -> bool:
        return self.ftype is None or self.ftype == ftype


class ServerKilled(RuntimeError):
    """The fault plan crashed the landing loop (the simulated kill -9)."""


def _fmix32(x: int) -> int:
    x &= 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & 0xFFFFFFFF
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & 0xFFFFFFFF
    x ^= x >> 16
    return x


class FaultPlan:
    """A seeded, parsed fault schedule shared by every socket it wraps."""

    def __init__(self, ops: list[_Op], *, seed: int = 0, spec: str = ""):
        self.ops = ops
        self.seed = seed
        self.spec = spec
        self.fired: dict[str, int] = {}
        self._lock = threading.Lock()

    @classmethod
    def parse(cls, spec: str, *, seed: int = 0) -> "FaultPlan":
        ops: list[_Op] = []
        for raw in spec.replace(",", ";").split(";"):
            tok = raw.strip()
            if not tok:
                continue
            side = CLIENT
            head, _, rest = tok.partition("@")
            if "." in head:
                side, head = head.split(".", 1)
                if side not in (CLIENT, SERVER):
                    raise ValueError(f"fault side must be client/server: {tok!r}")
            if head not in _OPS:
                raise ValueError(f"unknown fault op {head!r} in {tok!r}")
            if not rest:
                raise ValueError(f"fault op needs @arg: {tok!r}")
            parts = rest.split(":")
            arg = int(parts[0])
            if arg < 1:
                raise ValueError(f"fault arg must be >= 1: {tok!r}")
            ftype: int | None = None
            seconds = 0.0
            for q in parts[1:]:
                if q in _TYPE_NAMES:
                    ftype = _TYPE_NAMES[q]
                else:
                    seconds = float(q)
            if head == "delay" and seconds <= 0.0:
                raise ValueError(f"delay needs :seconds qualifier: {tok!r}")
            if head == "kill":
                side = SERVER  # kill is meaningful only at the landing loop
            ops.append(_Op(side, head, arg, ftype, seconds, tok))
        if not ops:
            raise ValueError(f"empty fault plan: {spec!r}")
        return cls(ops, seed=seed, spec=spec)

    def _fire(self, op: _Op) -> None:
        op.done = True
        with self._lock:
            self.fired[op.spec] = self.fired.get(op.spec, 0) + 1

    @property
    def total_fired(self) -> int:
        with self._lock:
            return sum(self.fired.values())

    # -- server-side landing-count trigger -----------------------------------

    def kill_after_landings(self) -> int | None:
        """M of the first pending ``kill@M`` op, or None."""
        for op in self.ops:
            if op.kind == "kill" and not op.done:
                return op.arg
        return None

    def maybe_kill(self, landings: int) -> None:
        """Called by the landing loop after each landing; raises
        `ServerKilled` when a pending kill op's threshold is reached."""
        for op in self.ops:
            if op.kind == "kill" and not op.done and landings >= op.arg:
                self._fire(op)
                raise ServerKilled(f"fault plan {op.spec!r} at {landings} landings")

    # -- socket wrapping ------------------------------------------------------

    def wrap(self, sock: socket.socket, side: str = CLIENT) -> "FaultySocket":
        return FaultySocket(sock, self, side)

    def _on_send(self, side: str, frame: bytes) -> list[bytes]:
        """Apply frame-level ops to one outbound frame; returns the list of
        byte strings actually to send ([] = dropped). The frame's type is
        read straight out of the wire header."""
        if len(frame) <= wire.HEADER_BYTES:
            return [frame]
        ftype = frame[wire.HEADER_BYTES]
        out = [frame]
        with self._lock:
            ops = [
                op for op in self.ops
                if op.side == side and op.kind in ("corrupt", "drop", "dup", "delay")
                and op.matches_frame(ftype)
            ]
            hits = []
            for op in ops:
                op.seen += 1
                if not op.done and op.seen == op.arg:
                    hits.append(op)
        for op in hits:
            if op.kind == "drop":
                out = []
            elif op.kind == "dup":
                out = out + list(out)
            elif op.kind == "delay":
                time.sleep(op.seconds)
            elif op.kind == "corrupt":
                # flip one seeded byte past the length prefix (the length
                # must stay honest so the receiver's parser keeps framing
                # and the CRC — not a desync — reports the damage)
                lo = wire._LEN.size
                pos = lo + _fmix32(self.seed * 0x9E3779B9 + op.seen) % (len(frame) - lo)
                out = [
                    bytes(frame[:pos]) + bytes([frame[pos] ^ 0xFF]) + bytes(frame[pos + 1:])
                    if b is frame else b
                    for b in out
                ]
            self._fire(op)
        return out

    def _sever_budget(self, side: str, nbytes: int) -> bool:
        """Account `nbytes` about to be sent; True => sever now."""
        with self._lock:
            for op in self.ops:
                if op.side == side and op.kind == "sever" and not op.done:
                    op.seen += nbytes
                    if op.seen >= op.arg:
                        self._fire_locked(op)
                        return True
        return False

    def _fire_locked(self, op: _Op) -> None:
        op.done = True
        self.fired[op.spec] = self.fired.get(op.spec, 0) + 1


class FaultySocket:
    """A socket proxy applying one `FaultPlan` side to outbound frames.

    Callers on both endpoints send exactly one complete frame per
    ``sendall`` (worker `_Conn.send`, server `_send`) — the invariant that
    makes frame-level interception possible without reparsing a stream.
    Reads and everything else pass straight through.
    """

    def __init__(self, sock: socket.socket, plan: FaultPlan, side: str):
        self._sock = sock
        self._plan = plan
        self._side = side

    def sendall(self, data: bytes) -> None:
        if self._plan._sever_budget(self._side, len(data)):
            try:
                self._sock.close()
            except OSError:
                pass
            raise ConnectionResetError(f"fault plan severed the {self._side} socket")
        for chunk in self._plan._on_send(self._side, data):
            self._sock.sendall(chunk)

    def __getattr__(self, name):
        return getattr(self._sock, name)
