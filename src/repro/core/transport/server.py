"""The server side of the wire transport (DESIGN.md §14).

`WireServer` owns the listening socket, one reader thread per client
connection, and THE landing loop — the single thread allowed to touch the
`ArrivalAsyncEngine`. Readers only parse frames and enqueue work:

    reader threads --(bounded landing queue)--> landing loop --> engine

The landing queue is bounded (``FedConfig.queue_cap``, default 2C): when
the loop falls behind, `queue.put` blocks the reader, the reader stops
draining its socket, the kernel's TCP window closes, and the *worker's*
send blocks — real end-to-end backpressure, counted in
``backpressure_blocks`` rather than buffered unboundedly.

Liveness is a two-state machine per client driven entirely by frame
arrival times: ALIVE -> DEAD after ``heartbeat_timeout_s`` of silence
(heartbeats ride their own frame type and never touch the engine), DEAD ->
ALIVE on any frame. Transitions land in ``liveness_log``. A dead client's
in-flight dispatch simply never returns; when it reconnects (a fresh HELLO
is the reconnect path) the landing loop redispatches the current global —
unless the client is staged in the pending flush, in which case the
dispatch is deferred to the flush boundary so the landed update is never
overwritten.

Every landing-loop action is recorded into an `ArrivalSchedule`
(`core/transport/replay.py`), timestamped off the engine's `WallClock` —
the record a SimClock replay must reproduce bit-for-bit (dense codec).
"""
from __future__ import annotations

import dataclasses
import queue
import socket
import threading
import time

import numpy as np

from repro.core.simclock import WallClock
from repro.core.transport import codec, wire
from repro.core.transport.faults import ServerKilled
from repro.core.transport.replay import ArrivalSchedule, WireEvent

ALIVE, DEAD = "alive", "dead"


@dataclasses.dataclass
class WireRunStats:
    """Operational counters the monitor renders next to the round history."""

    flushes: int = 0
    landed: int = 0
    dropped: int = 0
    heartbeats: int = 0
    reconnects: int = 0
    bytes_up: int = 0  # client -> server, payload+framing
    bytes_down: int = 0  # server -> client
    backpressure_blocks: int = 0  # reader puts that found the queue full
    queue_high_water: int = 0
    protocol_errors: int = 0  # frames the engine refused (double updates)
    superseded: int = 0  # updates whose echoed dispatch version was stale
    deadline_hit: bool = False
    crc_errors: int = 0  # frames the CRC firewall withheld (DESIGN.md §16)
    snapshots: int = 0  # durable full-engine snapshots written
    wal_events: int = 0  # events appended to the landing WAL
    recoveries: int = 0  # 1 on a server recovered from snapshot+WAL
    faults_injected: int = 0  # server-side FaultPlan ops that fired
    crashed: bool = False  # the fault plan killed this landing loop


class WireServer:
    """Socket front-end for one `ArrivalAsyncEngine`.

    The engine must have been built on a `simclock.WallClock` (the harness
    does this); `serve(n_flushes)` runs the landing loop until that many
    flushes land or the deadline passes.
    """

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 0,
                 record: bool = True, land_delay_s: float = 0.0,
                 durable=None, snapshot_every: int = 0, faults=None,
                 recovered: bool = False):
        fed = engine.fed
        if fed.transport != "socket":
            raise ValueError(
                f"WireServer needs FedConfig(transport='socket'), got {fed.transport!r}"
            )
        if not isinstance(engine.clock, WallClock):
            raise ValueError(
                "WireServer runs in real time: build the engine on a "
                "simclock.WallClock (replay is where a plain SimClock belongs)"
            )
        self.engine = engine
        self.fed = fed
        self.codec = fed.wire_codec
        if self.codec not in codec.CODECS:
            raise ValueError(f"unknown wire_codec {self.codec!r}")
        self.block = fed.quant_block
        self.queue_cap = fed.queue_cap or 2 * fed.n_clients
        self.land_delay_s = land_delay_s  # test hook: a deliberately slow landing loop
        self._q: queue.Queue = queue.Queue(self.queue_cap)
        self.stats = WireRunStats()
        # durability (DESIGN.md §16): every recorded event also lands in
        # the DurableRun's WAL; snapshot_every takes a full-engine snapshot
        # each N landings (0 = WAL only, recovery replays from the seed)
        self.durable = durable
        self.snapshot_every = snapshot_every
        self.faults = faults  # server-side FaultPlan (kill@M, corrupt dispatches)
        self._landings_since_snap = 0
        if recovered:
            self.stats.recoveries = 1
        self.schedule = ArrivalSchedule(meta={}) if record else None
        self._lock = threading.Lock()  # conns / last_seen / stats counters
        self._conns: dict[int, socket.socket] = {}
        self._send_locks: dict[int, threading.Lock] = {}
        self._last_seen: dict[int, float] = {}
        self.liveness: dict[int, str] = {}
        self.liveness_log: list[tuple[float, int, str]] = []
        self._deferred: set[int] = set()  # HELLOs from staged clients, dispatch at flush
        self._stopping = threading.Event()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(fed.n_clients + 4)
        self.host, self.port = self._listener.getsockname()[:2]
        self._accept_thread: threading.Thread | None = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "WireServer":
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="wire-accept", daemon=True
        )
        self._accept_thread.start()
        return self

    def stop(self) -> None:
        self._stopping.set()
        with self._lock:
            conns = dict(self._conns)
        for c, sock in conns.items():
            try:
                self._send(c, wire.pack_bye())
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass
        try:
            self._listener.close()
        except OSError:
            pass
        if self.durable is not None:
            self.durable.close()  # graceful stop: flush + fsync the WAL tail

    # -- reader side (per-connection threads; never touch the engine) --------

    def _accept_loop(self) -> None:
        while not self._stopping.is_set():
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            if self.faults is not None:
                sock = self.faults.wrap(sock, side="server")
            threading.Thread(
                target=self._reader, args=(sock,), name="wire-reader", daemon=True
            ).start()

    def _put(self, item) -> None:
        try:
            self._q.put_nowait(item)
        except queue.Full:
            with self._lock:
                self.stats.backpressure_blocks += 1
            self._q.put(item)  # blocks this reader: backpressure to the socket
        with self._lock:
            self.stats.queue_high_water = max(self.stats.queue_high_water, self._q.qsize())

    def _reader(self, sock: socket.socket) -> None:
        parser = wire.FrameParser()
        client: int | None = None
        while not self._stopping.is_set():
            try:
                data = sock.recv(1 << 16)
            except OSError:
                break
            if not data:
                break
            # peek, never sync: only the landing loop advances the engine clock
            t = self.engine.clock.peek()
            with self._lock:
                self.stats.bytes_up += len(data)
            try:
                frames = parser.feed(data)
            except ValueError:
                break  # corrupt stream: drop the connection, liveness handles it
            if parser.crc_errors:
                # the CRC firewall caught line damage (DESIGN.md §16): count
                # it and drop the connection — a stream that corrupted one
                # byte can't be trusted to have framed the next honestly.
                # The worker's reconnect path (HELLO -> redispatch) recovers.
                with self._lock:
                    self.stats.crc_errors += parser.crc_errors
                break
            for ftype, payload in frames:
                if ftype == wire.HELLO:
                    client = wire.parse_hello(payload)
                    if not 0 <= client < self.fed.n_clients:
                        sock.close()
                        return
                    with self._lock:
                        known = client in self._conns
                        self._conns[client] = sock
                        self._send_locks.setdefault(client, threading.Lock())
                        self._last_seen[client] = t
                        if known:
                            self.stats.reconnects += 1
                    self._put(("hello", client, None))
                elif ftype == wire.UPDATE:
                    c, seq, version, loss, buf = wire.parse_update(payload)
                    with self._lock:
                        self._last_seen[c] = t
                    self._put(("update", c, (seq, version, loss, buf)))
                elif ftype == wire.HEARTBEAT:
                    c = wire.parse_heartbeat(payload)
                    with self._lock:
                        self._last_seen[c] = t
                        self.stats.heartbeats += 1
                # BYE from a client is just a close; the recv() EOF handles it

    # -- landing loop (the only engine owner) ---------------------------------

    def _send(self, c: int, frame: bytes) -> None:
        with self._lock:
            sock = self._conns.get(c)
            slock = self._send_locks.get(c)
        if sock is None or slock is None:
            return
        try:
            with slock:
                sock.sendall(frame)
            with self._lock:
                self.stats.bytes_down += len(frame)
        except OSError:
            pass  # client gone mid-send; liveness will flag it

    def _send_dispatch(self, c: int) -> None:
        row = self.engine.dispatch_row(c)
        frame = wire.pack_dispatch(
            int(self.engine.dispatch_version[c]), codec.encode_row(row, self.codec)
        )
        self._send(c, frame)

    def _record(self, ev: WireEvent) -> None:
        if self.schedule is not None:
            self.schedule.events.append(ev)
        if self.durable is not None:
            self.durable.append_event(ev)
            self.stats.wal_events += 1

    def _check_liveness(self, t: float) -> None:
        timeout = self.fed.heartbeat_timeout_s
        with self._lock:
            seen = dict(self._last_seen)
        for c, last in seen.items():
            state = self.liveness.get(c)
            if t - last > timeout and state == ALIVE:
                self.liveness[c] = DEAD
                self.liveness_log.append((t, c, DEAD))
            elif t - last <= timeout and state != ALIVE:
                self.liveness[c] = ALIVE
                self.liveness_log.append((t, c, ALIVE))

    def _dispatch_now(self, c: int, t: float) -> None:
        v = self.engine.dispatch(c)
        self._record(WireEvent(kind="dispatch", t=t, client=c, version=v))
        self._send_dispatch(c)

    def serve(self, n_flushes: int, *, deadline_s: float = 120.0) -> WireRunStats:
        """Run the landing loop until `n_flushes` flushes land. Returns the
        stats; `engine.history` has the round records and `self.schedule`
        the replayable arrival record. A hung federation (every client dead,
        nothing arriving) exits at the deadline with ``deadline_hit`` set
        instead of stalling the caller — CI's hung-socket guard depends on
        this never blocking forever."""
        deadline = time.monotonic() + deadline_s
        while self.stats.flushes < n_flushes:
            if time.monotonic() > deadline:
                self.stats.deadline_hit = True
                break
            t = self.engine.clock.sync()
            self._check_liveness(t)
            try:
                kind, c, args = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            if self.land_delay_s:
                time.sleep(self.land_delay_s)
            t = self.engine.clock.sync()
            if kind == "hello":
                if c in self.engine.staged():
                    self._deferred.add(c)  # redispatch at the flush boundary
                else:
                    self._dispatch_now(c, t)
            elif kind == "update":
                seq, trained_against, loss, buf = args
                if trained_against != int(self.engine.dispatch_version[c]):
                    # the echoed dispatch was superseded (a flush or a
                    # reconnect redispatched this client while the update
                    # was in flight): the row it trained on is not the row
                    # the engine holds, so landing it would silently
                    # diverge from the replay. Refuse it; the newer
                    # dispatch's update is already on its way.
                    self.stats.superseded += 1
                    continue
                base = np.asarray(self.engine.state["params"][c], np.float32)
                try:
                    row = codec.decode_update(buf, base)
                except ValueError:
                    continue  # corrupt payload: skip; the client will retrain on redispatch
                try:
                    res = self.engine.land(c, row, loss=loss, t=t)
                except RuntimeError:
                    # protocol violation (double update for one dispatch) —
                    # never let a misbehaving client kill the landing loop
                    self.stats.protocol_errors += 1
                    continue
                self.stats.landed += 0 if res.dropped else 1
                self.stats.dropped += 1 if res.dropped else 0
                self._record(
                    WireEvent(
                        kind="land", t=t, client=c, version=trained_against, seq=seq,
                        dropped=res.dropped,
                        flush=-1 if res.flush is None else res.flush.round_idx,
                    )
                )
                if res.dropped:
                    # land() already redispatched the row+version; ship it
                    self._send_dispatch(c)
                elif res.flush is not None:
                    self.stats.flushes += 1
                    for sc in res.flush.participants:
                        self._send_dispatch(sc)  # staged rows already hold the global
                    # deferred reconnects were staged, hence participants:
                    # the flush dispatch above covered them
                    self._deferred.clear()
                if not res.dropped:
                    self._landings_since_snap += 1
                    if (self.durable is not None and self.snapshot_every
                            and self._landings_since_snap >= self.snapshot_every):
                        self.durable.snapshot(self.engine)
                        self.stats.snapshots += 1
                        self._landings_since_snap = 0
                    if self.faults is not None:
                        try:
                            self.faults.maybe_kill(self.stats.landed)
                        except ServerKilled:
                            # the kill -9 model: mark, slam every socket
                            # shut (no BYE), leave the WAL exactly as the
                            # last append left it, and propagate — the
                            # harness's recovery path takes over from disk
                            self.stats.crashed = True
                            self.stats.faults_injected = self.faults.total_fired
                            self.kill()
                            raise
        if self.faults is not None:
            self.stats.faults_injected = self.faults.total_fired
        return self.stats

    def kill(self) -> None:
        """Abrupt shutdown — the in-process stand-in for ``kill -9``: no
        BYE frames, no WAL close, sockets slammed. Workers see a bare EOF/
        reset and enter their reconnect-with-backoff loop."""
        self._stopping.set()
        with self._lock:
            conns = dict(self._conns)
        for sock in conns.values():
            try:
                sock.close()
            except OSError:
                pass
        # pop a blocked accept() before closing: on Linux the in-flight
        # accept call keeps the listening socket — and its port — alive
        # past close(), so without this the recovery path's rebind of the
        # same port races against the next worker reconnect
        try:
            socket.create_connection((self.host, self.port), timeout=0.2).close()
        except OSError:
            pass
        try:
            self._listener.close()
        except OSError:
            pass
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=2.0)
