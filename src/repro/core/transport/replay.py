"""Recorded arrival schedules + the SimClock replay harness (DESIGN.md §14).

Every wire run records its **arrival schedule**: the ordered dispatch/land
events the server's landing loop actually processed, with relative wall
times. `replay` drives the same `ArrivalAsyncEngine` from that record on a
plain `SimClock`, recomputing each trained row with the identical jitted
row update (`async_engine.build_row_update`) and pushing it through the
identical wire codec round-trip — so a recorded run replays end-to-end
in-process, and the replay-determinism contract holds:

    dense codec  -> the replayed global params equal the wire run's
                    **bit for bit** (same jit program, same codec bytes,
                    same landing order);
    quant8 codec -> 1e-5 agreement (the int8 delta round-trip is itself
                    deterministic NumPy, so in practice this is bitwise
                    too; the tolerance covers cross-platform rint/fma
                    variation between the worker's host and the replayer).

Replay cross-checks every recorded decision against the engine's own:
dispatch versions, staleness drops, and flush boundaries must all re-derive
identically, or `ReplayMismatch` pinpoints the first divergent event. The
schedule serializes to JSON (no tensors — rows are recomputed, never
stored) so CI can attach failing schedules as artifacts for offline replay.

The run **meta** block is the schedule's self-description: everything
needed to rebuild the config, engine, optimizer, and per-client synthetic
batches. Batches are derived, not recorded: client ``c``'s ``k``-th local
dataset is a pure function of ``(seed, c, k)`` (`synth_client_batch`), and
the UPDATE frame carries ``k`` so worker and replayer index the same data.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any

import numpy as np

import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import async_engine as ae
from repro.core.rounds import FedConfig
from repro.core.simclock import SimClock
from repro.core.transport import codec
from repro.optim import sgd


class ReplayMismatch(AssertionError):
    """The wire path drifted from the in-process reference — which is never
    allowed: the first recorded event whose re-derivation disagrees."""


@dataclasses.dataclass
class WireEvent:
    """One landing-loop action.

    kind "dispatch": the server pushed the current global to `client`
    (connect, reconnect, or a deferred post-flush dispatch); `version` is
    the global version sent. kind "land": an UPDATE arrived; `version` is
    the dispatch version it was trained against, `seq` the client-local
    update index (the batch selector), `dropped` whether the staleness gate
    discarded it, and `flush` the round index it completed (-1 otherwise).
    """

    kind: str
    t: float
    client: int
    version: int
    seq: int = -1
    dropped: bool = False
    flush: int = -1


@dataclasses.dataclass
class ArrivalSchedule:
    meta: dict[str, Any]
    events: list[WireEvent] = dataclasses.field(default_factory=list)

    def to_json(self) -> str:
        return json.dumps(
            {"meta": self.meta, "events": [dataclasses.asdict(e) for e in self.events]}
        )

    @classmethod
    def from_json(cls, text: str) -> "ArrivalSchedule":
        obj = json.loads(text)
        return cls(obj["meta"], [WireEvent(**e) for e in obj["events"]])

    def save(self, path) -> None:
        Path(path).write_text(self.to_json())

    @classmethod
    def load(cls, path) -> "ArrivalSchedule":
        return cls.from_json(Path(path).read_text())

    @property
    def n_flushes(self) -> int:
        return sum(1 for e in self.events if e.flush >= 0)

    @property
    def n_dropped(self) -> int:
        return sum(1 for e in self.events if e.kind == "land" and e.dropped)


# -- run meta: the schedule's self-description -------------------------------

def build_cfg(meta: dict):
    cfg = get_arch(meta["arch"])
    if meta.get("reduced", True):
        cfg = cfg.reduced()
    if meta.get("overrides"):
        cfg = dataclasses.replace(cfg, **meta["overrides"])
    return cfg


def build_fed(meta: dict) -> FedConfig:
    return FedConfig(
        n_clients=int(meta["n_clients"]),
        local_steps=int(meta.get("local_steps", 1)),
        aggregation=meta.get("aggregation", "dense"),
        client_axis="data",
        data_axis=None,
        state_layout="flat",
        mode="async",
        buffer_size=int(meta.get("buffer_size", 0)),
        staleness_alpha=float(meta.get("staleness_alpha", 0.5)),
        max_staleness=int(meta.get("max_staleness", 0)),
        transport=meta.get("transport", "socket"),
        wire_codec=meta.get("wire_codec", "dense"),
        queue_cap=int(meta.get("queue_cap", 0)),
        heartbeat_s=float(meta.get("heartbeat_s", 0.2)),
        heartbeat_timeout_s=float(meta.get("heartbeat_timeout_s", 2.0)),
    )


def build_optimizer(meta: dict):
    # the transport path trains statelessly at the worker (DESIGN.md §14):
    # momentum-free sgd is the build_row_update purity requirement
    return sgd(float(meta.get("lr", 0.05)), momentum=0.0)


def synth_client_batch(cfg, meta: dict, client: int, k: int):
    """Client ``c``'s ``k``-th local batch: (E, b, seq) tokens, a pure
    function of (seed, c, k) — the worker and the replayer derive the same
    data without any of it crossing the wire."""
    rng = np.random.default_rng([int(meta["seed"]), int(client), int(k)])
    shape = (int(meta.get("local_steps", 1)), int(meta["batch"]), int(meta["seq"]))
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, shape), jnp.int32)}


# -- replay ------------------------------------------------------------------

def make_engine(meta: dict, clock: SimClock | None = None) -> ae.ArrivalAsyncEngine:
    cfg, fed = build_cfg(meta), build_fed(meta)
    return ae.ArrivalAsyncEngine(
        cfg, fed, build_optimizer(meta), seed=int(meta["seed"]), clock=clock or SimClock()
    )


def apply_events(
    engine: ae.ArrivalAsyncEngine,
    events: list[WireEvent],
    meta: dict,
    *,
    update=None,
    start_index: int = 0,
) -> ae.ArrivalAsyncEngine:
    """Drive `engine` through recorded events, re-deriving each trained row
    with the jitted row update + the wire-codec round-trip and cross-checking
    every recorded decision (dispatch versions, drops, flush boundaries)
    against the engine's own.

    This is the one event interpreter BOTH consumers share: `replay` runs
    it from a fresh engine over a full schedule, and crash recovery
    (`checkpoint/durable.py`) runs it over the WAL suffix on top of a
    restored snapshot — recovery literally IS a partial replay, which is
    why the recovery-equals-replay invariant holds by construction.
    ``start_index`` only offsets the event numbering in mismatch messages.
    """
    cfg, fed = build_cfg(meta), build_fed(meta)
    if update is None:
        update = ae.build_row_update(
            cfg, fed, build_optimizer(meta),
            spec=engine.agg.ctx.spec, template=engine.agg.ctx.template,
        )
    wire_codec = meta.get("wire_codec", "dense")
    block = int(meta.get("quant_block", 1024))
    for i, ev in enumerate(events, start=start_index):
        where = f"event {i} ({ev.kind} client {ev.client} t={ev.t:.3f})"
        if ev.kind == "dispatch":
            engine.clock.advance_to(max(ev.t, engine.clock.now()))
            got = engine.dispatch(ev.client)
            if got != ev.version:
                raise ReplayMismatch(
                    f"{where}: replay dispatched version {got}, wire sent {ev.version}"
                )
        elif ev.kind == "land":
            have = int(engine.dispatch_version[ev.client])
            if have != ev.version:
                raise ReplayMismatch(
                    f"{where}: replay dispatch_version {have} != recorded {ev.version}"
                )
            base = np.asarray(engine.state["params"][ev.client], np.float32)
            batch = synth_client_batch(cfg, meta, ev.client, ev.seq)
            trained, loss = update(jnp.asarray(base), batch)
            # the exact worker-side wire hop: encode -> decode the update
            landed = codec.decode_update(
                codec.encode_update(np.asarray(trained, np.float32), base, wire_codec, block),
                base,
            )
            res = engine.land(ev.client, landed, loss=float(loss), t=ev.t)
            if res.dropped != ev.dropped:
                raise ReplayMismatch(
                    f"{where}: replay {'dropped' if res.dropped else 'staged'} "
                    f"(staleness {res.staleness}), wire "
                    f"{'dropped' if ev.dropped else 'staged'}"
                )
            got_flush = -1 if res.flush is None else res.flush.round_idx
            if got_flush != ev.flush:
                raise ReplayMismatch(
                    f"{where}: replay flush index {got_flush} != recorded {ev.flush}"
                )
        else:
            raise ReplayMismatch(f"{where}: unknown event kind {ev.kind!r}")
    return engine


def replay(schedule: ArrivalSchedule, *, clock: SimClock | None = None) -> ae.ArrivalAsyncEngine:
    """Re-derive a recorded wire run through the in-process engine on the
    SimClock. Returns the engine (history, state, drop counters populated);
    raises :class:`ReplayMismatch` at the first event whose re-derivation
    disagrees with the record."""
    engine = make_engine(schedule.meta, clock=clock or SimClock())
    return apply_events(engine, schedule.events, schedule.meta)
