"""Multi-process federation over a real wire (DESIGN.md §14).

The transport package splits the platform's client workers from the
server: `wire` frames the socket protocol, `codec` encodes row payloads
(dense f32 or int8-quantized deltas), `server.WireServer` runs the
landing loop that feeds an `ArrivalAsyncEngine` in wall-clock arrival
order, `replay` re-derives a recorded wire run through the in-process
SimClock engine (the determinism pin), and `harness.wire_run` orchestrates
a whole run — server plus worker subprocesses — in one call.
"""
from repro.core.transport.codec import (  # noqa: F401
    CODECS,
    decode_row,
    decode_update,
    encode_row,
    encode_update,
    payload_bytes,
)
from repro.core.transport.replay import (  # noqa: F401
    ArrivalSchedule,
    ReplayMismatch,
    WireEvent,
    synth_client_batch,
)
from repro.core.transport.replay import replay as replay_schedule  # noqa: F401
from repro.core.transport.wire import FrameParser, encode_frame  # noqa: F401

__all__ = [
    "ArrivalSchedule",
    "CODECS",
    "FrameParser",
    "ReplayMismatch",
    "WireEvent",
    "decode_row",
    "decode_update",
    "encode_frame",
    "encode_row",
    "encode_update",
    "payload_bytes",
    "replay_schedule",
    "synth_client_batch",
]
