"""Deterministic retry/backoff for the wire transport (DESIGN.md §16).

A worker that races the server's bind — or outlives a server crash — used
to die on its single ``socket.create_connection`` attempt. `Backoff` is
the one retry policy both ends share: exponential delays with
*deterministic* jitter (a seeded fmix32-style hash of ``(seed, attempt)``,
never host randomness), capped per-delay and bounded in attempts, so two
runs of the same scenario sleep the same schedule and the chaos tests can
pin reconnect behaviour exactly.

The jitter matters even deterministically: C workers restarted by the same
orchestrator all compute *different* delay sequences (seed = client id),
which de-synchronizes the reconnect stampede after a server restart.
"""
from __future__ import annotations

import socket
import time


def _fmix32(x: int) -> int:
    """Murmur3 finalizer — the same integer mixer the quant codec uses for
    its deterministic rotation; good avalanche from consecutive seeds."""
    x &= 0xFFFFFFFF
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & 0xFFFFFFFF
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & 0xFFFFFFFF
    x ^= x >> 16
    return x


class Backoff:
    """Bounded exponential backoff with seeded deterministic jitter.

    delay(k) = min(base * 2^k, cap) * (1 - jitter * u_k) where u_k in
    [0, 1) is the fmix32 hash of (seed, k) — pure, replayable, no RNG
    state. ``attempts`` bounds how many delays exist; iterating past the
    bound raises ``RetriesExhausted``.
    """

    def __init__(self, *, base: float = 0.05, cap: float = 2.0,
                 attempts: int = 8, jitter: float = 0.5, seed: int = 0):
        if base <= 0 or cap < base:
            raise ValueError(f"need 0 < base <= cap, got base={base} cap={cap}")
        if not 0.0 <= jitter < 1.0:
            raise ValueError(f"jitter must be in [0, 1), got {jitter}")
        if attempts < 1:
            raise ValueError(f"attempts must be >= 1, got {attempts}")
        self.base, self.cap, self.attempts = base, cap, attempts
        self.jitter, self.seed = jitter, seed

    def delay(self, attempt: int) -> float:
        """Seconds to sleep after failed attempt `attempt` (0-based)."""
        raw = min(self.base * (2.0 ** attempt), self.cap)
        u = _fmix32(self.seed * 0x9E3779B9 + attempt) / float(1 << 32)
        return raw * (1.0 - self.jitter * u)

    def delays(self) -> list[float]:
        """The full deterministic sleep schedule (attempts - 1 entries: no
        sleep follows the final attempt)."""
        return [self.delay(k) for k in range(self.attempts - 1)]


class RetriesExhausted(ConnectionError):
    """Every attempt in the backoff schedule failed; carries the last error."""


def connect_with_retry(host: str, port: int, backoff: Backoff, *,
                       timeout: float = 10.0,
                       sleep=time.sleep) -> socket.socket:
    """`socket.create_connection` under the backoff schedule. Retries
    ConnectionRefusedError/timeouts/transient OSErrors; raises
    `RetriesExhausted` (chaining the last failure) once the schedule runs
    out. ``sleep`` is injectable so tests measure the schedule without
    serving real seconds."""
    last: Exception | None = None
    for attempt in range(backoff.attempts):
        try:
            sock = socket.create_connection((host, port), timeout=timeout)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            return sock
        except OSError as e:
            last = e
            if attempt < backoff.attempts - 1:
                sleep(backoff.delay(attempt))
    raise RetriesExhausted(
        f"connect to {host}:{port} failed after {backoff.attempts} attempts"
    ) from last
