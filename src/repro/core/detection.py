"""Federated detection evaluation engine (DESIGN.md §10).

The paper trains *and serves* object detectors federatedly, so the platform
needs a detection metric in the round loop, not just scalar losses. This
module is that metric path, end to end and fully jit-stable:

  raw heads -> :func:`decode_predictions` (yolov3.decode_boxes + top-K +
  Pallas NMS) -> :func:`match_detections` (one tiled pairwise-IoU launch +
  greedy score-ordered matching) -> :func:`average_precision` (vectorized
  VOC all-point AP@0.5) -> :func:`build_evaluator` (per-client AND pooled
  global mAP from ONE jitted call over the (C, ...) client axis).

Every shape is fixed at trace time — detections are a constant
``max_detections`` slots with a 0/1 validity mask, ground truth is padded
with a mask — so per-round evaluation never retraces, mirroring how the
participation engine feeds the round (DESIGN.md §8). The per-client mAP
vector is what `server.evaluate_round` feeds into the Task Scheduler's
quality EMA (today loss-only), closing the paper's quality-aware selection
loop with an actual detection-quality signal.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models import yolov3

Batch = Any

# NMS pre-suppression score floor: conf * class-prob below this is noise
SCORE_THRESH = 0.05


def decode_predictions(
    cfg,
    params,
    images: jax.Array,
    *,
    max_detections: int = 64,
    score_thresh: float = SCORE_THRESH,
    nms_iou: float = 0.5,
    interpret: bool = True,
) -> dict[str, jax.Array]:
    """images (B, H, W, 3) -> fixed-size detections per image.

    Returns {"boxes" (B, K, 4) center-format, "scores" (B, K) descending,
    "cls" (B, K) int32, "valid" (B, K) 0/1 f32} with K = max_detections.
    All three scales are decoded, flattened, top-K'd by conf * max class
    prob, then suppressed by ONE batched Pallas NMS launch. NMS is
    class-aware via the coordinate-offset trick: each class's boxes are
    x-shifted by a stride wider than any box extent in the batch (decoded
    w/h can blow past [0, 1] — up to anchor * e^6 — so the stride is
    computed from the boxes, not assumed from normalized coordinates).
    """
    outs = yolov3.forward(params, images, cfg)
    boxes, scores, labels = [], [], []
    for raw, anchors in zip(outs, yolov3.ANCHORS):
        b, conf, cls = yolov3.decode_boxes(raw.astype(jnp.float32), anchors)
        B = b.shape[0]
        boxes.append(b.reshape(B, -1, 4))
        scores.append((conf * jnp.max(cls, axis=-1)).reshape(B, -1))
        labels.append(jnp.argmax(cls, axis=-1).reshape(B, -1).astype(jnp.int32))
    boxes = jnp.concatenate(boxes, axis=1)
    scores = jnp.concatenate(scores, axis=1)
    labels = jnp.concatenate(labels, axis=1)
    k = min(max_detections, scores.shape[1])
    top_scores, top_idx = jax.lax.top_k(scores, k)
    top_boxes = jnp.take_along_axis(boxes, top_idx[..., None], axis=1)
    top_labels = jnp.take_along_axis(labels, top_idx, axis=1)
    if k < max_detections:  # static pad up to the fixed K slots
        pad = max_detections - k
        top_boxes = jnp.pad(top_boxes, ((0, 0), (0, pad), (0, 0)))
        top_scores = jnp.pad(top_scores, ((0, 0), (0, pad)), constant_values=-1.0)
        top_labels = jnp.pad(top_labels, ((0, 0), (0, pad)))
    # |x1-x2| + (w1+w2)/2 <= 3 * max|coord|, so this stride strictly
    # separates classes for any decoded box. Per IMAGE, not per batch: with
    # a batch-wide max, image i's NMS arithmetic would depend on the other
    # images in the batch, and the serving plane's padded-batch pin (a
    # request's detections are bit-identical at any batch occupancy,
    # DESIGN.md §17) needs every slot's decode to be a function of that
    # slot alone.
    stride = 1.0 + 3.0 * jnp.max(jnp.abs(top_boxes), axis=(1, 2))
    shifted = top_boxes.at[..., 0].add(
        top_labels.astype(jnp.float32) * stride[:, None]
    )
    keep = ops.nms(
        shifted, top_scores, iou_thresh=nms_iou, score_thresh=score_thresh, interpret=interpret
    )
    return {"boxes": top_boxes, "scores": top_scores, "cls": top_labels, "valid": keep}


def match_detections(
    pred: dict[str, jax.Array],
    gt_boxes: jax.Array,
    gt_cls: jax.Array,
    gt_valid: jax.Array,
    *,
    iou_thresh: float = 0.5,
    interpret: bool = True,
) -> jax.Array:
    """Greedy score-ordered matching -> per-detection TP flags (B, K) f32.

    pred: decode_predictions output (scores already descending per image);
    gt_boxes (B, G, 4), gt_cls (B, G) int32, gt_valid (B, G) 0/1. One tiled
    Pallas pairwise-IoU launch covers the whole batch; the greedy pass is a
    lax.scan over the K score-ranked slots: a detection is a true positive
    iff its best same-class, still-unmatched, valid GT reaches iou_thresh
    (each GT matches at most one detection — COCO/VOC greedy semantics).
    """
    iou = ops.pairwise_iou(pred["boxes"], gt_boxes, interpret=interpret)  # (B, K, G)

    def per_image(iou_i, pcls_i, pvalid_i, gcls_i, gvalid_i):
        def step(matched, k):
            cand = (
                (iou_i[k] >= iou_thresh)
                & (gcls_i == pcls_i[k])
                & (gvalid_i > 0)
                & ~matched
            )
            j = jnp.argmax(jnp.where(cand, iou_i[k], -1.0))
            hit = cand[j] & (pvalid_i[k] > 0)
            return matched.at[j].set(matched[j] | hit), hit.astype(jnp.float32)

        matched0 = jnp.zeros(gcls_i.shape, bool)
        _, tp = jax.lax.scan(step, matched0, jnp.arange(iou_i.shape[0]))
        return tp

    return jax.vmap(per_image)(iou, pred["cls"], pred["valid"], gt_cls, gt_valid)


def average_precision(
    scores: jax.Array,
    tp: jax.Array,
    valid: jax.Array,
    cls: jax.Array,
    n_gt_per_class: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Vectorized VOC all-point AP over one detection pool.

    scores/tp/valid/cls: flat (D,) over every detection slot in the pool;
    n_gt_per_class: (n_classes,) GT counts. Returns (ap (n_classes,), mAP
    scalar) where mAP averages over classes with at least one GT (classes
    absent from the pool contribute nothing rather than a fake 0 or 1).
    """
    n_classes = n_gt_per_class.shape[0]

    def ap_for(c):
        m = (valid > 0) & (cls == c)
        order = jnp.argsort(-jnp.where(m, scores, -jnp.inf), stable=True)
        mf = m.astype(jnp.float32)
        tp_s = jnp.take(tp * mf, order)
        fp_s = jnp.take((1.0 - tp) * mf, order)
        ctp, cfp = jnp.cumsum(tp_s), jnp.cumsum(fp_s)
        recall = ctp / jnp.maximum(n_gt_per_class[c].astype(jnp.float32), 1.0)
        precision = ctp / jnp.maximum(ctp + cfp, 1e-9)
        env = jax.lax.cummax(precision[::-1])[::-1]  # precision envelope
        dr = jnp.diff(recall, prepend=0.0)
        return jnp.sum(env * dr)

    ap = jax.vmap(ap_for)(jnp.arange(n_classes))
    present = (n_gt_per_class > 0).astype(jnp.float32)
    map50 = jnp.sum(ap * present) / jnp.maximum(jnp.sum(present), 1.0)
    return ap, map50


def evaluate_detections(
    pred: dict[str, jax.Array],
    gt_boxes: jax.Array,
    gt_cls: jax.Array,
    gt_valid: jax.Array,
    n_classes: int,
    *,
    iou_thresh: float = 0.5,
    interpret: bool = True,
) -> dict[str, jax.Array]:
    """One population's detection quality: {"ap" (n_classes,), "map" ()}.

    Leading dim of every array is the image axis; matching runs once, AP
    pools every image's detections (mAP@iou_thresh, default 0.5).
    """
    tp = match_detections(
        pred, gt_boxes, gt_cls, gt_valid, iou_thresh=iou_thresh, interpret=interpret
    )
    n_gt = jnp.sum(
        jax.nn.one_hot(gt_cls, n_classes, dtype=jnp.float32) * gt_valid[..., None],
        axis=(0, 1),
    )
    ap, map50 = average_precision(
        pred["scores"].reshape(-1), tp.reshape(-1), pred["valid"].reshape(-1),
        pred["cls"].reshape(-1), n_gt,
    )
    return {"ap": ap, "map": map50}


def build_evaluator(
    cfg,
    *,
    max_detections: int = 64,
    score_thresh: float = SCORE_THRESH,
    nms_iou: float = 0.5,
    match_iou: float = 0.5,
    interpret: bool = True,
):
    """Jitted federated evaluator: (params, eval_batch) -> mAP tree.

    eval_batch: {"images" (C, B, H, W, 3), "gt_boxes" (C, B, G, 4),
    "gt_cls" (C, B, G) int32, "gt_valid" (C, B, G) 0/1}. Returns
    {"map": pooled global mAP@0.5, "per_client_map": (C,),
    "per_client_ap": (C, n_classes)} — per-client and global come out of
    the SAME call: decode/NMS/IoU run once over the flattened (C*B) image
    axis (one launch each), only the pure-jnp AP pooling differs.
    """
    n_classes = cfg.vocab_size

    @jax.jit
    def evaluate(params, batch):
        images = batch["images"]
        C, B = images.shape[:2]
        flat = lambda x: x.reshape((C * B,) + x.shape[2:])
        pred = decode_predictions(
            cfg, params, flat(images),
            max_detections=max_detections, score_thresh=score_thresh,
            nms_iou=nms_iou, interpret=interpret,
        )
        gt_boxes = flat(batch["gt_boxes"]).astype(jnp.float32)
        gt_cls = flat(batch["gt_cls"]).astype(jnp.int32)
        gt_valid = flat(batch["gt_valid"]).astype(jnp.float32)
        tp = match_detections(
            pred, gt_boxes, gt_cls, gt_valid, iou_thresh=match_iou, interpret=interpret
        )
        gt_hist = jax.nn.one_hot(gt_cls, n_classes, dtype=jnp.float32) * gt_valid[..., None]

        def client_ap(scores, tps, valids, clss, n_gt):
            return average_precision(scores, tps, valids, clss, n_gt)

        per = lambda x: x.reshape(C, -1)
        ap_c, map_c = jax.vmap(client_ap)(
            per(pred["scores"]), per(tp), per(pred["valid"]),
            per(pred["cls"]), gt_hist.reshape(C, -1, n_classes).sum(axis=1),
        )
        _, map_g = average_precision(
            pred["scores"].reshape(-1), tp.reshape(-1), pred["valid"].reshape(-1),
            pred["cls"].reshape(-1), gt_hist.sum(axis=(0, 1)),
        )
        return {"map": map_g, "per_client_map": map_c, "per_client_ap": ap_c}

    return evaluate
