"""FL_SERVER — orchestrates federated rounds (paper component #5).

"responsible for model parameter uploading, model aggregation, and model
dispatch." The server owns the jitted fed_round, the scheduler, the object
store, and the round loop; FL_CLIENTs are the mesh slices (their control
surface is repro.core.client). Aggregation policy is resolved purely
through the :mod:`repro.core.aggregators` registry — the server never
branches on a mode name.

Scheduler-in-the-loop (DESIGN.md §8): each round the Explorer's load model
reports per-client loads, `TaskScheduler.participation` turns them into the
mask/weight (and compact-index) vectors, and those flow into the jitted
round as traced inputs — selection changes every round, the compiled
program never retraces. Per-client losses come back in the metrics and feed
the scheduler's quality EMA for the *participants only* (a skipped client's
quality signal would otherwise be fabricated).

Async mode (DESIGN.md §12): ``FedConfig.mode == "async"`` swaps the round
control plane for `core.async_engine.BufferedAsyncEngine` — `run_async`
drives one buffered flush per call on the shared `SimClock`, records
per-update staleness and the simulated wall-clock into the history the
monitor renders, and the engine feeds the same scheduler quality EMA from
its async completions.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.configs.base import ArchConfig
from repro.core import aggregators, async_engine, explorer, rounds
from repro.core.async_engine import (
    AsyncRoundRecord,
    BufferedAsyncEngine,
    StreamingAsyncEngine,
    TimingModel,
    sync_round_seconds,
)
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.core.simclock import SimClock
from repro.optim import Optimizer

PyTree = Any


@dataclasses.dataclass
class RoundRecord:
    round_idx: int
    loss: float
    weights: list[float]
    seconds: float
    participants: list[int] = dataclasses.field(default_factory=list)
    loads: list[float] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class EvalRecord:
    """One `evaluate_round` outcome: global + per-client mAP@0.5."""

    round_idx: int
    map50: float
    per_client_map: list[float]


class FLServer:
    def __init__(
        self,
        cfg: ArchConfig,
        fed: rounds.FedConfig,
        optimizer: Optimizer,
        *,
        store: ObjectStore | None = None,
        scheduler: TaskScheduler | None = None,
        mesh=None,
        rules: dict | None = None,
        seed: int = 0,
        dtype=jnp.float32,
        checkpoint_every: int = 0,
        task_id: str = "task",
        load_model: explorer.ClientLoadModel | None = None,
        clock: SimClock | None = None,
        timing: TimingModel | None = None,
    ):
        if fed.mode not in ("sync", "async"):
            raise ValueError(f"unknown mode {fed.mode!r}; expected sync|async")
        self.cfg = cfg
        self.fed = fed
        self.optimizer = optimizer
        self.store = store
        self.task_id = task_id
        self.checkpoint_every = checkpoint_every
        self.scheduler = scheduler or TaskScheduler(fed.n_clients, SchedulerConfig())
        self.load_model = load_model or explorer.ClientLoadModel(fed.n_clients, seed=seed)
        # an explicitly shared clock makes sync rounds advance simulated
        # time too (wait-for-slowest), so sync and async servers interleave
        # under TaskManager.step_shared_clock; without one, sync rounds
        # keep the legacy timeless cadence
        self._shared_clock = clock is not None
        self.clock = clock or SimClock()
        self.timing = timing or TimingModel()
        # compact rounds need the scheduler to emit exactly K indices
        self._k_static = rounds.static_budget(fed) if fed.participation == "compact" else None
        # registry dispatch: validates the mode name and any mode config
        # (e.g. quant8 divisibility, trimmed_mean ratio) before any jit
        self.aggregator = rounds.make_aggregator(cfg, fed, mesh)
        self.dtype = dtype
        self.engine: BufferedAsyncEngine | None = None
        if fed.mode == "async":
            # the engine owns the flat state and the (donated) flush
            # program; the server's round surface delegates to it.
            # stream=True swaps the O(C·N) buffered flush for the ring +
            # running-accumulator discipline (DESIGN.md §13)
            engine_cls = StreamingAsyncEngine if fed.stream else BufferedAsyncEngine
            self.engine = engine_cls(
                cfg, fed, optimizer, mesh=mesh, rules=rules, seed=seed, dtype=dtype,
                clock=self.clock, load_model=self.load_model, timing=self.timing,
                scheduler=self.scheduler, aggregator=self.aggregator,
            )
            self.state = self.engine.state
            self._fed_round = None
            self._upload_s = self.engine.upload_s
        else:
            self.state = rounds.make_state(cfg, fed, optimizer, jax.random.key(seed), dtype)
            # donated jit (DESIGN.md §11): run_round consumes self.state and
            # rebinds the returned one, so XLA reuses the round buffers in place
            self._fed_round = rounds.jit_fed_round(rounds.build_fed_round(cfg, fed, optimizer, mesh, rules))
            self._upload_s = async_engine.default_upload_terms(
                self.timing, fed.n_clients, self.aggregator.ctx.spec.n_total, seed
            )
        self.history: list[RoundRecord | AsyncRoundRecord] = []
        self.eval_history: list[EvalRecord] = []
        self._evaluator = None  # (max_detections, jitted fn), built lazily

    @property
    def aggregation_modes(self) -> tuple[str, ...]:
        """Every mode this server could be configured with."""
        return aggregators.names()

    def global_params(self) -> PyTree:
        """Dispatchable global model (synced post-round; fedsgd topology
        already holds the single shared copy). Sync rounds broadcast the
        global to every row, so row 0 serves; an async state only
        guarantees *some* rows hold the fresh global — in-flight rows (row
        0 included) may carry stale dispatch versions — so this reads the
        engine's `global_packed_row()`, never a fixed row index. Each
        engine knows where its global lives: buffered keeps `global_row`
        (the last-staged row, immutable until the next flush), streaming
        the live ring slot, and the arrival engine an explicit snapshot
        (its rows mutate on every landing, so no buffer row is trustworthy
        mid-window). Async checkpoints go through here, so a checkpoint
        taken right after drops/redispatches stores the flushed global,
        not a client's half-trained row — tests/test_transport.py pins
        that. This is a pack/unpack EDGE (DESIGN.md §11): the flat round
        state unpacks to a param pytree only here — checkpoint PUT and
        model dispatch to serving — never inside the round."""
        if not self.aggregator.stacked:
            return self.state["params"]
        if self.engine is not None:
            # the engine knows which row is current (buffered: the last
            # staged client's row; streaming: the live ring slot)
            packed = self.engine.global_packed_row()[None]
            params = rounds.unpacked_params(self.cfg, self.fed, {"params": packed}, self.dtype)
            return jax.tree.map(lambda x: x[0], params)
        params = self.state["params"]
        if isinstance(params, jax.Array):  # flat layout: unpack one row only
            params = rounds.unpacked_params(
                self.cfg, self.fed, {"params": params[:1]}, self.dtype
            )
            return jax.tree.map(lambda x: x[0], params)
        return jax.tree.map(lambda x: x[0], params)

    def run_round(self, batch: PyTree) -> RoundRecord:
        if self.engine is not None:
            raise RuntimeError(
                "FedConfig(mode='async') servers run buffered flushes — call "
                "run_async(batch) (or fit(), which dispatches on the mode)"
            )
        t0 = time.time()
        if self._shared_clock:
            # shared-clock semantics: this round's report is the load
            # process state *now*; the round then consumes wait-for-slowest
            # simulated time and the process evolves over that same span
            # (stepping by 1.0 here would re-conflate process time with
            # round count — the cadence bug the §12 Explorer fix removed)
            loads = self.load_model.loads.copy()
        else:
            loads = self.load_model.step()  # legacy: one tick per round
        sel = self.scheduler.participation(loads, k_static=self._k_static)
        part = rounds.participation_input(self.fed, sel["mask"], sel["weights"], sel.get("idx"))
        if self._shared_clock:
            # the round takes as long as its slowest selected client
            dur = sync_round_seconds(
                self.timing, loads, self._upload_s, self.fed.local_steps,
                mask=sel["mask"],
            )
            self.clock.advance(dur)
            self.load_model.step(dur)
        self.state, metrics = self._fed_round(self.state, batch, part)
        loss = float(metrics["loss"])
        participants = [int(c) for c in np.nonzero(sel["mask"])[0]]
        client_loss = np.asarray(metrics["client_loss"], np.float32)
        for c in participants:
            self.scheduler.report_quality(c, float(client_loss[c]))
        rec = RoundRecord(
            len(self.history),
            loss,
            [float(w) for w in sel["weights"]],
            time.time() - t0,
            participants=participants,
            loads=[float(x) for x in loads],
        )
        self.history.append(rec)
        if self.store and self.checkpoint_every and rec.round_idx % self.checkpoint_every == 0:
            self.store.put_model(self.task_id, rec.round_idx, self.global_params(), {"loss": loss})
        return rec

    def run_async(self, batch: PyTree) -> AsyncRoundRecord:
        """One buffered-aggregation flush on the simulated clock (DESIGN.md
        §12): the engine pops completion events until ``buffer_size`` updates
        stage (dropping and counting anything staler than max_staleness),
        applies the staleness-weighted donated flush, and redispatches. The
        record lands in the same history the monitor renders — per-update
        staleness and the simulated wall-clock included — and the engine has
        already fed the scheduler quality EMA from the completions."""
        if self.engine is None:
            raise RuntimeError("run_async needs FedConfig(mode='async')")
        rec = self.engine.step_round(batch)
        self.state = self.engine.state  # global_params/eval read through here
        self.history.append(rec)
        if self.store and self.checkpoint_every and rec.round_idx % self.checkpoint_every == 0:
            self.store.put_model(self.task_id, rec.round_idx, self.global_params(), {"loss": rec.loss})
        return rec

    def next_time(self) -> float:
        """Simulated completion time of this server's next round — the
        `FederatedTask.next_time` hook for TaskManager's shared-clock
        interleave (DESIGN.md §12). Async servers report their earliest
        queued completion; sync servers estimate now + wait-for-slowest
        over the clients the scheduler is likely to select: the K fastest
        under its budget (an under-budget fleet never waits for unselected
        stragglers) PLUS every client whose idle streak hit the fairness
        floor — the scheduler guarantees those join the next round, so a
        floored straggler's wait belongs in the estimate."""
        if self.engine is not None:
            t = self.engine.next_completion_time()
            return self.clock.now() if t is None else t
        per = np.array([
            self.timing.compute_seconds(l, self.fed.local_steps)
            for l in self.load_model.loads
        ]) + self._upload_s
        k = self._k_static or self.scheduler.cfg.max_participants or self.fed.n_clients
        k = min(k, self.fed.n_clients)
        dur = float(np.sort(per)[:k].max())
        floored = per[self.scheduler.idle_rounds >= self.scheduler.cfg.fairness_rounds]
        if floored.size:
            dur = max(dur, float(floored.max()))
        return self.clock.now() + dur

    def evaluate_round(
        self,
        eval_batch: PyTree,
        *,
        max_detections: int = 64,
        feed_scheduler: bool = True,
    ) -> EvalRecord:
        """Detection-quality checkpoint: global model vs each client's eval
        slice (DESIGN.md §10).

        eval_batch: {"images" (C, B, H, W, 3), "gt_boxes"/"gt_cls"/
        "gt_valid" (C, B, G, ...)} — e.g. `data.pipeline.detection_suite`'s
        holdout. One jitted call returns the pooled global mAP@0.5 and the
        per-client vector; the latter feeds the Task Scheduler's quality
        EMA (`report_eval`), so selection tracks *detection* quality, not
        just training loss — the signal the paper's load-balancing
        scheduler is supposed to maximize.
        """
        from repro.core import detection  # lazy: only detection tasks pay the import

        if self._evaluator is None or self._evaluator[0] != max_detections:
            self._evaluator = (
                max_detections,
                detection.build_evaluator(self.cfg, max_detections=max_detections),
            )
        out = self._evaluator[1](self.global_params(), jax.tree.map(jnp.asarray, eval_batch))
        per_client = [float(x) for x in np.asarray(out["per_client_map"], np.float64)]
        if feed_scheduler:
            for c, m in enumerate(per_client):
                self.scheduler.report_eval(c, m)
        rec = EvalRecord(max(len(self.history) - 1, 0), float(out["map"]), per_client)
        self.eval_history.append(rec)
        return rec

    def fit(self, batches: Iterator[PyTree], n_rounds: int, log: Callable[[str], None] = lambda m: print(m, flush=True)) -> list[RoundRecord]:
        step = self.run_async if self.engine is not None else self.run_round
        for r in range(n_rounds):
            rec = step(next(batches))
            if log and (r % max(1, n_rounds // 10) == 0 or r == n_rounds - 1):
                msg = (f"round {rec.round_idx:4d}  loss {rec.loss:.4f}  "
                       f"participants {len(rec.participants)}/{self.fed.n_clients}")
                if isinstance(rec, AsyncRoundRecord):
                    msg += (f"  sim {rec.sim_time:7.0f}s  staleness "
                            f"{np.mean(rec.staleness):.2f}  dropped {rec.dropped}")
                log(msg)
        return self.history
