"""Baseline aggregators: Eq. 5 dense FedAvg, static layer schedules, FedSGD."""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator, register


def static_layer_schedule(n_buckets: int, topn: int, round_idx: int) -> tuple[int, ...]:
    """Round-robin layer subset for round `round_idx` (trace-time static)."""
    off = (round_idx * topn) % n_buckets
    return tuple((off + i) % n_buckets for i in range(topn))


@register
class Dense(Aggregator):
    """Paper Eq. 5: weighted mean of every parameter, full upload."""

    name = "dense"

    def aggregate(self, packed, weights, agg_state, mask=None):
        g = self._wmean_full(packed, weights, mask)
        return self._broadcast(g, packed), agg_state


@register
class StaticTopN(Aggregator):
    """Beyond-paper: trace-time round-robin layer subset. Only the scheduled
    buckets aggregate; the rest keep each client's local values, so the
    cross-client collective operand shrinks structurally."""

    name = "static_topn"

    def __init__(self, ctx):
        super().__init__(ctx)
        sched = static_layer_schedule(ctx.spec.n_buckets, ctx.fed.topn, ctx.fed.round_idx_static)
        mask = np.zeros(ctx.spec.n_buckets, np.float32)
        mask[list(sched)] = 1.0
        self._bucket_mask = mask

    def aggregate(self, packed, weights, agg_state, mask=None):
        from repro.core import packing

        wmask = weights.astype(jnp.float32)[:, None] * jnp.asarray(self._bucket_mask)[None, :]
        g, den_b = self._mean(packed, wmask, mask)  # den_b: per-bucket (B,)
        up = packing.expand_bucket_vec(self.ctx.spec, den_b > 0)
        out = jnp.where(up[None, :], self._broadcast(g, packed), packed)
        return out, agg_state


@register
class FedSGD(Aggregator):
    """FedSGD-equivalent topology: clients are data-parallel shards of ONE
    shared model copy, so there is no client-stacked buffer to aggregate
    (param-averaging == gradient-averaging for E=1; DESIGN.md §5).
    `core.rounds` branches on `stacked`, never on the mode name."""

    name = "fedsgd"
    stacked = False

    def aggregate(self, packed, weights, agg_state, mask=None):  # pragma: no cover
        raise RuntimeError("fedsgd runs one shared model copy; nothing to aggregate")
