"""quant4: 4-bit stochastic-rounded delta upload over the packed buffer.

quant8's sub-byte sibling: global = base + wmean_c(dequant(quant4(new_c -
base))) with one f32 scale per `quant_block` elements and values in the
[-7, 7] nibble range (two per byte on the wire — codec.py's QUANT4 framing;
~8x smaller uplink than dense, ~2x under quant8). ``quant4_mode`` picks the
rounding:

  stochastic — clip(floor(x/s + u), -7, 7), u from the fmix32 counter PRNG
               keyed per round. The key derives from a TRACED round counter
               in ``state["agg"]``, so rounds never retrace and the same
               (seed, round, client, element) always rounds the same way —
               bit-for-bit reproducible across ref/Pallas/NumPy.
  nearest    — clip(rint(x/s), -7, 7), deterministic half-step error bound.
  skip       — statically routes through dense's exact reduction (the
               bitwise dense-equivalence pin in the frontier tests).

Meshless path only: at 4 bits the transport win is already modeled by the
fused encode->decode->reduce (`kernels/quant4.quant4_reduce` under
agg_impl="pallas", `packing.quant4_mean_ref` otherwise); the int8-collective
machinery stays quant8's.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import packing
from repro.core.aggregators.base import Aggregator, register


@register
class Quant4(Aggregator):
    name = "quant4"

    def __init__(self, ctx):
        super().__init__(ctx)
        if ctx.fed.quant4_mode not in ("stochastic", "nearest", "skip"):
            raise ValueError(
                f"quant4_mode={ctx.fed.quant4_mode!r} not in ('stochastic', 'nearest', 'skip')"
            )
        shards = 1
        if ctx.mesh is not None:
            shards = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get(
                ctx.fed.client_axis, 1
            )
        if shards > 1:
            raise ValueError(
                f"quant4 has no sharded int4 collective; '{ctx.fed.client_axis}' "
                f"mesh axis must be 1 (got {shards}) — use quant8 for the "
                f"gathered transport"
            )

    def init_state(self, packed0):
        # base: dispatched (N,) row (fresh slice, donation-safe — see
        # quant8); round: the traced counter the per-round PRNG key mixes
        return {"base": packed0[0], "round": jnp.zeros((), jnp.int32)}

    def aggregate(self, packed, weights, agg_state, mask=None):
        fed = self.ctx.fed
        base = agg_state["base"]
        r = agg_state["round"]
        if fed.quant4_mode == "skip":  # static route: dense bit-for-bit
            g = self._wmean_full(packed, weights, mask)
            out = self._broadcast(g, packed)
            return out, {"base": out[0], "round": r + 1}
        w_eff = self._masked_weights(weights, mask)
        key = packing.round_key(fed.quant4_seed, r)
        delta = packed.astype(jnp.float32) - base.astype(jnp.float32)[None, :]
        if fed.agg_impl == "pallas":
            from repro.kernels import quant4 as _kq

            gd = _kq.quant4_reduce(
                delta, w_eff, key, mode=fed.quant4_mode, block=fed.quant_block
            )
        else:
            gd = packing.quant4_mean_ref(
                delta, w_eff, fed.quant_block, key=key, mode=fed.quant4_mode
            )
        g = (base.astype(jnp.float32) + gd).astype(packed.dtype)
        out = jnp.broadcast_to(g[None, :], packed.shape)
        return out, {"base": out[0], "round": r + 1}
