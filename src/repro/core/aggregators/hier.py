"""Hierarchical two-level aggregation over the packed buffer (DESIGN.md §13).

FedVision's deployment is many cameras behind few edge servers: ``hier``
makes that topology a registered aggregator that composes any *stacked*
mode. Clients are split into C/G contiguous edge groups of
``FedConfig.group_size`` G; each group reduces locally with a per-group
renormalized weighted mean (`packing.grouped_weighted_mean` — one fused
chain per group under the CHAIN_MAX_CLIENTS cutover, one batched
contraction or `kernels/pack.grouped_reduce` launch above it), then the
registered ``FedConfig.hier_base`` reducer merges the (C/G, N_total) group
rows exactly as it would merge client rows. Group weights are the sums of
their members' (mask-folded) weights, so the two-level dense mean IS the
flat dense mean analytically:

    sum_g (sum_i w_gi) * [sum_i w_gi x_gi / sum_i w_gi] / sum_g sum_i w_gi
  = sum_c w_c x_c / sum_c w_c                                     (Eq. 5)

A group none of whose members participated reduces to a zero row with a
zero group weight and is masked out of the outer reduce. The outer
dispatch row of each group is broadcast to all its members — the edge
server redistributes within its group.

Equivalence anchors (pinned in tests/test_hier.py): at ``G == 1`` every
group is one client and at ``G == C`` there is one group — both degenerate
points are *the flat path itself*, so ``hier`` delegates verbatim to the
``hier_base`` aggregator over the full cohort and is bit-for-bit the
existing engine by construction (recomputing through the generic two-level
program would re-order the floating-point reductions).

Sharded client axis: with a mesh whose client axis has S > 1 shards, the
inner group reduce runs inside `shard_map` — groups must be shard-local
((C/S) % G == 0, validated at build — so every group mean completes
without communication, and the only cross-shard data movement is the
gather of the small (C/G, N) group-row operand into the outer reduce.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.aggregators.base import AggContext, Aggregator, get, register


@register
class Hier(Aggregator):
    name = "hier"

    def __init__(self, ctx: AggContext):
        super().__init__(ctx)
        fed = ctx.fed
        C = fed.n_clients
        G = fed.group_size or C
        if not 1 <= G <= C or C % G:
            raise ValueError(
                f"hier: group_size={G} must divide n_clients={C} "
                f"(and lie in [1, {C}])"
            )
        base = fed.hier_base
        if base == "hier":
            raise ValueError("hier: hier_base='hier' would recurse; name a flat reducer")
        base_cls = get(base)  # build-time: unknown names fail here
        if not base_cls.stacked:
            raise ValueError(
                f"hier: hier_base={base!r} runs one shared model copy "
                "(fedsgd topology); compose a client-stacked reducer"
            )
        self.group_size = G
        self.ngroups = C // G
        self._shards = 1
        if ctx.mesh is not None:
            self._shards = dict(
                zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)
            ).get(fed.client_axis, 1)
        self._delegate = G in (1, C)
        if self._delegate:
            # the equivalence anchor: both degenerate geometries ARE the
            # flat path, so run the base aggregator verbatim — same program,
            # bit-for-bit, for every registered stacked mode
            impl_ctx = dataclasses.replace(
                ctx, fed=dataclasses.replace(fed, aggregation=base, group_size=0)
            )
            self._impl = base_cls(impl_ctx)
            return
        if self._shards > 1 and (C // self._shards) % G:
            raise ValueError(
                f"hier: groups must be shard-local — n_clients={C} over "
                f"{self._shards} '{fed.client_axis}' shards leaves "
                f"{C // self._shards} rows per shard, not divisible by "
                f"group_size={G}"
            )
        # the outer reduce sees C/G "clients" (the group rows), replicated:
        # the gathered (C/G, N) operand is the one cross-shard merge
        outer_fed = dataclasses.replace(
            fed, n_clients=self.ngroups, aggregation=base, group_size=0
        )
        self._impl = base_cls(dataclasses.replace(ctx, fed=outer_fed, mesh=None))

    # -- cross-round state ---------------------------------------------------
    def init_state(self, packed0):
        if self._delegate:
            return self._impl.init_state(packed0)
        # one representative row per group: every client starts from the
        # same dispatch, so the strided slice is the initial group-row view
        return self._impl.init_state(packed0[:: self.group_size])

    def state_pspecs(self):
        if self._delegate:
            return self._impl.state_pspecs()
        # outer state is group-granular ((C/G, ...) at most) — replicate it
        # server-side rather than inheriting client-axis pspecs the group
        # count need not divide
        C = self.ctx.fed.n_clients
        abs_in = jax.ShapeDtypeStruct((C, self.ctx.spec.n_total), jnp.float32)
        return jax.tree.map(lambda _: P(), jax.eval_shape(self.init_state, abs_in))

    # -- the round -----------------------------------------------------------
    def _inner(self, packed, w):
        """(C, N) + mask-folded (C,) weights -> ((C/G, N) rows, (C/G,) den),
        shard-local under shard_map when the client axis is sharded."""
        fed = self.ctx.fed
        if self._shards > 1:
            pspec = packing.packed_pspec(self.ctx.spec, fed.client_axis, self.ctx.mesh)

            def body(p_loc, w_loc):
                return packing.grouped_weighted_mean(
                    p_loc, w_loc, self.group_size, impl=fed.agg_impl
                )

            return jax.shard_map(
                body,
                mesh=self.ctx.mesh,
                in_specs=(pspec, P(fed.client_axis)),
                out_specs=(P(*pspec), P(fed.client_axis)),
                check_vma=False,
            )(packed, w)
        return packing.grouped_weighted_mean(
            packed, w, self.group_size, impl=fed.agg_impl
        )

    def aggregate(self, packed, weights, agg_state, mask=None):
        if self._delegate:
            return self._impl.aggregate(packed, weights, agg_state, mask)
        w = self._masked_weights(weights, mask)
        rows, den = self._inner(packed, w)  # (C/G, N) f32, (C/G,)
        gmask = (den > 0).astype(jnp.float32)  # empty groups drop out
        out_g, agg_state = self._impl.aggregate(rows, den, agg_state, gmask)
        C, N = packed.shape
        out = jnp.broadcast_to(
            out_g.astype(packed.dtype)[:, None, :], (self.ngroups, self.group_size, N)
        ).reshape(C, N)
        return out, agg_state
