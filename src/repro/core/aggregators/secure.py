"""secure: pairwise additive masking in the packed integer domain.

`core.secure_agg`'s Bonawitz construction ported onto the (C, N_total)
buffer — and moved from float masks to the uint32 ring, so cancellation is
EXACT: every active pair (a, b) derives a shared fmix32 mask stream; a adds
+m, b adds -m (mod 2^32); the server's modular sum of active rows equals
the unmasked sum BIT-FOR-BIT. That is only possible because the masked
quantities are integers: each client's weighted delta w_c * (new_c - base)
is quantized to a SHARED per-block scale (amax over participants), values
in [-Q, Q] with Q = 127 ("int8" domain) or 7 ("int4" — composes with the
quant4 wire budget). |sum_c q_c| <= C * Q << 2^31, so the uint32 total
reinterprets as the true signed sum.

Participation-mask-aware: a deselected client is excluded from the scale,
contributes no row to the sum, and activates NO pair — so no orphan mask
survives (the dropout-recovery secret-sharing layer stays out of scope, as
in core.secure_agg).

``secure_mask=False`` skips the masking but keeps the identical quantized
sum — the masked == unmasked bitwise pin in the frontier tests. Pairwise
masking is O(C^2 N); build-time bound C <= 32 keeps the traced program
sane (the paper's federations are tens of parties).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.aggregators.base import Aggregator, register

MAX_SECURE_CLIENTS = 32


@register
class Secure(Aggregator):
    name = "secure"

    def __init__(self, ctx):
        super().__init__(ctx)
        if ctx.fed.secure_domain not in ("int8", "int4"):
            raise ValueError(
                f"secure_domain={ctx.fed.secure_domain!r} not in ('int8', 'int4')"
            )
        if ctx.fed.n_clients > MAX_SECURE_CLIENTS:
            raise ValueError(
                f"secure pairwise masking is O(C^2); n_clients={ctx.fed.n_clients} "
                f"exceeds the build-time bound {MAX_SECURE_CLIENTS}"
            )
        shards = 1
        if ctx.mesh is not None:
            shards = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get(
                ctx.fed.client_axis, 1
            )
        if shards > 1:
            raise ValueError(
                f"secure masking needs every client row on one host; "
                f"'{ctx.fed.client_axis}' mesh axis must be 1 (got {shards})"
            )

    def init_state(self, packed0):
        return {"base": packed0[0], "round": jnp.zeros((), jnp.int32)}

    def aggregate(self, packed, weights, agg_state, mask=None):
        fed = self.ctx.fed
        C = packed.shape[0]
        base = agg_state["base"]
        r = agg_state["round"]
        Q = 127.0 if fed.secure_domain == "int8" else 7.0
        block = fed.quant_block
        pm = jnp.ones((C,), jnp.float32) if mask is None else mask.astype(jnp.float32)
        w_eff = self._masked_weights(weights, mask)

        # weighted deltas: their plain sum IS the weighted mean (the
        # scheduler normalizes weights over participants)
        delta = packed.astype(jnp.float32) - base.astype(jnp.float32)[None, :]
        v = w_eff[:, None] * delta
        N = v.shape[1]
        pad = (-N) % block
        vb = jnp.pad(v, ((0, 0), (0, pad))).reshape(C, -1, block)
        # SHARED per-block scale over participants only: a junk row from a
        # deselected client must not blow up everyone's quantization step
        amax = jnp.max(jnp.where(pm[:, None, None] > 0, jnp.abs(vb), 0.0), axis=(0, 2))
        scale = jnp.maximum(amax, 1e-12) / Q
        q = jnp.clip(jnp.round(vb / scale[None, :, None]), -Q, Q).astype(jnp.int32)
        q = q.reshape(C, -1)

        rk = packing.round_key(fed.secure_session, r)
        rows = jax.lax.bitcast_convert_type(q, jnp.uint32)
        if fed.secure_mask:
            rows = rows + packing.secure_client_masks(rk, pm, q.shape[1])
        if fed.agg_impl == "pallas":
            from repro.kernels import mask as _km

            total = _km.masked_u32_sum(rows, pm)
        else:
            total = jnp.sum(
                jnp.where(pm[:, None] > 0, rows, jnp.uint32(0)), axis=0, dtype=jnp.uint32
            )
        s = jax.lax.bitcast_convert_type(total, jnp.int32)  # masks cancelled exactly
        gd = (s.astype(jnp.float32).reshape(-1, block) * scale[:, None]).reshape(-1)[:N]
        g = (base.astype(jnp.float32) + gd).astype(packed.dtype)
        out = jnp.broadcast_to(g[None, :], packed.shape)
        return out, {"base": out[0], "round": r + 1}
