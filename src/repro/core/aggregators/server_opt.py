"""Server-side optimizer aggregation (FedAvgM / FedAdam, Reddi et al. 2021).

The weighted client mean is treated as a target and ``delta = global - avg``
as a pseudo-gradient; a server optimizer from `repro.optim` (whose states
are plain pytrees, so a flat (N,) vector works unchanged) takes one step per
round. With server_lr=1 and zero momentum this reduces exactly to dense
FedAvg; momentum/adaptivity accelerate under client drift.

FedAdam wants a small server_lr (0.01-0.1): the adaptive step is ~server_lr
per coordinate regardless of delta magnitude.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator, register
from repro.optim import adamw, sgd


class _ServerOpt(Aggregator):
    def _optimizer(self):
        raise NotImplementedError

    def init_state(self, packed0):
        g = packed0[0].astype(jnp.float32)  # clients start from one dispatch
        return {"global": g, "opt": self._optimizer().init(g)}

    def aggregate(self, packed, weights, agg_state, mask=None):
        avg = self._wmean_full(packed, weights, mask)
        delta = agg_state["global"] - avg  # pseudo-gradient
        g, opt_state = self._optimizer().update(agg_state["global"], delta, agg_state["opt"])
        return self._broadcast(g, packed), {"global": g, "opt": opt_state}


@register
class FedAvgM(_ServerOpt):
    """Dense FedAvg + server momentum on the aggregated delta."""

    name = "fedavgm"

    def _optimizer(self):
        fed = self.ctx.fed
        return sgd(lr=fed.server_lr, momentum=fed.server_momentum, clip_norm=0.0)


@register
class FedAdam(_ServerOpt):
    """Adam on the server delta (weight decay off, clipping off)."""

    name = "fedadam"

    def _optimizer(self):
        fed = self.ctx.fed
        return adamw(
            lr=fed.server_lr,
            b1=fed.server_momentum,
            b2=fed.server_beta2,
            eps=fed.server_eps,
            weight_decay=0.0,
            clip_norm=0.0,
        )
