"""Pluggable aggregation strategies (DESIGN.md §7).

Importing this package registers every built-in mode:
dense | eq6 | quant8 | static_topn | fedavgm | fedadam | trimmed_mean
plus the `fedsgd` topology marker, the two-level `hier` composer
(DESIGN.md §13), and the communication frontier (DESIGN.md §15):
topk_ef | quant4 | secure. `get(name)` resolves a FedConfig aggregation
name to its strategy class; `names()` lists what is available.
"""
from repro.core.aggregators.base import AggContext, Aggregator, get, names, register
from repro.core.aggregators import basic, eq6, hier, lowbit, quant, robust, secure, server_opt, sparse  # noqa: F401,E402 (registration)
from repro.core.aggregators.basic import static_layer_schedule

__all__ = [
    "AggContext",
    "Aggregator",
    "get",
    "names",
    "register",
    "static_layer_schedule",
]
