"""Paper Eq. 6 top-n aggregation over the packed buffer.

Each client ranks its score buckets by v(j) = |sum_k - sum_{k-1}| (signed
per-layer parameter sums across consecutive rounds) and uploads only its
top-n. A bucket's global value is the weighted mean over the clients that
uploaded it; buckets uploaded by nobody keep each client's local values.

On the packed transport this is: two segment-sum passes for the scores plus
ONE masked reduction — versus the seed's per-leaf mask/sum/where tree walk.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compression as comp
from repro.core import packing
from repro.core.aggregators.base import Aggregator, register


@register
class Eq6(Aggregator):
    name = "eq6"

    def init_state(self, packed0):
        return {"prev_sums": packing.bucket_sums(self.ctx.spec, packed0)}

    def state_pspecs(self):
        return {"prev_sums": P(self.ctx.fed.client_axis, None)}

    def aggregate(self, packed, weights, agg_state, mask=None):
        new_sums = packing.bucket_sums(self.ctx.spec, packed)  # (C, B)
        v = comp.contribution_scores(agg_state["prev_sums"], new_sums)
        upload = jax.vmap(lambda s: comp.topn_mask(s, self.ctx.fed.topn))(v)
        wmask = upload.astype(jnp.float32) * weights.astype(jnp.float32)[:, None]
        g, den_b = self._mean(packed, wmask, mask)  # den_b: per-bucket (B,)
        up = packing.expand_bucket_vec(self.ctx.spec, den_b > 0)
        out = jnp.where(up[None, :], self._broadcast(g, packed), packed)
        return out, {"prev_sums": new_sums}
