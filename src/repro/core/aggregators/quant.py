"""quant8: int8-quantized delta upload over the packed buffer.

global = base + wmean_c(dequant(quant(new_c - base))). The transport is an
explicit int8 all_gather over the client mesh axis inside shard_map, so the
HLO moves 1-byte operands — ~4x fewer collective bytes than f32 — and it is
ONE collective over the packed buffer instead of one per leaf. Scale
granularity is one f32 per `FedConfig.quant_block` elements per client row
(0.4% overhead at the default 1024).

`FedConfig.agg_impl="pallas"` routes the quantize/dequantize through the
packed row-block kernels (`kernels/pack.quantize_rows`); the default "ref"
impl uses the numerically identical jnp formulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.aggregators.base import Aggregator, register


@register
class Quant8(Aggregator):
    name = "quant8"

    def __init__(self, ctx):
        super().__init__(ctx)
        C = ctx.fed.n_clients
        if ctx.mesh is not None:
            shards = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get(
                ctx.fed.client_axis, 1
            )
            if C % shards:
                raise ValueError(
                    f"quant8 requires n_clients ({C}) divisible by the "
                    f"'{ctx.fed.client_axis}' mesh axis ({shards} shards); "
                    f"otherwise the gathered row-scale vector has the wrong length"
                )

    def init_state(self, packed0):
        # the dispatched base model each client diffs against next round
        return {"base": packed0}

    def state_pspecs(self):
        return {"base": packing.packed_pspec(self.ctx.spec, self.ctx.fed.client_axis, self.ctx.mesh)}

    def _quant(self, delta, block):
        if self.ctx.fed.agg_impl == "pallas":
            from repro.kernels import pack as _pk

            return _pk.quantize_rows(delta, block=block)
        return packing.quantize_rows_ref(delta, block)

    def _dequant(self, q, scales, block):
        if self.ctx.fed.agg_impl == "pallas":
            from repro.kernels import pack as _pk

            return _pk.dequantize_rows(q, scales, block=block)
        return packing.dequantize_rows_ref(q, scales, block)

    def aggregate(self, packed, weights, agg_state, mask=None):
        base = agg_state["base"]
        block = self.ctx.fed.quant_block
        axis = self.ctx.fed.client_axis
        w_eff = self._masked_weights(weights, mask)

        def body(new, base_, w):
            delta = new.astype(jnp.float32) - base_.astype(jnp.float32)  # (C_loc, N)
            q, scales = self._quant(delta, block)
            if self.ctx.mesh is not None:
                q = jax.lax.all_gather(q, axis, axis=0, tiled=True)  # int8 (C, N)
                scales = jax.lax.all_gather(scales, axis, axis=0, tiled=True)
            d = self._dequant(q, scales, block)  # (C, N) f32
            gd = jnp.einsum("c,cn->n", w, d)
            return (base_.astype(jnp.float32) + gd[None, :]).astype(new.dtype)

        if self.ctx.mesh is None:
            out = body(packed, base, w_eff)
        else:
            spec = packing.packed_pspec(self.ctx.spec, axis, self.ctx.mesh)
            out = jax.shard_map(
                body,
                mesh=self.ctx.mesh,
                in_specs=(spec, spec, P()),
                out_specs=spec,
                check_vma=False,
            )(packed, base, w_eff)
        return out, {"base": out}
