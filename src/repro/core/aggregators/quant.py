"""quant8: int8-quantized delta upload over the packed buffer.

global = base + wmean_c(dequant(quant(new_c - base))). With a client mesh
axis the transport is an explicit int8 all_gather inside shard_map, so the
HLO moves 1-byte operands — ~4x fewer collective bytes than f32 — and it is
ONE collective over the packed buffer instead of one per leaf; the gathered
payload then feeds a fused decode->reduce (no (C, N) dequant buffer).
Without a mesh there is no wire to put int8 bytes on, so encode, decode and
reduction fuse into a single pass (`packing.quant8_mean_ref`, or ONE
`kernels/pack.quant8_reduce` launch under agg_impl="pallas") —
clip(round(x/s)) in f32 is bit-identical to the int8 round-trip. Scale
granularity is one f32 per `FedConfig.quant_block` elements per client row
(0.4% overhead at the default 1024).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import packing
from repro.core.aggregators.base import Aggregator, register


@register
class Quant8(Aggregator):
    name = "quant8"

    def __init__(self, ctx):
        super().__init__(ctx)
        C = ctx.fed.n_clients
        G = ctx.fed.group_size
        shards = 1
        if ctx.mesh is not None:
            shards = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape)).get(
                ctx.fed.client_axis, 1
            )
        if G:
            # hierarchical geometry: groups must tile the cohort AND each
            # shard must hold whole groups, or the gathered int8 rows of a
            # group straddle devices and the row-scale vectors misalign
            if C % G or (shards > 1 and G % shards):
                raise ValueError(
                    f"quant8 hierarchical geometry invalid: n_clients={C}, "
                    f"group_size={G}, '{ctx.fed.client_axis}' shards={shards} "
                    f"— need n_clients % group_size == 0 and "
                    f"group_size % shards == 0"
                )
        elif C % max(shards, 1):
            raise ValueError(
                f"quant8 requires n_clients ({C}) divisible by the "
                f"'{ctx.fed.client_axis}' mesh axis ({shards} shards); "
                f"otherwise the gathered row-scale vector has the wrong length"
            )

    def init_state(self, packed0):
        # the dispatched base model each client diffs against next round —
        # ONE (N,) row, not (C, N): every client starts from the same
        # dispatch, and a (C, N) base would alias the flat round state
        # (aggregate returns the dispatch as both), which the donated jit
        # rejects as a double-donated buffer
        return {"base": packed0[0]}

    def state_pspecs(self):
        ps = packing.packed_pspec(self.ctx.spec, self.ctx.fed.client_axis, self.ctx.mesh)
        return {"base": P(*ps[1:])}  # the dispatched row: no client dim

    def _quant(self, delta, block):
        if self.ctx.fed.agg_impl == "pallas":
            from repro.kernels import pack as _pk

            return _pk.quantize_rows(delta, block=block)
        return packing.quantize_rows_ref(delta, block)

    def _quant_reduce(self, delta, w, block):
        """Collective-free transport: encode -> decode -> reduce in one
        fused pass/launch; the int8 payload never materializes."""
        if self.ctx.fed.agg_impl == "pallas":
            from repro.kernels import pack as _pk

            return _pk.quant8_reduce(delta, w, block=block)
        return packing.quant8_mean_ref(delta, w, block)

    def aggregate(self, packed, weights, agg_state, mask=None):
        base = agg_state["base"]  # (N,) dispatched global, see init_state
        block = self.ctx.fed.quant_block
        axis = self.ctx.fed.client_axis
        w_eff = self._masked_weights(weights, mask)

        def body(new, base_, w):
            delta = new.astype(jnp.float32) - base_.astype(jnp.float32)[None, :]
            q, scales = self._quant(delta, block)  # (C_loc, N) int8
            q = jax.lax.all_gather(q, axis, axis=0, tiled=True)  # int8 (C, N)
            scales = jax.lax.all_gather(scales, axis, axis=0, tiled=True)
            gd = packing.dequant_reduce_ref(q, scales, w, block)
            g = (base_.astype(jnp.float32) + gd).astype(new.dtype)  # (N_loc,)
            return jnp.broadcast_to(g[None, :], new.shape)

        if self.ctx.mesh is None:
            delta = packed.astype(jnp.float32) - base.astype(jnp.float32)[None, :]
            gd = self._quant_reduce(delta, w_eff, block)
            g = (base.astype(jnp.float32) + gd).astype(packed.dtype)
            out = jnp.broadcast_to(g[None, :], packed.shape)
        else:
            spec = packing.packed_pspec(self.ctx.spec, axis, self.ctx.mesh)
            out = jax.shard_map(
                body,
                mesh=self.ctx.mesh,
                in_specs=(spec, P(*spec[1:]), P()),
                out_specs=spec,
                check_vma=False,
            )(packed, base, w_eff)
        # next round's dispatch: row 0 (a fresh slice — never an alias of
        # the params buffer, so the donated round stays donate-able)
        return out, {"base": out[0]}
