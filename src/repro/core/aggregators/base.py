"""Aggregator strategy interface + registry (DESIGN.md §7).

An :class:`Aggregator` is the server-side policy for one federated round:
``init_state`` builds any cross-round aggregator state (Eq. 6 score sums,
the quant8 base model, server-optimizer moments) and ``aggregate`` maps the
packed client-stacked update buffer to the packed post-round buffer. All
modes operate on the single ``(C, N_total)`` buffer from `core.packing`, so
the hot loop is one masked/weighted reduction regardless of mode.

`core.rounds` and `core.server` dispatch purely through :func:`get` — adding
an aggregation mode is one `@register`-decorated subclass, and
``FedConfig.aggregation`` accepts any registered name.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import packing

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AggContext:
    """Everything an aggregator may need, fixed at build time."""

    cfg: Any  # ArchConfig
    fed: Any  # rounds.FedConfig
    template: PyTree  # ParamInfo pytree
    spec: packing.PackSpec
    mesh: Any = None  # jax Mesh (quant8 int8 collectives) or None


class Aggregator:
    """Strategy interface: init_state / aggregate over the packed buffer."""

    name: str = ""
    stacked: bool = True  # False -> fedsgd topology: one shared model copy

    def __init__(self, ctx: AggContext):
        self.ctx = ctx

    # -- cross-round state ---------------------------------------------------
    def init_state(self, packed0: jax.Array) -> PyTree:
        """Aggregator state from the packed initial params. Default: none."""
        return {}

    def state_pspecs(self) -> PyTree:
        """PartitionSpecs matching init_state's structure. Default: all
        replicated server-side state; override for client-sharded state."""
        C = self.ctx.fed.n_clients
        abs_in = jax.ShapeDtypeStruct((C, self.ctx.spec.n_total), jnp.float32)
        return jax.tree.map(lambda _: P(), jax.eval_shape(self.init_state, abs_in))

    # -- the round -----------------------------------------------------------
    def aggregate(
        self, packed: jax.Array, weights: jax.Array, agg_state: PyTree
    ) -> tuple[jax.Array, PyTree]:
        """(C, N) packed updates + (C,) weights -> (packed', agg_state')."""
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------
    def _mean(self, packed: jax.Array, wmask: jax.Array) -> tuple[jax.Array, jax.Array]:
        """One masked bucket-weighted reduction (ref jnp or Pallas kernel)."""
        return packing.masked_bucket_mean(
            packed, wmask, self.ctx.spec, impl=self.ctx.fed.agg_impl
        )

    def _wmean_full(self, packed: jax.Array, weights: jax.Array) -> jax.Array:
        """Unmasked Eq. 5 mean — for modes whose mask is uniform across
        buckets the flat contraction avoids the bucket machinery entirely
        (the Pallas impl still exercises the packed kernel)."""
        if self.ctx.fed.agg_impl == "pallas":
            g, _ = self._mean(packed, self._full_wmask(weights))
            return g
        return packing.weighted_mean(packed, weights)

    def _full_wmask(self, weights: jax.Array) -> jax.Array:
        """(C,) weights -> (C, B) mask with every bucket uploaded."""
        return jnp.broadcast_to(
            weights.astype(jnp.float32)[:, None],
            (weights.shape[0], self.ctx.spec.n_buckets),
        )

    def _broadcast(self, global_: jax.Array, packed: jax.Array) -> jax.Array:
        """(N,) global -> (C, N) dispatch (every client gets the new model)."""
        return jnp.broadcast_to(global_.astype(packed.dtype)[None], packed.shape)


_REGISTRY: dict[str, type[Aggregator]] = {}


def register(cls: type[Aggregator]) -> type[Aggregator]:
    assert cls.name, f"{cls.__name__} needs a non-empty .name"
    assert cls.name not in _REGISTRY, f"duplicate aggregator {cls.name!r}"
    _REGISTRY[cls.name] = cls
    return cls


def get(name: str) -> type[Aggregator]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregation {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
