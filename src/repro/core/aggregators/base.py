"""Aggregator strategy interface + registry (DESIGN.md §7, Appendix A).

An :class:`Aggregator` is the server-side policy for one federated round:
``init_state`` builds any cross-round aggregator state (Eq. 6 score sums,
the quant8 base model, server-optimizer moments) and ``aggregate`` maps the
packed client-stacked update buffer to the packed post-round buffer. All
modes operate on the single ``(C, N_total)`` buffer from `core.packing`, so
the hot loop is one masked/weighted reduction regardless of mode. Under the
flat engine (DESIGN.md §11) that buffer IS ``state["params"]``: aggregate's
input arrives as the just-trained round state (written in place through the
donated jit) and its output becomes next round's state directly — an
aggregator must therefore never assume a private copy it may scribble on
beyond returning ``packed'``.

`core.rounds` and `core.server` dispatch purely through :func:`get` — adding
an aggregation mode is one `@register`-decorated subclass, and
``FedConfig.aggregation`` accepts any registered name.

Adding an aggregator — the contract
-----------------------------------

1. Subclass :class:`Aggregator`, set a unique ``name``, and decorate with
   :func:`register` (importing your module must run the decorator; built-ins
   register from ``aggregators/__init__.py``).

2. ``__init__(self, ctx)`` receives an :class:`AggContext` and is the place
   for *build-time validation* — raise ``ValueError`` on invalid configs
   (see quant8's divisibility check, trimmed_mean's ratio check) so bad
   setups fail before any tracing. ``ctx.fed`` carries every FedConfig knob;
   add new knobs there rather than inventing side-channels.

3. ``init_state(packed0) -> pytree`` builds cross-round state from the
   packed initial params. It must be shape-derivable: `rounds.state_template`
   calls it under ``jax.eval_shape`` for the dry-run, so no host-side
   branching on values. Return ``{}`` if the mode is stateless.

4. ``aggregate(packed, weights, agg_state, mask=None)`` is traced inside
   the jitted round every round. Inputs:

   - ``packed``: the (C, N_total) client-stacked update buffer;
   - ``weights``: (C,) scheduler weights (sum 1 over participants);
   - ``agg_state``: whatever ``init_state`` returned, threaded each round;
   - ``mask``: (C,) 0/1 participation vector, or None when the caller runs
     full participation. **Honor it**: rows with ``mask == 0`` are clients
     that did not train this round — they must contribute to neither the
     numerator nor denominator of any mean. The helpers below do this for
     you; only a mode that reduces over clients directly (like
     trimmed_mean's sort) needs mask-aware logic of its own. A mask of all
     ones must be numerically identical to ``mask=None``.

   Return ``(packed', agg_state')`` where ``packed'`` is the post-round
   (C, N_total) buffer (the dispatch: usually the global model broadcast
   to every row via :meth:`_broadcast`, with non-aggregated positions
   keeping each client's local values).

5. ``state_pspecs()`` only needs overriding when the state is not
   replicated server-side (e.g. eq6's client-sharded ``prev_sums``).

`tests/test_aggregators.py::test_state_template_matches_make_state` and the
equivalence suite in `tests/test_participation.py` will exercise a new mode
automatically once it is added to their mode lists.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import packing

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AggContext:
    """Everything an aggregator may need, fixed at build time."""

    cfg: Any  # ArchConfig
    fed: Any  # rounds.FedConfig
    template: PyTree  # ParamInfo pytree
    spec: packing.PackSpec
    mesh: Any = None  # jax Mesh (quant8 int8 collectives) or None


class Aggregator:
    """Strategy interface: init_state / aggregate over the packed buffer.

    See the module docstring for the full "adding an aggregator" contract.
    """

    name: str = ""
    stacked: bool = True  # False -> fedsgd topology: one shared model copy

    def __init__(self, ctx: AggContext):
        self.ctx = ctx

    # -- cross-round state ---------------------------------------------------
    def init_state(self, packed0: jax.Array) -> PyTree:
        """Aggregator state from the packed initial params. Default: none.

        Must work under jax.eval_shape (dry-run lowering) — derive shapes
        from ``packed0``, never branch on its values host-side."""
        return {}

    def state_pspecs(self) -> PyTree:
        """PartitionSpecs matching init_state's structure. Default: all
        replicated server-side state; override for client-sharded state."""
        C = self.ctx.fed.n_clients
        abs_in = jax.ShapeDtypeStruct((C, self.ctx.spec.n_total), jnp.float32)
        return jax.tree.map(lambda _: P(), jax.eval_shape(self.init_state, abs_in))

    # -- the round -----------------------------------------------------------
    def aggregate(
        self,
        packed: jax.Array,
        weights: jax.Array,
        agg_state: PyTree,
        mask: jax.Array | None = None,
    ) -> tuple[jax.Array, PyTree]:
        """(C, N) packed updates + (C,) weights [+ (C,) 0/1 participation
        mask] -> (packed', agg_state'). mask=None means full participation;
        an all-ones mask must be numerically identical to None."""
        raise NotImplementedError

    # -- shared helpers ------------------------------------------------------
    def _mean(
        self, packed: jax.Array, wmask: jax.Array, mask: jax.Array | None = None
    ) -> tuple[jax.Array, jax.Array]:
        """One masked bucket-weighted reduction (ref jnp or Pallas kernel)
        -> (global (N,), den (B,) per-BUCKET denominator — expand with
        packing.expand_bucket_vec, it fuses into the consumer).

        The participation mask rides as its own kernel operand so selection
        changes per round without retracing."""
        return packing.masked_bucket_mean(
            packed, wmask, self.ctx.spec, mask, impl=self.ctx.fed.agg_impl
        )

    def _wmean_full(
        self, packed: jax.Array, weights: jax.Array, mask: jax.Array | None = None
    ) -> jax.Array:
        """Participation-weighted Eq. 5 mean — for modes whose upload mask is
        uniform across buckets the flat contraction avoids the bucket
        machinery entirely (the Pallas impl still exercises the packed
        kernel)."""
        if self.ctx.fed.agg_impl == "pallas":
            g, _ = self._mean(packed, self._full_wmask(weights), mask)
            return g
        return packing.weighted_mean(packed, weights, mask)

    def _full_wmask(self, weights: jax.Array) -> jax.Array:
        """(C,) weights -> (C, B) mask with every bucket uploaded."""
        return jnp.broadcast_to(
            weights.astype(jnp.float32)[:, None],
            (weights.shape[0], self.ctx.spec.n_buckets),
        )

    def _masked_weights(self, weights: jax.Array, mask: jax.Array | None) -> jax.Array:
        """Fold the participation mask into the weight vector (f32)."""
        w = weights.astype(jnp.float32)
        return w if mask is None else w * mask.astype(jnp.float32)

    def _broadcast(self, global_: jax.Array, packed: jax.Array) -> jax.Array:
        """(N,) global -> (C, N) dispatch (every client gets the new model)."""
        return jnp.broadcast_to(global_.astype(packed.dtype)[None], packed.shape)


_REGISTRY: dict[str, type[Aggregator]] = {}


def register(cls: type[Aggregator]) -> type[Aggregator]:
    assert cls.name, f"{cls.__name__} needs a non-empty .name"
    assert cls.name not in _REGISTRY, f"duplicate aggregator {cls.name!r}"
    _REGISTRY[cls.name] = cls
    return cls


def get(name: str) -> type[Aggregator]:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown aggregation {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))
