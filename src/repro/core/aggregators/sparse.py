"""topk_ef: per-client top-k sparsified delta upload with error feedback.

Each round every client uploads only the k = round(topk_frac * N) largest-
magnitude entries of its *compensated* delta (this round's delta plus the
residual the previous rounds did not upload); what stays home accumulates
in a per-client error-feedback row carried in ``state["agg"]["ef"]``. The
EF telescoping invariant — uploaded + residual == compensated delta,
EXACTLY — holds bitwise because selection is a disjoint-support
`jnp.where` split, never arithmetic (adding 0.0 would already flip -0.0).

Masked/zero-weight rows must not leak residual state: a deselected
client's ef row passes through bit-for-bit (select, not blend) and its
upload row never reaches the mean (weight 0 there).

The aggregate runs through the SAME ``_wmean_full`` path as `dense` on the
per-client upload rows ``where(sel, compensated, base)`` — positions nobody
selected average to the dispatched base, and at k == N (topk_frac >= 1)
the whole mode collapses to `dense` bit-for-bit (the equivalence pin in
tests/test_compression_frontier.py).

``topk_quant="quant4"`` composes 4-bit quantization over the selected
values (the wire payload of codec.TOPK + nibbles); EF then absorbs the
quantization error too: residual = compensated - dequant(upload).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.aggregators.base import Aggregator, register


def topk_count(frac: float, n_total: int) -> int:
    """Static per-client upload budget: k in [1, n_total]."""
    return max(1, min(n_total, int(round(frac * n_total))))


@register
class TopKEF(Aggregator):
    name = "topk_ef"

    def __init__(self, ctx):
        super().__init__(ctx)
        fed = ctx.fed
        if not 0.0 < fed.topk_frac <= 1.0:
            raise ValueError(f"topk_frac={fed.topk_frac} must be in (0, 1]")
        if fed.topk_quant not in ("none", "quant4"):
            raise ValueError(f"topk_quant={fed.topk_quant!r} not in ('none', 'quant4')")
        if fed.topk_quant == "quant4" and fed.quant4_mode not in ("nearest", "stochastic"):
            raise ValueError(
                f"quant4_mode={fed.quant4_mode!r}: the topk_ef x quant4 composition "
                f"supports 'nearest' | 'stochastic' ('skip' belongs to the pure quant4 mode)"
            )
        self._k = topk_count(fed.topk_frac, ctx.spec.n_total)

    def init_state(self, packed0):
        # base: the dispatched row clients diff against (fresh (N,) slice —
        # see quant8's donation note); ef: per-client residual rows; round:
        # traced counter feeding the quant4 composition's per-round key
        return {
            "base": packed0[0],
            "ef": jnp.zeros(packed0.shape, jnp.float32),
            "round": jnp.zeros((), jnp.int32),
        }

    def aggregate(self, packed, weights, agg_state, mask=None):
        fed = self.ctx.fed
        base = agg_state["base"].astype(jnp.float32)
        ef = agg_state["ef"]
        r = agg_state["round"]
        part = jnp.ones((packed.shape[0], 1), jnp.float32) if mask is None else mask.astype(jnp.float32)[:, None]

        t = packed.astype(jnp.float32) + ef  # compensated params (ef==0 -> t==packed)
        acc = t - base[None, :]  # compensated delta each client would upload
        if self._k >= self.ctx.spec.n_total:
            sel = jnp.ones(acc.shape, bool)
        else:
            thresh = jax.lax.top_k(jnp.abs(acc), self._k)[0][:, -1]
            sel = jnp.abs(acc) >= thresh[:, None]

        if fed.topk_quant == "none":
            up = jnp.where(sel, t, base[None, :])  # unselected positions say "no change"
            residual = jnp.where(sel, 0.0, acc)  # disjoint split: sel*acc + residual == acc bitwise
        else:
            key = packing.round_key(fed.quant4_seed, r)
            vq = packing.quant4_dequant_rows_ref(
                jnp.where(sel, acc, 0.0), fed.quant_block, key=key, mode=fed.quant4_mode
            )
            up = base[None, :] + vq
            residual = acc - vq  # EF absorbs sparsification AND quantization error

        g = self._wmean_full(up, weights, mask)  # dense's exact reduction path
        out = self._broadcast(g, packed)
        # masked rows retain their residual bit-for-bit (select, not blend)
        ef_new = jnp.where(part > 0, residual, ef)
        return out, {"base": out[0], "ef": ef_new, "round": r + 1}
