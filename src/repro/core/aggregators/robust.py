"""Robust aggregation: coordinate-wise trimmed mean (Yin et al. 2018).

Sorts each packed coordinate over the client dim and averages after
discarding the k = floor(trim_ratio * C) largest and smallest values —
tolerant to up to k Byzantine/outlier clients per coordinate. Scheduler
weights are intentionally ignored: weighting re-opens the attack surface
robustness is meant to close (a poisoned high-weight client would dominate).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator, register


@register
class TrimmedMean(Aggregator):
    name = "trimmed_mean"

    def __init__(self, ctx):
        super().__init__(ctx)
        C = ctx.fed.n_clients
        self._k = int(ctx.fed.trim_ratio * C)
        if self._k == 0:
            raise ValueError(
                f"trimmed_mean: floor(trim_ratio * n_clients) = "
                f"floor({ctx.fed.trim_ratio} * {C}) = 0 — this would be a "
                f"plain mean with zero Byzantine tolerance; raise trim_ratio "
                f"(>= {1.0 / C:.3f}) or use aggregation='dense'"
            )
        if 2 * self._k >= C:
            raise ValueError(
                f"trimmed_mean: trim_ratio {ctx.fed.trim_ratio} trims "
                f"2*{self._k} >= n_clients ({C}); nothing left to average"
            )

    def aggregate(self, packed, weights, agg_state):
        C = packed.shape[0]
        x = jnp.sort(packed.astype(jnp.float32), axis=0)
        g = jnp.mean(x[self._k : C - self._k], axis=0)
        return self._broadcast(g, packed), agg_state
