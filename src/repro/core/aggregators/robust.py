"""Robust aggregation: coordinate-wise trimmed mean (Yin et al. 2018).

Sorts each packed coordinate over the client dim and averages after
discarding the k = floor(trim_ratio * C) largest and smallest values —
tolerant to up to k Byzantine/outlier clients per coordinate. Scheduler
weights are intentionally ignored: weighting re-opens the attack surface
robustness is meant to close (a poisoned high-weight client would dominate).

Under partial participation the trim happens *within the selected subset*:
with C_sel participants this round, k = floor(trim_ratio * C_sel) extremes
are dropped per side among participant values only — a non-participating
client's stale row can neither be trimmed in place of an attacker nor leak
into the average. C_sel is traced (the fairness floor makes it dynamic), so
the masked path ranks participants per coordinate instead of slicing.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.aggregators.base import Aggregator, register


@register
class TrimmedMean(Aggregator):
    name = "trimmed_mean"

    def __init__(self, ctx):
        super().__init__(ctx)
        C = ctx.fed.n_clients
        self._k = int(ctx.fed.trim_ratio * C)
        if self._k == 0:
            raise ValueError(
                f"trimmed_mean: floor(trim_ratio * n_clients) = "
                f"floor({ctx.fed.trim_ratio} * {C}) = 0 — this would be a "
                f"plain mean with zero Byzantine tolerance; raise trim_ratio "
                f"(>= {1.0 / C:.3f}) or use aggregation='dense'"
            )
        if 2 * self._k >= C:
            raise ValueError(
                f"trimmed_mean: trim_ratio {ctx.fed.trim_ratio} trims "
                f"2*{self._k} >= n_clients ({C}); nothing left to average"
            )

    def aggregate(self, packed, weights, agg_state, mask=None):
        C = packed.shape[0]
        if mask is None:
            x = jnp.sort(packed.astype(jnp.float32), axis=0)
            g = jnp.mean(x[self._k : C - self._k], axis=0)
            return self._broadcast(g, packed), agg_state
        # masked trim: rank each coordinate's *participant* values; drop the
        # k = floor(ratio * C_sel) extremes per side (k and C_sel traced)
        m = mask.astype(jnp.float32)
        c_sel = jnp.sum(m)
        k = jnp.floor(self.ctx.fed.trim_ratio * c_sel).astype(jnp.int32)
        order = jnp.argsort(packed.astype(jnp.float32), axis=0)  # (C, N)
        x_sorted = jnp.take_along_axis(packed.astype(jnp.float32), order, axis=0)
        m_sorted = jnp.take_along_axis(
            jnp.broadcast_to(m[:, None], packed.shape), order, axis=0
        )
        rank = jnp.cumsum(m_sorted, axis=0) - m_sorted  # participant rank, 0-based
        keep = m_sorted * (rank >= k) * (rank < c_sel - k)
        g = jnp.sum(x_sorted * keep, axis=0) / jnp.maximum(jnp.sum(keep, axis=0), 1.0)
        return self._broadcast(g, packed), agg_state
