"""fed_round: one federated round as a single jit-able SPMD program.

Structure (DESIGN.md §4, §8):
  1. `vmap` of the local trainer over the client-stacked state — each mesh
     slice along the client axis trains its own divergent model copy for
     E local steps (lax.scan), with *no* cross-client collectives;
  2. aggregation: the client-stacked param tree is packed once into a single
     (C, N_total) buffer (core.packing) and handed to the configured
     :mod:`repro.core.aggregators` strategy — one masked/weighted reduction
     per round regardless of mode (DESIGN.md §7).

Partial participation (DESIGN.md §8): the Task Scheduler's selection enters
the jitted round as a *traced* participation pytree (`participation_input`),
so per-round selection changes never retrace. `FedConfig.participation`
picks the round body:
  - ``full``   — every client trains; weights alone shape the aggregate
                 (PR 1 behavior, and the numerical reference);
  - ``masked`` — per-client `lax.cond` gates the whole local-training scan
                 on the mask; unselected clients carry params/opt through
                 unchanged and drop out of the aggregation denominator;
  - ``compact``— a static budget K = max_participants gathers the selected
                 client rows into a compact (K, ...) axis, trains only
                 those, and scatters back — per-round local-training work is
                 K/C of full participation.

There is no mode-specific branching here: `FedConfig.aggregation` names any
registered aggregator, whose cross-round state lives under ``state["agg"]``.
The same builder also yields `make_state`, `state_template`, and the
sharding specs used by the launcher and the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import aggregators, packing
from repro.models import params as mp
from repro.models import transformer, yolov3
from repro.optim import Optimizer

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int
    local_steps: int = 1
    aggregation: str = "eq6"  # any name in repro.core.aggregators.names()
    topn: int = 8  # Eq. 6 / static_topn upload budget (layer buckets)
    client_axis: str = "pod"  # mesh axis acting as the federation
    data_axis: str | None = "data"  # within-client data-parallel axis
    round_idx_static: int = 0  # static_topn: trace-time round phase
    microbatches: int = 1  # grad-accumulation splits of each local step
    agg_impl: str = "ref"  # ref (jnp) | pallas (packed kernel, interpret on CPU)
    quant_block: int = 1024  # quant8: elements per int8 scale block
    server_lr: float = 1.0  # fedavgm/fedadam server step (fedadam wants ~0.01-0.1)
    server_momentum: float = 0.9  # fedavgm momentum / fedadam b1
    server_beta2: float = 0.99  # fedadam second-moment decay
    server_eps: float = 1e-3  # fedadam adaptivity floor (Reddi et al. tau)
    trim_ratio: float = 0.25  # trimmed_mean: fraction trimmed per side (>=1 client)
    participation: str = "full"  # full | masked | compact (DESIGN.md §8)
    max_participants: int = 0  # compact: static per-round budget K (0 -> C)


def loss_for(cfg: ArchConfig) -> Callable:
    if cfg.family == "yolo":
        return lambda params, batch: yolov3.yolo_loss(params, batch, cfg)
    return lambda params, batch: transformer.loss_fn(cfg, params, batch)


def make_template(cfg: ArchConfig) -> PyTree:
    if cfg.family == "yolo":
        return yolov3.template(cfg)
    return transformer.template(cfg)


def make_aggregator(cfg: ArchConfig, fed: FedConfig, mesh=None) -> aggregators.Aggregator:
    """Resolve FedConfig.aggregation through the registry (build-time
    validation: unknown names and invalid mode configs fail here)."""
    tpl = make_template(cfg)
    spec = packing.build_pack_spec(cfg, tpl)
    ctx = aggregators.AggContext(cfg=cfg, fed=fed, template=tpl, spec=spec, mesh=mesh)
    return aggregators.get(fed.aggregation)(ctx)


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

def stacked_pspecs(template: PyTree, client_axis: str, rules: dict | None = None) -> PyTree:
    """Param PartitionSpecs with the leading client dim on `client_axis`."""
    base = mp.pspecs(template, rules)
    return jax.tree.map(lambda s: P(client_axis, *s), base, is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(batch_template: PyTree, fed: FedConfig) -> PyTree:
    spec = P(fed.client_axis, None, fed.data_axis)  # (C, E, b, ...)
    return jax.tree.map(lambda _: spec, batch_template)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def state_template(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, dtype) -> PyTree:
    """Abstract FedState (ShapeDtypeStructs) for dry-run lowering."""
    agg = make_aggregator(cfg, fed)
    tpl = agg.ctx.template
    pabs = mp.abstract(tpl, dtype)
    if not agg.stacked:
        stack = lambda t: t  # FedSGD-equivalent: one shared model copy
    else:
        stack = lambda t: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((fed.n_clients,) + s.shape, s.dtype), t
        )
    opt_abs = jax.eval_shape(optimizer.init, pabs)
    packed_abs = jax.ShapeDtypeStruct((fed.n_clients, agg.ctx.spec.n_total), dtype)
    return {
        "params": stack(pabs),
        "opt": stack(opt_abs),
        "agg": jax.eval_shape(agg.init_state, packed_abs) if agg.stacked else {},
        "round": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_state(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, rng, dtype=jnp.float32) -> PyTree:
    agg = make_aggregator(cfg, fed)
    tpl = agg.ctx.template
    if not agg.stacked:
        params = mp.init_params(tpl, rng, dtype)
        return {"params": params, "opt": optimizer.init(params), "agg": {}, "round": jnp.int32(0)}
    keys = jax.random.split(rng, fed.n_clients)
    params = jax.vmap(lambda k: mp.init_params(tpl, k, dtype))(keys)
    # clients start from the same global model (server dispatch)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), params)
    opt = jax.vmap(optimizer.init)(params)
    # pack the initial params only for aggregators that keep packed state —
    # eval_shape first so stateless modes skip the O(C*N) concat entirely
    packed_abs = jax.ShapeDtypeStruct((fed.n_clients, agg.ctx.spec.n_total), dtype)
    agg_abs = jax.eval_shape(agg.init_state, packed_abs)
    agg_state = (
        agg.init_state(packing.pack(agg.ctx.spec, params))
        if jax.tree.leaves(agg_abs)
        else agg_abs
    )
    return {
        "params": params,
        "opt": opt,
        "agg": agg_state,
        "round": jnp.int32(0),
    }


def state_pspecs(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, rules: dict | None = None, opt_rules: dict | None = None) -> PyTree:
    """opt_rules: optional separate sharding rules for optimizer moments —
    ZeRO-1 style (moments sharded over data while params stay TP-only)."""
    agg = make_aggregator(cfg, fed)
    tpl = agg.ctx.template
    if not agg.stacked:
        pspec = mp.pspecs(tpl, rules)
        mspec = mp.pspecs(tpl, opt_rules) if opt_rules else pspec
    else:
        pspec = stacked_pspecs(tpl, fed.client_axis, rules)
        mspec = stacked_pspecs(tpl, fed.client_axis, opt_rules) if opt_rules else pspec
    opt_shape = jax.eval_shape(optimizer.init, mp.abstract(tpl, jnp.float32))
    ospec = {k: (mspec if k in ("mu", "m", "v") else P()) for k in opt_shape}
    return {
        "params": pspec,
        "opt": ospec,
        "agg": agg.state_pspecs() if agg.stacked else {},
        "round": P(),
    }


# ---------------------------------------------------------------------------
# Participation input
# ---------------------------------------------------------------------------

def static_budget(fed: FedConfig) -> int:
    """Compact mode's static per-round participant count K."""
    return fed.max_participants or fed.n_clients


def participation_input(fed: FedConfig, mask, weights, idx=None) -> dict:
    """Host arrays from the scheduler -> the traced pytree fed_round takes.

    mask: (C,) 0/1; weights: (C,) normalized over participants; idx: (K,)
    int32 selected-client indices, required (and only used) in compact mode.
    The structure is fixed per FedConfig, so only leaf *values* change per
    round — selection never retraces the jitted round.
    """
    part = {
        "mask": jnp.asarray(mask, jnp.float32),
        "weights": jnp.asarray(weights, jnp.float32),
    }
    if fed.participation == "compact":
        if idx is None:
            raise ValueError("compact participation needs the (K,) idx vector")
        idx = jnp.asarray(idx, jnp.int32)
        if idx.shape != (static_budget(fed),):
            raise ValueError(
                f"compact idx has shape {idx.shape}; the static budget is "
                f"({static_budget(fed)},) — the scheduler must emit exactly K indices"
            )
        part["idx"] = idx
    return part


def _parse_participation(fed: FedConfig, part) -> tuple[jax.Array, jax.Array | None, jax.Array | None]:
    """Normalize fed_round's third argument.

    A bare (C,) array is the PR 1 calling convention: weights only, full
    participation (mask None keeps the aggregation graph bit-identical to
    the pre-participation engine). A dict is participation_input's output.
    """
    if isinstance(part, dict):
        return part["weights"].astype(jnp.float32), part["mask"].astype(jnp.float32), part.get("idx")
    return part.astype(jnp.float32), None, None


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------

def build_fed_round(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, mesh=None, rules: dict | None = None) -> Callable:
    """Returns fed_round(state, batch, part) -> (state, metrics).

    batch leaves: (C, E, per_step_shard...). part: either a bare (C,)
    normalized weight vector (full participation, the PR 1 convention) or
    the `participation_input` pytree {mask, weights[, idx]} from the
    scheduler. metrics: {"loss": participant mean, "client_loss": (C,)}.

    `rules` shapes the per-leaf training-state shardings (consumed via
    state_pspecs by the launcher); the packed aggregation operand itself
    shards (client_axis, "model") when divisible — packing.packed_pspec.
    """
    agg = make_aggregator(cfg, fed, mesh)
    loss_fn = loss_for(cfg)
    spec = agg.ctx.spec
    if fed.participation not in ("full", "masked", "compact"):
        raise ValueError(
            f"unknown participation {fed.participation!r}; expected full|masked|compact"
        )
    if fed.participation != "full" and not agg.stacked:
        raise ValueError(
            f"participation={fed.participation!r} needs a client-stacked "
            "topology; fedsgd runs one shared model copy (use participation='full')"
        )
    if fed.participation == "compact":
        K = static_budget(fed)
        if not 1 <= K <= fed.n_clients:
            raise ValueError(
                f"compact participation: max_participants={fed.max_participants} "
                f"must be in [1, n_clients={fed.n_clients}]"
            )

    def grads_of(params, step_batch):
        """Gradients for one local step, with microbatch accumulation.

        (A measured alternative — putting the micro scan inside the
        differentiated function so the gradient tree is produced once —
        left the collective term unchanged and tripled temp memory on the
        gemma3 single-pod dry-run; see EXPERIMENTS.md §Perf hillclimb #2.)
        """
        if fed.microbatches <= 1:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, step_batch)
            return loss, grads
        micro = jax.tree.map(
            lambda x: x.reshape((fed.microbatches, x.shape[0] // fed.microbatches) + x.shape[1:]),
            step_batch,
        )

        def acc(carry, mb):
            tot, g_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            return (tot + loss, jax.tree.map(jnp.add, g_acc, g)), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (tot, g_sum), _ = jax.lax.scan(acc, (jnp.float32(0), zeros), micro)
        n = jnp.float32(fed.microbatches)
        return tot / n, jax.tree.map(lambda g: (g / n.astype(g.dtype)), g_sum)

    def local_train(params, opt, client_batch):
        def step(carry, micro):
            p, o = carry
            loss, grads = grads_of(p, micro)
            p, o = optimizer.update(p, grads, o)
            return (p, o), loss

        (params, opt), losses = jax.lax.scan(step, (params, opt), client_batch)
        return params, opt, jnp.mean(losses)

    def gated_local_train(on, params, opt, client_batch):
        """Whole-client gate: the masked branch carries params/opt through
        untouched (vmap lowers the cond to a select along the client axis)."""
        return jax.lax.cond(
            on > 0,
            local_train,
            lambda p, o, b: (p, o, jnp.float32(0.0)),
            params, opt, client_batch,
        )

    def train_clients(state, batch, mask, idx):
        """Dispatch on the participation mode; returns (new_p, new_o,
        client_loss (C,))."""
        if fed.participation == "compact":
            # gather the K selected client rows into a compact axis: local
            # training runs K clients' worth of work, not C (DESIGN.md §8).
            take = lambda t: jax.tree.map(lambda x: jnp.take(x, idx, axis=0), t)
            p_k, o_k, loss_k = jax.vmap(local_train)(
                take(state["params"]), take(state["opt"]), take(batch)
            )
            put = lambda full, upd: jax.tree.map(lambda x, u: x.at[idx].set(u), full, upd)
            loss = jnp.zeros((fed.n_clients,), jnp.float32).at[idx].set(loss_k)
            return put(state["params"], p_k), put(state["opt"], o_k), loss
        if fed.participation == "masked":
            on = jnp.ones((fed.n_clients,), jnp.float32) if mask is None else mask
            return jax.vmap(gated_local_train, spmd_axis_name=fed.client_axis)(
                on, state["params"], state["opt"], batch
            )
        return jax.vmap(local_train, spmd_axis_name=fed.client_axis)(
            state["params"], state["opt"], batch
        )

    def fed_round(state, batch, part):
        weights, mask, idx = _parse_participation(fed, part)
        if not agg.stacked:
            # FedSGD-equivalent: clients = data-parallel shards, E=1,
            # param-averaging == gradient-averaging (DESIGN.md §5). One
            # shared model copy, so FSDP-style rules fit huge archs.
            p, o, loss = local_train(state["params"], state["opt"], batch)
            return (
                {**state, "params": p, "opt": o, "round": state["round"] + 1},
                {"loss": loss, "client_loss": jnp.full((fed.n_clients,), loss)},
            )
        if fed.participation == "compact" and idx is None:
            raise ValueError(
                "compact participation: pass participation_input(fed, mask, "
                "weights, idx), not a bare weight vector"
            )
        new_p, new_o, loss = train_clients(state, batch, mask, idx)
        packed = packing.pack(spec, new_p)
        packed_out, agg_state = agg.aggregate(packed, weights, state["agg"], mask)
        out = {
            **state,
            "params": packing.unpack(spec, packed_out, new_p),
            "opt": new_o,
            "agg": agg_state,
            "round": state["round"] + 1,
        }
        if mask is None:
            mean_loss = jnp.mean(loss)
        else:
            mean_loss = jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        return out, {"loss": mean_loss, "client_loss": loss}

    return fed_round


def uniform_weights(n_clients: int) -> jax.Array:
    """Paper Eq. 5: unweighted average."""
    return jnp.full((n_clients,), 1.0 / n_clients, jnp.float32)
