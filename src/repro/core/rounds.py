"""fed_round: one federated round as a single jit-able SPMD program.

Flat-state engine (DESIGN.md §11): for every client-stacked aggregator the
canonical round state ``state["params"]`` IS the packed ``(C, N_total)``
buffer from `core.packing`. One round:
  1. per-leaf *views* of the buffer are reconstructed from the PackSpec
     slots (`packing.unpack_views` — reshape-of-slice, fused into the
     training consumers, no copy);
  2. `vmap` of the local trainer over the views — each mesh slice along the
     client axis trains its own divergent model copy for E local steps
     (lax.scan), with *no* cross-client collectives;
  3. trained leaves are written back in place (`packing.write_slots`) and
     the buffer goes STRAIGHT to the configured
     :mod:`repro.core.aggregators` strategy — no pack concat, no unpack
     copy on the round boundary; pack/unpack survive only at the
     `make_state` / checkpoint / serving edges.
Jit the round with :func:`jit_fed_round` so the state (and with it the
packed operand chain) is donated — XLA aliases the round's buffers in
place instead of double-buffering the model state.

``FedConfig.state_layout="tree"`` keeps the PR 3 engine (param pytree state,
pack -> aggregate -> unpack each round) as the numerical reference:
tests/test_flat_engine.py pins the flat engine against it bit-for-bit under
full participation (1-2 ulp under masked/compact, where the surrounding
program shape changes the compiler's FMA contraction choices).

Partial participation (DESIGN.md §8): the Task Scheduler's selection enters
the jitted round as a *traced* participation pytree (`participation_input`),
so per-round selection changes never retrace. `FedConfig.participation`
picks the round body:
  - ``full``   — every client trains; weights alone shape the aggregate
                 (PR 1 behavior, and the numerical reference);
  - ``masked`` — per-client `lax.cond` gates the whole local-training scan
                 on the mask; unselected clients carry params/opt through
                 unchanged and drop out of the aggregation denominator;
  - ``compact``— a static budget K = max_participants gathers the selected
                 client rows into a compact (K, ...) axis, trains only
                 those, and scatters back — per-round local-training work is
                 K/C of full participation (on the flat state the gather is
                 K rows of the packed buffer).

There is no mode-specific branching here: `FedConfig.aggregation` names any
registered aggregator, whose cross-round state lives under ``state["agg"]``.
The same builder also yields `make_state`, `state_template`, and the
sharding specs used by the launcher and the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import aggregators, packing
from repro.models import params as mp
from repro.models import transformer, yolov3
from repro.optim import Optimizer

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int
    local_steps: int = 1
    aggregation: str = "eq6"  # any name in repro.core.aggregators.names()
    topn: int = 8  # Eq. 6 / static_topn upload budget (layer buckets)
    client_axis: str = "pod"  # mesh axis acting as the federation
    data_axis: str | None = "data"  # within-client data-parallel axis
    round_idx_static: int = 0  # static_topn: trace-time round phase
    microbatches: int = 1  # grad-accumulation splits of each local step
    agg_impl: str = "ref"  # ref (jnp) | pallas (packed kernel, interpret on CPU)
    quant_block: int = 1024  # quant8: elements per int8 scale block
    server_lr: float = 1.0  # fedavgm/fedadam server step (fedadam wants ~0.01-0.1)
    server_momentum: float = 0.9  # fedavgm momentum / fedadam b1
    server_beta2: float = 0.99  # fedadam second-moment decay
    server_eps: float = 1e-3  # fedadam adaptivity floor (Reddi et al. tau)
    trim_ratio: float = 0.25  # trimmed_mean: fraction trimmed per side (>=1 client)
    participation: str = "full"  # full | masked | compact (DESIGN.md §8)
    max_participants: int = 0  # compact: static per-round budget K (0 -> C)
    state_layout: str = "flat"  # flat (packed (C,N) round state) | tree (PR 3 reference)
    mode: str = "sync"  # sync | async (buffered FedBuff-style engine, DESIGN.md §12)
    buffer_size: int = 0  # async: K_buf staged updates per flush (0 -> n_clients)
    staleness_alpha: float = 0.5  # async: polynomial staleness discount (1+s)^-alpha
    max_staleness: int = 0  # async: drop updates staler than this (0 -> keep all)
    group_size: int = 0  # hier: edge-group width G (DESIGN.md §13; 0 -> C, one group)
    hier_base: str = "dense"  # hier: the registered reducer composed over group rows
    stream: bool = False  # async: streaming O(buffer_size*N) flush (DESIGN.md §13)
    # --- communication frontier (DESIGN.md §15) ---
    topk_frac: float = 0.1  # topk_ef: uploaded fraction k/N of each client delta
    topk_quant: str = "none"  # topk_ef: quantize the selected values (none | quant4)
    quant4_mode: str = "stochastic"  # quant4: stochastic | nearest | skip (dense passthrough)
    quant4_seed: int = 0  # quant4/topk_ef: session seed of the per-round counter PRNG
    secure_domain: str = "int8"  # secure: shared-scale integer ring width (int8 | int4)
    secure_mask: bool = True  # secure: pairwise masks on (False -> plain integer sum)
    secure_session: int = 0  # secure: session key feeding the per-round mask PRNG
    # --- multi-process transport (DESIGN.md §14) ---
    transport: str = "inproc"  # inproc (SimClock event heap) | socket (real wire)
    wire_codec: str = "dense"  # dense | quant8 | quant4 | topk (see transport/codec.py)
    queue_cap: int = 0  # socket: bounded landing-queue depth (0 -> 2 * n_clients)
    heartbeat_s: float = 0.2  # socket: worker heartbeat period (wall seconds)
    heartbeat_timeout_s: float = 2.0  # socket: silence beyond this marks a client dead
    # --- serving plane (DESIGN.md §17) ---
    serve_batch: int = 8  # inference batch slots of the jitted decode+NMS program
    serve_max_wait_s: float = 0.004  # batcher linger: how long a formed batch waits to fill
    serve_max_detections: int = 16  # NMS output slots per served image
    serve_soft_stale_rounds: int = 2  # freshness: rounds-behind beyond this -> soft_stale
    serve_hard_stale_rounds: int = 8  # freshness: rounds-behind beyond this -> hard_stale
    serve_soft_stale_s: float = 60.0  # freshness: seconds-behind beyond this -> soft_stale
    serve_hard_stale_s: float = 600.0  # freshness: seconds-behind beyond this -> hard_stale


def loss_for(cfg: ArchConfig) -> Callable:
    if cfg.family == "yolo":
        return lambda params, batch: yolov3.yolo_loss(params, batch, cfg)
    return lambda params, batch: transformer.loss_fn(cfg, params, batch)


def make_template(cfg: ArchConfig) -> PyTree:
    if cfg.family == "yolo":
        return yolov3.template(cfg)
    return transformer.template(cfg)


def make_aggregator(cfg: ArchConfig, fed: FedConfig, mesh=None) -> aggregators.Aggregator:
    """Resolve FedConfig.aggregation through the registry (build-time
    validation: unknown names and invalid mode configs fail here)."""
    tpl = make_template(cfg)
    spec = packing.build_pack_spec(cfg, tpl)
    ctx = aggregators.AggContext(cfg=cfg, fed=fed, template=tpl, spec=spec, mesh=mesh)
    return aggregators.get(fed.aggregation)(ctx)


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

def stacked_pspecs(template: PyTree, client_axis: str, rules: dict | None = None) -> PyTree:
    """Param PartitionSpecs with the leading client dim on `client_axis`."""
    base = mp.pspecs(template, rules)
    return jax.tree.map(lambda s: P(client_axis, *s), base, is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(batch_template: PyTree, fed: FedConfig) -> PyTree:
    spec = P(fed.client_axis, None, fed.data_axis)  # (C, E, b, ...)
    return jax.tree.map(lambda _: spec, batch_template)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def _layout(fed: FedConfig) -> str:
    if fed.state_layout not in ("flat", "tree"):
        raise ValueError(
            f"unknown state_layout {fed.state_layout!r}; expected flat|tree"
        )
    return fed.state_layout


def state_template(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, dtype) -> PyTree:
    """Abstract FedState (ShapeDtypeStructs) for dry-run lowering."""
    agg = make_aggregator(cfg, fed)
    tpl = agg.ctx.template
    pabs = mp.abstract(tpl, dtype)
    if not agg.stacked:
        stack = lambda t: t  # FedSGD-equivalent: one shared model copy
    else:
        stack = lambda t: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((fed.n_clients,) + s.shape, s.dtype), t
        )
    opt_abs = jax.eval_shape(optimizer.init, pabs)
    packed_abs = jax.ShapeDtypeStruct((fed.n_clients, agg.ctx.spec.n_total), dtype)
    if agg.stacked and _layout(fed) == "flat":
        params_abs = packed_abs  # the packed buffer IS the round state
    else:
        params_abs = stack(pabs)
    return {
        "params": params_abs,
        "opt": stack(opt_abs),
        "agg": jax.eval_shape(agg.init_state, packed_abs) if agg.stacked else {},
        "round": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_state(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, rng, dtype=jnp.float32) -> PyTree:
    agg = make_aggregator(cfg, fed)
    tpl = agg.ctx.template
    if not agg.stacked:
        params = mp.init_params(tpl, rng, dtype)
        return {"params": params, "opt": optimizer.init(params), "agg": {}, "round": jnp.int32(0)}
    keys = jax.random.split(rng, fed.n_clients)
    params = jax.vmap(lambda k: mp.init_params(tpl, k, dtype))(keys)
    # clients start from the same global model (server dispatch)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), params)
    opt = jax.vmap(optimizer.init)(params)
    if _layout(fed) == "flat":
        # the ONE pack of the flat engine: init is an edge, not the round
        packed = packing.pack(agg.ctx.spec, params, dtype)
        return {
            "params": packed,
            "opt": opt,
            "agg": agg.init_state(packed),
            "round": jnp.int32(0),
        }
    # tree layout: pack the initial params only for aggregators that keep
    # packed state — eval_shape first so stateless modes skip the O(C*N)
    # concat entirely
    packed_abs = jax.ShapeDtypeStruct((fed.n_clients, agg.ctx.spec.n_total), dtype)
    agg_abs = jax.eval_shape(agg.init_state, packed_abs)
    agg_state = (
        agg.init_state(packing.pack(agg.ctx.spec, params))
        if jax.tree.leaves(agg_abs)
        else agg_abs
    )
    return {
        "params": params,
        "opt": opt,
        "agg": agg_state,
        "round": jnp.int32(0),
    }


def unpacked_params(cfg: ArchConfig, fed: FedConfig, state: PyTree, dtype=jnp.float32) -> PyTree:
    """Edge helper: the client-stacked param *pytree* from a FedState,
    whatever the layout — flat states unpack (one copy, edge cost), tree and
    fedsgd states pass through."""
    params = state["params"]
    if not isinstance(params, jax.Array):
        return params
    tpl = make_template(cfg)
    spec = packing.build_pack_spec(cfg, tpl)
    like = jax.tree.map(lambda i: jax.ShapeDtypeStruct(i.shape, dtype), tpl,
                        is_leaf=mp.is_info)
    return packing.unpack(spec, params, like)


def state_pspecs(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, rules: dict | None = None, opt_rules: dict | None = None) -> PyTree:
    """opt_rules: optional separate sharding rules for optimizer moments —
    ZeRO-1 style (moments sharded over data while params stay TP-only)."""
    agg = make_aggregator(cfg, fed)
    tpl = agg.ctx.template
    if not agg.stacked:
        pspec = mp.pspecs(tpl, rules)
        mspec = mp.pspecs(tpl, opt_rules) if opt_rules else pspec
    else:
        tree_pspec = stacked_pspecs(tpl, fed.client_axis, rules)
        pspec = (
            packing.packed_pspec(agg.ctx.spec, fed.client_axis)
            if _layout(fed) == "flat"
            else tree_pspec
        )
        mspec = stacked_pspecs(tpl, fed.client_axis, opt_rules) if opt_rules else tree_pspec
    opt_shape = jax.eval_shape(optimizer.init, mp.abstract(tpl, jnp.float32))
    ospec = {k: (mspec if k in ("mu", "m", "v") else P()) for k in opt_shape}
    return {
        "params": pspec,
        "opt": ospec,
        "agg": agg.state_pspecs() if agg.stacked else {},
        "round": P(),
    }


# ---------------------------------------------------------------------------
# Participation input
# ---------------------------------------------------------------------------

def static_budget(fed: FedConfig) -> int:
    """Compact mode's static per-round participant count K."""
    return fed.max_participants or fed.n_clients


def participation_input(fed: FedConfig, mask, weights, idx=None) -> dict:
    """Host arrays from the scheduler -> the traced pytree fed_round takes.

    mask: (C,) 0/1; weights: (C,) normalized over participants; idx: (K,)
    int32 selected-client indices, required (and only used) in compact mode.
    The structure is fixed per FedConfig, so only leaf *values* change per
    round — selection never retraces the jitted round.
    """
    part = {
        "mask": jnp.asarray(mask, jnp.float32),
        "weights": jnp.asarray(weights, jnp.float32),
    }
    if fed.participation == "compact":
        if idx is None:
            raise ValueError("compact participation needs the (K,) idx vector")
        idx = jnp.asarray(idx, jnp.int32)
        if idx.shape != (static_budget(fed),):
            raise ValueError(
                f"compact idx has shape {idx.shape}; the static budget is "
                f"({static_budget(fed)},) — the scheduler must emit exactly K indices"
            )
        if len(np.unique(np.asarray(idx))) != idx.shape[0]:
            # the engines rely on distinctness: gather/scatter by idx must
            # be invertible (and the K == C flat fast path treats idx as a
            # permutation) — a duplicate would silently train a client twice
            raise ValueError(
                f"compact idx {np.asarray(idx).tolist()} has duplicate "
                "client indices; the scheduler must select K distinct clients"
            )
        part["idx"] = idx
    return part


def _parse_participation(fed: FedConfig, part) -> tuple[jax.Array, jax.Array | None, jax.Array | None]:
    """Normalize fed_round's third argument.

    A bare (C,) array is the PR 1 calling convention: weights only, full
    participation (mask None keeps the aggregation graph bit-identical to
    the pre-participation engine). A dict is participation_input's output.
    """
    if isinstance(part, dict):
        return part["weights"].astype(jnp.float32), part["mask"].astype(jnp.float32), part.get("idx")
    return part.astype(jnp.float32), None, None


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------

def build_fed_round(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, mesh=None, rules: dict | None = None) -> Callable:
    """Returns fed_round(state, batch, part) -> (state, metrics).

    batch leaves: (C, E, per_step_shard...). part: either a bare (C,)
    normalized weight vector (full participation, the PR 1 convention) or
    the `participation_input` pytree {mask, weights[, idx]} from the
    scheduler. metrics: {"loss": participant mean, "client_loss": (C,)}.

    `FedConfig.state_layout` picks the engine: "flat" trains on slot views
    of the packed (C, N_total) round state and writes back in place (jit via
    `jit_fed_round` to donate the state); "tree" is the PR 3 reference
    (param pytree state, pack -> aggregate -> unpack every round).

    `rules` shapes the per-leaf training-state shardings (consumed via
    state_pspecs by the launcher); the packed aggregation operand itself
    shards (client_axis, "model") when divisible — packing.packed_pspec.
    """
    agg = make_aggregator(cfg, fed, mesh)
    if fed.mode != "sync":
        # this builder always emits the synchronous round — silently
        # ignoring buffer_size/staleness_alpha here would masquerade as
        # async. The buffered control plane lives in
        # core/async_engine.BufferedAsyncEngine (which calls back into this
        # builder with mode="sync" for its full-buffer flush).
        raise ValueError(
            f"build_fed_round builds the synchronous round (mode='sync'), got "
            f"mode={fed.mode!r}; drive async mode through "
            "core/async_engine.BufferedAsyncEngine or FLServer"
        )
    if fed.participation not in ("full", "masked", "compact"):
        raise ValueError(
            f"unknown participation {fed.participation!r}; expected full|masked|compact"
        )
    if fed.participation != "full" and not agg.stacked:
        raise ValueError(
            f"participation={fed.participation!r} needs a client-stacked "
            "topology; fedsgd runs one shared model copy (use participation='full')"
        )
    if fed.participation == "compact":
        K = static_budget(fed)
        if not 1 <= K <= fed.n_clients:
            raise ValueError(
                f"compact participation: max_participants={fed.max_participants} "
                f"must be in [1, n_clients={fed.n_clients}]"
            )
    if _layout(fed) == "tree":
        return _build_tree_round(cfg, fed, optimizer, agg)
    return _build_flat_round(cfg, fed, optimizer, agg, mesh)


def jit_fed_round(round_fn: Callable) -> Callable:
    """Jit a fed_round with the state donated (DESIGN.md §11 donation
    contract): the incoming FedState's buffers — including the packed
    (C, N_total) params of the flat engine — are reused in place by XLA, so
    the round holds ONE copy of the model state instead of two. Callers must
    drop the old state (``state, m = fr(state, ...)``); timing loops that
    replay one state must use plain `jax.jit`."""
    return jax.jit(round_fn, donate_argnums=(0,))


def _local_training(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer):
    """The shared per-client training kernels: (local_train,
    gated_local_train) over param/opt pytrees — identical computation in
    both state layouts (the flat engine feeds slot views instead of
    materialized leaves)."""
    loss_fn = loss_for(cfg)

    def grads_of(params, step_batch):
        """Gradients for one local step, with microbatch accumulation.

        (A measured alternative — putting the micro scan inside the
        differentiated function so the gradient tree is produced once —
        left the collective term unchanged and tripled temp memory on the
        gemma3 single-pod dry-run; see EXPERIMENTS.md §Perf hillclimb #2.)
        """
        if fed.microbatches <= 1:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, step_batch)
            return loss, grads
        micro = jax.tree.map(
            lambda x: x.reshape((fed.microbatches, x.shape[0] // fed.microbatches) + x.shape[1:]),
            step_batch,
        )

        def acc(carry, mb):
            tot, g_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            return (tot + loss, jax.tree.map(jnp.add, g_acc, g)), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (tot, g_sum), _ = jax.lax.scan(acc, (jnp.float32(0), zeros), micro)
        n = jnp.float32(fed.microbatches)
        return tot / n, jax.tree.map(lambda g: (g / n.astype(g.dtype)), g_sum)

    def local_train(params, opt, client_batch):
        def step(carry, micro):
            p, o = carry
            loss, grads = grads_of(p, micro)
            p, o = optimizer.update(p, grads, o)
            return (p, o), loss

        (params, opt), losses = jax.lax.scan(step, (params, opt), client_batch)
        return params, opt, jnp.mean(losses)

    def gated_local_train(on, params, opt, client_batch):
        """Whole-client gate: the masked branch carries params/opt through
        untouched (vmap lowers the cond to a select along the client axis)."""
        return jax.lax.cond(
            on > 0,
            local_train,
            lambda p, o, b: (p, o, jnp.float32(0.0)),
            params, opt, client_batch,
        )

    return local_train, gated_local_train


def _train_clients_fn(fed: FedConfig, local_train, gated_local_train):
    """full/masked dispatch over materialized-or-view param trees; compact's
    gather/scatter stays with each engine (it moves state rows)."""

    def train_clients(params, opt, batch, mask):
        if fed.participation == "masked":
            on = jnp.ones((fed.n_clients,), jnp.float32) if mask is None else mask
            return jax.vmap(gated_local_train, spmd_axis_name=fed.client_axis)(
                on, params, opt, batch
            )
        return jax.vmap(local_train, spmd_axis_name=fed.client_axis)(params, opt, batch)

    return train_clients


def _round_metrics(fed: FedConfig, loss, mask):
    if mask is None:
        mean_loss = jnp.mean(loss)
    else:
        mean_loss = jnp.sum(loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return {"loss": mean_loss, "client_loss": loss}


def _check_compact_idx(fed: FedConfig, idx):
    if fed.participation == "compact" and idx is None:
        raise ValueError(
            "compact participation: pass participation_input(fed, mask, "
            "weights, idx), not a bare weight vector"
        )


def _fedsgd_round(fed: FedConfig, local_train, state, batch):
    # FedSGD-equivalent: clients = data-parallel shards, E=1,
    # param-averaging == gradient-averaging (DESIGN.md §5). One
    # shared model copy, so FSDP-style rules fit huge archs.
    p, o, loss = local_train(state["params"], state["opt"], batch)
    return (
        {**state, "params": p, "opt": o, "round": state["round"] + 1},
        {"loss": loss, "client_loss": jnp.full((fed.n_clients,), loss)},
    )


def _build_tree_round(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, agg) -> Callable:
    """The PR 3 engine: pytree state, pack -> aggregate -> unpack per round.

    Kept verbatim as the numerical reference for the flat engine — the
    equivalence suite demands bit-for-bit agreement, so the computation here
    must not drift."""
    spec = agg.ctx.spec
    local_train, gated = _local_training(cfg, fed, optimizer)
    train_clients = _train_clients_fn(fed, local_train, gated)

    def fed_round(state, batch, part):
        weights, mask, idx = _parse_participation(fed, part)
        if not agg.stacked:
            return _fedsgd_round(fed, local_train, state, batch)
        _check_compact_idx(fed, idx)
        if fed.participation == "compact":
            # gather the K selected client rows into a compact axis: local
            # training runs K clients' worth of work, not C (DESIGN.md §8).
            take = lambda t: jax.tree.map(lambda x: jnp.take(x, idx, axis=0), t)
            p_k, o_k, loss_k = jax.vmap(local_train)(
                take(state["params"]), take(state["opt"]), take(batch)
            )
            put = lambda full, upd: jax.tree.map(lambda x, u: x.at[idx].set(u), full, upd)
            loss = jnp.zeros((fed.n_clients,), jnp.float32).at[idx].set(loss_k)
            new_p, new_o = put(state["params"], p_k), put(state["opt"], o_k)
        else:
            new_p, new_o, loss = train_clients(state["params"], state["opt"], batch, mask)
        packed = packing.pack(spec, new_p)
        packed_out, agg_state = agg.aggregate(packed, weights, state["agg"], mask)
        out = {
            **state,
            "params": packing.unpack(spec, packed_out, new_p),
            "opt": new_o,
            "agg": agg_state,
            "round": state["round"] + 1,
        }
        return out, _round_metrics(fed, loss, mask)

    return fed_round


def _client_shards(fed: FedConfig, mesh) -> int:
    """Size of the mesh axis acting as the federation (1 without a mesh)."""
    if mesh is None:
        return 1
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(fed.client_axis, 1)


def _build_flat_round(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, agg, mesh=None) -> Callable:
    """The flat-state engine (DESIGN.md §11): state["params"] is the packed
    (C, N_total) buffer. Training consumes slot views (reshape-of-slice) and
    writes trained leaves back in place; the aggregator reads the buffer
    directly — the per-round pack/unpack copies of the tree engine are gone,
    and under `jit_fed_round`'s donation XLA reuses the state buffers.

    With a mesh whose client axis has more than one shard, the round pins
    the buffer's C dim to that axis (`packing.packed_pspec`) on entry and
    exit — per-client training and the hier inner reduce then run
    shard-local, the single cross-shard merge lives inside the aggregator,
    and `jit_fed_round` still emits ONE donated program (DESIGN.md §13).
    A 1-shard client axis adds no constraint, keeping the single-device
    program bit-identical to the meshless build."""
    spec = agg.ctx.spec
    tpl = agg.ctx.template
    local_train, gated = _local_training(cfg, fed, optimizer)
    train_clients = _train_clients_fn(fed, local_train, gated)
    constrain = None
    if _client_shards(fed, mesh) > 1:
        if fed.n_clients % _client_shards(fed, mesh):
            raise ValueError(
                f"sharded client axis: n_clients={fed.n_clients} must be "
                f"divisible by the '{fed.client_axis}' mesh axis "
                f"({_client_shards(fed, mesh)} shards)"
            )
        sharding = jax.sharding.NamedSharding(
            mesh, packing.packed_pspec(spec, fed.client_axis, mesh)
        )
        constrain = lambda x: jax.lax.with_sharding_constraint(x, sharding)

    def fed_round(state, batch, part):
        weights, mask, idx = _parse_participation(fed, part)
        if not agg.stacked:
            return _fedsgd_round(fed, local_train, state, batch)
        _check_compact_idx(fed, idx)
        packed = state["params"]
        if constrain is not None:
            packed = constrain(packed)
        if fed.participation == "compact" and static_budget(fed) == fed.n_clients:
            # K == C: the scheduler's idx is a permutation, so gathering
            # rows by idx and scattering them back is an identity — train
            # the views directly and skip two (C, N) row moves. No loss
            # scatter either: the vmap output is already in client order
            # (gather-then-scatter by the same permutation would restore
            # exactly this ordering).
            p_k, o_k, loss = jax.vmap(local_train)(
                packing.unpack_views(spec, packed, tpl), state["opt"], batch
            )
            packed_new = packing.write_slots(spec, packed, p_k)
            new_o = o_k
        elif fed.participation == "compact":
            # K rows of the packed buffer gather into the compact axis; the
            # trained rows scatter straight back — row moves, not tree walks
            take = lambda t: jax.tree.map(lambda x: jnp.take(x, idx, axis=0), t)
            sub = jnp.take(packed, idx, axis=0)  # (K, N)
            p_k, o_k, loss_k = jax.vmap(local_train)(
                packing.unpack_views(spec, sub, tpl), take(state["opt"]), take(batch)
            )
            put = lambda full, upd: jax.tree.map(lambda x, u: x.at[idx].set(u), full, upd)
            loss = jnp.zeros((fed.n_clients,), jnp.float32).at[idx].set(loss_k)
            packed_new = packed.at[idx].set(packing.write_slots(spec, sub, p_k))
            new_o = put(state["opt"], o_k)
        else:
            new_p, new_o, loss = train_clients(
                packing.unpack_views(spec, packed, tpl), state["opt"], batch, mask
            )
            packed_new = packing.write_slots(spec, packed, new_p)
        packed_out, agg_state = agg.aggregate(packed_new, weights, state["agg"], mask)
        if constrain is not None:
            packed_out = constrain(packed_out)
        out = {
            **state,
            "params": packed_out,
            "opt": new_o,
            "agg": agg_state,
            "round": state["round"] + 1,
        }
        return out, _round_metrics(fed, loss, mask)

    return fed_round


def uniform_weights(n_clients: int) -> jax.Array:
    """Paper Eq. 5: unweighted average."""
    return jnp.full((n_clients,), 1.0 / n_clients, jnp.float32)
