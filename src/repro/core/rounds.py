"""fed_round: one federated round as a single jit-able SPMD program.

Structure (DESIGN.md §4):
  1. `vmap` of the local trainer over the client-stacked state — each mesh
     slice along the client axis trains its own divergent model copy for
     E local steps (lax.scan), with *no* cross-client collectives;
  2. aggregation over the client axis per the configured mode (Eq. 5 dense,
     Eq. 6 top-n, int8-quantized delta, or static layer schedule).

The same builder also yields `make_state`, `input_template`, and the
sharding specs used by the launcher and the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import compression as comp
from repro.core import fedavg
from repro.models import params as mp
from repro.models import transformer, yolov3
from repro.optim import Optimizer

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FedConfig:
    n_clients: int
    local_steps: int = 1
    aggregation: str = "eq6"  # dense | eq6 | quant8 | static_topn | fedsgd
    topn: int = 8  # Eq. 6 / static_topn upload budget (layer buckets)
    client_axis: str = "pod"  # mesh axis acting as the federation
    data_axis: str | None = "data"  # within-client data-parallel axis
    round_idx_static: int = 0  # static_topn: trace-time round phase
    microbatches: int = 1  # grad-accumulation splits of each local step


def loss_for(cfg: ArchConfig) -> Callable:
    if cfg.family == "yolo":
        return lambda params, batch: yolov3.yolo_loss(params, batch, cfg)
    return lambda params, batch: transformer.loss_fn(cfg, params, batch)


def make_template(cfg: ArchConfig) -> PyTree:
    if cfg.family == "yolo":
        return yolov3.template(cfg)
    return transformer.template(cfg)


# ---------------------------------------------------------------------------
# Sharding specs
# ---------------------------------------------------------------------------

def stacked_pspecs(template: PyTree, client_axis: str, rules: dict | None = None) -> PyTree:
    """Param PartitionSpecs with the leading client dim on `client_axis`."""
    base = mp.pspecs(template, rules)
    return jax.tree.map(lambda s: P(client_axis, *s), base, is_leaf=lambda x: isinstance(x, P))


def batch_pspecs(batch_template: PyTree, fed: FedConfig) -> PyTree:
    spec = P(fed.client_axis, None, fed.data_axis)  # (C, E, b, ...)
    return jax.tree.map(lambda _: spec, batch_template)


# ---------------------------------------------------------------------------
# State
# ---------------------------------------------------------------------------

def state_template(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, dtype) -> PyTree:
    """Abstract FedState (ShapeDtypeStructs) for dry-run lowering."""
    tpl = make_template(cfg)
    pabs = mp.abstract(tpl, dtype)
    if fed.aggregation == "fedsgd":
        stack = lambda t: t  # FedSGD-equivalent: one shared model copy
    else:
        stack = lambda t: jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((fed.n_clients,) + s.shape, s.dtype), t
        )
    opt_abs = jax.eval_shape(optimizer.init, pabs)
    st = {
        "params": stack(pabs),
        "opt": stack(opt_abs),
        "round": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if fed.aggregation == "eq6":
        st["prev_sums"] = jax.ShapeDtypeStruct((fed.n_clients, comp.n_score_buckets(cfg)), jnp.float32)
    return st


def make_state(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, rng, dtype=jnp.float32) -> PyTree:
    tpl = make_template(cfg)
    if fed.aggregation == "fedsgd":
        params = mp.init_params(tpl, rng, dtype)
        return {"params": params, "opt": optimizer.init(params), "round": jnp.int32(0)}
    keys = jax.random.split(rng, fed.n_clients)
    params = jax.vmap(lambda k: mp.init_params(tpl, k, dtype))(keys)
    # clients start from the same global model (server dispatch)
    params = jax.tree.map(lambda x: jnp.broadcast_to(x[:1], x.shape), params)
    opt = jax.vmap(optimizer.init)(params)
    st = {"params": params, "opt": opt, "round": jnp.int32(0)}
    if fed.aggregation == "eq6":
        st["prev_sums"] = jax.vmap(lambda p: comp.layer_sums(cfg, tpl, p))(params)
    return st


def state_pspecs(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, rules: dict | None = None, opt_rules: dict | None = None) -> PyTree:
    """opt_rules: optional separate sharding rules for optimizer moments —
    ZeRO-1 style (moments sharded over data while params stay TP-only)."""
    tpl = make_template(cfg)
    if fed.aggregation == "fedsgd":
        pspec = mp.pspecs(tpl, rules)
        mspec = mp.pspecs(tpl, opt_rules) if opt_rules else pspec
    else:
        pspec = stacked_pspecs(tpl, fed.client_axis, rules)
        mspec = stacked_pspecs(tpl, fed.client_axis, opt_rules) if opt_rules else pspec
    opt_shape = jax.eval_shape(optimizer.init, mp.abstract(tpl, jnp.float32))
    ospec = {k: (mspec if k in ("mu", "m", "v") else P()) for k in opt_shape}
    st = {"params": pspec, "opt": ospec, "round": P()}
    if fed.aggregation == "eq6":
        st["prev_sums"] = P(fed.client_axis, None)
    return st


# ---------------------------------------------------------------------------
# The round
# ---------------------------------------------------------------------------

def build_fed_round(cfg: ArchConfig, fed: FedConfig, optimizer: Optimizer, mesh=None, rules: dict | None = None) -> Callable:
    """Returns fed_round(state, batch, weights) -> (state, metrics).

    batch leaves: (C, E, per_step_shard...). weights: (C,) normalized
    participation weights from the scheduler (Eq. 5 uses 1/N).
    """
    tpl = make_template(cfg)
    loss_fn = loss_for(cfg)
    pspec = stacked_pspecs(tpl, fed.client_axis, rules)

    def grads_of(params, step_batch):
        """Gradients for one local step, with microbatch accumulation.

        (A measured alternative — putting the micro scan inside the
        differentiated function so the gradient tree is produced once —
        left the collective term unchanged and tripled temp memory on the
        gemma3 single-pod dry-run; see EXPERIMENTS.md §Perf hillclimb #2.)
        """
        if fed.microbatches <= 1:
            (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, step_batch)
            return loss, grads
        micro = jax.tree.map(
            lambda x: x.reshape((fed.microbatches, x.shape[0] // fed.microbatches) + x.shape[1:]),
            step_batch,
        )

        def acc(carry, mb):
            tot, g_acc = carry
            (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            return (tot + loss, jax.tree.map(jnp.add, g_acc, g)), None

        zeros = jax.tree.map(jnp.zeros_like, params)
        (tot, g_sum), _ = jax.lax.scan(acc, (jnp.float32(0), zeros), micro)
        n = jnp.float32(fed.microbatches)
        return tot / n, jax.tree.map(lambda g: (g / n.astype(g.dtype)), g_sum)

    def local_train(params, opt, client_batch):
        def step(carry, micro):
            p, o = carry
            loss, grads = grads_of(p, micro)
            p, o = optimizer.update(p, grads, o)
            return (p, o), loss

        (params, opt), losses = jax.lax.scan(step, (params, opt), client_batch)
        return params, opt, jnp.mean(losses)

    def fed_round(state, batch, weights):
        if fed.aggregation == "fedsgd":
            # FedSGD-equivalent: clients = data-parallel shards, E=1,
            # param-averaging == gradient-averaging (DESIGN.md §5). One
            # shared model copy, so FSDP-style rules fit huge archs.
            p, o, loss = local_train(state["params"], state["opt"], batch)
            return (
                {**state, "params": p, "opt": o, "round": state["round"] + 1},
                {"loss": loss},
            )
        new_p, new_o, loss = jax.vmap(local_train, spmd_axis_name=fed.client_axis)(
            state["params"], state["opt"], batch
        )
        metrics = {"loss": jnp.mean(loss)}
        if fed.aggregation == "dense":
            agg = fedavg.aggregate_dense(new_p, weights)
            out = {**state, "params": agg, "opt": new_o}
        elif fed.aggregation == "eq6":
            agg, sums = fedavg.aggregate_eq6(cfg, tpl, new_p, weights, state["prev_sums"], fed.topn)
            out = {**state, "params": agg, "opt": new_o, "prev_sums": sums}
        elif fed.aggregation == "quant8":
            agg = fedavg.aggregate_quant8(new_p, state["params"], weights, mesh, fed.client_axis, pspec)
            out = {**state, "params": agg, "opt": new_o}
        elif fed.aggregation == "static_topn":
            sched = fedavg.static_layer_schedule(comp.n_score_buckets(cfg), fed.topn, fed.round_idx_static)
            agg = fedavg.aggregate_static_topn(cfg, tpl, new_p, weights, sched)
            out = {**state, "params": agg, "opt": new_o}
        else:
            raise ValueError(fed.aggregation)
        out["round"] = state["round"] + 1
        return out, metrics

    return fed_round


def uniform_weights(n_clients: int) -> jax.Array:
    """Paper Eq. 5: unweighted average."""
    return jnp.full((n_clients,), 1.0 / n_clients, jnp.float32)
