"""FL_CLIENT — client-side control surface (paper component #6).

"hosts the Task Manager and Explorer components and performs local model
training." In the TPU adaptation local training executes inside the SPMD
fed_round; this class is the *control plane* view of one client: its data
shard, its Explorer reports, and its reconnection/participation state
(the paper's Configuration module exposes reconnection counts).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterator

import numpy as np

from repro.core import explorer

PyTree = Any


@dataclasses.dataclass
class ClientConfig:
    client_id: int
    max_reconnects: int = 3  # paper Configuration: "number of reconnections"


class FLClient:
    def __init__(self, config: ClientConfig, data: Iterator[PyTree] | None = None, rng=None):
        self.cfg = config
        self.data = data
        self._rng = rng or np.random.default_rng(config.client_id)
        self.reconnects = 0
        self.connected = True

    def resource_report(self) -> float:
        """Load in [0,1] for the Explorer feed (simulated per client)."""
        return float(np.clip(self._rng.uniform(0.0, 0.8), 0.0, 1.0))

    def next_batch(self) -> PyTree:
        if self.data is None:
            raise RuntimeError("client has no data pipeline attached")
        return next(self.data)

    def drop(self) -> bool:
        """Simulate a disconnect; returns False when out of reconnect budget."""
        self.reconnects += 1
        self.connected = self.reconnects <= self.cfg.max_reconnects
        return self.connected

    def reconnect(self) -> None:
        if self.reconnects <= self.cfg.max_reconnects:
            self.connected = True
