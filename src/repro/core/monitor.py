"""Round-monitoring view (paper Fig. 9: "Monitoring multiple rounds of
federated model training on FedVision").

Renders per-task progress — round, loss curve sparkline, participation,
upload bytes — as the text analogue of the platform's dashboard, and
exports the same data as JSON for a real UI.

Per-client detail is capped at a top-k (`top_clients` ranking: latest
per-client mAP when an eval trajectory exists, participation frequency
otherwise) so a C=1024 federation renders and exports O(k) client rows,
not O(C); pass ``per_client_cap=0`` to `export_json` to get the full
per-client vectors on request.
"""
from __future__ import annotations

import json
from typing import Sequence

_SPARK = "▁▂▃▄▅▆▇█"


def sparkline(values: Sequence[float], width: int = 32) -> str:
    if not values:
        return ""
    vals = list(values)[-width:]
    lo, hi = min(vals), max(vals)
    span = (hi - lo) or 1.0
    return "".join(_SPARK[int((v - lo) / span * (len(_SPARK) - 1))] for v in vals)


def top_clients(history, n_clients: int, eval_history=None, k: int = 8) -> list[int]:
    """The k clients worth per-client lines: ranked by the latest
    per-client mAP when evals exist (quality is what the dashboard
    watches), else by participation frequency. O(C log C) host-side once
    per render — never O(C) render/export rows downstream."""
    k = max(0, min(k, n_clients))
    if eval_history:
        per = eval_history[-1].per_client_map
        order = sorted(range(min(n_clients, len(per))), key=lambda c: (-per[c], c))
    else:
        freq = [0] * n_clients
        for r in history:
            for c, w in enumerate(r.weights[:n_clients]):
                if w > 0:
                    freq[c] += 1
        order = sorted(range(n_clients), key=lambda c: (-freq[c], c))
    return order[:k]


def render_task(task_id: str, history, n_clients: int, upload_bytes_per_round: float = 0.0, eval_history=None, top_k: int = 4) -> str:
    if not history:
        return f"[{task_id}] no rounds yet"
    losses = [r.loss for r in history]
    last = history[-1]
    parts = sum(1 for w in last.weights if w > 0)
    lines = [
        f"[{task_id}] round {last.round_idx + 1}/{len(history)} complete",
        f"  loss     {losses[0]:.4f} → {losses[-1]:.4f}   {sparkline(losses)}",
        f"  clients  {parts}/{n_clients} participating   round wall {last.seconds:.2f}s",
    ]
    if getattr(last, "sim_time", None) is not None and hasattr(last, "staleness"):
        # buffered-async rounds (DESIGN.md §12): simulated wall-clock,
        # per-flush staleness trajectory, and dropped stale updates
        stale = [
            (sum(r.staleness) / len(r.staleness)) if r.staleness else 0.0
            for r in history
        ]
        dropped = sum(getattr(r, "dropped", 0) for r in history)
        lines.append(
            f"  async    sim clock {last.sim_time:.0f}s   staleness "
            f"{stale[-1]:.2f}   {sparkline(stale)}   dropped {dropped}"
        )
    if eval_history:
        # per-round detection quality (server.evaluate_round trajectory)
        maps = [e.map50 for e in eval_history]
        spread = max(eval_history[-1].per_client_map) - min(eval_history[-1].per_client_map)
        lines.append(
            f"  mAP@0.5  {maps[0]:.3f} → {maps[-1]:.3f}   {sparkline(maps)}"
            f"   client spread {spread:.3f}"
        )
        # top-k per-client trajectories only — the render stays O(k) lines
        # at C=1024 (the full vectors live in export_json(per_client_cap=0))
        for c in top_clients(history, n_clients, eval_history, k=top_k):
            traj = [e.per_client_map[c] for e in eval_history if c < len(e.per_client_map)]
            lines.append(
                f"    client {c:<5d} mAP {traj[-1]:.3f}   {sparkline(traj)}"
            )
    if upload_bytes_per_round:
        lines.append(
            f"  upload   {upload_bytes_per_round / 1e6:.2f} MB/client/round "
            f"({upload_bytes_per_round * parts / 1e6:.2f} MB total)"
        )
    return "\n".join(lines)


def render_wire(task_id: str, history, stats, n_clients: int, liveness_log=()) -> str:
    """The socket-transport lines (DESIGN.md §14): the round view plus the
    wire's own operational counters — landings/drops, reconnects, dead-peer
    detections, uplink/downlink bytes, and landing-queue backpressure."""
    lines = [render_task(task_id, history, n_clients)]
    deaths = sum(1 for _, _, s in liveness_log if s == "dead")
    lines.append(
        f"  wire     {stats.flushes} flushes   {stats.landed} landed"
        f" / {stats.dropped} dropped   {stats.reconnects} reconnects"
        f"   {deaths} dead-peer events"
    )
    lines.append(
        f"  bytes    up {stats.bytes_up / 1e6:.2f} MB   down {stats.bytes_down / 1e6:.2f} MB"
        f"   heartbeats {stats.heartbeats}"
    )
    lines.append(
        f"  queue    high water {stats.queue_high_water}"
        f"   backpressure blocks {stats.backpressure_blocks}"
        f"   protocol errors {stats.protocol_errors}"
        f"   superseded {stats.superseded}"
        + ("   DEADLINE HIT" if stats.deadline_hit else "")
    )
    # the durability/chaos line (DESIGN.md §16) only appears when any of it
    # happened — plain runs keep the compact three-line summary
    if (stats.crc_errors or stats.snapshots or stats.wal_events
            or stats.recoveries or stats.faults_injected or stats.crashed):
        lines.append(
            f"  durable  {stats.snapshots} snapshots   {stats.wal_events} WAL events"
            f"   {stats.recoveries} recoveries   crc errors {stats.crc_errors}"
            f"   faults injected {stats.faults_injected}"
            + ("   CRASHED" if stats.crashed else "")
        )
    return "\n".join(lines)


def render_serving(task_id: str, status: dict) -> str:
    """The serving-plane lines (DESIGN.md §17). ``status`` is a
    `serving.model_status` dict — the SAME evaluation the service answers
    STATUS frames with (one evaluator, two callers), so this view can
    never disagree with what the wire reports."""
    tier = status["tier"]
    flag = {"fresh": "", "soft_stale": "   WARN stale", "hard_stale": "   DEGRADED"}[tier]
    lines = [
        f"[{task_id}] serving round v{status['version']}"
        f" (latest landed v{status['latest_version']})   {tier}{flag}",
        f"  behind   {status['rounds_behind']} rounds"
        f"   {status['seconds_behind']:.1f}s"
        f"   swaps {status['swaps']}",
    ]
    if "requests" in status:
        lines.append(
            f"  traffic  {status['requests']} requests   {status['results']} results"
            f"   {status['batches']} batches"
            f"   occupancy {status['avg_occupancy']:.2f}"
            f"   in flight {status['in_flight']}"
        )
    return "\n".join(lines)


def export_json(task_id: str, history, n_clients: int, eval_history=None, per_client_cap: int = 16) -> str:
    """JSON dashboard feed. Eval rows carry the full per-client mAP vector
    only while ``n_clients <= per_client_cap``; above it each row exports
    the top-``per_client_cap`` clients as a ``per_client_top`` map plus the
    pooled spread, so the payload is O(k) per round at C=1024. Pass
    ``per_client_cap=0`` (or None) to always export the full vectors."""

    def row(r):
        d = {"round": r.round_idx, "loss": r.loss, "participants": sum(1 for w in r.weights if w > 0), "seconds": r.seconds}
        if getattr(r, "sim_time", None) is not None and hasattr(r, "staleness"):
            d.update(sim_time=r.sim_time, staleness=list(r.staleness), dropped=r.dropped)
        return d

    out = {
        "task": task_id,
        "rounds": [row(r) for r in history],
        "n_clients": n_clients,
    }
    if eval_history:
        cap = per_client_cap or 0
        if cap and n_clients > cap:
            top = top_clients(history, n_clients, eval_history, k=cap)

            def erow(e):
                per = e.per_client_map
                return {
                    "round": e.round_idx,
                    "map50": e.map50,
                    "per_client_top": {str(c): per[c] for c in top if c < len(per)},
                    "per_client_capped": n_clients,
                }

            out["eval"] = [erow(e) for e in eval_history]
        else:
            out["eval"] = [
                {"round": e.round_idx, "map50": e.map50, "per_client_map": e.per_client_map}
                for e in eval_history
            ]
    return json.dumps(out)
