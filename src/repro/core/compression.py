"""Upload compression: Eq. 6 layer-contribution scores + int8 quantization.

Eq. 6 of the paper: v(j) = | sum(M_j^{i,k}) - sum(M_j^{i,k-1}) | — the
*signed* sums of all parameters in layer j across consecutive rounds. Each
client ranks its own layers by v(j) and uploads only the top-n.

"Layer" granularity: every scan-stacked slice of the model is a layer
(homogeneous stacks: index l; pattern groups: g*period+j); all unstacked
tensors (embeddings, final norm, shared blocks) share one extra bucket at
index n_layers. `layer_sums` / `apply_layer_mask` implement the mapping from
a parameter pytree to the (n_layers+1,) score vector and back.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.params import ParamInfo, is_info

PyTree = Any


def n_score_buckets(cfg) -> int:
    return cfg.n_layers + 1


def leaf_layer_ids(path, info: ParamInfo, cfg) -> tuple[str, int]:
    """-> (kind, offset): kind in {stack1, stack2, misc}.

    The single source of truth for the param-leaf -> score-bucket mapping;
    `core.packing` reuses it to lay out the packed aggregation buffer.
    """
    top = path[0].key if hasattr(path[0], "key") else str(path[0])
    if info.axes[:2] == ("group", "layer"):
        return "stack2", 0
    if info.axes[:1] == ("layer",):
        if top == "tail":  # gemma3 tail starts after the grouped layers
            period = cfg.local_global_period
            return "stack1", (cfg.n_layers // period) * period
        return "stack1", 0
    return "misc", cfg.n_layers


_leaf_layer_ids = leaf_layer_ids  # legacy-internal alias (core.fedavg)


def layer_sums(cfg, template: PyTree, params: PyTree) -> jax.Array:
    """Signed per-layer parameter sums -> (n_layers+1,) f32 (Eq. 6 inner sums)."""
    out = jnp.zeros((n_score_buckets(cfg),), jnp.float32)

    def add(path, info, x):
        nonlocal out
        kind, off = _leaf_layer_ids(path, info, cfg)
        if kind == "stack2":
            g, p = x.shape[:2]
            s = jnp.sum(x.astype(jnp.float32), axis=tuple(range(2, x.ndim))).reshape(g * p)
            out = out.at[off : off + g * p].add(s)
        elif kind == "stack1":
            l = x.shape[0]
            s = jnp.sum(x.astype(jnp.float32), axis=tuple(range(1, x.ndim)))
            out = out.at[off : off + l].add(s)
        else:
            out = out.at[off].add(jnp.sum(x.astype(jnp.float32)))

    jax.tree_util.tree_map_with_path(add, template, params, is_leaf=lambda t: is_info(t))
    return out


def contribution_scores(prev_sums: jax.Array, new_sums: jax.Array) -> jax.Array:
    """Eq. 6: v(j) = |sum_k - sum_{k-1}|."""
    return jnp.abs(new_sums - prev_sums)


def topn_mask(scores: jax.Array, n: int) -> jax.Array:
    """Boolean mask of the n largest scores (per client). (NL+1,) -> (NL+1,)."""
    n = min(n, scores.shape[-1])
    kth = jax.lax.top_k(scores, n)[0][..., -1:]
    return scores >= kth


def apply_layer_mask(cfg, template: PyTree, params: PyTree, mask: jax.Array) -> PyTree:
    """Multiply each layer slice of `params` by its mask entry (0/1)."""

    def apply(path, info, x):
        kind, off = _leaf_layer_ids(path, info, cfg)
        if kind == "stack2":
            g, p = x.shape[:2]
            m = mask[off : off + g * p].reshape((g, p) + (1,) * (x.ndim - 2))
        elif kind == "stack1":
            l = x.shape[0]
            m = mask[off : off + l].reshape((l,) + (1,) * (x.ndim - 1))
        else:
            m = mask[off]
        return x * m.astype(x.dtype)

    return jax.tree_util.tree_map_with_path(apply, template, params, is_leaf=lambda t: is_info(t))


# ---------------------------------------------------------------------------
# int8 symmetric quantization (upload transport for quant8 aggregation)
# ---------------------------------------------------------------------------

def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8. Returns (q int8, scale f32 scalar)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array, dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compression_ratio(cfg, n: int) -> float:
    """Fraction of layer buckets uploaded under top-n selection."""
    return n / n_score_buckets(cfg)
