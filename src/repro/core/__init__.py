"""The paper's primary contribution: the FedVision HFL engine.

fedavg (Eq. 5) + compression (Eq. 6 / int8) + rounds (SPMD fed_round) +
scheduler/explorer/task_manager/server/client (platform components).
"""
from repro.core import aggregators, compression, explorer, fedavg, monitor, packing, rounds, scheduler, secure_agg, server, task_manager
from repro.core.rounds import FedConfig, build_fed_round, make_state, uniform_weights
from repro.core.server import FLServer

__all__ = [
    "FedConfig",
    "aggregators",
    "packing",
    "FLServer",
    "build_fed_round",
    "compression",
    "explorer",
    "fedavg",
    "make_state",
    "monitor",
    "secure_agg",
    "rounds",
    "scheduler",
    "server",
    "task_manager",
    "uniform_weights",
]
