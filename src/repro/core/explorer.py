"""Explorer — client-side resource monitor (paper component #4).

"monitors the resource utilization situation on the client side (e.g., CPU
usage, memory usage, network load) so as to inform the Task Scheduler."

/proc-based (no external deps). In the TPU adaptation each simulated client
shares this host, so monitor() returns the host telemetry,
`simulated_loads` draws i.i.d. per-client loads for quick experiments, and
:class:`ClientLoadModel` is the persistent heterogeneous straggler model
whose per-round reports feed the Task Scheduler (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import math
import time

import numpy as np


@dataclasses.dataclass
class ResourceReport:
    cpu_frac: float
    mem_frac: float
    load1: float
    timestamp: float


def _read_cpu_times() -> tuple[float, float]:
    with open("/proc/stat") as f:
        parts = f.readline().split()[1:]
    vals = [float(x) for x in parts]
    idle = vals[3] + (vals[4] if len(vals) > 4 else 0.0)
    return sum(vals), idle


def monitor(sample_interval: float = 0.05) -> ResourceReport:
    t0, i0 = _read_cpu_times()
    time.sleep(sample_interval)
    t1, i1 = _read_cpu_times()
    dt, di = t1 - t0, i1 - i0
    cpu = 1.0 - di / dt if dt > 0 else 0.0
    total = avail = 1.0
    with open("/proc/meminfo") as f:
        for line in f:
            if line.startswith("MemTotal:"):
                total = float(line.split()[1])
            elif line.startswith("MemAvailable:"):
                avail = float(line.split()[1])
    with open("/proc/loadavg") as f:
        load1 = float(f.read().split()[0])
    return ResourceReport(cpu, 1.0 - avail / total, load1, time.time())


def simulated_loads(n_clients: int, rng: np.random.Generator, base: ResourceReport | None = None) -> np.ndarray:
    """Per-client load in [0,1]: host load plus client-specific jitter."""
    host = base.cpu_frac if base else 0.2
    return np.clip(host + rng.uniform(-0.1, 0.6, n_clients), 0.0, 1.0)


@dataclasses.dataclass
class LoadModelConfig:
    straggler_frac: float = 0.25  # fraction of chronically overloaded clients
    straggler_load: float = 0.85  # their baseline load
    base_load: float = 0.25  # everyone else's baseline
    base_spread: float = 0.1  # per-client baseline spread
    persistence: float = 0.8  # AR(1) pull toward the baseline, per sim second
    jitter: float = 0.08  # AR(1) innovation scale, per sqrt(sim second)
    spike_prob: float = 0.05  # transient spike probability per sim second
    spike_load: float = 1.0  # spike level (device fully busy)
    spike_duration_s: float = 1.0  # how long a spike pins the load, sim seconds


class ClientLoadModel:
    """Persistent per-client load process: stragglers + AR(1) drift + spikes.

    Unlike `simulated_loads` (i.i.d. per round), clients here have identity:
    a fixed straggler subset sits near `straggler_load` every round, the
    rest drift around their own baseline, and any client can transiently
    spike to `spike_load`. This is what makes the scheduler's load term do
    real work — a quality-only policy would keep picking stragglers.
    Deterministic under a fixed seed.

    Time-based (DESIGN.md §12): ``step(dt)`` advances ``dt`` *simulated
    seconds* on the platform's `core.simclock.SimClock` timeline, so the
    async engine's variable inter-event gaps and the sync loop's fixed
    one-step-per-round cadence drive the same process. The AR(1) pull and
    innovation scale with dt (``persistence**dt``, ``jitter*sqrt(dt)``),
    and a spike pins the load for ``spike_duration_s`` simulated seconds —
    previously a spike lasted exactly one *step call*, which conflated
    duration with the caller's step count. ``step()`` with the default
    dt=1.0 reproduces the legacy per-round behavior exactly.
    """

    def __init__(self, n_clients: int, seed: int = 0, config: LoadModelConfig | None = None):
        self.cfg = config or LoadModelConfig()
        self.n = n_clients
        self._rng = np.random.default_rng(seed)
        n_strag = int(round(self.cfg.straggler_frac * n_clients))
        self.stragglers = self._rng.choice(n_clients, size=n_strag, replace=False)
        self.baseline = np.clip(
            self.cfg.base_load + self.cfg.base_spread * self._rng.standard_normal(n_clients),
            0.05,
            0.6,
        )
        self.baseline[self.stragglers] = self.cfg.straggler_load
        self.loads = self.baseline.copy()
        self.t = 0.0  # simulated seconds of process time advanced so far
        self._spike_until = np.full(n_clients, -np.inf)  # spike end times

    def step(self, dt: float = 1.0) -> np.ndarray:
        """Advance `dt` simulated seconds; returns the (n,) load in [0, 1].

        dt=1.0 (the default) is the legacy one-call-per-round cadence and
        is bit-compatible with it under a fixed seed.
        """
        if dt < 0:
            raise ValueError(f"load model cannot run backwards (dt={dt})")
        c = self.cfg
        self.t += dt
        rho = c.persistence ** dt
        # AR(1)-consistent innovation for a dt-second step: composing k
        # steps of dt/k must give the same process variance as one step of
        # dt, so the scale is jitter * sqrt((1 - rho1^2dt) / (1 - rho1^2))
        # — NOT jitter * sqrt(dt), whose variance grows without bound and
        # saturates sparsely-sampled loads at the clip walls. At dt=1 the
        # ratio is exactly 1, keeping legacy seeds bit-compatible; the
        # persistence -> 1 (random-walk) limit is sqrt(dt).
        r2 = c.persistence ** 2
        scale = c.jitter * (
            math.sqrt(dt) if r2 >= 1.0 else math.sqrt((1.0 - r2 ** dt) / (1.0 - r2))
        )
        innov = scale * self._rng.standard_normal(self.n)
        ar = rho * self.loads + (1 - rho) * self.baseline + innov
        # spike arrivals: per-second rate. Only arrivals still *active* at
        # the sampled instant matter, so the arrival window is capped at
        # the spike duration — sampling sparsely (dt >> duration) must not
        # stretch every spike in the window to the endpoint, and sampling
        # densely accumulates activity through _spike_until instead; the
        # stationary active fraction ~ rate * duration either way. A
        # window of exactly 1 keeps the literal spike_prob so legacy
        # per-round seeds reproduce bit-for-bit.
        win = min(dt, c.spike_duration_s)
        p = c.spike_prob if win == 1.0 else 1.0 - (1.0 - c.spike_prob) ** win
        fired = self._rng.random(self.n) < p
        self._spike_until = np.where(fired, self.t + c.spike_duration_s, self._spike_until)
        # a spike pins the load for spike_duration_s of *simulated* time;
        # once it ends, AR(1) decays from the spike level it left behind
        active = fired | (self.t < self._spike_until)
        self.loads = np.where(active, c.spike_load, ar)
        self.loads = np.clip(self.loads, 0.0, 1.0)
        return self.loads.copy()
