"""Explorer — client-side resource monitor (paper component #4).

"monitors the resource utilization situation on the client side (e.g., CPU
usage, memory usage, network load) so as to inform the Task Scheduler."

/proc-based (no external deps). In the TPU adaptation each simulated client
shares this host, so monitor() returns the host telemetry and
`simulated_loads` draws per-client loads for scheduler experiments.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class ResourceReport:
    cpu_frac: float
    mem_frac: float
    load1: float
    timestamp: float


def _read_cpu_times() -> tuple[float, float]:
    with open("/proc/stat") as f:
        parts = f.readline().split()[1:]
    vals = [float(x) for x in parts]
    idle = vals[3] + (vals[4] if len(vals) > 4 else 0.0)
    return sum(vals), idle


def monitor(sample_interval: float = 0.05) -> ResourceReport:
    t0, i0 = _read_cpu_times()
    time.sleep(sample_interval)
    t1, i1 = _read_cpu_times()
    dt, di = t1 - t0, i1 - i0
    cpu = 1.0 - di / dt if dt > 0 else 0.0
    total = avail = 1.0
    with open("/proc/meminfo") as f:
        for line in f:
            if line.startswith("MemTotal:"):
                total = float(line.split()[1])
            elif line.startswith("MemAvailable:"):
                avail = float(line.split()[1])
    with open("/proc/loadavg") as f:
        load1 = float(f.read().split()[0])
    return ResourceReport(cpu, 1.0 - avail / total, load1, time.time())


def simulated_loads(n_clients: int, rng: np.random.Generator, base: ResourceReport | None = None) -> np.ndarray:
    """Per-client load in [0,1]: host load plus client-specific jitter."""
    host = base.cpu_frac if base else 0.2
    return np.clip(host + rng.uniform(-0.1, 0.6, n_clients), 0.0, 1.0)
