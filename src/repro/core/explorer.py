"""Explorer — client-side resource monitor (paper component #4).

"monitors the resource utilization situation on the client side (e.g., CPU
usage, memory usage, network load) so as to inform the Task Scheduler."

/proc-based (no external deps). In the TPU adaptation each simulated client
shares this host, so monitor() returns the host telemetry,
`simulated_loads` draws i.i.d. per-client loads for quick experiments, and
:class:`ClientLoadModel` is the persistent heterogeneous straggler model
whose per-round reports feed the Task Scheduler (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np


@dataclasses.dataclass
class ResourceReport:
    cpu_frac: float
    mem_frac: float
    load1: float
    timestamp: float


def _read_cpu_times() -> tuple[float, float]:
    with open("/proc/stat") as f:
        parts = f.readline().split()[1:]
    vals = [float(x) for x in parts]
    idle = vals[3] + (vals[4] if len(vals) > 4 else 0.0)
    return sum(vals), idle


def monitor(sample_interval: float = 0.05) -> ResourceReport:
    t0, i0 = _read_cpu_times()
    time.sleep(sample_interval)
    t1, i1 = _read_cpu_times()
    dt, di = t1 - t0, i1 - i0
    cpu = 1.0 - di / dt if dt > 0 else 0.0
    total = avail = 1.0
    with open("/proc/meminfo") as f:
        for line in f:
            if line.startswith("MemTotal:"):
                total = float(line.split()[1])
            elif line.startswith("MemAvailable:"):
                avail = float(line.split()[1])
    with open("/proc/loadavg") as f:
        load1 = float(f.read().split()[0])
    return ResourceReport(cpu, 1.0 - avail / total, load1, time.time())


def simulated_loads(n_clients: int, rng: np.random.Generator, base: ResourceReport | None = None) -> np.ndarray:
    """Per-client load in [0,1]: host load plus client-specific jitter."""
    host = base.cpu_frac if base else 0.2
    return np.clip(host + rng.uniform(-0.1, 0.6, n_clients), 0.0, 1.0)


@dataclasses.dataclass
class LoadModelConfig:
    straggler_frac: float = 0.25  # fraction of chronically overloaded clients
    straggler_load: float = 0.85  # their baseline load
    base_load: float = 0.25  # everyone else's baseline
    base_spread: float = 0.1  # per-client baseline spread
    persistence: float = 0.8  # AR(1) pull toward the client baseline
    jitter: float = 0.08  # AR(1) innovation scale
    spike_prob: float = 0.05  # transient spike probability per client-round
    spike_load: float = 1.0  # spike level (device fully busy)


class ClientLoadModel:
    """Persistent per-client load process: stragglers + AR(1) drift + spikes.

    Unlike `simulated_loads` (i.i.d. per round), clients here have identity:
    a fixed straggler subset sits near `straggler_load` every round, the
    rest drift around their own baseline, and any client can transiently
    spike to `spike_load`. This is what makes the scheduler's load term do
    real work — a quality-only policy would keep picking stragglers.
    Deterministic under a fixed seed.
    """

    def __init__(self, n_clients: int, seed: int = 0, config: LoadModelConfig | None = None):
        self.cfg = config or LoadModelConfig()
        self.n = n_clients
        self._rng = np.random.default_rng(seed)
        n_strag = int(round(self.cfg.straggler_frac * n_clients))
        self.stragglers = self._rng.choice(n_clients, size=n_strag, replace=False)
        self.baseline = np.clip(
            self.cfg.base_load + self.cfg.base_spread * self._rng.standard_normal(n_clients),
            0.05,
            0.6,
        )
        self.baseline[self.stragglers] = self.cfg.straggler_load
        self.loads = self.baseline.copy()

    def step(self) -> np.ndarray:
        """Advance one round; returns the (n,) load report in [0, 1]."""
        c = self.cfg
        innov = c.jitter * self._rng.standard_normal(self.n)
        self.loads = c.persistence * self.loads + (1 - c.persistence) * self.baseline + innov
        spikes = self._rng.random(self.n) < c.spike_prob
        self.loads = np.where(spikes, c.spike_load, self.loads)
        self.loads = np.clip(self.loads, 0.0, 1.0)
        return self.loads.copy()
