"""Task Scheduler — load-balancing client selection (Yu et al. 2017 style).

The paper: "The load-balancing approach ... jointly considers clients' local
model quality and the current load on their local computational resources in
an effort to maximize the quality of the resulting federated model."

We implement that as per-round selection maximizing
    score_i = alpha * quality_i - beta * load_i
subject to a participation budget, with a fairness floor so starved clients
eventually re-enter (their data would otherwise never contribute). Quality
is an EMA of each client's local loss improvement; load comes from Explorer
reports (`core.explorer.ClientLoadModel` in the simulated platform).

:meth:`TaskScheduler.participation` is the engine-facing output: a 0/1 mask,
the Eq. 5 weight vector, and (under a static budget) the compact index
vector — exactly the `rounds.participation_input` operands, so the selection
flows into the jitted round as traced values (DESIGN.md §8).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SchedulerConfig:
    alpha: float = 1.0  # quality weight
    beta: float = 0.5  # load penalty
    max_participants: int = 0  # 0 -> all
    fairness_rounds: int = 4  # force-include clients idle this many rounds
    quality_ema: float = 0.8


class TaskScheduler:
    def __init__(self, n_clients: int, config: SchedulerConfig | None = None):
        self.cfg = config or SchedulerConfig()
        self.n = n_clients
        self.quality = np.zeros(n_clients)  # EMA of loss/eval improvement
        self.last_loss = np.full(n_clients, np.nan)
        self.last_eval = np.full(n_clients, np.nan)
        self.idle_rounds = np.zeros(n_clients, int)

    def report_quality(self, client: int, loss: float) -> None:
        prev = self.last_loss[client]
        improvement = 0.0 if np.isnan(prev) else prev - loss
        e = self.cfg.quality_ema
        self.quality[client] = e * self.quality[client] + (1 - e) * improvement
        self.last_loss[client] = loss

    def report_eval(self, client: int, score: float) -> None:
        """Task-metric quality signal, higher-is-better (e.g. the client's
        mAP@0.5 from `server.evaluate_round`). Mirrors report_quality: the
        quality EMA tracks the *improvement* of the score, so a client
        whose detection quality is climbing outranks one that plateaued —
        loss- and eval-derived signals share one EMA and are comparable.
        """
        prev = self.last_eval[client]
        improvement = 0.0 if np.isnan(prev) else score - prev
        e = self.cfg.quality_ema
        self.quality[client] = e * self.quality[client] + (1 - e) * improvement
        self.last_eval[client] = score

    def participation(self, loads: np.ndarray, k_static: int | None = None) -> dict[str, np.ndarray]:
        """One round of selection. loads: (n,) in [0,1] from the Explorer.

        Returns {"mask": (n,) f32 0/1, "weights": (n,) f32 summing to 1 over
        participants, ["idx": (k_static,) int32]}.

        Without ``k_static`` the participant count is dynamic: the top
        ``max_participants`` by score, *plus* every client whose idle streak
        hit the fairness floor. With ``k_static`` (compact rounds need a
        static shape) exactly k_static clients are returned and the fairness
        floor *preempts* the budget instead of growing it: longest-idle
        floored clients claim slots first, best-scoring clients fill the
        rest.
        """
        loads = np.asarray(loads, float)
        score = self.cfg.alpha * self.quality - self.cfg.beta * loads
        order = np.argsort(-score)
        floored = [i for i in range(self.n) if self.idle_rounds[i] >= self.cfg.fairness_rounds]
        if k_static is None:
            k = min(self.cfg.max_participants or self.n, self.n)
            chosen = set(order[:k].tolist())
            chosen.update(floored)
        else:
            k = min(k_static, self.n)
            picked = sorted(floored, key=lambda i: (-self.idle_rounds[i], i))[:k]
            for i in order:
                if len(picked) >= k:
                    break
                if i not in picked:
                    picked.append(int(i))
            chosen = set(picked)
        mask = np.zeros(self.n, np.float32)
        mask[list(chosen)] = 1.0
        for i in range(self.n):
            self.idle_rounds[i] = 0 if mask[i] else self.idle_rounds[i] + 1
        total = float(mask.sum())
        weights = mask.astype(float) / total if total else np.full(self.n, 1.0 / self.n)
        out = {"mask": mask, "weights": weights}
        if k_static is not None:
            out["idx"] = np.asarray(sorted(chosen), np.int32)
        return out

    def select(self, loads: np.ndarray) -> np.ndarray:
        """loads: (n,) in [0,1] from Explorer. Returns weights (n,), sum 1.

        PR 1 convention (weights only); new callers want
        :meth:`participation` for the mask/idx the round engine consumes.
        """
        return self.participation(loads)["weights"].astype(float)
