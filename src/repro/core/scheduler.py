"""Task Scheduler — load-balancing client selection (Yu et al. 2017 style).

The paper: "The load-balancing approach ... jointly considers clients' local
model quality and the current load on their local computational resources in
an effort to maximize the quality of the resulting federated model."

We implement that as per-round selection maximizing
    score_i = alpha * quality_i - beta * load_i
subject to a participation budget, with a fairness floor so starved clients
eventually re-enter (their data would otherwise never contribute). Quality
is an EMA of each client's local loss improvement; load comes from Explorer
reports. The output is the weight vector fed to the Eq. 5 aggregation.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SchedulerConfig:
    alpha: float = 1.0  # quality weight
    beta: float = 0.5  # load penalty
    max_participants: int = 0  # 0 -> all
    fairness_rounds: int = 4  # force-include clients idle this many rounds
    quality_ema: float = 0.8


class TaskScheduler:
    def __init__(self, n_clients: int, config: SchedulerConfig | None = None):
        self.cfg = config or SchedulerConfig()
        self.n = n_clients
        self.quality = np.zeros(n_clients)  # EMA of loss improvement
        self.last_loss = np.full(n_clients, np.nan)
        self.idle_rounds = np.zeros(n_clients, int)

    def report_quality(self, client: int, loss: float) -> None:
        prev = self.last_loss[client]
        improvement = 0.0 if np.isnan(prev) else prev - loss
        e = self.cfg.quality_ema
        self.quality[client] = e * self.quality[client] + (1 - e) * improvement
        self.last_loss[client] = loss

    def select(self, loads: np.ndarray) -> np.ndarray:
        """loads: (n,) in [0,1] from Explorer. Returns weights (n,), sum 1."""
        loads = np.asarray(loads, float)
        score = self.cfg.alpha * self.quality - self.cfg.beta * loads
        k = self.cfg.max_participants or self.n
        k = min(k, self.n)
        chosen = set(np.argsort(-score)[:k].tolist())
        # fairness floor: anyone idle too long joins this round
        for i in range(self.n):
            if self.idle_rounds[i] >= self.cfg.fairness_rounds:
                chosen.add(i)
        weights = np.zeros(self.n)
        for i in range(self.n):
            if i in chosen:
                weights[i] = 1.0
                self.idle_rounds[i] = 0
            else:
                self.idle_rounds[i] += 1
        total = weights.sum()
        return weights / total if total else np.full(self.n, 1.0 / self.n)
