"""Legacy tree-path aggregation (paper Eq. 5) over a client-stacked pytree.

The live round path now packs the stacked tree into one (C, N_total) buffer
and dispatches through :mod:`repro.core.aggregators` (DESIGN.md §7). This
module is kept as the per-leaf reference implementation: the packed engine
is required to match it numerically on the four seed modes
(tests/test_aggregators.py), and it remains the clearest statement of each
mode's semantics.

All functions take `stacked`: a pytree whose every leaf has a leading client
dim C (sharded over the client mesh axis), plus participation `weights`
(C,) — the scheduler's output, normalized. Modes:

- `aggregate_dense`   — Eq. 5 FedAvg (weighted mean, full upload).
- `aggregate_eq6`     — paper-faithful top-n layer upload per client
                        (Eq. 6 contribution scores). Value-dependent, so the
                        collective still moves full tensors; semantics match
                        the platform (non-uploaded layers keep local values).
- `aggregate_quant8`  — beyond-paper: int8-quantized *delta* upload via an
                        explicit all_gather over the client axis (shard_map),
                        structurally shrinking collective bytes ~4x vs f32.
- `aggregate_static_topn` — beyond-paper: trace-time round-robin layer
                        subset; the collective operand itself is sliced, so
                        the dry-run/roofline sees the paper's bandwidth
                        saving structurally.
"""
from __future__ import annotations

from typing import Any

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import compression as comp
from repro.core.aggregators.basic import static_layer_schedule  # noqa: F401 (canonical home moved; re-exported for callers)
from repro.models.params import is_info

PyTree = Any

AGGREGATION_MODES = ("dense", "eq6", "quant8", "static_topn")


def _wmean(stacked: PyTree, weights: jax.Array) -> PyTree:
    """Weighted mean over the client dim, broadcast back to (C, ...)."""

    def f(x):
        g = jnp.einsum("c,c...->...", weights.astype(jnp.float32), x.astype(jnp.float32))
        return jnp.broadcast_to(g.astype(x.dtype)[None], x.shape)

    return jax.tree.map(f, stacked)


def aggregate_dense(stacked: PyTree, weights: jax.Array) -> PyTree:
    return _wmean(stacked, weights)


def aggregate_eq6(cfg, template, stacked: PyTree, weights: jax.Array, prev_sums: jax.Array, topn: int):
    """Returns (new_stacked, new_sums (C, NL+1)).

    Each client uploads only its top-n layers by Eq. 6 score; a layer's
    global value is the weighted mean over the clients that uploaded it;
    layers uploaded by nobody keep each client's local values.
    """
    new_sums = jax.vmap(lambda p: comp.layer_sums(cfg, template, p))(stacked)
    v = comp.contribution_scores(prev_sums, new_sums)  # (C, NL+1)
    mask = jax.vmap(lambda s: comp.topn_mask(s, topn))(v).astype(jnp.float32)
    wmask = mask * weights[:, None]  # (C, NL+1)
    den = jnp.sum(wmask, axis=0)  # (NL+1,)
    inv = jnp.where(den > 0, 1.0 / jnp.maximum(den, 1e-12), 0.0)
    masked = jax.vmap(lambda p, m: comp.apply_layer_mask(cfg, template, p, m))(stacked, wmask)
    num = jax.tree.map(lambda x: jnp.sum(x.astype(jnp.float32), axis=0), masked)
    global_f32 = comp.apply_layer_mask(cfg, template, num, inv)
    global_ = jax.tree.map(lambda g, x: g.astype(x.dtype), global_f32, stacked)
    uploaded = (den > 0).astype(jnp.float32)
    # per-leaf selection pattern: 1 where the layer was uploaded by anyone
    sel = comp.apply_layer_mask(cfg, template, jax.tree.map(lambda x: jnp.ones(x.shape[1:], x.dtype), stacked), uploaded)
    new_stacked = jax.tree.map(
        lambda s, g, x: jnp.where(s.astype(bool)[None], jnp.broadcast_to(g[None], x.shape), x),
        sel,
        global_,
        stacked,
    )
    return new_stacked, new_sums


def aggregate_quant8(stacked: PyTree, base: PyTree, weights: jax.Array, mesh, client_axis: str, specs: PyTree) -> PyTree:
    """global = base + wmean_c(dequant(quant(new_c - base))); int8 transport.

    `specs`: PartitionSpec pytree for `stacked` (leading client axis). The
    collective is an explicit int8 all_gather inside shard_map, so the HLO
    moves 1-byte operands over the client axis instead of bf16/f32.
    """
    C = weights.shape[0]
    n_shards = dict(zip(mesh.axis_names, mesh.devices.shape))[client_axis]
    if C % n_shards:
        raise ValueError(
            f"quant8 requires n_clients ({C}) divisible by the "
            f"'{client_axis}' mesh axis ({n_shards} shards): "
            f"jnp.repeat(scales, C // n_shards) would silently produce a "
            f"wrong-length row-scale vector"
        )

    def f(new, base_, w):
        def per_leaf(n_leaf, b_leaf):
            # local block holds C/n_shards client rows; one scale per shard
            delta = (n_leaf.astype(jnp.float32) - b_leaf.astype(jnp.float32))
            q, scale = comp.quantize(delta)
            qg = jax.lax.all_gather(q, client_axis, axis=0, tiled=True)  # (C, ...)
            sg = jax.lax.all_gather(scale, client_axis, axis=0)  # (n_shards,)
            row_scale = jnp.repeat(sg, C // n_shards)  # (C,)
            d = qg.astype(jnp.float32) * row_scale.reshape((C,) + (1,) * (qg.ndim - 1))
            gd = jnp.einsum("c,c...->...", w.astype(jnp.float32), d)
            return (b_leaf.astype(jnp.float32) + gd[None]).astype(n_leaf.dtype)

        return jax.tree.map(per_leaf, new, base_)

    return jax.shard_map(
        f, mesh=mesh, in_specs=(specs, specs, P()), out_specs=specs, check_vma=False
    )(stacked, base, weights)




def aggregate_static_topn(cfg, template, stacked: PyTree, weights: jax.Array, sync_layers: tuple[int, ...]) -> PyTree:
    """Aggregate only a static subset of layer buckets.

    The leading-stack rows of each leaf are sliced at trace time, so the
    cross-client collective operand is `len(sync_layers)/n_buckets` of the
    full size — the paper's upload saving made structural.
    """
    nl = cfg.n_layers
    mask_vec = np.zeros(comp.n_score_buckets(cfg), bool)
    mask_vec[list(sync_layers)] = True

    def agg(path, info, x):
        kind, off = comp._leaf_layer_ids(path, info, cfg)
        if kind == "misc":
            if not mask_vec[nl]:
                return x
            return _wmean_leaf(x, weights)
        if kind == "stack2":
            g, p = x.shape[1:3]
            flat = x.reshape((x.shape[0], g * p) + x.shape[3:])
            ids = np.arange(g * p) + off
            sel = np.nonzero(mask_vec[ids])[0]
            if sel.size == 0:
                return x
            sub = _wmean_leaf(flat[:, sel], weights)
            return flat.at[:, sel].set(sub).reshape(x.shape)
        l = x.shape[1]
        ids = np.arange(l) + off
        sel = np.nonzero(mask_vec[ids])[0]
        if sel.size == 0:
            return x
        sub = _wmean_leaf(x[:, sel], weights)
        return x.at[:, sel].set(sub)

    return jax.tree_util.tree_map_with_path(agg, template, stacked, is_leaf=is_info)


def _wmean_leaf(x: jax.Array, weights: jax.Array) -> jax.Array:
    g = jnp.einsum("c,c...->...", weights.astype(jnp.float32), x.astype(jnp.float32))
    return jnp.broadcast_to(g.astype(x.dtype)[None], x.shape)
