"""SimClock — the platform's shared simulated wall clock (DESIGN.md §12).

One monotonic simulated-seconds counter shared by everything that models
time: the async round engine's event queue (`core.async_engine`), the
Explorer's load process (`explorer.ClientLoadModel.step(dt)` — AR(1) drift
and spike *durations* are measured in simulated seconds, not step counts),
and the Task Manager's shared-clock interleaving of concurrent tasks.

The clock is deliberately dumb: it only moves forward, and it never reads
host time. Everything observable about the async engine (event order,
staleness, time-to-loss benches) is a deterministic function of the seeds
and this counter, so simulations replay exactly.
"""
from __future__ import annotations


class SimClock:
    """Monotonic simulated wall clock, in seconds."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        """Move `dt` simulated seconds forward; returns the new time."""
        if dt < 0:
            raise ValueError(f"SimClock cannot go backwards (dt={dt})")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        """Jump to absolute simulated time `t` (>= now); returns elapsed dt."""
        dt = t - self._t
        if dt < -1e-12:
            raise ValueError(
                f"SimClock cannot go backwards (now={self._t}, target={t})"
            )
        dt = max(dt, 0.0)
        self._t = t if dt else self._t
        return dt

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SimClock(t={self._t:.3f})"


class WallClock(SimClock):
    """SimClock slaved to the host's monotonic clock (DESIGN.md §14).

    The wire transport's landing loop runs in real time, but the arrival
    engine speaks the SimClock interface — `sync()` pulls the clock forward
    to ``monotonic() - t0`` (relative seconds since construction) and
    returns it. Times read off a WallClock are what a wire run records into
    its arrival schedule; replaying advances a plain SimClock to those same
    stamps, so a recorded run and its replay agree on every ``sim_time``.
    Only `sync` reads host time; between syncs the clock is as dumb and
    monotonic as its parent.

    A recovered server passes ``start=`` (the snapshot's clock time) so the
    resumed run's recorded times continue monotonically from where the
    crashed run stopped — the combined pre-crash + post-restore schedule
    must still be a valid (monotonic) `ArrivalSchedule`.
    """

    def __init__(self, start: float = 0.0):
        import time

        super().__init__(start)
        self._mono = time.monotonic
        self._t0 = self._mono() - start

    def sync(self) -> float:
        """Advance to now (relative host seconds); returns the new time.
        Only the landing loop — the single engine-owning thread — may call
        this; concurrent syncs could race the monotonicity check."""
        t = self._mono() - self._t0
        if t > self.now():
            self.advance_to(t)
        return self.now()

    def peek(self) -> float:
        """Relative host seconds WITHOUT advancing the clock — safe from
        any thread (reader threads stamp `last_seen` with this)."""
        return max(self.now(), self._mono() - self._t0)
