"""SimClock — the platform's shared simulated wall clock (DESIGN.md §12).

One monotonic simulated-seconds counter shared by everything that models
time: the async round engine's event queue (`core.async_engine`), the
Explorer's load process (`explorer.ClientLoadModel.step(dt)` — AR(1) drift
and spike *durations* are measured in simulated seconds, not step counts),
and the Task Manager's shared-clock interleaving of concurrent tasks.

The clock is deliberately dumb: it only moves forward, and it never reads
host time. Everything observable about the async engine (event order,
staleness, time-to-loss benches) is a deterministic function of the seeds
and this counter, so simulations replay exactly.
"""
from __future__ import annotations


class SimClock:
    """Monotonic simulated wall clock, in seconds."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        """Move `dt` simulated seconds forward; returns the new time."""
        if dt < 0:
            raise ValueError(f"SimClock cannot go backwards (dt={dt})")
        self._t += dt
        return self._t

    def advance_to(self, t: float) -> float:
        """Jump to absolute simulated time `t` (>= now); returns elapsed dt."""
        dt = t - self._t
        if dt < -1e-12:
            raise ValueError(
                f"SimClock cannot go backwards (now={self._t}, target={t})"
            )
        dt = max(dt, 0.0)
        self._t = t if dt else self._t
        return dt

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"SimClock(t={self._t:.3f})"
