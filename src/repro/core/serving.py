"""Production serving plane for the trained detector (DESIGN.md §17).

The paper's third leg — "model dispatch to visual serving" — as a real
inference service instead of a one-shot CLI decode:

- **Request batching into ONE jitted program.** Concurrent INFER requests
  are collected into a fixed ``FedConfig.serve_batch``-slot batch
  (zero-padded, per-request valid slots), and every batch runs the same
  cached jitted decode+NMS program (`detection.decode_predictions`) — the
  packed-buffer discipline applied to the serving axis: fixed shapes, no
  retrace, padding carried by masks. Per-slot decode is a function of that
  slot alone (per-image NMS class-shift stride), so a request's detections
  are bit-identical at any batch occupancy — the padding pin
  tests/test_serving.py holds the service to.

- **Round-versioned hot model swap.** A `ModelSlot` atomically publishes
  ``(round_version, params, published_t)``; training publishes off the
  async engine's *landed* global (`publish_from_engine` reads
  ``engine.global_packed_row()`` — the engine's own global copy, never a
  mid-window in-flight buffer row) as flushes land, and the batcher takes
  one slot snapshot per batch, so a swap is just "the next batch serves
  the new version": no lock spans a jit call, no request is ever dropped
  by a swap, and every RESULT carries the version it was served from.

- **Freshness tiers.** fresh / soft_stale (warning) / hard_stale
  (degraded), computed by ONE evaluator (:func:`freshness_tier`) from
  rounds-behind and wall-seconds-behind thresholds in `FedConfig`. The
  service's STATUS frame and `monitor.render_serving` both call
  :func:`model_status` — one function, two callers, no drift.

The wire is the federation transport's own framing (`transport/wire.py`
CRC'd frames) with the INFER/RESULT/STATUS types; `InferenceClient` is the
consumer half. `benchmarks/serve_bench.py` measures served QPS and
p50/p99 latency across batch occupancies and pins zero dropped requests
across a hot swap under load.
"""
from __future__ import annotations

import dataclasses
import functools
import queue
import socket
import threading
import time
from typing import Any, Callable

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import detection
from repro.core import rounds as R
from repro.core.transport import wire

PyTree = Any

# -- freshness tiers (the Anti-Coin-style status model) ----------------------

FRESH, SOFT_STALE, HARD_STALE = "fresh", "soft_stale", "hard_stale"
TIER_CODES = {FRESH: 0, SOFT_STALE: 1, HARD_STALE: 2}
TIER_NAMES = {v: k for k, v in TIER_CODES.items()}


def freshness_tier(rounds_behind: int, seconds_behind: float, fed: R.FedConfig) -> str:
    """THE status evaluator — the serving path (STATUS frame) and
    `monitor.render_serving` both call this one function, so the wire's
    health report and the dashboard can never disagree.

    A model is ``soft_stale`` (serve, but warn) once it is strictly more
    than ``serve_soft_stale_rounds`` landed rounds OR
    ``serve_soft_stale_s`` wall seconds behind; ``hard_stale`` (degraded:
    still served, loudly flagged) past the hard thresholds. Exactly-at-
    threshold is the lower tier — `tests/test_serving.py` pins the
    boundaries."""
    if (rounds_behind > fed.serve_hard_stale_rounds
            or seconds_behind > fed.serve_hard_stale_s):
        return HARD_STALE
    if (rounds_behind > fed.serve_soft_stale_rounds
            or seconds_behind > fed.serve_soft_stale_s):
        return SOFT_STALE
    return FRESH


def model_status(slot: "ModelSlot", latest_version: int, now: float,
                 fed: R.FedConfig, stats: "ServeStats | None" = None) -> dict:
    """The serving health report: version lineage + freshness tier (+ the
    service's operational counters when given). JSON-able — this dict IS
    the STATUS frame payload and the monitor's input."""
    pub = slot.snapshot()
    rounds_behind = max(0, int(latest_version) - pub.version)
    seconds_behind = max(0.0, float(now) - pub.published_t)
    tier = freshness_tier(rounds_behind, seconds_behind, fed)
    out = {
        "version": pub.version,
        "latest_version": int(latest_version),
        "rounds_behind": rounds_behind,
        "seconds_behind": seconds_behind,
        "tier": tier,
        "degraded": tier == HARD_STALE,
        "swaps": slot.swaps,
    }
    if stats is not None:
        out.update(stats.as_dict())
    return out


# -- the hot-swap slot -------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PublishedModel:
    """One atomic publication: the model, the landed round version it came
    from, and when it was published (the freshness clock's anchor)."""

    version: int
    params: PyTree
    published_t: float


class ModelSlot:
    """Atomic publish/snapshot of ``(round_version, params)``.

    Training and serving share one live state through this slot: the
    training side calls :meth:`publish` as rounds land, the batcher calls
    :meth:`snapshot` once per batch. Publish is version-monotonic — a
    publisher racing an already-landed newer round is refused (returns
    False, counted in ``stale_publishes``) so the served model can never
    move backwards.

    ``clock`` is anything with ``.now()`` (a `SimClock` in tests — the
    controlled freshness transitions); None means host monotonic time.
    """

    def __init__(self, clock=None):
        self._lock = threading.Lock()
        self._clock = clock
        self._published: PublishedModel | None = None
        self.swaps = 0  # successful publishes (the first one included)
        self.stale_publishes = 0  # refused version regressions

    def now(self) -> float:
        return self._clock.now() if self._clock is not None else time.monotonic()

    def publish(self, version: int, params: PyTree, t: float | None = None) -> bool:
        pub = PublishedModel(int(version), params,
                             self.now() if t is None else float(t))
        with self._lock:
            if self._published is not None and pub.version < self._published.version:
                self.stale_publishes += 1
                return False
            self._published = pub
            self.swaps += 1
        return True

    def snapshot(self) -> PublishedModel:
        with self._lock:
            if self._published is None:
                raise RuntimeError("ModelSlot is empty: nothing published yet")
            return self._published

    @property
    def empty(self) -> bool:
        with self._lock:
            return self._published is None


def unpack_global(cfg, fed: R.FedConfig, row) -> PyTree:
    """(N_total,) packed global row -> param pytree (one pack/unpack edge —
    the same edge `server.global_params` crosses)."""
    params = R.unpacked_params(cfg, fed, {"params": jnp.asarray(row)[None]})
    return jax.tree.map(lambda x: x[0], params)


def publish_from_engine(slot: ModelSlot, engine, cfg, *, t: float | None = None) -> bool:
    """Publish the engine's landed global at its landed round version.

    Reads ``engine.global_packed_row()`` — each engine's own notion of
    "the current global" (the arrival engine keeps an explicit snapshot
    because its buffer rows mutate on every landing) — NEVER a row indexed
    out of ``state["params"]``, which mid-window may hold a client's next
    trained update. This is what makes the served version equal the
    engine's landed round version by construction."""
    return slot.publish(
        engine.version, unpack_global(cfg, engine.fed, engine.global_packed_row()), t=t
    )


# -- the jitted program cache ------------------------------------------------

@functools.lru_cache(maxsize=16)
def detection_program(cfg, max_detections: int) -> Callable:
    """One cached jitted decode+NMS callable per (cfg, max_detections) —
    every batch the service runs goes through this program (jit re-traces
    per batch shape internally and caches; the wrapper itself is built
    once, the `launch/serve.py::generate` retrace fix applied here too)."""

    @jax.jit
    def program(params, images):
        return detection.decode_predictions(
            cfg, params, images, max_detections=max_detections
        )

    return program


def decode_result(pred: dict, i: int) -> list[tuple[int, float, tuple]]:
    """Slot ``i`` of a program output -> the RESULT frame's detection list
    (kept slots only, score order preserved)."""
    valid = np.asarray(pred["valid"][i])
    cls = np.asarray(pred["cls"][i])
    scores = np.asarray(pred["scores"][i])
    boxes = np.asarray(pred["boxes"][i])
    return [
        (int(cls[k]), float(scores[k]), tuple(float(v) for v in boxes[k]))
        for k in np.nonzero(valid)[0]
    ]


# -- the service -------------------------------------------------------------

@dataclasses.dataclass
class ServeStats:
    """Operational counters (rendered by `monitor.render_serving`)."""

    requests: int = 0  # INFER frames accepted into the batcher
    results: int = 0  # RESULT frames sent
    batches: int = 0  # jitted program launches
    occupancy_sum: int = 0  # real (non-padding) slots across launches
    status_requests: int = 0
    protocol_errors: int = 0  # malformed INFER payloads (connection dropped)
    crc_errors: int = 0

    @property
    def in_flight(self) -> int:
        """Requests accepted but not yet answered; 0 once the service is
        quiescent — the hot-swap bench's zero-dropped-requests check."""
        return self.requests - self.results

    @property
    def avg_occupancy(self) -> float:
        return self.occupancy_sum / self.batches if self.batches else 0.0

    def as_dict(self) -> dict:
        return {
            "requests": self.requests,
            "results": self.results,
            "batches": self.batches,
            "avg_occupancy": round(self.avg_occupancy, 3),
            "in_flight": self.in_flight,
            "status_requests": self.status_requests,
            "protocol_errors": self.protocol_errors,
        }


class InferenceService:
    """Socket-served batched detection over the wire framing.

    Reader threads parse INFER frames and enqueue ``(conn, request_id,
    image)``; ONE batcher thread (the only jit caller) collects up to
    ``fed.serve_batch`` requests per launch — the first request opens the
    batch, then the batcher lingers ``fed.serve_max_wait_s`` for the rest
    of the slots — zero-pads to the fixed batch, snapshots the `ModelSlot`
    once, runs the cached program, and answers each request with its
    slot's detections + the snapshot's round version + the freshness tier.
    STATUS frames are answered from the reader (they never touch the jit)
    through the same :func:`model_status` evaluator the monitor uses.

    ``latest_version``: callable returning the newest landed training
    round (e.g. ``lambda: engine.version``) — what rounds-behind is
    measured against. None means the slot's own version (a serve-only
    restore: rounds_behind 0, freshness then decays on wall time alone).
    """

    def __init__(self, cfg, fed: R.FedConfig, slot: ModelSlot, *,
                 img_size: int, host: str = "127.0.0.1", port: int = 0,
                 latest_version: Callable[[], int] | None = None,
                 max_detections: int = 0):
        if fed.serve_batch < 1:
            raise ValueError(f"serve_batch={fed.serve_batch} must be >= 1")
        self.cfg, self.fed, self.slot = cfg, fed, slot
        self.img_size = int(img_size)
        self.batch = fed.serve_batch
        self.max_wait_s = fed.serve_max_wait_s
        self.max_detections = int(max_detections) or fed.serve_max_detections
        self._latest_version = latest_version
        self._program = detection_program(cfg, self.max_detections)
        self.stats = ServeStats()
        self._stats_lock = threading.Lock()
        self._q: queue.Queue = queue.Queue()
        self._send_locks: dict[int, threading.Lock] = {}
        self._stopping = threading.Event()
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._listener.bind((host, port))
        self._listener.listen(64)
        self.host, self.port = self._listener.getsockname()[:2]
        self._threads: list[threading.Thread] = []

    # -- status (the one evaluator, called here and by the monitor) ----------

    def latest_version(self) -> int:
        if self._latest_version is not None:
            return int(self._latest_version())
        return self.slot.snapshot().version

    def status(self) -> dict:
        return model_status(
            self.slot, self.latest_version(), self.slot.now(), self.fed, self.stats
        )

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> "InferenceService":
        if self.slot.empty:
            raise RuntimeError("publish a model into the ModelSlot before start()")
        accept = threading.Thread(target=self._accept_loop, name="serve-accept",
                                  daemon=True)
        batcher = threading.Thread(target=self._batch_loop, name="serve-batcher",
                                   daemon=True)
        self._threads = [accept, batcher]
        accept.start()
        batcher.start()
        return self

    def stop(self) -> None:
        self._stopping.set()
        try:
            self._listener.close()
        except OSError:
            pass
        for t in self._threads:
            t.join(timeout=5.0)

    # -- reader side ---------------------------------------------------------

    def _accept_loop(self) -> None:
        while not self._stopping.is_set():
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._send_locks[id(sock)] = threading.Lock()
            threading.Thread(target=self._reader, args=(sock,),
                             name="serve-reader", daemon=True).start()

    def _send(self, sock: socket.socket, frame: bytes) -> None:
        lock = self._send_locks.get(id(sock))
        try:
            if lock is None:
                sock.sendall(frame)
            else:
                with lock:
                    sock.sendall(frame)
        except OSError:
            pass  # consumer gone mid-send; its requests die with the socket

    def _reader(self, sock: socket.socket) -> None:
        parser = wire.FrameParser()
        while not self._stopping.is_set():
            try:
                data = sock.recv(1 << 16)
            except OSError:
                break
            if not data:
                break
            try:
                frames = parser.feed(data)
            except ValueError:
                break  # structurally corrupt stream: drop the connection
            if parser.crc_errors:
                with self._stats_lock:
                    self.stats.crc_errors += parser.crc_errors
                break  # poisoned stream (same discipline as the WireServer)
            for ftype, payload in frames:
                if ftype == wire.INFER:
                    try:
                        rid, img = wire.parse_infer(payload)
                    except ValueError:
                        with self._stats_lock:
                            self.stats.protocol_errors += 1
                        sock.close()
                        return
                    if img.shape[:2] != (self.img_size, self.img_size):
                        # shape negotiation happens via STATUS; a wrong-size
                        # image is a protocol error, not a resize request
                        with self._stats_lock:
                            self.stats.protocol_errors += 1
                        sock.close()
                        return
                    with self._stats_lock:
                        self.stats.requests += 1
                    self._q.put((sock, rid, img))
                elif ftype == wire.STATUS:
                    with self._stats_lock:
                        self.stats.status_requests += 1
                    self._send(sock, wire.pack_status(self.status()))
                # anything else on a serving socket is ignored (the federation
                # frame types belong to the WireServer's port)

    # -- batcher (the only jit caller) ---------------------------------------

    def _batch_loop(self) -> None:
        while not self._stopping.is_set():
            try:
                first = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            items = [first]
            deadline = time.monotonic() + self.max_wait_s
            while len(items) < self.batch:
                left = deadline - time.monotonic()
                if left <= 0:
                    break
                try:
                    items.append(self._q.get(timeout=left))
                except queue.Empty:
                    break
            self._run_batch(items)

    def _run_batch(self, items: list) -> None:
        # ONE slot snapshot per batch: the whole batch — and every RESULT in
        # it — is served from a single (version, params) pair; a concurrent
        # publish simply lands in the next batch. This is the entire
        # hot-swap protocol: no lock spans the jit, no request can drop.
        pub = self.slot.snapshot()
        s = self.img_size
        imgs = np.zeros((self.batch, s, s, 3), np.float32)
        for i, (_, _, img) in enumerate(items):
            imgs[i] = img
        pred = self._program(pub.params, jnp.asarray(imgs))
        pred = jax.tree.map(np.asarray, pred)
        tier = freshness_tier(
            max(0, self.latest_version() - pub.version),
            max(0.0, self.slot.now() - pub.published_t),
            self.fed,
        )
        # Count the results BEFORE sending them: a client that has received
        # its RESULT must never observe in_flight > 0 for that request, so
        # the quiesce check (in_flight == 0 once every response arrived) is
        # race-free for any outside observer.
        with self._stats_lock:
            self.stats.batches += 1
            self.stats.occupancy_sum += len(items)
            self.stats.results += len(items)
        for i, (sock, rid, _) in enumerate(items):
            self._send(sock, wire.pack_result(
                rid, pub.version, TIER_CODES[tier], decode_result(pred, i)
            ))


# -- the consumer half -------------------------------------------------------

@dataclasses.dataclass
class ServeResult:
    """One RESULT frame, decoded."""

    request_id: int
    version: int  # the landed training round the model was published from
    tier: str  # freshness tier the server evaluated at serve time
    detections: list  # [(label, score, (x, y, w, h)), ...] score-descending


class InferenceClient:
    """One consumer connection: framed INFER/STATUS out, RESULT/STATUS in.

    `infer` is the blocking request/response form; `send_infer` +
    `recv_result` pipeline many requests over one connection (match
    responses by ``request_id`` — the batcher preserves per-connection
    order, but don't lean on it)."""

    def __init__(self, host: str, port: int, timeout: float = 60.0):
        self.sock = socket.create_connection((host, port), timeout=timeout)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._parser = wire.FrameParser()
        self._frames: list = []
        self._next_id = 0

    def _recv_frame(self):
        while not self._frames:
            data = self.sock.recv(1 << 16)
            if not data:
                raise ConnectionError("serving connection closed")
            self._frames.extend(self._parser.feed(data))
            if self._parser.crc_errors:
                raise ConnectionError("serving stream CRC-poisoned")
        return self._frames.pop(0)

    def send_infer(self, image) -> int:
        rid = self._next_id
        self._next_id += 1
        self.sock.sendall(wire.pack_infer(rid, image))
        return rid

    def recv_result(self) -> ServeResult:
        while True:
            ftype, payload = self._recv_frame()
            if ftype == wire.RESULT:
                rid, version, tier_code, dets = wire.parse_result(payload)
                return ServeResult(rid, version, TIER_NAMES[tier_code], dets)

    def infer(self, image) -> ServeResult:
        rid = self.send_infer(image)
        res = self.recv_result()
        if res.request_id != rid:
            raise ConnectionError(
                f"response {res.request_id} does not match request {rid}"
            )
        return res

    def status(self) -> dict:
        self.sock.sendall(wire.pack_status_request())
        while True:
            ftype, payload = self._recv_frame()
            if ftype == wire.STATUS:
                return wire.parse_status(payload)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass

    def __enter__(self) -> "InferenceClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
