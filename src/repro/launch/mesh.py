"""Production meshes (DESIGN.md §4).

Functions, not module-level constants: importing this module never touches
jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over real host devices (examples / smoke tests)."""
    n = data * model
    assert n <= len(jax.devices()), (n, len(jax.devices()))
    return jax.make_mesh(
        (data, model), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2
    )


# TPU v5e hardware model for the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
