"""Federated training launcher.

Runs the FedVision HFL loop (FL_SERVER + scheduler + Explorer + COS
checkpoints) for any assigned architecture at a CPU-runnable reduced size,
or emits the production-mesh launch configuration with --print-plan.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --rounds 20
  PYTHONPATH=src python -m repro.launch.train --arch granite-moe-1b-a400m \
      --agg quant8 --clients 8 --local-steps 2
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --rounds 20 \
      --participation compact --max-participants 2 --partition dirichlet
  PYTHONPATH=src python -m repro.launch.train --task detection --eval-every 1
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --rounds 20 \
      --mode async --buffer-size 2 --staleness-alpha 0.5 --max-staleness 4
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --mode async \
      --transport socket --clients 4 --buffer-size 2 --rounds 3 \
      --wire-codec quant8 --record-schedule /tmp/run.schedule.json
  PYTHONPATH=src python -m repro.launch.train --replay-schedule /tmp/run.schedule.json
  PYTHONPATH=src python -m repro.launch.train --arch grok-1-314b --print-plan

--transport socket runs a REAL multi-process federation (DESIGN.md §14):
worker processes (`repro.launch.worker`) train over TCP and the landing
loop feeds the arrival engine in wall-clock order; --rounds counts
flushes. The recorded arrival schedule replays deterministically through
the in-process SimClock engine (--replay-schedule verifies one).

--task detection runs the paper's actual workload: federated YOLOv3 over a
partitioned synthetic scene pool, with per-round global + per-client
mAP@0.5 from `server.evaluate_round` (--eval-every N) feeding the Task
Scheduler's quality EMA (DESIGN.md §10).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.configs import get_arch
from repro.core import aggregators
from repro.core.rounds import FedConfig
from repro.core import monitor
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.core.server import FLServer
from repro.data import partition
from repro.data.pipeline import detection_suite, fed_batches
from repro.launch import specs
from repro.optim import adamw, sgd


def print_plan(arch_name: str) -> None:
    for multi in (False, True):
        plan = specs.make_plan(arch_name, "train_4k", multi)
        print(f"== {plan.name}")
        print(f"   kind={plan.kind} aggregation={plan.aggregation}")
        if plan.fed:
            print(f"   clients={plan.fed.n_clients} client_axis={plan.fed.client_axis} "
                  f"data_axis={plan.fed.data_axis} microbatches={plan.fed.microbatches} topn={plan.fed.topn}")
        print(f"   rules={ {k: v for k, v in plan.rules.items() if v} }")


def _run_socket(args) -> None:
    """The --transport socket path: a real multi-process federation, then
    the wire summary + JSON (and optionally the recorded schedule)."""
    from repro.core.transport import harness

    meta = harness.make_meta(
        args.arch,
        reduced=not args.full_size,
        n_clients=args.clients,
        buffer_size=args.buffer_size,
        max_staleness=args.max_staleness,
        staleness_alpha=args.staleness_alpha,
        aggregation=args.agg if args.agg != "eq6" else "dense",
        local_steps=args.local_steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        wire_codec=args.wire_codec,
    )
    res = harness.wire_run(
        meta, args.rounds,
        durable_root=args.durable_dir or None,
        snapshot_every=args.snapshot_every,
        fault_plan=args.fault_plan,
        fault_seed=args.fault_seed,
    )
    if args.record_schedule:
        res.schedule.save(args.record_schedule)
    print(monitor.render_wire(args.arch, res.history, res.stats, args.clients,
                              liveness_log=res.liveness_log))
    stal = [s for r in res.history for s in r.staleness]
    print(json.dumps({
        "final_loss": res.history[-1].loss if res.history else float("nan"),
        "rounds": len(res.history),
        "mode": "async",
        "transport": "socket",
        "wire_codec": args.wire_codec,
        "landed": res.stats.landed,
        "dropped": res.dropped_total,
        "mean_staleness": (sum(stal) / len(stal)) if stal else 0.0,
        "bytes_up": res.stats.bytes_up,
        "bytes_down": res.stats.bytes_down,
        "deadline_hit": res.stats.deadline_hit,
        "recovered": res.recovered,
        "snapshots": res.stats.snapshots,
        "wal_events": res.stats.wal_events,
        "crc_errors": res.stats.crc_errors,
        "faults_injected": res.stats.faults_injected,
    }))


def _restore(path: str) -> None:
    """Recover an engine from a durable run directory (snapshot + WAL
    suffix through the jitted row update) and report what came back —
    the README's 'kill the server mid-round' quickstart verifier."""
    from repro.checkpoint.durable import DurableRun

    run = DurableRun(path)
    engine, replayed = run.recover_engine()
    print(json.dumps({
        "restored_from": str(path),
        "wal_events": run.n_events,
        "events_replayed": replayed,
        "version": engine.version,
        "flushes_recovered": len(engine.history),
        "staged_window": list(engine.staged()),
        "final_loss": engine.history[-1].loss if engine.history else float("nan"),
    }))


def _replay_schedule(path: str) -> None:
    """Replay a recorded arrival schedule (a CI artifact, say) through the
    SimClock engine; exits nonzero on the first divergent event."""
    from repro.core.transport import replay as rp

    schedule = rp.ArrivalSchedule.load(path)
    engine = rp.replay(schedule)
    print(json.dumps({
        "replayed_events": len(schedule.events),
        "flushes": len(engine.history),
        "final_loss": engine.history[-1].loss if engine.history else float("nan"),
        "dropped": engine.dropped_total,
        "deterministic": True,
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture name; optional with --task detection (defaults to fedyolov3)")
    ap.add_argument("--task", default="auto", choices=["auto", "lm", "detection"],
                    help="workload: lm (token batches) or detection (partitioned scene "
                    "pool + per-round mAP); auto picks detection for yolo-family archs")
    ap.add_argument("--eval-every", type=int, default=0,
                    help="detection: run server.evaluate_round every N rounds "
                    "(global + per-client mAP@0.5 into the scheduler quality EMA)")
    ap.add_argument("--img-size", type=int, default=64, help="detection scene size")
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=1)
    # any registered aggregator (fedsgd is a topology, not a CLI mode here)
    ap.add_argument("--agg", default="eq6", choices=[n for n in aggregators.names() if n != "fedsgd"])
    ap.add_argument("--server-lr", type=float, default=None,
                    help="fedavgm/fedadam server step (default: 1.0 for fedavgm, 0.02 for fedadam)")
    ap.add_argument("--group-size", type=int, default=0,
                    help="hier: clients per edge group (must divide --clients; "
                    "1 or --clients delegates to the flat base bit-for-bit)")
    ap.add_argument("--hier-base", default="dense",
                    help="hier: the stacked aggregator composed over group rows")
    ap.add_argument("--topn", type=int, default=0)
    ap.add_argument("--mode", default="sync", choices=["sync", "async"],
                    help="round control plane: sync (wait for every selected client) or "
                    "async (buffered staleness-weighted flushes on a simulated wall "
                    "clock, DESIGN.md §12)")
    ap.add_argument("--buffer-size", type=int, default=0,
                    help="async: flush after this many landed updates (0 -> clients, "
                    "which reproduces the sync round bit-for-bit)")
    ap.add_argument("--staleness-alpha", type=float, default=0.5,
                    help="async: polynomial staleness discount (1+s)^-alpha")
    ap.add_argument("--stream", action="store_true",
                    help="async: streaming O(buffer_size*N) flush — dispatch "
                    "ring + running accumulator instead of the (C, N) buffer "
                    "(forces --agg dense and a stateless sgd local optimizer)")
    ap.add_argument("--max-staleness", type=int, default=0,
                    help="async: drop updates staler than this many versions "
                    "(0 -> keep all; drops are counted, never silent)")
    ap.add_argument("--transport", default="inproc", choices=["inproc", "socket"],
                    help="inproc: simulated clients in this process; socket: real "
                    "worker processes over TCP (needs --mode async; --rounds "
                    "counts buffered flushes)")
    ap.add_argument("--wire-codec", default="dense",
                    choices=["dense", "quant8", "quant4", "topk"],
                    help="socket: UPDATE payload encoding — dense f32 rows, "
                    "int8 block-quantized deltas (the paper's ~4x uplink cut), "
                    "4-bit nibble-packed deltas (~8x), or sparse top-k deltas "
                    "(~18x; see transport/codec.py)")
    ap.add_argument("--record-schedule", default="",
                    help="socket: write the recorded arrival schedule (JSON) here")
    ap.add_argument("--replay-schedule", default="",
                    help="replay a recorded arrival schedule through the SimClock "
                    "engine and exit (no --arch needed; verifies determinism)")
    ap.add_argument("--durable-dir", default="",
                    help="socket: durable run directory (landing WAL + engine "
                    "snapshots; the server becomes kill -9 survivable)")
    ap.add_argument("--snapshot-every", type=int, default=0,
                    help="socket: full-engine snapshot every N landings "
                    "(0 = WAL only; needs --durable-dir)")
    ap.add_argument("--fault-plan", default="",
                    help="socket: deterministic fault injection spec "
                    "(transport/faults.py grammar, e.g. "
                    "'client.corrupt@2:update;kill@6'); with --durable-dir a "
                    "kill@M recovers automatically from snapshot+WAL")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="socket: seed for the fault plan's deterministic choices")
    ap.add_argument("--restore", default="",
                    help="recover an engine from a --durable-dir directory and "
                    "print the recovery report (no --arch needed; exits)")
    ap.add_argument("--participation", default="full", choices=["full", "masked", "compact"],
                    help="round body: full (everyone trains), masked (cond-gated), "
                    "compact (static-K gather; see --max-participants)")
    ap.add_argument("--max-participants", type=int, default=0,
                    help="scheduler budget per round (0 -> clients//2, min 2; "
                    "compact mode uses this as the static K)")
    ap.add_argument("--fairness-rounds", type=int, default=4,
                    help="force-include clients idle this many rounds")
    ap.add_argument("--partition", default="stream",
                    choices=["stream", *partition.SCENARIOS],
                    help="client data split: stream (per-client Markov drift) or a "
                    "data.partition scenario over a labeled pool (text archs)")
    ap.add_argument("--alpha", type=float, default=0.5, help="dirichlet label-skew concentration")
    ap.add_argument("--topk-frac", type=float, default=0.1,
                    help="topk_ef: upload fraction k/N of the packed row")
    ap.add_argument("--topk-quant", default="none", choices=["none", "quant4"],
                    help="topk_ef: quantize the selected values (composes the "
                    "sparsifier with the 4-bit codec)")
    ap.add_argument("--quant4-mode", default="stochastic",
                    choices=["stochastic", "nearest", "skip"],
                    help="quant4 aggregator rounding (skip -> dense bit-for-bit)")
    ap.add_argument("--quant4-seed", type=int, default=0,
                    help="quant4/topk_ef: per-round stochastic-rounding key seed")
    ap.add_argument("--secure-domain", default="int8", choices=["int8", "int4"],
                    help="secure: integer domain the masked sums run in")
    ap.add_argument("--no-secure-mask", action="store_true",
                    help="secure: skip the pairwise masks (the cancellation "
                    "equivalence baseline; quantized sum only)")
    ap.add_argument("--secure-session", type=int, default=0,
                    help="secure: session key the per-round pair masks derive from")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw", choices=["adamw", "sgd"])
    ap.add_argument("--full-size", action="store_true", help="use the full (non-reduced) config")
    ap.add_argument("--store", default="", help="COS object-store directory")
    ap.add_argument("--print-plan", action="store_true")
    args = ap.parse_args()

    if args.replay_schedule:
        _replay_schedule(args.replay_schedule)
        return
    if args.restore:
        _restore(args.restore)
        return
    if args.snapshot_every and not args.durable_dir:
        ap.error("--snapshot-every needs --durable-dir")
    if (args.durable_dir or args.fault_plan) and args.transport != "socket":
        ap.error("--durable-dir/--fault-plan belong to --transport socket")
    if args.transport == "socket":
        if args.mode != "async":
            ap.error("--transport socket is the async control plane over a real "
                     "wire; pass --mode async")
        if args.stream or args.task == "detection":
            ap.error("--transport socket runs the buffered arrival engine "
                     "(lm workload, no --stream)")
        if args.arch is None:
            ap.error("--arch is required")
        _run_socket(args)
        return

    if args.task == "detection" and args.arch is None:
        args.arch = "fedyolov3"  # the paper's own model
    if args.arch is None:
        ap.error("--arch is required (or pass --task detection)")
    if args.print_plan:
        print_plan(args.arch)
        return

    cfg = get_arch(args.arch)
    task = args.task
    if task == "auto":
        task = "detection" if cfg.family == "yolo" else "lm"
    if task == "detection" and cfg.family != "yolo":
        ap.error(f"--task detection needs a yolo-family arch (got {args.arch})")
    if not args.full_size:
        cfg = cfg.reduced()
    if args.mode == "async" and args.participation != "full":
        ap.error("--mode async owns its own participation plane (the event queue); "
                 "drop --participation")
    if args.agg != "hier" and (args.group_size or args.hier_base != "dense"):
        ap.error("--group-size/--hier-base configure the hierarchical "
                 "aggregator; pass --agg hier")
    if args.stream:
        if args.mode != "async":
            ap.error("--stream is an async flush discipline; pass --mode async")
        if args.agg not in ("dense", "eq6"):  # eq6 is the default; coerce it
            ap.error("--stream folds aggregation into a running sum; only "
                     "--agg dense streams")
        args.agg = "dense"
        args.optimizer = "sgd"
        if args.max_staleness < 1:
            args.max_staleness = 4  # the dispatch ring needs a bound
    budget = args.max_participants or max(2, args.clients // 2)
    fed = FedConfig(
        n_clients=args.clients,
        local_steps=args.local_steps,
        aggregation=args.agg,
        topn=args.topn or specs.default_topn(cfg),
        client_axis="data",
        data_axis=None,
        # adaptive server step is ~server_lr per coordinate: fedadam needs a
        # small one out of the box (see core/aggregators/server_opt.py)
        server_lr=args.server_lr if args.server_lr is not None else (0.02 if args.agg == "fedadam" else 1.0),
        participation=args.participation,
        max_participants=budget if args.participation == "compact" else 0,
        mode=args.mode,
        buffer_size=args.buffer_size,
        staleness_alpha=args.staleness_alpha,
        max_staleness=args.max_staleness,
        stream=args.stream,
        group_size=args.group_size,
        hier_base=args.hier_base,
        topk_frac=args.topk_frac,
        topk_quant=args.topk_quant,
        quant4_mode=args.quant4_mode,
        quant4_seed=args.quant4_seed,
        secure_domain=args.secure_domain,
        secure_mask=not args.no_secure_mask,
        secure_session=args.secure_session,
    )
    if args.stream:
        optimizer = sgd(args.lr, momentum=0.0)  # stateless: the ring keeps no opt rows
    elif args.optimizer == "adamw":
        optimizer = adamw(args.lr)
    else:
        optimizer = sgd(args.lr)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    store = ObjectStore(args.store) if args.store else None
    with jax.set_mesh(mesh):
        server = FLServer(
            cfg,
            fed,
            optimizer,
            store=store,
            scheduler=TaskScheduler(fed.n_clients, SchedulerConfig(
                max_participants=budget, fairness_rounds=args.fairness_rounds)),
            mesh=mesh,
            checkpoint_every=5 if store else 0,
            task_id=args.arch,
        )
        eval_batch = None
        if task == "detection":
            # "stream" has no meaning for the pooled detection suite: the
            # IID split is the control scenario
            scenario = "iid" if args.partition == "stream" else args.partition
            gen, eval_batch, _ = detection_suite(
                cfg, fed, batch=args.batch, img_size=args.img_size,
                scenario=scenario, alpha=args.alpha,
            )
            batches = (jax.tree.map(jnp.asarray, b) for b in gen)
        else:
            batches = (
                jax.tree.map(jnp.asarray, b)
                for b in fed_batches(cfg, fed, batch=args.batch, seq=args.seq,
                                     partition_name=args.partition, alpha=args.alpha)
            )
        if eval_batch is not None and args.eval_every:
            step = server.run_async if server.engine is not None else server.run_round
            for r in range(args.rounds):
                rec = step(next(batches))
                if r % args.eval_every == 0 or r == args.rounds - 1:
                    ev = server.evaluate_round(eval_batch)
                    per = " ".join(f"{m:.3f}" for m in ev.per_client_map)
                    print(f"round {rec.round_idx:4d}  loss {rec.loss:.4f}  "
                          f"mAP@0.5 {ev.map50:.3f}  per-client [{per}]", flush=True)
            history = server.history
        else:
            history = server.fit(batches, args.rounds)
    mean_participants = sum(len(r.participants) for r in history) / len(history)
    summary = {
        "final_loss": history[-1].loss,
        "rounds": len(history),
        "participation": args.participation,
        "mean_participants": mean_participants,
    }
    if args.mode == "async":
        stal = [s for r in history for s in r.staleness]
        summary.update(
            mode="async",
            sim_seconds=history[-1].sim_time,
            mean_staleness=(sum(stal) / len(stal)) if stal else 0.0,
            dropped=server.engine.dropped_total,
        )
    if server.eval_history:
        print(monitor.render_task(args.arch, history, fed.n_clients,
                                  eval_history=server.eval_history))
        summary["final_map"] = server.eval_history[-1].map50
        summary["per_client_map"] = server.eval_history[-1].per_client_map
    print(json.dumps(summary))


if __name__ == "__main__":
    main()
