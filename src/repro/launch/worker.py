"""A federated client worker process (DESIGN.md §14).

``python -m repro.launch.worker --host H --port P --meta meta.json
--client-ids 0,1`` connects each client id to a `WireServer` over TCP and
runs the dispatch/train/upload loop:

    HELLO(c) -> [DISPATCH(version, row) -> train -> UPDATE(c, seq, version, loss)]* -> BYE

The UPDATE echoes the DISPATCH version it trained against: a reconnect can
leave two processes holding dispatches for one client id, and the server
uses the echo to refuse an update trained on a row its engine has already
moved past (superseded dispatch).

Training goes through `async_engine.build_row_update` — the SAME jitted
single-row program the SimClock replay uses — on batches derived from
(seed, client, seq) via `transport.synth_client_batch`. Nothing about the
data crosses the wire; ``seq`` (the client-local update counter) rides the
UPDATE frame so the replayer indexes the same batch. One process can host
several clients as threads sharing the one jitted update (amortizing the
JAX import), while fault-scenario clients run alone so crashing or
delaying them is isolated.

Scenario hooks: ``--train-delay`` sleeps before each upload (a straggler;
with a small ``max_staleness`` its updates arrive stale and get dropped),
``--crash-after N`` hard-kills the process (``os._exit``) after N uploads
(mid-round crash), ``--max-updates N`` exits each client loop cleanly.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import sys
import threading
import time

import numpy as np

CRASH_EXIT_CODE = 17


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description="FedVision wire worker")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, required=True)
    p.add_argument("--meta", required=True, help="path to the run-meta JSON")
    p.add_argument("--client-ids", required=True, help="comma-separated client ids")
    p.add_argument("--train-delay", type=float, default=0.0,
                   help="seconds to sleep before each upload (straggler)")
    p.add_argument("--crash-after", type=int, default=0,
                   help="os._exit after this many uploads across the process")
    p.add_argument("--max-updates", type=int, default=0,
                   help="per-client clean exit after this many uploads")
    p.add_argument("--heartbeat-s", type=float, default=0.0,
                   help="override the meta heartbeat period (0 = use meta)")
    return p.parse_args(argv)


class _Conn:
    """One client's socket: framed sends under a lock (the heartbeat thread
    and the training loop both write) and a blocking framed-receive."""

    def __init__(self, host: str, port: int, client: int, wire):
        self.wire = wire
        self.client = client
        self.sock = socket.create_connection((host, port), timeout=60.0)
        self.sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self._parser = wire.FrameParser()
        self._send_lock = threading.Lock()
        self._frames: list = []

    def send(self, frame: bytes) -> None:
        with self._send_lock:
            self.sock.sendall(frame)

    def recv_frame(self):
        """Next (ftype, payload), or None on EOF."""
        while not self._frames:
            data = self.sock.recv(1 << 16)
            if not data:
                return None
            self._frames.extend(self._parser.feed(data))
        return self._frames.pop(0)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def _heartbeat_loop(conn: "_Conn", period: float, stop: threading.Event) -> None:
    wire = conn.wire
    while not stop.wait(period):
        try:
            conn.send(wire.pack_heartbeat(conn.client))
        except OSError:
            return


def run_client(client: int, args, meta: dict, cfg, update, crash_budget) -> None:
    """One client's dispatch/train/upload loop (runs in its own thread)."""
    from repro.core.transport import codec, replay, wire

    import jax.numpy as jnp

    wire_codec = meta.get("wire_codec", "dense")
    block = int(meta.get("quant_block", 1024))
    hb = args.heartbeat_s or float(meta.get("heartbeat_s", 0.2))
    conn = _Conn(args.host, args.port, client, wire)
    stop = threading.Event()
    try:
        conn.send(wire.pack_hello(client))
        threading.Thread(
            target=_heartbeat_loop, args=(conn, hb, stop),
            name=f"hb-{client}", daemon=True,
        ).start()
        seq = 0
        while True:
            got = conn.recv_frame()
            if got is None:
                return
            ftype, payload = got
            if ftype == wire.BYE:
                return
            if ftype != wire.DISPATCH:
                continue
            version, row_buf = wire.parse_dispatch(payload)
            base = codec.decode_row(row_buf).astype(np.float32)
            batch = replay.synth_client_batch(cfg, meta, client, seq)
            trained, loss = update(jnp.asarray(base), batch)
            trained = np.asarray(trained, np.float32)
            if args.train_delay:
                time.sleep(args.train_delay)
            buf = codec.encode_update(trained, base, wire_codec, block)
            conn.send(wire.pack_update(client, seq, version, float(loss), buf))
            seq += 1
            if crash_budget is not None and crash_budget.hit():
                os._exit(CRASH_EXIT_CODE)  # mid-round crash: no BYE, no cleanup
            if args.max_updates and seq >= args.max_updates:
                return
    except OSError:
        return  # server gone; the process exit path below cleans up
    finally:
        stop.set()
        try:
            conn.send(wire.pack_bye())
        except OSError:
            pass
        conn.close()


class _CrashBudget:
    """Process-wide upload countdown shared by this worker's clients."""

    def __init__(self, n: int):
        self._left = n
        self._lock = threading.Lock()

    def hit(self) -> bool:
        with self._lock:
            self._left -= 1
            return self._left <= 0


def main(argv=None) -> int:
    args = _parse_args(argv)
    meta = json.loads(open(args.meta).read())
    clients = [int(c) for c in args.client_ids.split(",") if c != ""]
    if not clients:
        raise SystemExit("--client-ids is empty")

    # one jit shared by every client thread in this process
    from repro.core.transport import replay

    cfg = replay.build_cfg(meta)
    fed = replay.build_fed(meta)
    opt = replay.build_optimizer(meta)
    from repro.core.async_engine import build_row_update

    update = build_row_update(cfg, fed, opt)
    crash = _CrashBudget(args.crash_after) if args.crash_after else None

    threads = [
        threading.Thread(
            target=run_client, args=(c, args, meta, cfg, update, crash),
            name=f"client-{c}",
        )
        for c in clients
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return 0


if __name__ == "__main__":
    sys.exit(main())
