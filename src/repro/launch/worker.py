"""A federated client worker process (DESIGN.md §14, resilience §16).

``python -m repro.launch.worker --host H --port P --meta meta.json
--client-ids 0,1`` connects each client id to a `WireServer` over TCP and
runs the dispatch/train/upload loop:

    HELLO(c) -> [DISPATCH(version, row) -> train -> UPDATE(c, seq, version, loss)]* -> BYE

The UPDATE echoes the DISPATCH version it trained against: a reconnect can
leave two processes holding dispatches for one client id, and the server
uses the echo to refuse an update trained on a row its engine has already
moved past (superseded dispatch).

Training goes through `async_engine.build_row_update` — the SAME jitted
single-row program the SimClock replay uses — on batches derived from
(seed, client, seq) via `transport.synth_client_batch`. Nothing about the
data crosses the wire; ``seq`` (the client-local update counter) rides the
UPDATE frame so the replayer indexes the same batch. One process can host
several clients as threads sharing the one jitted update (amortizing the
JAX import), while fault-scenario clients run alone so crashing or
delaying them is isolated.

Resilience (DESIGN.md §16): every connect goes through
`transport.retry.connect_with_retry` — exponential backoff with
deterministic per-client jitter, bounded attempts — so a worker that races
the server's bind, or outlives a server crash, retries instead of dying.
The client loop is a *session* loop: any connection death (EOF, reset, a
CRC-poisoned stream, a dispatch that never arrives within
``--dispatch-timeout``) tears down the session and reconnects; ``seq``
survives sessions so the batch sequence stays deterministic, and the
server's version-echo gate squares away whatever was in flight.

Scenario hooks: ``--train-delay`` sleeps before each upload (a straggler;
with a small ``max_staleness`` its updates arrive stale and get dropped),
``--crash-after N`` hard-kills the process (``os._exit``) after N uploads
(mid-round crash), ``--max-updates N`` exits each client loop cleanly,
``--fault-plan SPEC`` installs a client-side `transport.faults.FaultPlan`
on every connection (corrupt/drop/dup/delay/sever this worker's outbound
frames, deterministically).
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import sys
import threading
import time

import numpy as np

CRASH_EXIT_CODE = 17
RECONNECT, DONE = "reconnect", "done"


def _parse_args(argv=None):
    p = argparse.ArgumentParser(description="FedVision wire worker")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, required=True)
    p.add_argument("--meta", required=True, help="path to the run-meta JSON")
    p.add_argument("--client-ids", required=True, help="comma-separated client ids")
    p.add_argument("--train-delay", type=float, default=0.0,
                   help="seconds to sleep before each upload (straggler)")
    p.add_argument("--crash-after", type=int, default=0,
                   help="os._exit after this many uploads across the process")
    p.add_argument("--max-updates", type=int, default=0,
                   help="per-client clean exit after this many uploads")
    p.add_argument("--heartbeat-s", type=float, default=0.0,
                   help="override the meta heartbeat period (0 = use meta)")
    p.add_argument("--connect-retries", type=int, default=10,
                   help="bounded connect attempts per session (retry.Backoff)")
    p.add_argument("--backoff-base", type=float, default=0.05,
                   help="first backoff delay, doubling per attempt")
    p.add_argument("--backoff-max", type=float, default=2.0,
                   help="per-delay cap on the backoff schedule")
    p.add_argument("--dispatch-timeout", type=float, default=15.0,
                   help="seconds to wait for a frame before reconnecting "
                        "(covers a dropped dispatch or update)")
    p.add_argument("--max-sessions", type=int, default=50,
                   help="bound on reconnect sessions per client (safety net)")
    p.add_argument("--fault-plan", default="",
                   help="client-side faults.FaultPlan spec (e.g. "
                        "'corrupt@2:update;sever@5000')")
    p.add_argument("--fault-seed", type=int, default=0,
                   help="seed for the fault plan's deterministic choices")
    return p.parse_args(argv)


class _Conn:
    """One client's socket for one session: framed sends under a lock (the
    heartbeat thread and the training loop both write), a framed-receive
    with the dispatch timeout, and the CRC-poisoned-stream check."""

    def __init__(self, host: str, port: int, client: int, wire, args, plan=None):
        from repro.core.transport.retry import Backoff, connect_with_retry

        self.wire = wire
        self.client = client
        self.sock = connect_with_retry(
            host, port,
            Backoff(base=args.backoff_base, cap=args.backoff_max,
                    attempts=args.connect_retries, seed=client),
            timeout=10.0,
        )
        self.sock.settimeout(args.dispatch_timeout)
        if plan is not None:
            self.sock = plan.wrap(self.sock, side="client")
        self._parser = wire.FrameParser()
        self._send_lock = threading.Lock()
        self._frames: list = []

    def send(self, frame: bytes) -> None:
        with self._send_lock:
            self.sock.sendall(frame)

    def recv_frame(self):
        """Next (ftype, payload); None on EOF or a CRC-poisoned stream."""
        while not self._frames:
            data = self.sock.recv(1 << 16)
            if not data:
                return None
            self._frames.extend(self._parser.feed(data))
            if self._parser.crc_errors:
                # the server's bytes arrived damaged: treat the whole
                # connection as poisoned and resync via reconnect
                return None
        return self._frames.pop(0)

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:
            pass


def _heartbeat_loop(conn: "_Conn", period: float, stop: threading.Event) -> None:
    wire = conn.wire
    while not stop.wait(period):
        try:
            conn.send(wire.pack_heartbeat(conn.client))
        except OSError:
            return


def _session(client: int, args, meta: dict, cfg, update, crash_budget,
             seq: int, plan) -> tuple[str, int]:
    """One connection's dispatch/train/upload loop. Returns (outcome, seq):
    DONE on BYE/--max-updates, RECONNECT on any connection death — the
    caller re-enters with the preserved ``seq`` so the batch sequence
    (and with it the replay) is untouched by how many sessions it took."""
    from repro.core.transport import codec, replay, wire

    import jax.numpy as jnp

    wire_codec = meta.get("wire_codec", "dense")
    block = int(meta.get("quant_block", 1024))
    hb = args.heartbeat_s or float(meta.get("heartbeat_s", 0.2))
    conn = _Conn(args.host, args.port, client, wire, args, plan)
    stop = threading.Event()
    try:
        conn.send(wire.pack_hello(client))
        threading.Thread(
            target=_heartbeat_loop, args=(conn, hb, stop),
            name=f"hb-{client}", daemon=True,
        ).start()
        while True:
            try:
                got = conn.recv_frame()
            except socket.timeout:
                return RECONNECT, seq  # dispatch lost in flight: resync
            if got is None:
                return RECONNECT, seq  # server gone or stream poisoned
            ftype, payload = got
            if ftype == wire.BYE:
                return DONE, seq
            if ftype != wire.DISPATCH:
                continue
            version, row_buf = wire.parse_dispatch(payload)
            base = codec.decode_row(row_buf).astype(np.float32)
            batch = replay.synth_client_batch(cfg, meta, client, seq)
            trained, loss = update(jnp.asarray(base), batch)
            trained = np.asarray(trained, np.float32)
            if args.train_delay:
                time.sleep(args.train_delay)
            buf = codec.encode_update(trained, base, wire_codec, block)
            conn.send(wire.pack_update(client, seq, version, float(loss), buf))
            seq += 1
            if crash_budget is not None and crash_budget.hit():
                os._exit(CRASH_EXIT_CODE)  # mid-round crash: no BYE, no cleanup
            if args.max_updates and seq >= args.max_updates:
                try:
                    conn.send(wire.pack_bye())  # orderly exit, best effort
                except OSError:
                    pass
                return DONE, seq
    except OSError:
        return RECONNECT, seq  # reset/sever mid-send: next session resyncs
    finally:
        stop.set()
        conn.close()


def run_client(client: int, args, meta: dict, cfg, update, crash_budget,
               plan=None) -> None:
    """One client's session loop (runs in its own thread): reconnect —
    through the bounded backoff — until the work is DONE or the retry
    budget/session bound runs out."""
    from repro.core.transport.retry import RetriesExhausted

    seq = 0
    for _ in range(max(args.max_sessions, 1)):
        try:
            outcome, seq = _session(client, args, meta, cfg, update,
                                    crash_budget, seq, plan)
        except RetriesExhausted:
            return  # the server never came back within the backoff budget
        if outcome == DONE:
            return


class _CrashBudget:
    """Process-wide upload countdown shared by this worker's clients."""

    def __init__(self, n: int):
        self._left = n
        self._lock = threading.Lock()

    def hit(self) -> bool:
        with self._lock:
            self._left -= 1
            return self._left <= 0


def main(argv=None) -> int:
    args = _parse_args(argv)
    meta = json.loads(open(args.meta).read())
    clients = [int(c) for c in args.client_ids.split(",") if c != ""]
    if not clients:
        raise SystemExit("--client-ids is empty")

    # one jit shared by every client thread in this process
    from repro.core.transport import replay

    cfg = replay.build_cfg(meta)
    fed = replay.build_fed(meta)
    opt = replay.build_optimizer(meta)
    from repro.core.async_engine import build_row_update

    update = build_row_update(cfg, fed, opt)
    crash = _CrashBudget(args.crash_after) if args.crash_after else None
    plan = None
    if args.fault_plan:
        from repro.core.transport.faults import FaultPlan

        # one plan per process: counters persist across this worker's
        # reconnects, so 'drop@1:update' fires once, not once per session
        plan = FaultPlan.parse(args.fault_plan, seed=args.fault_seed)

    threads = [
        threading.Thread(
            target=run_client, args=(c, args, meta, cfg, update, crash, plan),
            name=f"client-{c}",
        )
        for c in clients
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return 0


if __name__ == "__main__":
    sys.exit(main())
