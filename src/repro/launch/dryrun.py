import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, lowers the plan's step
function with abstract inputs (no allocation), compiles, and records
memory_analysis / cost_analysis / trip-count-aware HLO costs / the
collective table into experiments/dryrun/<name>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--agg eq6] [--tag base]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ASSIGNED, SHAPES, shape_applicable
from repro.launch import hlo_analysis, roofline, specs
from repro.launch.mesh import make_production_mesh

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def run_one(arch_name: str, shape_name: str, multi_pod: bool, aggregation: str = "eq6", local_steps: int = 1, tag: str = "", variant: str = "") -> dict:
    plan = specs.make_plan(arch_name, shape_name, multi_pod, aggregation, local_steps, variant)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size
    fn = specs.step_fn(plan, mesh, variant)
    args, pspecs_ = specs.input_specs(plan)
    shardings = specs.to_shardings(mesh, pspecs_)
    donate = (0,) if plan.kind in ("train", "fedsgd") else ()
    t0 = time.time()
    with jax.set_mesh(mesh):
        lowered = jax.jit(fn, in_shardings=shardings, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    costs = hlo_analysis.analyze(hlo, pod_boundary=256 if multi_pod else 0)
    rl = roofline.terms(
        costs.flops, costs.traffic, dict(costs.coll_bytes), n_dev, plan.arch,
        plan.shape, local_steps, dict(costs.cross_pod_bytes)
    )
    rec = {
        "name": plan.name + (f"--{tag}" if tag else ""),
        "arch": arch_name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_dev,
        "kind": plan.kind,
        "aggregation": plan.aggregation,
        "variant": variant,
        "local_steps": local_steps,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "total_per_device": ma.argument_size_in_bytes + ma.output_size_in_bytes + ma.temp_size_in_bytes - ma.alias_size_in_bytes,
        },
        "xla_cost_analysis": {"flops": ca.get("flops"), "bytes_accessed": ca.get("bytes accessed")},
        "hlo_costs": {
            "flops_per_device": costs.flops,
            "traffic_bytes_per_device": costs.traffic,
            "collective_bytes": dict(costs.coll_bytes),
            "collective_ops": dict(costs.coll_ops),
            "cross_pod_bytes": dict(costs.cross_pod_bytes),
        },
        "roofline": rl.as_dict(),
        "hlo_chars": len(hlo),
    }
    return rec


def matrix(mesh_sel: str):
    for arch in ASSIGNED:
        for shape in SHAPES.values():
            ok, why = shape_applicable(arch, shape)
            for multi in ([False, True] if mesh_sel == "both" else [mesh_sel == "multi"]):
                yield arch.name, shape.name, multi, ok, why


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--agg", default="eq6")
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--tag", default="")
    ap.add_argument("--variant", default="")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    combos = []
    if args.all:
        combos = list(matrix(args.mesh))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        for multi in [False, True] if args.mesh == "both" else [args.mesh == "multi"]:
            from repro.configs import get_arch, get_shape

            arch_v = specs.variant_arch(get_arch(args.arch), args.variant)
            ok, why = shape_applicable(arch_v, get_shape(args.shape))
            combos.append((args.arch, args.shape, multi, ok, why))

    failures = 0
    for arch, shape, multi, ok, why in combos:
        mesh_name = "multipod" if multi else "singlepod"
        stem = f"{arch}--{shape}--{mesh_name}" + (f"--{args.tag}" if args.tag else "")
        path = out_dir / f"{stem}.json"
        if path.exists() and not args.force:
            print(f"SKIP (cached) {stem}")
            continue
        if not ok:
            path.write_text(json.dumps({"name": stem, "arch": arch, "shape": shape, "mesh": mesh_name, "skipped": why}, indent=1))
            print(f"SKIP (n/a)    {stem}: {why}")
            continue
        print(f"RUN           {stem} ...", flush=True)
        try:
            rec = run_one(arch, shape, multi, args.agg, args.local_steps, args.tag, args.variant)
        except Exception as e:  # noqa: BLE001
            failures += 1
            path.write_text(json.dumps({"name": stem, "error": str(e), "traceback": traceback.format_exc()}, indent=1))
            print(f"FAIL          {stem}: {e}")
            continue
        path.write_text(json.dumps(rec, indent=1))
        r = rec["roofline"]
        print(
            f"OK            {stem}  compile={rec['compile_s']}s  "
            f"mem/dev={rec['memory']['total_per_device']/2**30:.2f}GiB  "
            f"terms(c/m/x)=({r['compute_s']:.2e},{r['memory_s']:.2e},{r['collective_s']:.2e})s  dom={r['dominant']}",
            flush=True,
        )
    if failures:
        raise SystemExit(f"{failures} dry-run failures")


if __name__ == "__main__":
    main()
