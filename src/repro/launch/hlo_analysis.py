"""Trip-count-aware HLO cost analyzer for the roofline.

XLA's built-in `compiled.cost_analysis()` counts a `while` body ONCE, which
undercounts scan-over-layers models by the layer count (measured in
/tmp/spike_cost.py: 8-layer scan reported 1 layer of FLOPs). This module
parses `compiled.as_text()` and walks the computation graph with while
trip-count multipliers (`backend_config={"known_trip_count":{"n":...}}`).

Cost model (documented in EXPERIMENTS.md §Roofline):
- FLOPs: 2 * prod(result_shape) * prod(lhs contracting dims) per dot;
  convolutions 2 * prod(result) * (kh*kw*cin); elementwise ignored (<2%).
- HBM traffic: fusion-boundary model — every top-level op in a computation
  is one kernel moving (operands + result) bytes; fusions are opaque;
  dynamic-slice counts result*2, dynamic-update-slice update*2, broadcast
  result only; bookkeeping ops free.
- Collectives: result-shape bytes per kind; the roofline applies a ring
  factor (all-reduce 2x) and divides by per-link ICI bandwidth.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "ragged-all-to-all", "collective-broadcast",
)

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([^\s=]+)\s*=\s*(.*?)\s+([a-z][a-z0-9-]*)\((.*)$"
)
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([^\s,)]+)")
_BODY_RE = re.compile(r"body=%([^\s,)]+)")
_COND_RE = re.compile(r"condition=%([^\s,)]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERAND_RE = re.compile(r"%([^\s,()]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_WINDOW_RE = re.compile(r"window=\{size=([0-9x]+)")
_GROUPS_EXPL_RE = re.compile(r"replica_groups=\{(\{[0-9,{}]*\})\}")
_GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?"
)


def _group_ranges(rest: str):
    """Parse replica_groups -> list of (min_id, max_id) per group, or None."""
    m = _GROUPS_IOTA_RE.search(rest)
    if m:
        import numpy as np

        g, s = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        arr = np.arange(int(np.prod(dims))).reshape(dims)
        if m.group(4):
            arr = arr.transpose([int(x) for x in m.group(4).split(",")])
        arr = arr.reshape(g, s)
        return list(zip(arr.min(axis=1).tolist(), arr.max(axis=1).tolist()))
    m = _GROUPS_EXPL_RE.search(rest)
    if m:
        groups = re.findall(r"\{([0-9,]+)\}", m.group(0))
        out = []
        for grp in groups:
            ids = [int(x) for x in grp.split(",")]
            out.append((min(ids), max(ids)))
        return out
    return None


def crosses_boundary(rest: str, boundary: int) -> bool:
    """True if any replica group spans the pod boundary (id < b and >= b)."""
    ranges = _group_ranges(rest)
    if not ranges:
        return False
    return any(lo < boundary <= hi for lo, hi in ranges)


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    result: str  # result type text
    opcode: str
    rest: str  # operand list + attributes


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    traffic: float = 0.0  # HBM bytes
    coll_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))
    coll_ops: dict = dataclasses.field(default_factory=lambda: defaultdict(int))
    cross_pod_bytes: dict = dataclasses.field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] += v * mult
        for k, v in other.coll_ops.items():
            self.coll_ops[k] += int(v * mult)
        for k, v in other.cross_pod_bytes.items():
            self.cross_pod_bytes[k] += v * mult


_FREE_OPS = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "rng-bit-generator",
    "opt-barrier", "custom-call", "reshape",
}


def parse_computations(hlo_text: str) -> dict[str, list[Instr]]:
    comps: dict[str, list[Instr]] = {}
    current: list[Instr] | None = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(?:ENTRY\s+)?%?([^\s(]+)\s*\(.*\)\s*->.*\{$", stripped)
        if header and not stripped.startswith("%new") and "=" not in stripped.split("(")[0]:
            current = comps.setdefault(header.group(1), [])
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _INSTR_RE.match(line)
        if m:
            current.append(Instr(m.group(1), m.group(2), m.group(3), m.group(4)))
    return comps


def _dot_flops(instr: Instr, symtab: dict[str, str]) -> float:
    out = 1
    for d in _shape_dims(instr.result):
        out *= d
    m = _LHS_CONTRACT_RE.search(instr.rest)
    contract = [int(x) for x in m.group(1).split(",") if x] if m else []
    # first operand = lhs
    operands = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
    k = 1
    if operands:
        lhs_dims = _shape_dims(symtab.get(operands[0], ""))
        for c in contract:
            if c < len(lhs_dims):
                k *= lhs_dims[c]
    return 2.0 * out * max(k, 1)


def _conv_flops(instr: Instr, symtab: dict[str, str]) -> float:
    out = 1
    for d in _shape_dims(instr.result):
        out *= d
    m = _WINDOW_RE.search(instr.rest)
    k = 1
    if m:
        for d in m.group(1).split("x"):
            k *= int(d)
    operands = _OPERAND_RE.findall(instr.rest.split(")", 1)[0])
    cin = 1
    if len(operands) > 1:
        rhs_dims = _shape_dims(symtab.get(operands[1], ""))
        if len(rhs_dims) >= 2:
            cin = rhs_dims[-2]  # HWIO input-feature dim
    return 2.0 * out * k * cin


def analyze(hlo_text: str, entry: str | None = None, pod_boundary: int = 0) -> Costs:
    """pod_boundary > 0 additionally classifies collectives whose replica
    groups span device ids across the boundary (= cross-pod/DCN traffic)."""
    comps = parse_computations(hlo_text)
    if not comps:
        return Costs()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([^\s(]+)", hlo_text, re.M)
        entry = m.group(1) if m else next(iter(comps))
    memo: dict[str, Costs] = {}

    def comp_cost(name: str) -> Costs:
        if name in memo:
            return memo[name]
        memo[name] = Costs()  # break cycles defensively
        instrs = comps.get(name, [])
        symtab = {i.name: i.result for i in instrs}
        total = Costs()
        for ins in instrs:
            op = ins.opcode
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                b = _shape_bytes(ins.result)
                total.coll_bytes[base] += b
                total.coll_ops[base] += 1
                total.traffic += b + _operand_bytes(ins, symtab)
                if pod_boundary and crosses_boundary(ins.rest, pod_boundary):
                    total.cross_pod_bytes[base] += b
                continue
            if op == "while":
                trips = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trips = int(tm.group(1))
                bm = _BODY_RE.search(ins.rest)
                cm = _COND_RE.search(ins.rest)
                if bm:
                    total.add(comp_cost(bm.group(1)), trips)
                if cm:
                    total.add(comp_cost(cm.group(1)), trips)
                continue
            if op == "conditional":
                bm = _BRANCHES_RE.search(ins.rest)
                if bm:
                    branches = _OPERAND_RE.findall(bm.group(1))
                    costs = [comp_cost(b) for b in branches]
                    if costs:
                        worst = max(costs, key=lambda c: c.flops + c.traffic)
                        total.add(worst)
                continue
            if op in ("call", "async-start"):
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    total.add(comp_cost(cm.group(1)))
                continue
            if op == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    inner = comp_cost(cm.group(1))
                    # fusion is one kernel: flops/collectives from inside,
                    # traffic from the boundary
                    total.flops += inner.flops
                    for k, v in inner.coll_bytes.items():
                        total.coll_bytes[k] += v
                    for k, v in inner.coll_ops.items():
                        total.coll_ops[k] += v
                total.traffic += _shape_bytes(ins.result) + _operand_bytes(ins, symtab)
                continue
            if op == "dot":
                total.flops += _dot_flops(ins, symtab)
                total.traffic += _shape_bytes(ins.result) + _operand_bytes(ins, symtab)
                continue
            if op == "convolution":
                total.flops += _conv_flops(ins, symtab)
                total.traffic += _shape_bytes(ins.result) + _operand_bytes(ins, symtab)
                continue
            if op in _FREE_OPS:
                continue
            if op == "dynamic-slice":
                total.traffic += 2 * _shape_bytes(ins.result)
                continue
            if op == "dynamic-update-slice":
                ops_ = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
                upd = _shape_bytes(symtab.get(ops_[1], "")) if len(ops_) > 1 else 0
                total.traffic += 2 * upd
                continue
            if op == "broadcast":
                total.traffic += _shape_bytes(ins.result)
                continue
            if op == "copy":
                total.traffic += 2 * _shape_bytes(ins.result)
                continue
            # generic elementwise / reduce / transpose / concatenate ...
            total.traffic += _shape_bytes(ins.result) + _operand_bytes(ins, symtab)
        memo[name] = total
        return total

    def _operand_bytes(ins: Instr, symtab: dict[str, str]) -> int:
        names = _OPERAND_RE.findall(ins.rest.split(")", 1)[0])
        return sum(_shape_bytes(symtab.get(n, "")) for n in names)

    return comp_cost(entry)
