"""Roofline-term computation from dry-run artifacts (EXPERIMENTS.md §Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
All parsed HLO numbers are per-device (the compiled module is the SPMD
partition); terms are seconds per step on one chip, the max defines the
bottleneck.
"""
from __future__ import annotations

import dataclasses
import math

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import rounds as R
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

DCN_BW = 25e9  # cross-pod (inter-slice) bandwidth per device, B/s
from repro.models import params as mp

# ring all-reduce moves ~2x the payload per device; others ~1x
COLLECTIVE_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
    "ragged-all-to-all": 1.0,
    "collective-broadcast": 1.0,
}


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    cross_pod_s: float = 0.0
    cross_pod_bytes: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def expert_params(arch: ArchConfig) -> int:
    if not arch.n_experts:
        return 0
    per_layer = 3 * arch.d_model * arch.d_ff * arch.n_experts
    return per_layer * arch.n_layers


def active_params(arch: ArchConfig) -> int:
    tpl = R.make_template(arch)
    n = mp.count_params(tpl)
    if arch.n_experts:
        ep = expert_params(arch)
        n = n - ep + int(ep * arch.experts_per_token / arch.n_experts)
    return n


def model_flops(arch: ArchConfig, shape: ShapeConfig, local_steps: int = 1) -> float:
    """MODEL_FLOPS: 6*N_active*D train, 2*N_active*D inference (+KV reads)."""
    n = active_params(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len * local_steps
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    # decode: one token; add the attention context reads as flops
    flops = 2.0 * n * shape.global_batch
    if arch.n_heads and arch.family != "ssm":
        hd = arch.resolved_head_dim
        S = shape.seq_len
        if arch.family == "hybrid":
            # only the shared attention block applications read a KV cache
            n_attn_reads = (arch.n_layers // arch.shared_attn_period) * S
        elif arch.local_global_period:
            ng, nt = divmod(arch.n_layers, arch.local_global_period)
            n_local = ng * (arch.local_global_period - 1) + nt
            n_global = arch.n_layers - n_local
            W = min(arch.window, S)
            n_attn_reads = n_global * S + n_local * W
        else:
            n_attn_reads = arch.n_layers * S
        flops += 4.0 * arch.n_heads * hd * n_attn_reads * shape.global_batch
    return flops


def terms(flops_dev: float, traffic_dev: float, coll_bytes: dict, n_devices: int, arch: ArchConfig, shape: ShapeConfig, local_steps: int = 1, cross_pod_bytes: dict | None = None) -> Roofline:
    compute_s = flops_dev / PEAK_FLOPS_BF16
    memory_s = traffic_dev / HBM_BW
    coll_s = sum(COLLECTIVE_FACTOR.get(k, 1.0) * v for k, v in coll_bytes.items()) / ICI_BW
    cross_b = sum((cross_pod_bytes or {}).values())
    cross_s = sum(
        COLLECTIVE_FACTOR.get(k, 1.0) * v for k, v in (cross_pod_bytes or {}).items()
    ) / DCN_BW
    dom = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", coll_s), ("cross-pod", cross_s)],
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(arch, shape, local_steps)
    total_hlo = flops_dev * n_devices
    ratio = mf / total_hlo if total_hlo else math.nan
    return Roofline(compute_s, memory_s, coll_s, dom, mf, total_hlo, ratio, cross_s, cross_b)
