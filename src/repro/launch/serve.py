"""Serving launcher: batched prefill + decode with the global federated model.

CPU-runnable at reduced size; the production-mesh serve plans (32k decode,
500k long-context) are exercised via launch.dryrun.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --new-tokens 16
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import params as P
from repro.models import serving as S
from repro.models import transformer as T


def generate(cfg, params, prompts: jax.Array, new_tokens: int, images=None, temperature: float = 0.0, seed: int = 0):
    B, Sq = prompts.shape
    ni = cfg.n_image_tokens if cfg.modality == "vlm" else 0
    batch = {"tokens": prompts}
    if ni:
        batch["images"] = images
    max_len = ni + Sq + new_tokens
    logits, cache = jax.jit(lambda p, b: S.prefill(cfg, p, b, max_len=max_len))(params, batch)
    step = jax.jit(lambda p, c, t, pos: S.decode_step(cfg, p, c, t, pos))
    out = []
    key = jax.random.key(seed)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(ni + Sq + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_arch(args.arch).reduced()
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step (DESIGN.md)")
    params = P.init_params(T.template(cfg), jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    images = (
        jnp.asarray(rng.normal(size=(args.batch, cfg.n_image_tokens, cfg.d_model)) * 0.1, jnp.float32)
        if cfg.modality == "vlm"
        else None
    )
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.new_tokens, images, args.temperature)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name,
        "generated": np.asarray(toks[0]).tolist(),
        "tokens_per_s": round(args.batch * args.new_tokens / dt, 2),
    }))


if __name__ == "__main__":
    main()
