"""Serving launcher: the online detection service + batched LLM decode.

CPU-runnable at reduced size; the production-mesh serve plans (32k decode,
500k long-context) are exercised via launch.dryrun.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --new-tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch fedyolov3 --store /tmp/cos
  PYTHONPATH=src python -m repro.launch.serve --arch fedyolov3 --one-shot

yolo-family archs serve *detections* — the paper's "model dispatch to
visual serving" leg. The default mode stands up the real serving plane
(DESIGN.md §17): `core.serving.InferenceService` listening on a socket,
batching INFER frames into one jitted decode+NMS program, then drives
``--requests`` synthetic requests through an `InferenceClient` and prints
the QPS/latency/freshness summary. ``--store``/``--task-id`` restore the
federated global model from the COS object store that `launch.train` /
`examples/fed_yolo.py` checkpointed into, published at the stored round
version (so RESULT frames carry the training round they came from).
``--one-shot`` keeps the old decode-one-batch-and-exit behavior.
"""
from __future__ import annotations

import argparse
import functools
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.configs import get_arch
from repro.models import params as P
from repro.models import serving as S
from repro.models import transformer as T
from repro.models import yolov3


@functools.lru_cache(maxsize=8)
def decode_programs(cfg, max_len: int):
    """Cached jitted (prefill, decode_step) per (cfg, max_len).

    Built once and reused across `generate` calls — previously each call
    re-wrapped `jax.jit` around fresh lambdas, so every request paid a
    full retrace of both programs. `cfg` is a frozen dataclass, hence a
    valid cache key; `tests/test_serving.py` pins the cache hit."""
    prefill = jax.jit(lambda p, b: S.prefill(cfg, p, b, max_len=max_len))
    step = jax.jit(lambda p, c, t, pos: S.decode_step(cfg, p, c, t, pos))
    return prefill, step


def generate(cfg, params, prompts: jax.Array, new_tokens: int, images=None, temperature: float = 0.0, seed: int = 0):
    B, Sq = prompts.shape
    ni = cfg.n_image_tokens if cfg.modality == "vlm" else 0
    batch = {"tokens": prompts}
    if ni:
        batch["images"] = images
    prefill, step = decode_programs(cfg, ni + Sq + new_tokens)
    logits, cache = prefill(params, batch)
    out = []
    key = jax.random.key(seed)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(ni + Sq + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def restore_params(cfg, args):
    """COS restore -> (params, round version). The published version is the
    stored round index, so served RESULT frames carry the actual training
    round — not a fake 0 — after a restore."""
    params = P.init_params(yolov3.template(cfg), jax.random.key(0), jnp.float32)
    version = 0
    if args.store:
        store = ObjectStore(args.store)
        version = max(store.rounds(args.task_id))
        params = store.restore_into(args.task_id, params)
    return params, version


def serve_detection(cfg, args) -> None:
    """--one-shot: decode one synthetic batch -> box list JSON, exit."""
    from repro.core import detection
    from repro.data import synthetic

    params, _ = restore_params(cfg, args)
    rng = np.random.default_rng(7)
    imgs, _ = synthetic.scene_images(rng, args.batch, args.img_size, cfg.vocab_size)
    t0 = time.time()
    pred = detection.decode_predictions(
        cfg, params, jnp.asarray(imgs), max_detections=args.max_detections
    )
    jax.block_until_ready(pred)
    dt = time.time() - t0
    valid, cls, scores, boxes = (np.asarray(pred[k]) for k in ("valid", "cls", "scores", "boxes"))
    detections = [
        [
            {
                "label": int(cls[b, k]),
                "score": round(float(scores[b, k]), 4),
                "box": [round(float(v), 4) for v in boxes[b, k]],
            }
            for k in np.nonzero(valid[b])[0]
        ]
        for b in range(args.batch)
    ]
    print(json.dumps({
        "arch": cfg.name,
        "restored": bool(args.store),
        "detections": detections,
        "images_per_s": round(args.batch / dt, 2),
    }))


def serve_service(cfg, args) -> None:
    """The serving plane (DESIGN.md §17): stand up the socket service,
    drive --requests synthetic requests, print the operational summary."""
    from repro.core import rounds as R
    from repro.core import serving
    from repro.data import synthetic

    fed = R.FedConfig(
        n_clients=1,
        serve_batch=args.serve_batch,
        serve_max_detections=args.max_detections,
    )
    params, version = restore_params(cfg, args)
    slot = serving.ModelSlot()
    slot.publish(version, params)
    svc = serving.InferenceService(
        cfg, fed, slot, img_size=args.img_size, port=args.port
    ).start()
    rng = np.random.default_rng(7)
    imgs, _ = synthetic.scene_images(rng, args.requests, args.img_size, cfg.vocab_size)
    # warm the jitted program so compile time doesn't pollute the latencies
    with serving.InferenceClient(svc.host, svc.port) as warm:
        warm.infer(imgs[0])
    lat = []
    t0 = time.perf_counter()
    with serving.InferenceClient(svc.host, svc.port) as client:
        for i in range(args.requests):
            t1 = time.perf_counter()
            res = client.infer(imgs[i])
            lat.append(time.perf_counter() - t1)
        total = time.perf_counter() - t0
        status = client.status()
    svc.stop()
    lat.sort()
    print(json.dumps({
        "arch": cfg.name,
        "restored": bool(args.store),
        "version": status["version"],
        "tier": status["tier"],
        "requests": args.requests,
        "dropped": status["in_flight"],
        "qps": round(args.requests / total, 2),
        "p50_ms": round(lat[len(lat) // 2] * 1e3, 3),
        "p99_ms": round(lat[min(len(lat) - 1, int(len(lat) * 0.99))] * 1e3, 3),
        "avg_occupancy": status["avg_occupancy"],
        "last_detections": len(res.detections),
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--img-size", type=int, default=64, help="yolo: served image size")
    ap.add_argument("--max-detections", type=int, default=16, help="yolo: NMS output slots")
    ap.add_argument("--store", default="", help="COS dir to restore the federated model from")
    ap.add_argument("--task-id", default="fedyolo", help="COS task id (with --store)")
    ap.add_argument("--one-shot", action="store_true",
                    help="yolo: decode one synthetic batch and exit (pre-§17 behavior)")
    ap.add_argument("--port", type=int, default=0, help="service port (0 = ephemeral)")
    ap.add_argument("--requests", type=int, default=8,
                    help="service: synthetic requests to drive through the socket")
    ap.add_argument("--serve-batch", type=int, default=8,
                    help="service: batch slots of the jitted decode+NMS program")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (must match how the stored model was trained)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    if cfg.family == "yolo":
        if args.one_shot:
            serve_detection(cfg, args)
        else:
            serve_service(cfg, args)
        return
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step (DESIGN.md)")
    params = P.init_params(T.template(cfg), jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    images = (
        jnp.asarray(rng.normal(size=(args.batch, cfg.n_image_tokens, cfg.d_model)) * 0.1, jnp.float32)
        if cfg.modality == "vlm"
        else None
    )
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.new_tokens, images, args.temperature)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name,
        "generated": np.asarray(toks[0]).tolist(),
        "tokens_per_s": round(args.batch * args.new_tokens / dt, 2),
    }))


if __name__ == "__main__":
    main()
