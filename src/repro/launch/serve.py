"""Serving launcher: batched prefill + decode with the global federated model.

CPU-runnable at reduced size; the production-mesh serve plans (32k decode,
500k long-context) are exercised via launch.dryrun.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --new-tokens 16
  PYTHONPATH=src python -m repro.launch.serve --arch fedyolov3 --store /tmp/cos

yolo-family archs serve *detections*: forward + decode + the same Pallas
NMS/IoU path the evaluator uses (core.detection.decode_predictions), i.e.
the paper's "model dispatch to visual serving" leg. --store/--task-id
restore the federated global model from the COS object store that
`launch.train` / `examples/fed_yolo.py` checkpointed into.
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.configs import get_arch
from repro.models import params as P
from repro.models import serving as S
from repro.models import transformer as T
from repro.models import yolov3


def generate(cfg, params, prompts: jax.Array, new_tokens: int, images=None, temperature: float = 0.0, seed: int = 0):
    B, Sq = prompts.shape
    ni = cfg.n_image_tokens if cfg.modality == "vlm" else 0
    batch = {"tokens": prompts}
    if ni:
        batch["images"] = images
    max_len = ni + Sq + new_tokens
    logits, cache = jax.jit(lambda p, b: S.prefill(cfg, p, b, max_len=max_len))(params, batch)
    step = jax.jit(lambda p, c, t, pos: S.decode_step(cfg, p, c, t, pos))
    out = []
    key = jax.random.key(seed)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    for i in range(new_tokens):
        out.append(tok)
        logits, cache = step(params, cache, tok, jnp.int32(ni + Sq + i))
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


def serve_detection(cfg, args) -> None:
    """Detection serving: images -> decode + Pallas NMS -> box list JSON."""
    from repro.core import detection
    from repro.data import synthetic

    params = P.init_params(yolov3.template(cfg), jax.random.key(0), jnp.float32)
    if args.store:
        store = ObjectStore(args.store)
        params = store.restore_into(args.task_id, params)
    rng = np.random.default_rng(7)
    imgs, _ = synthetic.scene_images(rng, args.batch, args.img_size, cfg.vocab_size)
    t0 = time.time()
    pred = detection.decode_predictions(
        cfg, params, jnp.asarray(imgs), max_detections=args.max_detections
    )
    jax.block_until_ready(pred)
    dt = time.time() - t0
    valid, cls, scores, boxes = (np.asarray(pred[k]) for k in ("valid", "cls", "scores", "boxes"))
    detections = [
        [
            {
                "label": int(cls[b, k]),
                "score": round(float(scores[b, k]), 4),
                "box": [round(float(v), 4) for v in boxes[b, k]],
            }
            for k in np.nonzero(valid[b])[0]
        ]
        for b in range(args.batch)
    ]
    print(json.dumps({
        "arch": cfg.name,
        "restored": bool(args.store),
        "detections": detections,
        "images_per_s": round(args.batch / dt, 2),
    }))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--img-size", type=int, default=64, help="yolo: served image size")
    ap.add_argument("--max-detections", type=int, default=16, help="yolo: NMS output slots")
    ap.add_argument("--store", default="", help="COS dir to restore the federated model from")
    ap.add_argument("--task-id", default="fedyolo", help="COS task id (with --store)")
    ap.add_argument("--full-size", action="store_true",
                    help="use the full config (must match how the stored model was trained)")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if not args.full_size:
        cfg = cfg.reduced()
    if cfg.family == "yolo":
        serve_detection(cfg, args)
        return
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only: no decode step (DESIGN.md)")
    params = P.init_params(T.template(cfg), jax.random.key(0), jnp.float32)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    images = (
        jnp.asarray(rng.normal(size=(args.batch, cfg.n_image_tokens, cfg.d_model)) * 0.1, jnp.float32)
        if cfg.modality == "vlm"
        else None
    )
    t0 = time.time()
    toks = generate(cfg, params, prompts, args.new_tokens, images, args.temperature)
    dt = time.time() - t0
    print(json.dumps({
        "arch": cfg.name,
        "generated": np.asarray(toks[0]).tolist(),
        "tokens_per_s": round(args.batch * args.new_tokens / dt, 2),
    }))


if __name__ == "__main__":
    main()
