"""Per-(arch x shape x mesh) lowering policy: step fn + abstract inputs + shardings.

This is the single source of truth consumed by launch.dryrun, launch.train
and launch.serve. For every combination it decides:
- which step function lowers (fed_round / fedsgd step / prefill / decode),
- the federated client mapping (DESIGN.md §4),
- parameter/batch/cache PartitionSpecs, including FSDP-style rules for the
  architectures whose optimizer state exceeds per-device HBM under pure TP
  (gemma3-27b, grok-1-314b, llava-next-34b — see DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch, get_shape, shape_applicable
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import rounds as R
from repro.models import params as mp
from repro.models import serving, transformer
from repro.models.params import DEFAULT_RULES
from repro.optim import adamw

PyTree = Any

# Architectures needing parameter/optimizer sharding over the data axis.
FSDP_ARCHS = {"gemma3-27b", "grok-1-314b", "llava-next-34b"}
MODEL_AXIS = 16  # model-parallel width of both production meshes


def fsdp_rules() -> dict:
    rules = dict(DEFAULT_RULES)
    rules["embed"] = "data"  # ZeRO/FSDP-style: shard the d_model dim
    return rules


@dataclasses.dataclass(frozen=True)
class LoweringPlan:
    arch: ArchConfig
    shape: ShapeConfig
    multi_pod: bool
    kind: str  # train | fedsgd | prefill | decode
    fed: R.FedConfig | None
    rules: dict
    dp_axes: tuple[str, ...]  # serve batch axes
    aggregation: str
    opt_rules: dict | None = None  # ZeRO-1: separate moment sharding

    @property
    def name(self) -> str:
        mesh = "multipod" if self.multi_pod else "singlepod"
        return f"{self.arch.name}--{self.shape.name}--{mesh}"


# §Perf hillclimb variants (EXPERIMENTS.md):
#   moe_sort   — sort/gather-scatter MoE dispatch (no one-hot einsum FLOPs)
#   moe_ep     — expert-parallel: experts over "model" instead of d_ff
#   moe_sort_ep— both
#   zero1      — params TP-only, optimizer moments sharded over "data"
#   micro<N>   — override microbatch count
#   seqpar     — sequence-parallel residual stream (S over "model")
#   swa        — sliding-window serving variant for dense archs (enables
#                long_500k with ring-buffer KV caches; beyond-paper)
VARIANTS = ("", "moe_sort", "moe_ep", "moe_sort_ep", "zero1", "seqpar", "swa")
SWA_WINDOW = 4096


def variant_arch(arch: ArchConfig, variant: str) -> ArchConfig:
    """Arch-level transforms that must precede shape-applicability checks."""
    if variant == "swa" and not arch.window:
        return dataclasses.replace(arch, window=SWA_WINDOW)
    return arch


def apply_variant(arch: ArchConfig, rules: dict, fed, variant: str):
    opt_rules = None
    if variant.startswith("micro") and fed is not None:
        fed = dataclasses.replace(fed, microbatches=int(variant[5:]))
    if variant in ("moe_sort", "moe_sort_ep"):
        arch = dataclasses.replace(arch, moe_impl="sort")
    if variant in ("moe_ep", "moe_sort_ep"):
        rules = dict(rules)
        rules["expert"] = "model"
        rules["ffn"] = None
    if variant == "zero1":
        opt_rules = dict(rules)
        rules = {k: v for k, v in rules.items() if k != "embed" or v != "data"}
        rules["embed"] = None
        opt_rules["embed"] = "data"
    return arch, rules, fed, opt_rules


def make_plan(arch_name: str, shape_name: str, multi_pod: bool, aggregation: str = "eq6", local_steps: int = 1, variant: str = "") -> LoweringPlan:
    arch = variant_arch(get_arch(arch_name), variant)
    shape = get_shape(shape_name)
    ok, why = shape_applicable(arch, shape)
    if not ok:
        raise ValueError(f"{arch_name} x {shape_name}: {why}")
    big = arch.name in FSDP_ARCHS
    if shape.kind == "train":
        # microbatch counts target ~2 rows of 4k tokens per device per
        # microbatch, bounding the remat'd saved-carry stack.
        if multi_pod:
            fed = R.FedConfig(n_clients=2, local_steps=local_steps, aggregation=aggregation, client_axis="pod", data_axis="data", topn=default_topn(arch), microbatches=8 if big else 4)
            rules = fsdp_rules() if big else dict(DEFAULT_RULES)
            kind = "train"
        elif big:
            # single-pod: FedSGD-equivalent (E=1 param-avg == grad-avg) so
            # one model copy can shard over both axes.
            fed = R.FedConfig(n_clients=16, local_steps=local_steps, aggregation="fedsgd", client_axis="data", data_axis="data", topn=default_topn(arch), microbatches=8)
            rules = fsdp_rules()
            kind = "fedsgd"
        else:
            fed = R.FedConfig(n_clients=16, local_steps=local_steps, aggregation=aggregation, client_axis="data", data_axis=None, topn=default_topn(arch), microbatches=8)
            rules = dict(DEFAULT_RULES)
            kind = "train"
        arch, rules, fed, opt_rules = apply_variant(arch, rules, fed, variant)
        return LoweringPlan(arch, shape, multi_pod, kind, fed, rules, (), fed.aggregation, opt_rules)
    # serving
    rules = dict(DEFAULT_RULES)
    if arch.name == "grok-1-314b":
        rules["embed"] = "data"  # 314B bf16 exceeds HBM under pure TP
    dp = ("pod", "data") if multi_pod else ("data",)
    kind = "prefill" if shape.kind == "prefill" else "decode"
    arch, rules, _, opt_rules = apply_variant(arch, rules, None, variant)
    return LoweringPlan(arch, shape, multi_pod, kind, None, rules, dp, "none", opt_rules)


def default_topn(arch: ArchConfig) -> int:
    """Paper: user-set n. Default: a quarter of the layer buckets."""
    return max(1, (arch.n_layers + 1) // 4)


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_template(arch: ArchConfig, lead: tuple[int, ...], seq: int) -> PyTree:
    """Model inputs with `lead` prefix dims ((C,E,b) for train, (B,) serve)."""
    if arch.modality == "audio":
        return {
            "frames": _sds(lead + (seq, arch.d_model), jnp.bfloat16),
            "labels": _sds(lead + (seq,), jnp.int32),
            "mask": _sds(lead + (seq,), jnp.bool_),
        }
    if arch.modality == "vlm":
        ni = arch.n_image_tokens
        return {
            "tokens": _sds(lead + (seq - ni,), jnp.int32),
            "images": _sds(lead + (ni, arch.d_model), jnp.bfloat16),
        }
    return {"tokens": _sds(lead + (seq,), jnp.int32)}


def batch_pspec_tree(arch: ArchConfig, batch: PyTree, lead_spec: tuple) -> PyTree:
    def spec_for(leaf):
        extra = (None,) * (len(leaf.shape) - len(lead_spec))
        return P(*lead_spec, *extra)

    return jax.tree.map(spec_for, batch)


def input_specs(plan: LoweringPlan) -> tuple[PyTree, PyTree]:
    """Returns (abstract_args, pspecs) for the plan's step function."""
    arch, shape = plan.arch, plan.shape
    S, B = shape.seq_len, shape.global_batch
    optimizer = adamw()
    if plan.kind in ("train", "fedsgd"):
        fed = plan.fed
        state = R.state_template(arch, fed, optimizer, jnp.bfloat16)
        sspec = R.state_pspecs(arch, fed, optimizer, plan.rules, plan.opt_rules)
        if plan.kind == "fedsgd":
            batch = batch_template(arch, (fed.local_steps, B), S)
            bspec = batch_pspec_tree(arch, batch, (None, ("pod", "data") if plan.multi_pod else ("data",)))
        else:
            b = B // fed.n_clients
            batch = batch_template(arch, (fed.n_clients, fed.local_steps, b), S)
            bspec = batch_pspec_tree(arch, batch, (fed.client_axis, None, fed.data_axis))
        w = _sds((fed.n_clients,), jnp.float32)
        return (state, batch, w), (sspec, bspec, P())
    # serving: global (aggregated) model
    tpl = R.make_template(arch)
    params = mp.abstract(tpl, jnp.bfloat16)
    pspec = mp.pspecs(tpl, plan.rules)
    if plan.kind == "prefill":
        batch = batch_template(arch, (B,), S)
        bspec = batch_pspec_tree(arch, batch, (plan.dp_axes,))
        return (params, batch), (pspec, bspec)
    # decode
    cache = serving.cache_spec(arch, B, S, abstract=True)
    cspec = cache_pspecs(arch, B, plan.dp_axes)
    tokens = _sds((B, 1), jnp.int32)
    pos = _sds((), jnp.int32)
    tspec = P(plan.dp_axes if B > 1 else None, None)
    return (params, cache, tokens, pos), (pspec, cspec, tspec, P())


def cache_pspecs(arch: ArchConfig, B: int, dp_axes: tuple[str, ...]) -> PyTree:
    """PartitionSpecs mirroring serving.cache_spec structure (DESIGN.md §4)."""
    dp = dp_axes if B > 1 else None
    kv_ok = arch.n_kv_heads % MODEL_AXIS == 0 if arch.n_kv_heads else False
    if arch.family in ("dense", "vlm", "audio", "moe") and not arch.local_global_period:
        if kv_ok:
            spec = P(None, dp, None, "model", None)
        else:  # shard the cache sequence dim instead (flash-decode style)
            spec = P(None, dp, "model", None, None)
        return {"k": spec, "v": spec}
    if arch.local_global_period:
        head_ax = "model" if kv_ok else None
        long_seq = None if B > 1 else "data"  # long_500k: shard S over data
        local = P(None, None, dp, None, head_ax, None)
        glob_spec = P(None, dp, long_seq, head_ax, None)
        out = {"g_local": {"k": local, "v": local}, "g_global": {"k": glob_spec, "v": glob_spec}}
        ng, nt = transformer.gemma_pattern(arch)
        if nt:
            tail = P(None, dp, None, head_ax, None)
            out["tail"] = {"k": tail, "v": tail}
        return out
    if arch.family == "ssm":
        from repro.models import mamba2 as m2

        _, h, _ = m2.dims(arch)
        head_ax = "model" if h % MODEL_AXIS == 0 else None
        return {
            "ssm": P(None, dp, head_ax, None, None),
            "conv": P(None, dp, None, None),
        }
    if arch.family == "hybrid":
        from repro.models import mamba2 as m2

        _, h, _ = m2.dims(arch)
        head_ax = "model" if h % MODEL_AXIS == 0 else None
        kv_ax = "model" if kv_ok else None
        long_seq = None if B > 1 else "data"
        return {
            "ssm": P(None, None, dp, head_ax, None, None),
            "conv": P(None, None, dp, None, None),
            "shared": {
                "k": P(None, dp, long_seq, kv_ax, None),
                "v": P(None, dp, long_seq, kv_ax, None),
            },
        }
    raise ValueError(arch.family)


# ---------------------------------------------------------------------------
# Step functions
# ---------------------------------------------------------------------------

def _act_axes(plan: LoweringPlan):
    """Activation batch-dim sharding for the plan (see models.shard_ctx)."""
    if plan.kind == "fedsgd":
        return ("pod", "data") if plan.multi_pod else ("data",)
    if plan.kind == "train":
        # () -> constraint exists so vmap(spmd_axis_name) prepends the
        # client axis; data_axis added when within-client DP is present.
        return (plan.fed.data_axis,) if plan.fed.data_axis else ()
    return plan.dp_axes if plan.shape.global_batch > 1 else None


def step_fn(plan: LoweringPlan, mesh, variant: str = ""):
    from repro.models.shard_ctx import activation_sharding

    arch = plan.arch
    optimizer = adamw()
    axes = _act_axes(plan)
    seq_axis = "model" if variant == "seqpar" else None
    if plan.kind in ("train", "fedsgd"):
        inner = R.build_fed_round(arch, plan.fed, optimizer, mesh, plan.rules)

        def fed_wrapped(state, batch, weights):
            with activation_sharding(axes, seq_axis):
                return inner(state, batch, weights)

        return fed_wrapped
    if plan.kind == "prefill":
        if arch.is_encoder_only:
            # encoder inference: full-sequence logits (no cache)
            def enc_fwd(params, batch):
                with activation_sharding(axes):
                    x = transformer.embed_inputs(arch, params, batch)
                    hidden, _ = transformer.trunk(arch, params, x)
                    return transformer.logits_fn(arch, params, hidden)

            return enc_fwd

        def prefill_wrapped(params, batch):
            with activation_sharding(axes):
                return serving.prefill(arch, params, batch)

        return prefill_wrapped

    def decode_wrapped(params, cache, tokens, pos):
        with activation_sharding(axes):
            return serving.decode_step(arch, params, cache, tokens, pos)

    return decode_wrapped


def to_shardings(mesh, pspecs: PyTree) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspecs, is_leaf=lambda x: isinstance(x, P)
    )
