"""Durable round state: atomic engine snapshots + a landing WAL
(DESIGN.md §16).

The `WireServer` is the single point of total loss: the packed ``(C,
N_total)`` buffer, the aggregator substate (EF residual rows, fmix32 round
counters), and the dispatch versions all live in one process. `DurableRun`
makes that process killable: a directory of

    meta.json            the run meta (the schedule's self-description)
    wal_<E>.jsonl        event segments: every landing-loop event (dispatch
                         and land), CRC-guarded per line, segment starting
                         at global event index E
    snap_<E>.ckpt        full-engine snapshots (atomic tmp+fsync+rename,
                         CRC-guarded) taken after event E

Recovery = the newest CRC-valid snapshot + a *partial replay* of the WAL
suffix through `transport.replay.apply_events` — the identical jitted
single-row update and codec round-trip the full replay harness already
proves deterministic. Nothing model-sized ever enters the WAL: a land
event is ~100 bytes of JSON, the trained row is recomputed from
``(seed, client, seq)`` at recovery time.

Durability model: the WAL is flushed (OS buffer) per event — surviving
``kill -9`` of the server process, the crash model this PR defends
against — and fsynced at snapshot boundaries; pass ``fsync_every_event``
for whole-machine-loss durability at a per-landing fsync cost
(`benchmarks/wire_bench.py` measures both). A torn final WAL line (the
crash interrupting the write itself) fails its line CRC and is discarded:
the engine recovers to the last *complete* event, and the version-echo
gate reconciles any worker whose update landed after it.

WAL segments are never deleted, so the concatenation of all segments is
the complete `ArrivalSchedule` of the run across every crash — which is
what lets the chaos tests pin a recovered run bit-for-bit against an
uninterrupted replay of the combined schedule.
"""
from __future__ import annotations

import dataclasses
import io
import json
import os
import zlib
from pathlib import Path

import numpy as np

from repro.core.transport.replay import ArrivalSchedule, WireEvent, apply_events, make_engine

SNAP_MAGIC = b"FVSNAP01"


def _fsync_dir(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write_bytes(path: Path, blob: bytes) -> None:
    """tmp + fsync + rename: the file either fully exists or never did."""
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(path.parent)


# -- snapshot file format -----------------------------------------------------

def write_snapshot(path: Path, snap: dict) -> int:
    """Serialize an `ArrivalAsyncEngine.export_state` dict to `path`
    atomically. Layout: magic | u32 crc32(body) | u64 len(body) | body,
    body = npz of the arrays plus the scalars JSON as a uint8 array.
    Returns the bytes written (the wire_bench snapshot-cost row)."""
    buf = io.BytesIO()
    scal = json.dumps(snap["scalars"]).encode()
    np.savez(buf, __scalars__=np.frombuffer(scal, np.uint8), **snap["arrays"])
    body = buf.getvalue()
    blob = (
        SNAP_MAGIC
        + zlib.crc32(body).to_bytes(4, "big")
        + len(body).to_bytes(8, "big")
        + body
    )
    atomic_write_bytes(path, blob)
    return len(blob)


def read_snapshot(path: Path) -> dict:
    """Load + verify one snapshot file; raises ValueError on any damage
    (bad magic, truncation, CRC mismatch) so recovery can fall back to an
    older snapshot instead of importing garbage."""
    blob = Path(path).read_bytes()
    if blob[: len(SNAP_MAGIC)] != SNAP_MAGIC:
        raise ValueError(f"{path}: bad snapshot magic")
    off = len(SNAP_MAGIC)
    crc = int.from_bytes(blob[off : off + 4], "big")
    n = int.from_bytes(blob[off + 4 : off + 12], "big")
    body = blob[off + 12 :]
    if len(body) != n:
        raise ValueError(f"{path}: truncated snapshot ({len(body)} != {n} bytes)")
    if zlib.crc32(body) != crc:
        raise ValueError(f"{path}: snapshot CRC mismatch")
    with np.load(io.BytesIO(body)) as z:
        arrays = {k: z[k] for k in z.files if k != "__scalars__"}
        scalars = json.loads(z["__scalars__"].tobytes().decode())
    return {"arrays": arrays, "scalars": scalars}


# -- WAL ----------------------------------------------------------------------

def _wal_line(idx: int, ev: WireEvent) -> str:
    body = json.dumps({"i": idx, "ev": dataclasses.asdict(ev)},
                      separators=(",", ":"))
    return f"{zlib.crc32(body.encode()):08x} {body}\n"


def _parse_wal_line(line: str) -> tuple[int, WireEvent] | None:
    """(index, event), or None for a torn/corrupt line."""
    if len(line) < 10 or line[8] != " ":
        return None
    body = line[9:].rstrip("\n")
    try:
        if int(line[:8], 16) != zlib.crc32(body.encode()):
            return None
        obj = json.loads(body)
        return int(obj["i"]), WireEvent(**obj["ev"])
    except (ValueError, KeyError, TypeError):
        return None


class DurableRun:
    """One run's durable directory: meta + WAL segments + snapshots.

    The landing loop calls `append_event` for every recorded event and
    `snapshot(engine)` whenever its policy fires; both are cheap enough to
    live inline in the loop (wire_bench's 15% WAL-overhead guard pins
    this). Opening an existing directory resumes: the event counter
    continues from the last complete WAL line.
    """

    def __init__(self, root: str | Path, meta: dict | None = None, *,
                 fsync_every_event: bool = False):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.fsync_every_event = fsync_every_event
        meta_path = self.root / "meta.json"
        if meta is not None:
            atomic_write_bytes(meta_path, json.dumps(meta).encode())
            self.meta = dict(meta)
        elif meta_path.exists():
            self.meta = json.loads(meta_path.read_text())
        else:
            raise FileNotFoundError(f"{meta_path}: new DurableRun needs meta")
        self.n_events = sum(len(evs) for _, evs in self._segments())
        self.snapshots_written = 0
        self._wal = None  # lazily (re)opened; a snapshot rotates it

    # -- write path ----------------------------------------------------------

    def _open_wal(self) -> None:
        if self._wal is None:
            self._wal = open(self.root / f"wal_{self.n_events:08d}.jsonl", "a")

    def append_event(self, ev: WireEvent) -> None:
        self._open_wal()
        self._wal.write(_wal_line(self.n_events, ev))
        self._wal.flush()
        if self.fsync_every_event:
            os.fsync(self._wal.fileno())
        self.n_events += 1

    def snapshot(self, engine) -> int:
        """Write a full-engine snapshot at the current event count, fsync
        and rotate the WAL (the next segment starts here). Returns bytes
        written."""
        if self._wal is not None:
            self._wal.flush()
            os.fsync(self._wal.fileno())
            self._wal.close()
            self._wal = None
        n = write_snapshot(
            self.root / f"snap_{self.n_events:08d}.ckpt", engine.export_state()
        )
        self.snapshots_written += 1
        return n

    def close(self) -> None:
        if self._wal is not None:
            self._wal.flush()
            os.fsync(self._wal.fileno())
            self._wal.close()
            self._wal = None

    # -- read path ------------------------------------------------------------

    def _segments(self) -> list[tuple[int, list[WireEvent]]]:
        """All WAL segments as (start_index, events), index-ordered; a torn
        or corrupt line ends its segment (everything before it is intact —
        the WAL is append-only)."""
        out = []
        for p in sorted(self.root.glob("wal_*.jsonl")):
            start = int(p.stem.split("_")[1])
            events = []
            for line in p.read_text().splitlines(keepends=True):
                parsed = _parse_wal_line(line)
                if parsed is None:
                    break
                events.append(parsed[1])
            out.append((start, events))
        return out

    def events(self) -> list[WireEvent]:
        """The complete recorded event sequence across every crash —
        segment concatenation, gap-checked."""
        all_events: list[WireEvent] = []
        for start, evs in self._segments():
            if start > len(all_events):
                raise ValueError(
                    f"WAL gap: segment starts at event {start}, have {len(all_events)}"
                )
            all_events = all_events[:start] + evs
        return all_events

    def schedule(self) -> ArrivalSchedule:
        """The run's full `ArrivalSchedule` as persisted — what the
        recovery-equals-replay pin replays."""
        return ArrivalSchedule(meta=dict(self.meta), events=self.events())

    def latest_snapshot(self) -> tuple[int, dict] | None:
        """(event_count, snapshot dict) of the newest CRC-valid snapshot,
        falling back across damaged ones; None if no usable snapshot."""
        for p in sorted(self.root.glob("snap_*.ckpt"), reverse=True):
            try:
                return int(p.stem.split("_")[1]), read_snapshot(p)
            except ValueError:
                continue
        return None

    def recover_engine(self, *, clock=None):
        """Rebuild the engine exactly as it stood at the last complete WAL
        event: newest valid snapshot imported, then the WAL suffix replayed
        through the jitted row update (`replay.apply_events`). Returns
        ``(engine, n_events_replayed)``; a run with no snapshot replays the
        whole WAL from the seed engine — recovery degrades gracefully to a
        full replay, never to data loss."""
        events = self.events()
        engine = make_engine(self.meta, clock=clock)
        at = 0
        found = self.latest_snapshot()
        if found is not None:
            at, snap = found
            engine.import_state(snap)
        apply_events(engine, events[at:], self.meta, start_index=at)
        self.n_events = len(events)
        return engine, len(events) - at
