"""Cloud Object Storage (COS) abstraction — round-indexed model storage.

The paper: "The number of such model parameter files, and thus the storage
size required, increases with the rounds of training operations. FedVision
adopts Cloud Object Storage (COS)."

Filesystem-backed, content-addressed object store: each PUT writes an
immutable blob keyed by SHA-256 and records (task, round) -> key in a JSON
manifest. GC keeps the newest `keep` rounds per task (the paper's unbounded
growth, bounded).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
from pathlib import Path
from typing import Any

import numpy as np

import jax

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class ObjectStore:
    def __init__(self, root: str | Path):
        self.root = Path(root)
        (self.root / "objects").mkdir(parents=True, exist_ok=True)
        self.manifest_path = self.root / "manifest.json"
        self.manifest: dict = (
            json.loads(self.manifest_path.read_text()) if self.manifest_path.exists() else {}
        )

    def _save_manifest(self) -> None:
        # atomic tmp+fsync+rename: a crash mid-write must never leave a
        # half-written manifest.json bricking every subsequent restore
        tmp = self.manifest_path.with_suffix(".json.tmp")
        with open(tmp, "w") as f:
            f.write(json.dumps(self.manifest, indent=1, sort_keys=True))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.manifest_path)

    def put_model(self, task_id: str, round_idx: int, params: PyTree, meta: dict | None = None) -> str:
        buf = io.BytesIO()
        np.savez_compressed(buf, **_flatten(params))
        blob = buf.getvalue()
        key = hashlib.sha256(blob).hexdigest()
        obj = self.root / "objects" / key
        if not obj.exists():
            obj.write_bytes(blob)
        self.manifest.setdefault(task_id, {})[str(round_idx)] = {
            "key": key,
            "bytes": len(blob),
            **(meta or {}),
        }
        self._save_manifest()
        return key

    def get_model(self, task_id: str, round_idx: int | None = None) -> dict[str, np.ndarray]:
        if task_id not in self.manifest or not self.manifest[task_id]:
            raise KeyError(
                f"no stored model for task {task_id!r}; stored tasks: "
                f"{sorted(self.manifest) or 'none'}"
            )
        rounds = self.manifest[task_id]
        r = str(max(int(k) for k in rounds) if round_idx is None else round_idx)
        if r not in rounds:
            raise KeyError(
                f"task {task_id!r} has no round {r}; available rounds: "
                f"{self.rounds(task_id)}"
            )
        key = rounds[r]["key"]
        with np.load(self.root / "objects" / key) as z:
            return {k: z[k] for k in z.files}

    def restore_into(self, task_id: str, params: PyTree, round_idx: int | None = None) -> PyTree:
        """Load a stored model into an existing pytree structure."""
        flat = self.get_model(task_id, round_idx)
        paths, treedef = jax.tree_util.tree_flatten_with_path(params)
        leaves = []
        for path, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            arr = flat[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def rounds(self, task_id: str) -> list[int]:
        return sorted(int(k) for k in self.manifest.get(task_id, {}))

    def total_bytes(self) -> int:
        return sum(f.stat().st_size for f in (self.root / "objects").iterdir())

    def gc(self, keep: int = 3) -> int:
        """Keep newest `keep` rounds per task; drop unreferenced blobs."""
        for task_id, rounds in self.manifest.items():
            for r in sorted((int(k) for k in rounds), reverse=True)[keep:]:
                del rounds[str(r)]
        live = {e["key"] for rs in self.manifest.values() for e in rs.values()}
        removed = 0
        for f in (self.root / "objects").iterdir():
            if f.name not in live:
                f.unlink()
                removed += 1
        self._save_manifest()
        return removed
