from repro.checkpoint.store import ObjectStore

__all__ = ["ObjectStore"]
