from repro.checkpoint.store import ObjectStore

__all__ = ["ObjectStore", "DurableRun"]


def __getattr__(name):
    # durable imports the transport stack (and through it JAX); keep the
    # plain ObjectStore import light for callers that only store blobs
    if name == "DurableRun":
        from repro.checkpoint.durable import DurableRun

        return DurableRun
    raise AttributeError(name)
