"""granite-moe-1b-a400m [moe] — 32 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    experts_per_token=8,
    # tiny experts (d_ff=512): dispatch cost ~ E*C*D rivals the expert FFN,
    # so keep routing groups small (see EXPERIMENTS.md §Perf hillclimb #1)
    moe_group_size=512,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
