"""llava-next-34b [vlm] — anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf]

The vision tower (ViT/SigLIP) + projector is a stub per the assignment
carve-out: input_specs() provides pre-computed patch embeddings of shape
(B, n_image_tokens, d_model) which the language backbone consumes, prepended
to the text tokens. n_image_tokens=2880 models anyres tiling (5 tiles x 576).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    modality="vlm",
    n_image_tokens=2880,
    # 56 q heads = 8 kv groups of 7; pad each group to 8 (64 total, one
    # masked dead head per group) so heads shard 16-way with the exact
    # original GQA grouping preserved. See DESIGN.md §4.
    q_group_pad=8,
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
