"""Config registry: ``--arch <id>`` ids -> ArchConfig."""
from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, shape_applicable
from repro.configs import (
    fedyolov3,
    gemma3_27b,
    granite_3_8b,
    granite_moe_1b_a400m,
    grok_1_314b,
    hubert_xlarge,
    llava_next_34b,
    mamba2_1_3b,
    minitron_8b,
    qwen3_1_7b,
    zamba2_2_7b,
)

# The 10 assigned architectures (matrix order) + the paper's own model.
ASSIGNED = [
    granite_3_8b.CONFIG,
    qwen3_1_7b.CONFIG,
    hubert_xlarge.CONFIG,
    grok_1_314b.CONFIG,
    granite_moe_1b_a400m.CONFIG,
    gemma3_27b.CONFIG,
    llava_next_34b.CONFIG,
    minitron_8b.CONFIG,
    mamba2_1_3b.CONFIG,
    zamba2_2_7b.CONFIG,
]

REGISTRY: dict[str, ArchConfig] = {c.name: c for c in ASSIGNED}
REGISTRY[fedyolov3.CONFIG.name] = fedyolov3.CONFIG


def get_arch(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; known: {sorted(SHAPES)}")
    return SHAPES[name]


__all__ = [
    "ASSIGNED",
    "REGISTRY",
    "SHAPES",
    "ArchConfig",
    "ShapeConfig",
    "get_arch",
    "get_shape",
    "shape_applicable",
]
