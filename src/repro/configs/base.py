"""Architecture and run configuration for the repro framework.

Every assigned architecture gets one module in this package exporting
``CONFIG: ArchConfig``. The registry in ``__init__`` maps ``--arch`` ids to
these configs. ``ShapeConfig`` describes the four assigned input shapes.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm | yolo
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qk_norm: bool = False
    causal: bool = True  # False for encoder-only (hubert)
    tie_embeddings: bool = True
    # --- attention pattern ---
    window: int = 0  # sliding-window size for local layers (0 = full)
    local_global_period: int = 0  # gemma3: 6 -> [5 local, 1 global] repeating
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_group_size: int = 4096  # GShard routing group (bounds capacity/dispatch)
    moe_impl: str = "gshard"  # gshard (one-hot einsum) | sort (gather/scatter)
    # --- SSM (mamba2 SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_impl: str = "ref"  # ref (jnp) | pallas (SSD chunk kernel fwd)
    # --- hybrid (zamba2) ---
    shared_attn_period: int = 0  # apply one shared attention block every N layers
    # --- modality stubs ---
    modality: str = "text"  # text | audio | vlm
    n_image_tokens: int = 0  # vlm: anyres patch-embedding tokens prepended
    # --- sharding-only structural padding (exact semantics preserved) ---
    q_group_pad: int = 0  # pad each GQA group to this many q heads (masked)
    attention_impl: str = "ref"  # ref (jnp) | pallas (flash kernel fwd)
    # --- misc ---
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    source: str = ""  # citation bracket from the assignment

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_decode(self) -> bool:
        """True if long_500k decode is sub-quadratic/memory-feasible: SSM /
        hybrid state, or a structural sliding window (gemma3 natively, any
        dense arch under the beyond-paper `swa` serving variant)."""
        if self.is_encoder_only:
            return False
        if self.family in ("ssm", "hybrid"):
            return True
        return self.window > 0

    @property
    def has_decode(self) -> bool:
        return not self.is_encoder_only

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = max(1, min(self.n_kv_heads, n_heads, 2))
        period = self.local_global_period
        n_layers = max(2, period) if period else 2
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=64 if self.head_dim else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token
            else 0,
            window=min(self.window, 16) if self.window else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_chunk=8 if self.ssm_state else 128,
            shared_attn_period=min(self.shared_attn_period, 2)
            if self.shared_attn_period
            else 0,
            n_image_tokens=min(self.n_image_tokens, 16) if self.n_image_tokens else 0,
            q_group_pad=0,
            dtype="float32",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Return (applicable, reason-if-not) per the DESIGN.md skip matrix."""
    if shape.kind == "decode" and not arch.has_decode:
        return False, "encoder-only architecture has no decode step"
    if shape.name == "long_500k" and not arch.supports_long_decode:
        return False, "pure full-attention arch: long-context decode skipped (see DESIGN.md)"
    return True, ""
