"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242]

54 Mamba2 layers; one *shared* (single weight set) attention+MLP block is
applied every 9 layers (6 applications), following Zamba2's shared-block
design.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    shared_attn_period=9,
    source="arXiv:2411.15242",
)
