"""fedyolov3 — the paper's own model (YOLOv3-lite, Eqs 2-4 loss).

Not part of the assigned 10x4 matrix; used by examples/ and benchmarks/.
The ArchConfig fields are repurposed: d_model = base conv width, n_layers =
number of darknet residual stages.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="fedyolov3",
    family="yolo",
    n_layers=5,  # darknet-lite residual stages
    d_model=32,  # base conv channels
    n_heads=3,  # anchor boxes per scale (B in the paper)
    n_kv_heads=3,
    d_ff=0,
    vocab_size=3,  # C classes (e.g. fire / smoke / disaster)
    causal=False,
    modality="image",
    source="AAAI 2020 FedVision (Redmon & Farhadi 2018)",
)
