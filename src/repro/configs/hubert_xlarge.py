"""hubert-xlarge [audio] — encoder-only, same arch as w2v2. [arXiv:2106.07447]

The conv/mel frontend is a stub per the assignment carve-out: input_specs()
provides pre-computed frame embeddings (B, T, d_model); the training
objective is HuBERT masked cluster prediction over vocab=504 cluster ids.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    tie_embeddings=False,
    modality="audio",
    source="arXiv:2106.07447",
)
