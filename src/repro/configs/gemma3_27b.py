"""gemma3-27b [dense] — 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt]

Layer pattern repeats with period 6: five local (1024-token sliding window)
layers then one global layer. 62 layers -> 10 full periods + 2 local layers.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    d_ff=21504,
    vocab_size=262144,
    head_dim=128,
    qk_norm=True,
    window=1024,
    local_global_period=6,
    source="hf:google/gemma-3-1b-pt",
)
