"""FedVision reproduction: federated visual/LM training on jax+Pallas."""
from repro import _jax_compat  # noqa: F401 — uniform jax API across versions
