"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

The detection oracles (`pairwise_iou_np`, `nms_np`) are pure NumPy and run
entirely host-side: every op is a plain IEEE add/sub/mul/div/min/max in
float32, mirroring the kernel bodies in `kernels.detect` op for op, so the
golden tests pin the Pallas outputs against them *bit-for-bit* in
interpret mode — not merely allclose.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def fedavg_masked_mean(stacked: jax.Array, weights: jax.Array, mask: jax.Array) -> jax.Array:
    """Fused Eq.5 + Eq.6 for one layer tensor.

    stacked: (C, N); weights: (C,) scheduler weights; mask: (C,) 0/1 upload
    mask for this layer. out[n] = sum_c w_c m_c x_cn / max(sum_c w_c m_c, eps).
    """
    wm = (weights * mask).astype(jnp.float32)
    num = jnp.einsum("c,cn->n", wm, stacked.astype(jnp.float32))
    den = jnp.maximum(jnp.sum(wm), 1e-12)
    return (num / den).astype(stacked.dtype)


def packed_bucket_reduce(packed: jax.Array, wmask: jax.Array, bucket_ids: jax.Array, mask: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.pack.packed_bucket_reduce.

    packed: (C, N); wmask: (C, B) per-(client, bucket) weights; bucket_ids:
    (N,) int32; mask: optional (C,) 0/1 participation vector (None -> all).
    Returns (num (N,), den (N,)) f32.
    """
    wm = wmask.astype(jnp.float32)
    if mask is not None:
        wm = wm * mask.astype(jnp.float32)[:, None]
    w = jnp.take(wm, bucket_ids, axis=1)  # (C, N)
    num = jnp.sum(packed.astype(jnp.float32) * w, axis=0)
    return num, jnp.sum(w, axis=0)


def quantize_blocks(x: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 per block of `block` elements. x: (N,), N % block == 0.

    Returns (q int8 (N,), scales f32 (N/block,)).
    """
    xb = x.reshape(-1, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_blocks(q: jax.Array, scales: jax.Array, block: int, dtype=jnp.float32) -> jax.Array:
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(-1).astype(dtype)


_IOU_EPS = np.float32(1e-9)


def _corners_np(boxes: np.ndarray):
    """(..., 4) center-format float32 -> x1, y1, x2, y2, area (all f32)."""
    boxes = np.asarray(boxes, np.float32)
    x1 = boxes[..., 0] - boxes[..., 2] * np.float32(0.5)
    y1 = boxes[..., 1] - boxes[..., 3] * np.float32(0.5)
    x2 = boxes[..., 0] + boxes[..., 2] * np.float32(0.5)
    y2 = boxes[..., 1] + boxes[..., 3] * np.float32(0.5)
    area = np.maximum((x2 - x1) * (y2 - y1), np.float32(0.0))
    return x1, y1, x2, y2, area


def pairwise_iou_np(boxes_a: np.ndarray, boxes_b: np.ndarray, giou: bool = False) -> np.ndarray:
    """NumPy oracle for kernels.detect.pairwise_iou (bit-for-bit).

    boxes_a (B?, N, 4), boxes_b (B?, M, 4) center-format -> (B?, N, M) f32.
    Zero-area boxes score IoU 0 against everything (eps floor, no NaN).
    """
    ax1, ay1, ax2, ay2, aa = _corners_np(boxes_a)
    bx1, by1, bx2, by2, ba = _corners_np(boxes_b)
    ix = np.maximum(np.minimum(ax2[..., :, None], bx2[..., None, :]) - np.maximum(ax1[..., :, None], bx1[..., None, :]), np.float32(0.0))
    iy = np.maximum(np.minimum(ay2[..., :, None], by2[..., None, :]) - np.maximum(ay1[..., :, None], by1[..., None, :]), np.float32(0.0))
    inter = np.maximum(ix * iy, np.float32(0.0))
    union = aa[..., :, None] + ba[..., None, :] - inter
    iou = inter / np.maximum(union, _IOU_EPS)
    if not giou:
        return iou
    cx = np.maximum(ax2[..., :, None], bx2[..., None, :]) - np.minimum(ax1[..., :, None], bx1[..., None, :])
    cy = np.maximum(ay2[..., :, None], by2[..., None, :]) - np.minimum(ay1[..., :, None], by1[..., None, :])
    carea = np.maximum(cx * cy, np.float32(0.0))
    return iou - (carea - union) / np.maximum(carea, _IOU_EPS)


def nms_np(
    boxes: np.ndarray,
    scores: np.ndarray,
    iou_thresh: float = 0.5,
    score_thresh: float = 0.0,
    max_keep: int = 0,
) -> np.ndarray:
    """NumPy oracle for kernels.detect.nms (bit-for-bit).

    Same contract: stable descending-score sort (ties keep original order),
    sequential suppression over the sorted list, 0/1 keep mask returned in
    the ORIGINAL box order; ``max_keep > 0`` caps survivors to the top
    max_keep by score.
    """
    boxes = np.asarray(boxes, np.float32)
    scores = np.asarray(scores, np.float32)
    squeeze = boxes.ndim == 2
    if squeeze:
        boxes, scores = boxes[None], scores[None]
    B, N = scores.shape
    keep = np.zeros((B, N), np.float32)
    for b in range(B):
        order = np.argsort(-scores[b], kind="stable")
        bs = boxes[b][order]
        x1, y1, x2, y2, area = _corners_np(bs)
        k = (scores[b][order] > np.float32(score_thresh)).astype(np.float32)
        for i in range(N):
            if k[i] <= 0:
                continue
            ix = np.maximum(np.minimum(x2[i], x2) - np.maximum(x1[i], x1), np.float32(0.0))
            iy = np.maximum(np.minimum(y2[i], y2) - np.maximum(y1[i], y1), np.float32(0.0))
            inter = np.maximum(ix * iy, np.float32(0.0))
            iou = inter / np.maximum(area[i] + area - inter, _IOU_EPS)
            k[(np.arange(N) > i) & (iou > np.float32(iou_thresh))] = 0.0
        if max_keep:
            k = k * (np.cumsum(k) <= max_keep).astype(np.float32)
        keep[b][order] = k
    return keep[0] if squeeze else keep


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, window: int = 0) -> jax.Array:
    """Reference attention. q: (B, H, S, hd); k/v: (B, Hkv, S, hd).

    GQA mapping: q head h uses kv head h // (H // Hkv). window > 0 limits
    causal attention to the trailing `window` positions.
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, hd)
    scores = jnp.einsum("bkgsh,bkth->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)


def ssd_chunk(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array):
    """Intra-chunk SSD for ONE chunk (the Pallas kernel body's math).

    xdt: (Q, H, P) [x*dt]; dA: (Q, H); Bm/Cm: (Q, N).
    Returns (y_diag (Q,H,P), states (H,P,N), chunk_decay (H,)).
    """
    Q = xdt.shape[0]
    cum = jnp.cumsum(dA.astype(jnp.float32), axis=0)  # (Q,H)
    diff = cum[:, None, :] - cum[None, :, :]  # (Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[:, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("qn,tn->qt", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    y_diag = jnp.einsum("qt,qth,thp->qhp", scores, L, xdt.astype(jnp.float32))
    decay_states = jnp.exp(cum[-1:, :] - cum)  # (Q,H)
    states = jnp.einsum("tn,th,thp->hpn", Bm.astype(jnp.float32), decay_states, xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[-1])  # (H,)
    return y_diag.astype(xdt.dtype), states, chunk_decay


# ---------------------------------------------------------------------------
# communication-frontier oracles (DESIGN.md §15): counter PRNG, 4-bit
# quantization, nibble packing, top-k selection, pairwise integer masking.
# All pure NumPy uint32/float32 so the jnp refs in `core.packing` and the
# Pallas kernels in `kernels.quant4` / `kernels.mask` pin against them
# bit-for-bit (every op below is an exact IEEE/modular twin of the traced
# version).
# ---------------------------------------------------------------------------

_FMIX_C1 = np.uint32(0x85EBCA6B)
_FMIX_C2 = np.uint32(0xC2B2AE35)
GOLDEN = np.uint32(0x9E3779B9)  # round/session mixing constant
IDX_C = np.uint32(0x9E3779B1)  # client-index stride (quant4 counter, pair lo)
IDX_N = np.uint32(0x85EBCA77)  # element-index stride (quant4 counter, pair hi)
IDX_E = np.uint32(0xC2B2AE3D)  # mask element stride (secure pair masks)


def fmix32_np(h) -> np.ndarray:
    """murmur3 fmix32 finalizer over uint32 (scalar or array) — the shared
    counter-based PRNG: uint32 wraparound is the modular arithmetic, so the
    NumPy, jnp (`packing.fmix32`) and in-kernel versions are bit-identical."""
    h = np.asarray(h, np.uint32).copy()
    h ^= h >> np.uint32(16)
    h *= _FMIX_C1
    h ^= h >> np.uint32(13)
    h *= _FMIX_C2
    h ^= h >> np.uint32(16)
    return h


def round_key_np(seed: int, round_idx: int) -> np.uint32:
    """Per-round PRNG key: fmix32(seed ^ fmix32(round + GOLDEN))."""
    # 0-d arrays throughout: NumPy's scalar path warns on (intended) wraparound
    r = fmix32_np(np.asarray(round_idx & 0xFFFFFFFF, np.uint32) + GOLDEN)
    return np.uint32(fmix32_np(np.asarray(seed & 0xFFFFFFFF, np.uint32) ^ r))


def counter_uniform_np(key, c, n) -> np.ndarray:
    """u in [0, 1) f32 for (client c, flat element n) under `key`.

    24 high bits of the counter hash scaled by 2^-24 — both steps exact in
    f32, so traced and host-side values agree bitwise."""
    bits = fmix32_np(
        np.asarray(key, np.uint32) + np.asarray(c, np.uint32) * IDX_C + np.asarray(n, np.uint32) * IDX_N
    )
    return (bits >> np.uint32(8)).astype(np.float32) * np.float32(2.0**-24)


def quant4_blocks_np(x, block: int, *, mode: str = "nearest", key=0, c=0):
    """(N,) f32 -> (q int8 in [-7, 7] (Npad,), scales f32 (Npad/block,)).

    Symmetric 4-bit per `block` elements: scale = max(amax, 1e-12)/7.
    mode "nearest": q = clip(rint(x/s), -7, 7); "stochastic":
    q = clip(floor(x/s + u), -7, 7) with u the counter uniform for (client
    c, global element n). The clip runs AFTER the floor: 7 + u can round to
    8.0 in f32, so clipping the pre-floor sum would be off by one step.
    Zero padding quantizes to exactly 0 in either mode (floor(u) == 0)."""
    x = np.asarray(x, np.float32)
    pad = (-x.shape[0]) % block
    xp = np.pad(x, (0, pad))
    xb = xp.reshape(-1, block)
    amax = np.max(np.abs(xb), axis=1)
    scale = np.maximum(amax, np.float32(1e-12)) / np.float32(7.0)
    v = xb / scale[:, None]
    if mode == "nearest":
        q = np.clip(np.rint(v), np.float32(-7), np.float32(7))
    else:
        u = counter_uniform_np(key, c, np.arange(len(xp), dtype=np.uint32))
        q = np.clip(np.floor(v + u.reshape(-1, block)), np.float32(-7), np.float32(7))
    return q.reshape(-1).astype(np.int8), scale


def dequant4_blocks_np(q, scales, block: int) -> np.ndarray:
    qb = np.asarray(q, np.float32).reshape(-1, block)
    return (qb * np.asarray(scales, np.float32)[:, None]).reshape(-1)


def quant4_reduce_np(delta, weights, block: int, *, mode: str = "nearest", key=0) -> np.ndarray:
    """Fused oracle for kernels.quant4.quant4_reduce: per-client 4-bit
    encode -> decode -> weighted client sum. The per-client q values are
    bit-exact twins of the kernel's; the final sum differs only in
    accumulation order (kernel pins allclose, q pins bitwise)."""
    delta = np.asarray(delta, np.float32)
    C, N = delta.shape
    acc = np.zeros((N + (-N) % block,), np.float32)
    for c in range(C):
        q, s = quant4_blocks_np(delta[c], block, mode=mode, key=key, c=c)
        acc += dequant4_blocks_np(q, s, block) * np.float32(weights[c])
    return acc[:N]


def pack_nibbles_np(q) -> np.ndarray:
    """int8 values in [-8, 7] -> two's-complement nibbles, two per byte
    (low nibble first; odd length pads one zero nibble)."""
    u = np.asarray(q, np.int8).astype(np.uint8) & np.uint8(0xF)
    if len(u) % 2:
        u = np.append(u, np.uint8(0))
    return (u[0::2] | (u[1::2] << np.uint8(4))).astype(np.uint8)


def unpack_nibbles_np(buf, n: int) -> np.ndarray:
    """Inverse of pack_nibbles_np: first n sign-extended int8 values."""
    b = np.asarray(buf, np.uint8)
    u = np.empty(len(b) * 2, np.uint8)
    u[0::2] = b & np.uint8(0xF)
    u[1::2] = b >> np.uint8(4)
    return ((u[:n].astype(np.int16) ^ 8) - 8).astype(np.int8)


def topk_select_np(acc, k: int) -> np.ndarray:
    """(C, N) -> bool (C, N): per-row |value| >= that row's k-th largest
    |value|. Ties at the threshold all select — same contract as
    thresholding on lax.top_k's k-th value, so the selection can exceed k
    elements only on exact magnitude ties."""
    a = np.abs(np.asarray(acc, np.float32))
    thr = -np.sort(-a, axis=1, kind="stable")[:, k - 1]
    return a >= thr[:, None]


def pair_key_np(round_key, a, b) -> np.ndarray:
    """Symmetric per-pair key: ordered (lo, hi) chain of fmix32 mixes."""
    # 0-d arrays: scalar uint32 ops warn on (intended) wraparound
    lo = np.asarray(np.minimum(np.asarray(a, np.uint32), np.asarray(b, np.uint32)))
    hi = np.asarray(np.maximum(np.asarray(a, np.uint32), np.asarray(b, np.uint32)))
    return fmix32_np(fmix32_np(np.asarray(round_key, np.uint32) + lo * IDX_C) ^ (hi * IDX_N))


def pair_mask_np(round_key, a, b, n: int) -> np.ndarray:
    """(n,) uint32 pairwise mask stream for the (a, b) client pair."""
    pk = pair_key_np(round_key, a, b)
    return fmix32_np(pk + np.arange(n, dtype=np.uint32) * IDX_E)


def secure_masked_rows_np(q, participation, round_key) -> np.ndarray:
    """q (C, N) int32 -> (C, N) uint32: each ACTIVE client's row in two's
    complement plus its pairwise masks (+m toward higher active peers, -m
    toward lower, uint32 wraparound); inactive rows are zero and contribute
    no mask — the Bonawitz cancellation restricted to participants."""
    q = np.asarray(q, np.int32)
    C, N = q.shape
    act = np.asarray(participation, np.float32) > 0
    out = np.zeros((C, N), np.uint32)
    for c in range(C):
        if not act[c]:
            continue
        row = q[c].view(np.uint32).copy()
        for p in range(C):
            if p == c or not act[p]:
                continue
            m = pair_mask_np(round_key, c, p, N)
            row = row + m if p > c else row - m
        out[c] = row
    return out


def secure_sum_np(q, participation, round_key, *, use_masks: bool = True) -> np.ndarray:
    """Server-side oracle: uint32 sum of the (masked) active rows,
    reinterpreted int32. With masks the pair terms cancel mod 2^32, so the
    result equals the unmasked sum BIT-FOR-BIT (|sum q| < 2^31 assumed —
    the aggregator's C * Q bound guarantees it)."""
    q = np.asarray(q, np.int32)
    act = np.asarray(participation, np.float32) > 0
    if use_masks:
        rows = secure_masked_rows_np(q, participation, round_key)
    else:
        rows = np.where(act[:, None], q.view(np.uint32), np.uint32(0))
    total = np.zeros(q.shape[1], np.uint32)
    for c in range(q.shape[0]):
        if act[c]:
            total += rows[c]
    return total.view(np.int32)


def pair_seed_np(i: int, j: int, round_idx: int, session: int = 0) -> int:
    """uint32-mix twin of core.secure_agg.pair_seed — the PYTHONHASHSEED
    regression pin: both sides must produce this exact value."""
    a, b = (i, j) if i < j else (j, i)
    h = fmix32_np(np.asarray(session & 0xFFFFFFFF, np.uint32) + GOLDEN)
    h = fmix32_np(h ^ fmix32_np(np.asarray(round_idx & 0xFFFFFFFF, np.uint32) + GOLDEN))
    h = fmix32_np(h + np.asarray(a & 0xFFFFFFFF, np.uint32) * IDX_C)
    h = fmix32_np(h ^ (np.asarray(b & 0xFFFFFFFF, np.uint32) * IDX_N))
    return int(h) & 0x7FFFFFFF
