"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth).

The detection oracles (`pairwise_iou_np`, `nms_np`) are pure NumPy and run
entirely host-side: every op is a plain IEEE add/sub/mul/div/min/max in
float32, mirroring the kernel bodies in `kernels.detect` op for op, so the
golden tests pin the Pallas outputs against them *bit-for-bit* in
interpret mode — not merely allclose.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp


def fedavg_masked_mean(stacked: jax.Array, weights: jax.Array, mask: jax.Array) -> jax.Array:
    """Fused Eq.5 + Eq.6 for one layer tensor.

    stacked: (C, N); weights: (C,) scheduler weights; mask: (C,) 0/1 upload
    mask for this layer. out[n] = sum_c w_c m_c x_cn / max(sum_c w_c m_c, eps).
    """
    wm = (weights * mask).astype(jnp.float32)
    num = jnp.einsum("c,cn->n", wm, stacked.astype(jnp.float32))
    den = jnp.maximum(jnp.sum(wm), 1e-12)
    return (num / den).astype(stacked.dtype)


def packed_bucket_reduce(packed: jax.Array, wmask: jax.Array, bucket_ids: jax.Array, mask: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.pack.packed_bucket_reduce.

    packed: (C, N); wmask: (C, B) per-(client, bucket) weights; bucket_ids:
    (N,) int32; mask: optional (C,) 0/1 participation vector (None -> all).
    Returns (num (N,), den (N,)) f32.
    """
    wm = wmask.astype(jnp.float32)
    if mask is not None:
        wm = wm * mask.astype(jnp.float32)[:, None]
    w = jnp.take(wm, bucket_ids, axis=1)  # (C, N)
    num = jnp.sum(packed.astype(jnp.float32) * w, axis=0)
    return num, jnp.sum(w, axis=0)


def quantize_blocks(x: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 per block of `block` elements. x: (N,), N % block == 0.

    Returns (q int8 (N,), scales f32 (N/block,)).
    """
    xb = x.reshape(-1, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_blocks(q: jax.Array, scales: jax.Array, block: int, dtype=jnp.float32) -> jax.Array:
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(-1).astype(dtype)


_IOU_EPS = np.float32(1e-9)


def _corners_np(boxes: np.ndarray):
    """(..., 4) center-format float32 -> x1, y1, x2, y2, area (all f32)."""
    boxes = np.asarray(boxes, np.float32)
    x1 = boxes[..., 0] - boxes[..., 2] * np.float32(0.5)
    y1 = boxes[..., 1] - boxes[..., 3] * np.float32(0.5)
    x2 = boxes[..., 0] + boxes[..., 2] * np.float32(0.5)
    y2 = boxes[..., 1] + boxes[..., 3] * np.float32(0.5)
    area = np.maximum((x2 - x1) * (y2 - y1), np.float32(0.0))
    return x1, y1, x2, y2, area


def pairwise_iou_np(boxes_a: np.ndarray, boxes_b: np.ndarray, giou: bool = False) -> np.ndarray:
    """NumPy oracle for kernels.detect.pairwise_iou (bit-for-bit).

    boxes_a (B?, N, 4), boxes_b (B?, M, 4) center-format -> (B?, N, M) f32.
    Zero-area boxes score IoU 0 against everything (eps floor, no NaN).
    """
    ax1, ay1, ax2, ay2, aa = _corners_np(boxes_a)
    bx1, by1, bx2, by2, ba = _corners_np(boxes_b)
    ix = np.maximum(np.minimum(ax2[..., :, None], bx2[..., None, :]) - np.maximum(ax1[..., :, None], bx1[..., None, :]), np.float32(0.0))
    iy = np.maximum(np.minimum(ay2[..., :, None], by2[..., None, :]) - np.maximum(ay1[..., :, None], by1[..., None, :]), np.float32(0.0))
    inter = np.maximum(ix * iy, np.float32(0.0))
    union = aa[..., :, None] + ba[..., None, :] - inter
    iou = inter / np.maximum(union, _IOU_EPS)
    if not giou:
        return iou
    cx = np.maximum(ax2[..., :, None], bx2[..., None, :]) - np.minimum(ax1[..., :, None], bx1[..., None, :])
    cy = np.maximum(ay2[..., :, None], by2[..., None, :]) - np.minimum(ay1[..., :, None], by1[..., None, :])
    carea = np.maximum(cx * cy, np.float32(0.0))
    return iou - (carea - union) / np.maximum(carea, _IOU_EPS)


def nms_np(
    boxes: np.ndarray,
    scores: np.ndarray,
    iou_thresh: float = 0.5,
    score_thresh: float = 0.0,
    max_keep: int = 0,
) -> np.ndarray:
    """NumPy oracle for kernels.detect.nms (bit-for-bit).

    Same contract: stable descending-score sort (ties keep original order),
    sequential suppression over the sorted list, 0/1 keep mask returned in
    the ORIGINAL box order; ``max_keep > 0`` caps survivors to the top
    max_keep by score.
    """
    boxes = np.asarray(boxes, np.float32)
    scores = np.asarray(scores, np.float32)
    squeeze = boxes.ndim == 2
    if squeeze:
        boxes, scores = boxes[None], scores[None]
    B, N = scores.shape
    keep = np.zeros((B, N), np.float32)
    for b in range(B):
        order = np.argsort(-scores[b], kind="stable")
        bs = boxes[b][order]
        x1, y1, x2, y2, area = _corners_np(bs)
        k = (scores[b][order] > np.float32(score_thresh)).astype(np.float32)
        for i in range(N):
            if k[i] <= 0:
                continue
            ix = np.maximum(np.minimum(x2[i], x2) - np.maximum(x1[i], x1), np.float32(0.0))
            iy = np.maximum(np.minimum(y2[i], y2) - np.maximum(y1[i], y1), np.float32(0.0))
            inter = np.maximum(ix * iy, np.float32(0.0))
            iou = inter / np.maximum(area[i] + area - inter, _IOU_EPS)
            k[(np.arange(N) > i) & (iou > np.float32(iou_thresh))] = 0.0
        if max_keep:
            k = k * (np.cumsum(k) <= max_keep).astype(np.float32)
        keep[b][order] = k
    return keep[0] if squeeze else keep


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, window: int = 0) -> jax.Array:
    """Reference attention. q: (B, H, S, hd); k/v: (B, Hkv, S, hd).

    GQA mapping: q head h uses kv head h // (H // Hkv). window > 0 limits
    causal attention to the trailing `window` positions.
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, hd)
    scores = jnp.einsum("bkgsh,bkth->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)


def ssd_chunk(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array):
    """Intra-chunk SSD for ONE chunk (the Pallas kernel body's math).

    xdt: (Q, H, P) [x*dt]; dA: (Q, H); Bm/Cm: (Q, N).
    Returns (y_diag (Q,H,P), states (H,P,N), chunk_decay (H,)).
    """
    Q = xdt.shape[0]
    cum = jnp.cumsum(dA.astype(jnp.float32), axis=0)  # (Q,H)
    diff = cum[:, None, :] - cum[None, :, :]  # (Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[:, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("qn,tn->qt", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    y_diag = jnp.einsum("qt,qth,thp->qhp", scores, L, xdt.astype(jnp.float32))
    decay_states = jnp.exp(cum[-1:, :] - cum)  # (Q,H)
    states = jnp.einsum("tn,th,thp->hpn", Bm.astype(jnp.float32), decay_states, xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[-1])  # (H,)
    return y_diag.astype(xdt.dtype), states, chunk_decay
