"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fedavg_masked_mean(stacked: jax.Array, weights: jax.Array, mask: jax.Array) -> jax.Array:
    """Fused Eq.5 + Eq.6 for one layer tensor.

    stacked: (C, N); weights: (C,) scheduler weights; mask: (C,) 0/1 upload
    mask for this layer. out[n] = sum_c w_c m_c x_cn / max(sum_c w_c m_c, eps).
    """
    wm = (weights * mask).astype(jnp.float32)
    num = jnp.einsum("c,cn->n", wm, stacked.astype(jnp.float32))
    den = jnp.maximum(jnp.sum(wm), 1e-12)
    return (num / den).astype(stacked.dtype)


def packed_bucket_reduce(packed: jax.Array, wmask: jax.Array, bucket_ids: jax.Array, mask: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.pack.packed_bucket_reduce.

    packed: (C, N); wmask: (C, B) per-(client, bucket) weights; bucket_ids:
    (N,) int32; mask: optional (C,) 0/1 participation vector (None -> all).
    Returns (num (N,), den (N,)) f32.
    """
    wm = wmask.astype(jnp.float32)
    if mask is not None:
        wm = wm * mask.astype(jnp.float32)[:, None]
    w = jnp.take(wm, bucket_ids, axis=1)  # (C, N)
    num = jnp.sum(packed.astype(jnp.float32) * w, axis=0)
    return num, jnp.sum(w, axis=0)


def quantize_blocks(x: jax.Array, block: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 per block of `block` elements. x: (N,), N % block == 0.

    Returns (q int8 (N,), scales f32 (N/block,)).
    """
    xb = x.reshape(-1, block).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1), scale


def dequantize_blocks(q: jax.Array, scales: jax.Array, block: int, dtype=jnp.float32) -> jax.Array:
    qb = q.reshape(-1, block).astype(jnp.float32)
    return (qb * scales[:, None]).reshape(-1).astype(dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *, causal: bool = True, window: int = 0) -> jax.Array:
    """Reference attention. q: (B, H, S, hd); k/v: (B, Hkv, S, hd).

    GQA mapping: q head h uses kv head h // (H // Hkv). window > 0 limits
    causal attention to the trailing `window` positions.
    """
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, S, hd)
    scores = jnp.einsum("bkgsh,bkth->bkgst", qg.astype(jnp.float32), k.astype(jnp.float32))
    scores = scores / jnp.sqrt(jnp.float32(hd))
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qp >= kp
    if window:
        mask &= qp - kp < window
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,bkth->bkgsh", probs, v.astype(jnp.float32))
    return out.reshape(B, H, S, hd).astype(q.dtype)


def ssd_chunk(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array):
    """Intra-chunk SSD for ONE chunk (the Pallas kernel body's math).

    xdt: (Q, H, P) [x*dt]; dA: (Q, H); Bm/Cm: (Q, N).
    Returns (y_diag (Q,H,P), states (H,P,N), chunk_decay (H,)).
    """
    Q = xdt.shape[0]
    cum = jnp.cumsum(dA.astype(jnp.float32), axis=0)  # (Q,H)
    diff = cum[:, None, :] - cum[None, :, :]  # (Q,Q,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))[:, :, None]
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.einsum("qn,tn->qt", Cm.astype(jnp.float32), Bm.astype(jnp.float32))
    y_diag = jnp.einsum("qt,qth,thp->qhp", scores, L, xdt.astype(jnp.float32))
    decay_states = jnp.exp(cum[-1:, :] - cum)  # (Q,H)
    states = jnp.einsum("tn,th,thp->hpn", Bm.astype(jnp.float32), decay_states, xdt.astype(jnp.float32))
    chunk_decay = jnp.exp(cum[-1])  # (H,)
    return y_diag.astype(xdt.dtype), states, chunk_decay
