"""Pallas kernels over the packed (C, N_total) aggregation buffer.

The reduction kernels run on a 2-D ``(N-block x client-block)`` grid
(DESIGN.md §11): the N axis is the outer grid dim, clients the inner, and
partial sums accumulate into the revisited output block across consecutive
client steps. Each grid step therefore loads only a ``(BLOCK_C, BLOCK_N)``
window — the old single-axis grid reloaded *all* C rows per N-block, which
is exactly why the monolithic launches lost to the per-leaf tree path once
C x BLOCK_N outgrew VMEM.

`packed_bucket_reduce` additionally tiles the bucket -> weight recovery:
per N-block the one-hot matmul runs over a ``bucket_tile`` window of the
(C, B) weight-mask (a block of a sorted-id buffer touches few buckets;
`packing.bucket_tile_bound` gives the static bound), not all B columns.

`quant8_reduce` fuses the int8 transport into the reduction — encode
(per-block amax scale, round, clip), decode, and the weighted client sum in
ONE launch, versus the old encode -> decode -> reduce triple pass.
`quantize_rows` survives for the sharded transport, where the int8 payload
must materialize for the all_gather (the gathered decode+reduce then runs
fused via `packing.dequant_reduce_ref`); `dequantize_rows` is its
standalone inverse, used by tests/tooling rather than the round path.

`grouped_reduce` is the hierarchical inner reduce (DESIGN.md §13): a 3-D
``(N-block x group x member-block)`` grid turns every edge group's
renormalized weighted mean into one accumulating launch, so the two-level
`hier` aggregator costs one launch for all C/G groups plus the registered
outer reduce over (C/G, N) rows.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024
BLOCK_C = 8


def client_block(C: int) -> int:
    """Client-block width for a C-row launch. BLOCK_C=8 was tuned at the
    C=8 federation; at C=256/1024 an 8-row block revisits every output
    N-block C/8 times, and the revisit overhead (output reload + grid-step
    bookkeeping) dominates. Wider client blocks amortize the revisits while
    a (32, BLOCK_N) f32 window still sits far under VMEM."""
    if C <= 64:
        return BLOCK_C
    return 32


def _pad_rows(x: jax.Array, block_c: int) -> jax.Array:
    pad = (-x.shape[0]) % block_c
    return jnp.pad(x, ((0, pad),) + ((0, 0),) * (x.ndim - 1)) if pad else x


def _reduce_kernel(x_ref, wm_ref, pm_ref, bid_ref, b0_ref, num_ref, den_ref, *, bucket_tile):
    ci = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (BC, BN)
    wm = wm_ref[...].astype(jnp.float32)  # (BC, B + TB) zero-padded columns
    pm = pm_ref[...].astype(jnp.float32)  # (BC, 1) participation mask
    b0 = b0_ref[0]  # first bucket this N-block touches
    bn = x.shape[1]
    # bucket-tiled weight recovery: slice the TB-wide bucket window, then
    # one-hot matmul on the MXU over TB columns instead of all B. Padding
    # positions carry bucket id B, which lands in the zero-padded columns.
    wt = jax.lax.dynamic_slice(wm * pm, (0, b0), (wm.shape[0], bucket_tile))
    local = bid_ref[...] - b0  # (BN,) in [0, TB) for real elements
    onehot = (
        jax.lax.broadcasted_iota(jnp.int32, (bucket_tile, bn), 0) == local[None, :]
    ).astype(jnp.float32)
    w = jnp.dot(wt, onehot, preferred_element_type=jnp.float32)  # (BC, BN)
    pnum = jnp.sum(x * w, axis=0)
    pden = jnp.sum(w, axis=0)

    @pl.when(ci == 0)
    def _():
        num_ref[...] = pnum
        den_ref[...] = pden

    @pl.when(ci > 0)
    def _():
        num_ref[...] += pnum
        den_ref[...] += pden


@functools.partial(jax.jit, static_argnames=("interpret", "block_n", "block_c", "bucket_tile"))
def packed_bucket_reduce(
    packed: jax.Array,
    wmask: jax.Array,
    bucket_ids: jax.Array,
    mask: jax.Array | None = None,
    *,
    interpret: bool = True,
    block_n: int = BLOCK_N,
    block_c: int | None = None,
    bucket_tile: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """packed (C, N), wmask (C, B), bucket_ids (N,), mask (C,) or None
    -> (num (N,), den (N,)).

    num[n] = sum_c mask[c] wmask[c, bucket_ids[n]] * packed[c, n];
    den[n] = sum_c mask[c] wmask[c, bucket_ids[n]]. `mask` is the 0/1
    participation vector from the scheduler (None -> all participate); it is
    a traced operand, so per-round selection changes never retrace. N pads
    to block_n (padding gets bucket id B, whose weight column is zero) and C
    pads to block_c with zero-weight rows (block_c None -> `client_block(C)`:
    wider client blocks at C > 64). `bucket_tile` bounds how many buckets
    one N-block spans (packing.bucket_tile_bound for a real spec);
    None means B — always safe, e.g. for unsorted id vectors.
    """
    C, N = packed.shape
    B = wmask.shape[1]
    if mask is None:
        mask = jnp.ones((C,), jnp.float32)
    tb = B if bucket_tile is None else min(bucket_tile, B)
    pad = (-N) % block_n
    if pad:
        packed = jnp.pad(packed, ((0, 0), (0, pad)))
        bucket_ids = jnp.pad(bucket_ids, (0, pad), constant_values=B)
    npad = N + pad
    bc = min(client_block(C) if block_c is None else block_c, C)
    packed = _pad_rows(packed, bc)
    cpad = packed.shape[0]
    # zero-pad TB weight columns so the dynamic_slice window never reads
    # real buckets' weights for padding ids, and zero-weight padding rows
    wmp = jnp.pad(wmask.astype(jnp.float32), ((0, cpad - C), (0, tb)))
    pmp = jnp.pad(mask.astype(jnp.float32).reshape(C, 1), ((0, cpad - C), (0, 0)))
    ids = bucket_ids.astype(jnp.int32)
    b0 = jnp.min(ids.reshape(npad // block_n, block_n), axis=1)  # (nblocks,)
    num, den = pl.pallas_call(
        functools.partial(_reduce_kernel, bucket_tile=tb),
        grid=(npad // block_n, cpad // bc),
        in_specs=[
            pl.BlockSpec((bc, block_n), lambda j, ci: (ci, j)),
            pl.BlockSpec((bc, B + tb), lambda j, ci: (ci, 0)),
            pl.BlockSpec((bc, 1), lambda j, ci: (ci, 0)),
            pl.BlockSpec((block_n,), lambda j, ci: (j,)),
            pl.BlockSpec((1,), lambda j, ci: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda j, ci: (j,)),
            pl.BlockSpec((block_n,), lambda j, ci: (j,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(packed, wmp, pmp, ids, b0)
    return num[:N], den[:N]


def _rowquant_kernel(x_ref, q_ref, s_ref, *, block):
    x = x_ref[...].astype(jnp.float32)  # (BC, BN)
    bc, bn = x.shape
    xb = x.reshape(bc, bn // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)
    q_ref[...] = q.reshape(bc, bn).astype(jnp.int8)
    s_ref[...] = scale


def _rowdequant_kernel(q_ref, s_ref, o_ref, *, block):
    q = q_ref[...].astype(jnp.float32)
    bc, bn = q.shape
    d = q.reshape(bc, bn // block, block) * s_ref[...][..., None]
    o_ref[...] = d.reshape(bc, bn).astype(o_ref.dtype)


def _quant_grid(C, N, block, block_n, block_c):
    bn = max(block_n, block)
    bn -= bn % block
    pad = (-N) % bn
    bc = min(block_c, C)
    return bn, pad, bc


@functools.partial(jax.jit, static_argnames=("interpret", "block", "block_n", "block_c"))
def quantize_rows(
    x: jax.Array, *, interpret: bool = True, block: int = BLOCK_N,
    block_n: int = 4 * BLOCK_N, block_c: int = BLOCK_C,
):
    """x (C, N) -> (q int8 (C, N), scales f32 (C, ceil(N/block))).

    Scale granularity is one f32 per `block` elements per client row; each
    grid step quantizes a (block_c, block_n) window (block_n a multiple of
    block), so the whole packed buffer is one launch.
    """
    C, N = x.shape
    bn, pad, bc = _quant_grid(C, N, block, block_n, block_c)
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    x = _pad_rows(x, bc)
    cpad = x.shape[0]
    nb = (N + pad) // block
    nb_real = -(-N // block)  # ceil: the scale sideband's real width
    q, s = pl.pallas_call(
        functools.partial(_rowquant_kernel, block=block),
        grid=((N + pad) // bn, cpad // bc),
        in_specs=[pl.BlockSpec((bc, bn), lambda j, ci: (ci, j))],
        out_specs=[
            pl.BlockSpec((bc, bn), lambda j, ci: (ci, j)),
            pl.BlockSpec((bc, bn // block), lambda j, ci: (ci, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((cpad, N + pad), jnp.int8),
            jax.ShapeDtypeStruct((cpad, nb), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:C, :N], s[:C, :nb_real]


@functools.partial(jax.jit, static_argnames=("interpret", "block", "dtype", "block_n", "block_c"))
def dequantize_rows(
    q: jax.Array, scales: jax.Array, *, dtype=jnp.float32, interpret: bool = True,
    block: int = BLOCK_N, block_n: int = 4 * BLOCK_N, block_c: int = BLOCK_C,
) -> jax.Array:
    C, N = q.shape
    bn, pad, bc = _quant_grid(C, N, block, block_n, block_c)
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    q = _pad_rows(q, bc)
    cpad = q.shape[0]
    nb = (N + pad) // block
    s = jnp.pad(scales, ((0, 0), (0, nb - scales.shape[1])))
    s = _pad_rows(s, bc)
    out = pl.pallas_call(
        functools.partial(_rowdequant_kernel, block=block),
        grid=((N + pad) // bn, cpad // bc),
        in_specs=[
            pl.BlockSpec((bc, bn), lambda j, ci: (ci, j)),
            pl.BlockSpec((bc, bn // block), lambda j, ci: (ci, j)),
        ],
        out_specs=pl.BlockSpec((bc, bn), lambda j, ci: (ci, j)),
        out_shape=jax.ShapeDtypeStruct((cpad, N + pad), dtype),
        interpret=interpret,
    )(q, s)
    return out[:C, :N]


def _quant_reduce_kernel(x_ref, w_ref, num_ref, *, block):
    ci = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (BC, BN) delta window
    w = w_ref[...].astype(jnp.float32)  # (BC, 1)
    bc, bn = x.shape
    xb = x.reshape(bc, bn // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xb / scale[..., None]), -127, 127)  # int8 values, f32 lanes
    d = (q * scale[..., None]).reshape(bc, bn)
    partial = jnp.sum(d * w, axis=0)

    @pl.when(ci == 0)
    def _():
        num_ref[...] = partial

    @pl.when(ci > 0)
    def _():
        num_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret", "block", "block_n", "block_c"))
def quant8_reduce(
    delta: jax.Array, weights: jax.Array, *, interpret: bool = True,
    block: int = BLOCK_N, block_n: int = 4 * BLOCK_N, block_c: int = BLOCK_C,
) -> jax.Array:
    """Fused int8 transport: delta (C, N) + weights (C,) -> (N,) f32
    weighted sum of dequant(quant(delta)) in ONE launch (encode, decode and
    client reduction never leave the grid step). Matches
    `packing.quant8_mean_ref` — clip(round(x/s)) in f32 lanes is exactly the
    int8 value. Weights are used as-is; fold the participation mask in
    before calling. Zero-padding is exact: pad blocks quantize to 0.
    """
    C, N = delta.shape
    bn, pad, bc = _quant_grid(C, N, block, block_n, block_c)
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, pad)))
    delta = _pad_rows(delta, bc)
    cpad = delta.shape[0]
    wp = jnp.pad(weights.astype(jnp.float32).reshape(C, 1), ((0, cpad - C), (0, 0)))
    num = pl.pallas_call(
        functools.partial(_quant_reduce_kernel, block=block),
        grid=((N + pad) // bn, cpad // bc),
        in_specs=[
            pl.BlockSpec((bc, bn), lambda j, ci: (ci, j)),
            pl.BlockSpec((bc, 1), lambda j, ci: (ci, 0)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, ci: (j,)),
        out_shape=jax.ShapeDtypeStruct((N + pad,), jnp.float32),
        interpret=interpret,
    )(delta, wp)
    return num[:N]


def _grouped_kernel(x_ref, w_ref, out_ref):
    ci = pl.program_id(2)
    x = x_ref[0].astype(jnp.float32)  # (BC, BN) member window of one group
    w = w_ref[...].astype(jnp.float32)  # (1, BC) pre-normalized weights
    partial = jnp.sum(x * w.reshape(-1, 1), axis=0)

    @pl.when(ci == 0)
    def _():
        out_ref[...] = partial[None, :]

    @pl.when(ci > 0)
    def _():
        out_ref[...] += partial[None, :]


@functools.partial(jax.jit, static_argnames=("interpret", "block_n", "block_c"))
def grouped_reduce(
    packed: jax.Array, wn: jax.Array, *, interpret: bool = True,
    block_n: int = BLOCK_N, block_c: int | None = None,
) -> jax.Array:
    """Hierarchical inner reduce: packed (C, N) + wn (C/G, G) pre-normalized
    per-group weights -> (C/G, N) f32 group rows, ONE launch for all groups.

    ``out[g] = sum_i wn[g, i] * packed[g*G + i]``. The grid is 3-D
    (N-block x group x member-block): each step loads one group's
    (block_c, block_n) member window and accumulates into the revisited
    group-row output block — the same client-step accumulation as
    `packed_bucket_reduce`, batched over groups. Callers fold the 1/den
    group renormalization into ``wn`` (`packing.grouped_weighted_mean`);
    zero-weight padding rows keep the sums exact."""
    C, N = packed.shape
    ngroups, G = wn.shape
    assert ngroups * G == C, (wn.shape, packed.shape)
    bc = min(client_block(G) if block_c is None else block_c, G)
    gpad = (-G) % bc
    pad = (-N) % block_n
    if pad:
        packed = jnp.pad(packed, ((0, 0), (0, pad)))
    xg = packed.reshape(ngroups, G, N + pad)
    if gpad:
        xg = jnp.pad(xg, ((0, 0), (0, gpad), (0, 0)))
        wn = jnp.pad(wn, ((0, 0), (0, gpad)))
    npad, Gp = N + pad, G + gpad
    out = pl.pallas_call(
        _grouped_kernel,
        grid=(npad // block_n, ngroups, Gp // bc),
        in_specs=[
            pl.BlockSpec((1, bc, block_n), lambda j, g, ci: (g, ci, j)),
            pl.BlockSpec((1, bc), lambda j, g, ci: (g, ci)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda j, g, ci: (g, j)),
        out_shape=jax.ShapeDtypeStruct((ngroups, npad), jnp.float32),
        interpret=interpret,
    )(xg, wn)
    return out[:, :N]
