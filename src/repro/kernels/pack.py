"""Pallas kernels over the packed (C, N_total) aggregation buffer.

`packed_bucket_reduce` is the single launch the whole round's aggregation
lowers to: a tiled masked/weighted reduction over the flat buffer. Each grid
step loads one (C, BLOCK_N) window plus the small (C, B) per-bucket weight
mask and the (C, 1) participation mask from the Task Scheduler; the
per-element weights are recovered on the MXU as
``(mask * wmask) @ one_hot(bucket_ids)`` (B is n_layers+1, so the one-hot
matmul is tiny) and the client reduction runs on the VPU with f32
accumulation. Rows of non-participating clients (mask 0) contribute to
neither numerator nor denominator, so partial participation is one traced
operand away — no recompilation when the selection changes per round.

`quantize_rows` / `dequantize_rows` are the packed int8 transport: one 2-D
grid over (client row, block) quantizes the entire buffer in a single
launch, instead of a `tree_map` of per-leaf 1-D quant calls.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _reduce_kernel(x_ref, wm_ref, pm_ref, bid_ref, num_ref, den_ref):
    x = x_ref[...].astype(jnp.float32)  # (C, BN)
    wm = wm_ref[...].astype(jnp.float32)  # (C, B)
    pm = pm_ref[...].astype(jnp.float32)  # (C, 1) participation mask
    bid = bid_ref[...]  # (BN,) int32
    B = wm.shape[1]
    bn = bid.shape[0]
    # per-element weights via one-hot matmul (MXU): (C, B) @ (B, BN); the
    # participation mask zeroes whole client rows before the matmul
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (B, bn), 0) == bid[None, :]).astype(jnp.float32)
    w = jnp.dot(wm * pm, onehot, preferred_element_type=jnp.float32)  # (C, BN)
    num_ref[...] = jnp.sum(x * w, axis=0)
    den_ref[...] = jnp.sum(w, axis=0)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def packed_bucket_reduce(
    packed: jax.Array,
    wmask: jax.Array,
    bucket_ids: jax.Array,
    mask: jax.Array | None = None,
    *,
    interpret: bool = True,
    block_n: int = BLOCK_N,
) -> tuple[jax.Array, jax.Array]:
    """packed (C, N), wmask (C, B), bucket_ids (N,), mask (C,) or None
    -> (num (N,), den (N,)).

    num[n] = sum_c mask[c] wmask[c, bucket_ids[n]] * packed[c, n];
    den[n] = sum_c mask[c] wmask[c, bucket_ids[n]]. `mask` is the 0/1
    participation vector from the scheduler (None -> all participate);
    it is a traced operand, so per-round selection changes never retrace.
    N is padded to block_n internally (padding positions get bucket id B,
    which one-hots to zero).
    """
    C, N = packed.shape
    B = wmask.shape[1]
    if mask is None:
        mask = jnp.ones((C,), jnp.float32)
    pad = (-N) % block_n
    if pad:
        packed = jnp.pad(packed, ((0, 0), (0, pad)))
        bucket_ids = jnp.pad(bucket_ids, (0, pad), constant_values=B)
    npad = N + pad
    num, den = pl.pallas_call(
        _reduce_kernel,
        grid=(npad // block_n,),
        in_specs=[
            pl.BlockSpec((C, block_n), lambda i: (0, i)),
            pl.BlockSpec((C, B), lambda i: (0, 0)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ],
        interpret=interpret,
    )(
        packed,
        wmask.astype(jnp.float32),
        mask.astype(jnp.float32).reshape(C, 1),
        bucket_ids.astype(jnp.int32),
    )
    return num[:N], den[:N]


def _rowquant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)  # (1, BLOCK)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0, 0] = scale


def _rowdequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0, 0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def quantize_rows(x: jax.Array, *, interpret: bool = True, block: int = BLOCK_N):
    """x (C, N) -> (q int8 (C, N), scales f32 (C, ceil(N/block))).

    One 2-D-grid launch quantizing the whole packed buffer; scale
    granularity is one f32 per `block` elements per client row.
    """
    C, N = x.shape
    pad = (-N) % block
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    nb = (N + pad) // block
    q, s = pl.pallas_call(
        _rowquant_kernel,
        grid=(C, nb),
        in_specs=[pl.BlockSpec((1, block), lambda c, i: (c, i))],
        out_specs=[
            pl.BlockSpec((1, block), lambda c, i: (c, i)),
            pl.BlockSpec((1, 1), lambda c, i: (c, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((C, N + pad), jnp.int8),
            jax.ShapeDtypeStruct((C, nb), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:, :N], s


@functools.partial(jax.jit, static_argnames=("interpret", "block", "dtype"))
def dequantize_rows(q: jax.Array, scales: jax.Array, *, dtype=jnp.float32, interpret: bool = True, block: int = BLOCK_N) -> jax.Array:
    C, N = q.shape
    pad = (-N) % block
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _rowdequant_kernel,
        grid=(C, (N + pad) // block),
        in_specs=[
            pl.BlockSpec((1, block), lambda c, i: (c, i)),
            pl.BlockSpec((1, 1), lambda c, i: (c, i)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda c, i: (c, i)),
        out_shape=jax.ShapeDtypeStruct((C, N + pad), dtype),
        interpret=interpret,
    )(q, scales)
    return out[:, :N]
