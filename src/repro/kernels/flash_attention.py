"""Pallas TPU kernel: blockwise online-softmax attention (flash) forward.

Causal/windowed GQA attention with MXU-aligned (BLOCK_Q x BLOCK_K) tiles.
Grid (B, H, nq, nk) with the K dimension innermost & sequential; running
max/sum and the f32 accumulator live in VMEM scratch. Blocks fully outside
the causal/window band are skipped with pl.when, which is what realizes the
~2x causal saving the jnp reference (repro.kernels.ref.flash_attention)
cannot express.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLOCK_Q = 128
BLOCK_K = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *, scale, causal, window, bq, bk, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk
    # skip blocks fully outside the causal/window band
    relevant = True
    if causal:
        relevant = k_start <= q_start + bq - 1
    if window:
        relevant = relevant & (k_start + bk - 1 >= q_start - window + 1)

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= qpos >= kpos
        if window:
            mask &= qpos - kpos < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        corr = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "block_q", "block_k", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = BLOCK_Q,
    block_k: int = BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    """q (B,H,S,hd); k/v (B,Hkv,S,hd) -> (B,H,S,hd). S % blocks == 0."""
    B, H, S, hd = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / (hd ** 0.5)
    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, bq=block_q, bk=block_k, nk=nk
    )
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
