"""Pallas TPU kernel: Mamba2 SSD intra-chunk block.

One grid cell = one (batch, head, chunk): computes the quadratic intra-chunk
output Y_diag, the chunk's state contribution, the chunk decay, and exp(cum)
(needed by the host-side inter-chunk pass). The (Q x Q) decay matrix L lives
entirely in VMEM; Q = ssm_chunk (128 default) keeps it MXU-aligned. The
inter-chunk recurrence stays a lax.scan in ops.py (O(1) state, 500k-ready).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(xdt_ref, dA_ref, b_ref, c_ref, y_ref, st_ref, dec_ref, cum_ref):
    xdt = xdt_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dA = dA_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    Bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, N)
    Q = xdt.shape[0]
    cum = jnp.cumsum(dA)  # (Q,)
    diff = cum[:, None] - cum[None, :]  # (Q, Q)
    tri = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (Q, Q), 1
    )
    L = jnp.where(tri, jnp.exp(diff), 0.0)
    scores = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)  # (Q, Q)
    y = jnp.dot(scores * L, xdt, preferred_element_type=jnp.float32)  # (Q, P)
    decay_states = jnp.exp(cum[-1] - cum)  # (Q,)
    st = jnp.dot((Bm * decay_states[:, None]).T, xdt, preferred_element_type=jnp.float32)  # (N, P)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0, 0] = st.T  # (P, N)
    dec_ref[0, 0, 0] = jnp.exp(cum[-1])
    cum_ref[0, :, 0] = jnp.exp(cum)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array, *, chunk: int = 128, interpret: bool = True):
    """Intra-chunk pass. xdt (B,S,H,P); dA (B,S,H); Bm/Cm (B,S,N).

    Returns (y_diag (B,S,H,P) f32, states (B,nc,H,P,N) f32,
    chunk_decay (B,nc,H) f32, exp_cum (B,S,H) f32). S % chunk == 0.
    """
    B, S, H, P = xdt.shape
    N = Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    out = pl.pallas_call(
        _kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, 1, P, N), lambda b, h, c: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, nc, H), jnp.float32),
            jax.ShapeDtypeStruct((B, S, H), jnp.float32),
        ],
        interpret=interpret,
    )(xdt, dA, Bm, Cm)
    return out
