"""Secure-aggregation integer reduce kernel (DESIGN.md §15).

`masked_u32_sum` is the server side of the packed Bonawitz transport: the
participation-gated uint32 sum of the masked client rows, on the same 2-D
(N-block x client-block) accumulating grid as `kernels.pack`. All
arithmetic is mod-2^32 (uint32 lanes wrap), which IS the masking ring — the
pairwise masks cancel bit-exactly in this sum, not to float tolerance.
Mask construction itself stays in `packing.secure_client_masks` (shared by
the ref and kernel paths); only the hot gated reduction lives here.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pack import BLOCK_N, _pad_rows, client_block


def _masked_sum_kernel(x_ref, pm_ref, out_ref):
    ci = pl.program_id(1)
    x = x_ref[...]  # (BC, BN) uint32 masked rows
    pm = pm_ref[...].astype(jnp.float32)  # (BC, 1) participation
    partial = jnp.sum(
        jnp.where(pm > 0, x, jnp.uint32(0)), axis=0, dtype=jnp.uint32
    )

    @pl.when(ci == 0)
    def _():
        out_ref[...] = partial

    @pl.when(ci > 0)
    def _():
        out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret", "block_n", "block_c"))
def masked_u32_sum(
    rows: jax.Array, participation: jax.Array, *, interpret: bool = True,
    block_n: int = BLOCK_N, block_c: int | None = None,
) -> jax.Array:
    """rows (C, N) uint32 + participation (C,) -> (N,) uint32 modular sum
    of the participating rows, one accumulating launch. Padding rows carry
    participation 0, so the modular total is exact."""
    C, N = rows.shape
    pad = (-N) % block_n
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad)))
    bc = min(client_block(C) if block_c is None else block_c, C)
    rows = _pad_rows(rows, bc)
    cpad = rows.shape[0]
    pmp = jnp.pad(
        participation.astype(jnp.float32).reshape(C, 1), ((0, cpad - C), (0, 0))
    )
    out = pl.pallas_call(
        _masked_sum_kernel,
        grid=((N + pad) // block_n, cpad // bc),
        in_specs=[
            pl.BlockSpec((bc, block_n), lambda j, ci: (ci, j)),
            pl.BlockSpec((bc, 1), lambda j, ci: (ci, 0)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda j, ci: (j,)),
        out_shape=jax.ShapeDtypeStruct((N + pad,), jnp.uint32),
        interpret=interpret,
    )(rows, pmp)
    return out[:N]
