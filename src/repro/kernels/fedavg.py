"""Pallas kernel: fused FedAvg (Eq. 5) + Eq. 6 mask for one layer tensor.

The aggregation server's hot loop: out[n] = sum_c w_c m_c x[c,n] / den.
Tiled over N so the (C, BLOCK_N) window sits in VMEM; the weighted mask is
precomputed into a (C,) vector and the reduction runs on the VPU with an
f32 accumulator. 8-bit/bf16 inputs upcast in-register.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 1024


def _kernel(x_ref, wm_ref, den_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)  # (C, BN)
    wm = wm_ref[...].astype(jnp.float32)  # (C, 1)
    num = jnp.sum(x * wm, axis=0)  # (BN,)
    o_ref[...] = (num / den_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block_n"))
def fedavg_masked_mean(stacked: jax.Array, weights: jax.Array, mask: jax.Array, *, interpret: bool = True, block_n: int = BLOCK_N) -> jax.Array:
    """stacked (C, N) -> (N,). N padded to block_n internally."""
    C, N = stacked.shape
    pad = (-N) % block_n
    if pad:
        stacked = jnp.pad(stacked, ((0, 0), (0, pad)))
    npad = N + pad
    wm = (weights * mask).astype(jnp.float32)[:, None]  # (C,1)
    den = jnp.maximum(jnp.sum(wm), 1e-12).reshape(1)
    out = pl.pallas_call(
        _kernel,
        grid=(npad // block_n,),
        in_specs=[
            pl.BlockSpec((C, block_n), lambda i: (0, i)),
            pl.BlockSpec((C, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((npad,), stacked.dtype),
        interpret=interpret,
    )(stacked, wm, den)
    return out[:N]
