"""Pallas kernels: symmetric int8 block quantization of update deltas.

The transport stage of the quant8 aggregation mode: each BLOCK-element tile
is scaled by max|x|/127 and rounded on the VPU; dequant is the inverse.
Block size doubles as the scale granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 1024


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0] = scale


def _dequant_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def quantize(x: jax.Array, *, interpret: bool = True, block: int = BLOCK):
    """x (N,) -> (q int8 (N,), scales f32 (ceil(N/block),)). Pads with 0."""
    N = x.shape[0]
    pad = (-N) % block
    if pad:
        x = jnp.pad(x, (0, pad))
    nb = (N + pad) // block
    q, s = pl.pallas_call(
        _quant_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N + pad,), jnp.int8),
            jax.ShapeDtypeStruct((nb,), jnp.float32),
        ],
        interpret=interpret,
    )(x)
    return q[:N], s


@functools.partial(jax.jit, static_argnames=("interpret", "block", "dtype"))
def dequantize(q: jax.Array, scales: jax.Array, *, dtype=jnp.float32, interpret: bool = True, block: int = BLOCK) -> jax.Array:
    N = q.shape[0]
    pad = (-N) % block
    if pad:
        q = jnp.pad(q, (0, pad))
    out = pl.pallas_call(
        _dequant_kernel,
        grid=((N + pad) // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N + pad,), dtype),
        interpret=interpret,
    )(q, scales)
    return out[:N]
