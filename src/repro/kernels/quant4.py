"""Fused 4-bit transport kernel (DESIGN.md §15).

`quant4_reduce` is `kernels.pack.quant8_reduce`'s 4-bit sibling: per-block
symmetric quantization to the [-7, 7] nibble range, dequant, and the
weighted client sum in ONE launch on the same 2-D (N-block x client-block)
accumulating grid. The stochastic-rounding bits come from a counter-based
PRNG (murmur3 fmix32 over the GLOBAL (client, element) index — derived
in-kernel from program_id + iota, so every grid decomposition produces the
same stream) keyed by a TRACED uint32 scalar: the per-round key changes
every round without retracing, and `kernels.ref.quant4_reduce_np` /
`packing.quant4_mean_ref` generate the exact same bits host-side/traced.

The wire payload this models packs two nibbles per byte (codec.py); here —
as in quant8 — the nibble values live in f32 lanes (|q| <= 7 is exact) and
the payload never materializes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pack import BLOCK_C, BLOCK_N, _pad_rows, _quant_grid

_C1 = 0x85EBCA6B
_C2 = 0xC2B2AE35
_IDX_C = 0x9E3779B1
_IDX_N = 0x85EBCA77


def _fmix32(h):
    h = h ^ (h >> 16)
    h = h * jnp.uint32(_C1)
    h = h ^ (h >> 13)
    h = h * jnp.uint32(_C2)
    h = h ^ (h >> 16)
    return h


def _quant4_reduce_kernel(x_ref, w_ref, key_ref, num_ref, *, block, mode):
    j = pl.program_id(0)
    ci = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)  # (BC, BN) delta window
    w = w_ref[...].astype(jnp.float32)  # (BC, 1)
    bc, bn = x.shape
    xb = x.reshape(bc, bn // block, block)
    amax = jnp.max(jnp.abs(xb), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 7.0
    v = xb / scale[..., None]
    if mode == "nearest":
        q = jnp.clip(jnp.round(v), -7, 7)
    else:
        # global (client, element) indices: the counter stream is identical
        # for every grid decomposition; zero padding floors to exactly 0
        cg = ci * bc + jax.lax.broadcasted_iota(jnp.int32, (bc, bn), 0)
        ng = j * bn + jax.lax.broadcasted_iota(jnp.int32, (bc, bn), 1)
        bits = _fmix32(
            key_ref[0]
            + cg.astype(jnp.uint32) * jnp.uint32(_IDX_C)
            + ng.astype(jnp.uint32) * jnp.uint32(_IDX_N)
        )
        u = (bits >> 8).astype(jnp.float32) * jnp.float32(2.0**-24)
        # clip AFTER the floor: 7 + u can round to 8.0 in f32
        q = jnp.clip(jnp.floor(v + u.reshape(bc, bn // block, block)), -7, 7)
    d = (q * scale[..., None]).reshape(bc, bn)
    partial = jnp.sum(d * w, axis=0)

    @pl.when(ci == 0)
    def _():
        num_ref[...] = partial

    @pl.when(ci > 0)
    def _():
        num_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("interpret", "block", "mode", "block_n", "block_c"))
def quant4_reduce(
    delta: jax.Array, weights: jax.Array, key: jax.Array | int = 0, *,
    mode: str = "nearest", interpret: bool = True,
    block: int = BLOCK_N, block_n: int = 4 * BLOCK_N, block_c: int = BLOCK_C,
) -> jax.Array:
    """Fused 4-bit transport: delta (C, N) + weights (C,) [+ uint32 round
    key] -> (N,) f32 weighted sum of dequant(quant4(delta)) in ONE launch.
    ``mode`` is "nearest" (half-step error bound) or "stochastic"
    (counter-PRNG rounding, mean-unbiased); the key is a traced operand so
    per-round keys never retrace. Weights are used as-is; fold the
    participation mask in before calling. Matches `packing.quant4_mean_ref`
    bit-for-bit on the q values (the reduction differs only in
    accumulation order)."""
    C, N = delta.shape
    bn, pad, bc = _quant_grid(C, N, block, block_n, block_c)
    if pad:
        delta = jnp.pad(delta, ((0, 0), (0, pad)))
    delta = _pad_rows(delta, bc)
    cpad = delta.shape[0]
    wp = jnp.pad(weights.astype(jnp.float32).reshape(C, 1), ((0, cpad - C), (0, 0)))
    kv = jnp.asarray(key).astype(jnp.uint32).reshape(1)
    num = pl.pallas_call(
        functools.partial(_quant4_reduce_kernel, block=block, mode=mode),
        grid=((N + pad) // bn, cpad // bc),
        in_specs=[
            pl.BlockSpec((bc, bn), lambda j, ci: (ci, j)),
            pl.BlockSpec((bc, 1), lambda j, ci: (ci, 0)),
            pl.BlockSpec((1,), lambda j, ci: (0,)),
        ],
        out_specs=pl.BlockSpec((bn,), lambda j, ci: (j,)),
        out_shape=jax.ShapeDtypeStruct((N + pad,), jnp.float32),
        interpret=interpret,
    )(delta, wp, kv)
    return num[:N]
