"""Pallas detection kernels: pairwise IoU/GIoU matrix + mask-based NMS.

The federated eval engine (core.detection, DESIGN.md §10) replaces the
seed's O(pairs) per-pair Python IoU with two launches per eval batch:

``pairwise_iou`` — a tiled (batch, N-tile, M-tile) grid over center-format
box arrays; each grid step loads one (BN, 4) / (BM, 4) pair of box tiles
and emits the (BN, BM) IoU (or GIoU) block on the VPU. Boxes are tiny on
the lane axis (4 coordinates), so tiles block only the pair dims.

``nms`` — fixed-size, score-sorted, mask-based non-maximum suppression
with jit-stable shapes: the wrapper sorts by score (stable, so score ties
break by original index) and the kernel runs one grid step per image,
walking the N sorted boxes with a `fori_loop` that zeroes later boxes
overlapping a still-kept earlier box. The output is a 0/1 keep mask in the
*original* box order, never a dynamic-length index list — the whole eval
stays one compiled program.

Every op in both kernel bodies is plain IEEE add/sub/mul/div/min/max, so
the NumPy oracles in `kernels.ref` (`pairwise_iou_np`, `nms_np`) match
bit-for-bit in interpret mode (pinned by tests/test_detect.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_BOXES = 128
IOU_EPS = 1e-9


def _area(p):
    """Clamp a geometric product to >= 0 (areas/intersections are
    non-negative; negative-w/h degenerate boxes collapse to zero area).

    Doubles as the bit-for-bit guard: LLVM contracts `a - x*y` into an FMA
    (one rounding where NumPy rounds twice, a 1-ulp drift vs kernels.ref) —
    `jax.lax.optimization_barrier` does NOT stop that backend contraction.
    Routing every product through `max(., 0)` breaks the fsub(., fmul)
    pattern, so kernel and NumPy oracle round identically.
    (`w * 0.5` is exact — power-of-two scale — so corners need no guard.)
    """
    return jnp.maximum(p, 0.0)


def _corners(boxes):
    """(..., 4) center-format (x, y, w, h) -> x1, y1, x2, y2, area."""
    x1 = boxes[..., 0] - boxes[..., 2] * 0.5
    y1 = boxes[..., 1] - boxes[..., 3] * 0.5
    x2 = boxes[..., 0] + boxes[..., 2] * 0.5
    y2 = boxes[..., 1] + boxes[..., 3] * 0.5
    return x1, y1, x2, y2, _area((x2 - x1) * (y2 - y1))


def _iou_tile(a, b, giou: bool):
    """(BN, 4) x (BM, 4) -> (BN, BM) IoU (or GIoU) block.

    Shared between the kernel body and the jnp fallback; zero-area boxes
    get IoU 0 against everything (the eps floor, never NaN).
    """
    ax1, ay1, ax2, ay2, aa = _corners(a)
    bx1, by1, bx2, by2, ba = _corners(b)
    ix = jnp.maximum(jnp.minimum(ax2[:, None], bx2[None, :]) - jnp.maximum(ax1[:, None], bx1[None, :]), 0.0)
    iy = jnp.maximum(jnp.minimum(ay2[:, None], by2[None, :]) - jnp.maximum(ay1[:, None], by1[None, :]), 0.0)
    inter = _area(ix * iy)
    union = aa[:, None] + ba[None, :] - inter
    iou = inter / jnp.maximum(union, IOU_EPS)
    if not giou:
        return iou
    cx = jnp.maximum(ax2[:, None], bx2[None, :]) - jnp.minimum(ax1[:, None], bx1[None, :])
    cy = jnp.maximum(ay2[:, None], by2[None, :]) - jnp.minimum(ay1[:, None], by1[None, :])
    carea = _area(cx * cy)
    return iou - (carea - union) / jnp.maximum(carea, IOU_EPS)


def _iou_kernel(a_ref, b_ref, o_ref, *, giou):
    o_ref[0] = _iou_tile(a_ref[0].astype(jnp.float32), b_ref[0].astype(jnp.float32), giou)


@functools.partial(jax.jit, static_argnames=("giou", "interpret", "block_n", "block_m"))
def pairwise_iou(
    boxes_a: jax.Array,
    boxes_b: jax.Array,
    *,
    giou: bool = False,
    interpret: bool = True,
    block_n: int = BLOCK_BOXES,
    block_m: int = BLOCK_BOXES,
) -> jax.Array:
    """boxes_a (B?, N, 4), boxes_b (B?, M, 4) center-format -> (B?, N, M).

    One launch over a (B, ceil(N/bn), ceil(M/bm)) grid; a leading batch dim
    is optional and becomes the outer grid axis (no vmap of the kernel).
    N/M are padded to the tile sizes internally with zero-area boxes, whose
    IoU against anything is 0 — the padding is sliced off before returning.
    """
    squeeze = boxes_a.ndim == 2
    if squeeze:
        boxes_a, boxes_b = boxes_a[None], boxes_b[None]
    B, N, _ = boxes_a.shape
    M = boxes_b.shape[1]
    bn, bm = min(block_n, max(N, 1)), min(block_m, max(M, 1))
    pad_n, pad_m = (-N) % bn, (-M) % bm
    if pad_n:
        boxes_a = jnp.pad(boxes_a, ((0, 0), (0, pad_n), (0, 0)))
    if pad_m:
        boxes_b = jnp.pad(boxes_b, ((0, 0), (0, pad_m), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_iou_kernel, giou=giou),
        grid=(B, (N + pad_n) // bn, (M + pad_m) // bm),
        in_specs=[
            pl.BlockSpec((1, bn, 4), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bm, 4), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn, bm), lambda b, i, j: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, N + pad_n, M + pad_m), jnp.float32),
        interpret=interpret,
    )(boxes_a.astype(jnp.float32), boxes_b.astype(jnp.float32))
    out = out[:, :N, :M]
    return out[0] if squeeze else out


def _nms_kernel(boxes_ref, valid_ref, keep_ref, *, iou_thresh):
    boxes = boxes_ref[0].astype(jnp.float32)  # (N, 4) score-sorted desc
    n = boxes.shape[0]
    x1, y1, x2, y2, area = _corners(boxes)
    pos = jax.lax.iota(jnp.int32, n)

    def body(i, keep):
        ix = jnp.maximum(jnp.minimum(x2[i], x2) - jnp.maximum(x1[i], x1), 0.0)
        iy = jnp.maximum(jnp.minimum(y2[i], y2) - jnp.maximum(y1[i], y1), 0.0)
        inter = _area(ix * iy)
        iou = inter / jnp.maximum(area[i] + area - inter, IOU_EPS)
        # a box only suppresses *later* boxes, and only while itself kept —
        # suppressed boxes never cascade (sequential NMS semantics)
        suppress = (pos > i) & (iou > iou_thresh) & (keep[i] > 0)
        return jnp.where(suppress, 0.0, keep)

    keep_ref[0] = jax.lax.fori_loop(0, n, body, valid_ref[0].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("iou_thresh", "score_thresh", "max_keep", "interpret"))
def nms(
    boxes: jax.Array,
    scores: jax.Array,
    *,
    iou_thresh: float = 0.5,
    score_thresh: float = 0.0,
    max_keep: int = 0,
    interpret: bool = True,
) -> jax.Array:
    """boxes (B?, N, 4), scores (B?, N) -> keep mask (B?, N) f32, original order.

    Score-sorted sequential NMS with fixed shapes: boxes are stably sorted
    by descending score (ties keep original order), the kernel walks the
    sorted list once per image (grid step = image), and the keep mask is
    scattered back to the caller's order. ``score_thresh`` pre-drops boxes
    below it; ``max_keep > 0`` caps the survivors to the top max_keep by
    score (the fixed-size output contract — extra survivors are masked, not
    sliced, so shapes never depend on data).
    """
    squeeze = boxes.ndim == 2
    if squeeze:
        boxes, scores = boxes[None], scores[None]
    scores = scores.astype(jnp.float32)
    order = jnp.argsort(-scores, axis=-1, stable=True)
    boxes_s = jnp.take_along_axis(boxes.astype(jnp.float32), order[..., None], axis=1)
    valid_s = (jnp.take_along_axis(scores, order, axis=1) > score_thresh).astype(jnp.float32)
    B, N = valid_s.shape
    keep_s = pl.pallas_call(
        functools.partial(_nms_kernel, iou_thresh=iou_thresh),
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, N, 4), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, N), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, N), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N), jnp.float32),
        interpret=interpret,
    )(boxes_s, valid_s)
    if max_keep:
        rank = jnp.cumsum(keep_s, axis=-1)  # survivor rank in score order
        keep_s = keep_s * (rank <= max_keep).astype(jnp.float32)
    inv = jnp.argsort(order, axis=-1, stable=True)
    keep = jnp.take_along_axis(keep_s, inv, axis=1)
    return keep[0] if squeeze else keep
