"""Pallas TPU kernels (validated via interpret=True on CPU) + jnp oracles."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
