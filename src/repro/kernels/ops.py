"""Jit'd public wrappers around the Pallas kernels.

`interpret` defaults to True: this container is CPU-only, so kernels execute
their bodies in interpret mode; on real TPU pass interpret=False. The
wrappers compose kernels into the shapes the rest of the framework uses
(pytree-wide aggregation, full SSD with the inter-chunk recurrence, etc.).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import detect as _detect
from repro.kernels import fedavg as _fedavg
from repro.kernels import flash_attention as _flash
from repro.kernels import pack as _pack
from repro.kernels import quant as _quant
from repro.kernels import ref
from repro.kernels import ssd_scan as _ssd

PyTree = Any

fedavg_masked_mean = _fedavg.fedavg_masked_mean
pairwise_iou = _detect.pairwise_iou
nms = _detect.nms
packed_bucket_reduce = _pack.packed_bucket_reduce
quantize_rows = _pack.quantize_rows
dequantize_rows = _pack.dequantize_rows
quantize = _quant.quantize
dequantize = _quant.dequantize
flash_attention = _flash.flash_attention
ssd_chunk_scan = _ssd.ssd_chunk_scan


def flash_attention_trainable(q, k, v, *, causal: bool = True, window: int = 0, interpret: bool = True):
    """Flash-kernel forward with the jnp-reference VJP (training-safe).

    The Pallas kernel implements only the forward pass; custom_vjp pairs it
    with gradients derived from the numerically-equivalent reference, so
    models can select `attention_impl="pallas"` for both train and serve.
    Layout: (B, H, S, hd) like kernels.ref.flash_attention.
    """

    @jax.custom_vjp
    def fa(q, k, v):
        return _flash.flash_attention(q, k, v, causal=causal, window=window, interpret=interpret)

    def fwd(q, k, v):
        return fa(q, k, v), (q, k, v)

    def bwd(res, g):
        q, k, v = res
        _, vjp = jax.vjp(lambda a, b, c: ref.flash_attention(a, b, c, causal=causal, window=window), q, k, v)
        return vjp(g)

    fa.defvjp(fwd, bwd)
    return fa(q, k, v)


def ssd_full_trainable(xdt, dA, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    """ssd_full forward (Pallas intra-chunk) with the jnp-reference VJP."""
    from repro.models.mamba2 import ssd_chunked

    @jax.custom_vjp
    def ssd(xdt, dA, Bm, Cm):
        return ssd_full(xdt, dA, Bm, Cm, chunk=chunk, interpret=interpret)

    def fwd(xdt, dA, Bm, Cm):
        return ssd(xdt, dA, Bm, Cm), (xdt, dA, Bm, Cm)

    def bwd(res, g):
        _, vjp = jax.vjp(lambda a, b, c, d: ssd_chunked(a, b, c, d, chunk), *res)
        return vjp(g)

    ssd.defvjp(fwd, bwd)
    return ssd(xdt, dA, Bm, Cm)


def fedavg_tree(stacked: PyTree, weights: jax.Array, mask_per_leaf: PyTree, *, interpret: bool = True) -> PyTree:
    """Kernel-backed Eq.5+Eq.6 over a client-stacked pytree.

    mask_per_leaf: (C,) upload mask per leaf (from Eq. 6 layer scores).
    Each leaf is flattened to (C, N) and aggregated by the fedavg kernel.
    """

    def agg(x, m):
        C = x.shape[0]
        flat = x.reshape(C, -1)
        out = _fedavg.fedavg_masked_mean(flat, weights, m, interpret=interpret)
        return out.reshape(x.shape[1:])

    return jax.tree.map(agg, stacked, mask_per_leaf)


def quantize_tree(tree: PyTree, *, interpret: bool = True) -> PyTree:
    """Per-leaf int8 block quantization -> {"q", "scales"} leaves."""
    return jax.tree.map(
        lambda x: dict(zip(("q", "scales"), _quant.quantize(x.reshape(-1), interpret=interpret))),
        tree,
    )


def dequantize_tree(qtree: PyTree, like: PyTree, *, interpret: bool = True) -> PyTree:
    return jax.tree.map(
        lambda qt, x: _quant.dequantize(qt["q"], qt["scales"], dtype=x.dtype, interpret=interpret).reshape(x.shape),
        qtree,
        like,
        is_leaf=lambda t: isinstance(t, dict) and "q" in t,
    )


def ssd_full(xdt: jax.Array, dA: jax.Array, Bm: jax.Array, Cm: jax.Array, *, chunk: int = 128, interpret: bool = True, init_state: jax.Array | None = None):
    """Full SSD = Pallas intra-chunk kernel + lax.scan inter-chunk pass.

    Same contract as models.mamba2.ssd_chunked: returns (y (B,S,H,P),
    final_state (B,H,P,N)).
    """
    B, S, H, P = xdt.shape
    N = Bm.shape[-1]
    y_diag, states, chunk_decay, exp_cum = _ssd.ssd_chunk_scan(
        xdt, dA, Bm, Cm, chunk=chunk, interpret=interpret
    )
    nc = S // chunk

    def scan_fn(carry, inp):
        st, cd = inp  # (B,H,P,N), (B,H)
        new = carry * cd[:, :, None, None] + st
        return new, carry

    init = jnp.zeros((B, H, P, N), jnp.float32) if init_state is None else init_state
    final_state, prev = jax.lax.scan(
        scan_fn, init, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    prev = jnp.moveaxis(prev, 0, 1)  # (B,nc,H,P,N)
    Cc = Cm.reshape(B, nc, chunk, N)
    ec = exp_cum.reshape(B, nc, chunk, H)
    y_off = jnp.einsum("bcqn,bchpn,bcqh->bcqhp", Cc.astype(jnp.float32), prev, ec)
    y = y_diag + y_off.reshape(B, S, H, P)
    return y.astype(xdt.dtype), final_state
