"""Synthetic data generators for every modality (offline container).

Token streams are Markov-chain text-like data (learnable structure, so
convergence benchmarks are meaningful); audio provides frame embeddings +
cluster labels (HuBERT objective); vlm provides patch embeddings + captions;
images are procedurally drawn scenes with bounding-box ground truth in the
paper's Darknet format.
"""
from __future__ import annotations

import numpy as np

from repro.data.darknet import BBox


class MarkovTokens:
    """Order-1 Markov token source with client-dependent drift (non-IID)."""

    def __init__(self, vocab: int, seed: int = 0, drift: float = 0.0):
        rng = np.random.default_rng(seed)
        k = min(vocab, 64)  # latent states
        self.vocab = vocab
        base = rng.dirichlet([0.3] * k, size=k)
        if drift:
            base = (1 - drift) * base + drift * rng.dirichlet([0.3] * k, size=k)
        self.trans = base
        self.emit = rng.integers(0, vocab, size=k)
        self.k = k

    def sample(self, rng: np.random.Generator, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq), np.int32)
        state = rng.integers(0, self.k, size=batch)
        for t in range(seq):
            out[:, t] = self.emit[state] % self.vocab
            u = rng.random((batch, 1))
            state = (np.cumsum(self.trans[state], axis=1) > u).argmax(axis=1)
        return out


def token_batches(vocab: int, n_clients: int, local_steps: int, batch: int, seq: int, seed: int = 0, non_iid_drift: float = 0.5):
    """Yields {"tokens": (C, E, b, S)} with per-client distributions."""
    sources = [MarkovTokens(vocab, seed=seed + c, drift=non_iid_drift * c / max(n_clients - 1, 1)) for c in range(n_clients)]
    rng = np.random.default_rng(seed + 999)
    while True:
        yield {
            "tokens": np.stack(
                [np.stack([s.sample(rng, batch, seq) for _ in range(local_steps)]) for s in sources]
            )
        }


def audio_batches(d_model: int, vocab: int, n_clients: int, local_steps: int, batch: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    proto = rng.normal(size=(vocab, d_model)).astype(np.float32)
    while True:
        labels = rng.integers(0, vocab, size=(n_clients, local_steps, batch, seq))
        frames = proto[labels] + 0.5 * rng.normal(size=(n_clients, local_steps, batch, seq, d_model)).astype(np.float32)
        mask = rng.random((n_clients, local_steps, batch, seq)) < 0.3
        yield {"frames": frames.astype(np.float32), "labels": labels.astype(np.int32), "mask": mask}


def scene_images(
    rng: np.random.Generator,
    batch: int,
    size: int,
    n_classes: int,
    max_boxes: int = 3,
    class_probs=None,
    scale_range: tuple[float, float] = (0.15, 0.5),
):
    """Procedural detection scenes: bright rectangles = objects.

    Returns (images (B,size,size,3) f32, boxes list[list[BBox]]).
    ``class_probs`` (n_classes,) skews the object-class distribution and
    ``scale_range`` the box sizes — the per-client non-IID knobs the
    detection scenario suite turns (label skew + box-scale skew).
    """
    imgs = rng.normal(0.0, 0.05, size=(batch, size, size, 3)).astype(np.float32)
    lo, hi = scale_range
    all_boxes: list[list[BBox]] = []
    for b in range(batch):
        boxes = []
        for _ in range(int(rng.integers(1, max_boxes + 1))):
            w, h = rng.uniform(lo, hi, 2)
            x = rng.uniform(w / 2, 1 - w / 2)
            y = rng.uniform(h / 2, 1 - h / 2)
            if class_probs is None:
                label = int(rng.integers(0, n_classes))
            else:
                label = int(rng.choice(n_classes, p=class_probs))
            x0, y0 = int((x - w / 2) * size), int((y - h / 2) * size)
            x1, y1 = int((x + w / 2) * size), int((y + h / 2) * size)
            color = np.zeros(3, np.float32)
            color[label % 3] = 1.0
            imgs[b, y0:y1, x0:x1] += color  # class-colored rectangle
            boxes.append(BBox(label, x, y, w, h))
        all_boxes.append(boxes)
    return imgs, all_boxes


def boxes_to_arrays(all_boxes: list[list[BBox]], max_boxes: int):
    """Pad BBox lists to the fixed-shape GT arrays the jitted evaluator
    takes: (B, G, 4) center-format f32, (B, G) int32 labels, (B, G) 0/1
    validity. Boxes beyond ``max_boxes`` are dropped (shape stability wins
    over the tail of a synthetic scene)."""
    B = len(all_boxes)
    boxes = np.zeros((B, max_boxes, 4), np.float32)
    cls = np.zeros((B, max_boxes), np.int32)
    valid = np.zeros((B, max_boxes), np.float32)
    for b, bs in enumerate(all_boxes):
        for g, bb in enumerate(bs[:max_boxes]):
            boxes[b, g] = [bb.x, bb.y, bb.w, bb.h]
            cls[b, g] = bb.label
            valid[b, g] = 1.0
    return boxes, cls, valid


def detection_scene_pool(
    n_scenes: int,
    size: int,
    n_classes: int,
    rng: np.random.Generator,
    *,
    max_boxes: int = 3,
    dominance: float = 0.8,
    scale_spread: float = 0.25,
):
    """Labeled scene pool for `data.partition.make_scenario` splits.

    Scene i has a *dominant class* (its partition label): objects draw
    that class with probability ``dominance`` and a box-scale band tied to
    it (class c's boxes live around ``0.12 + scale_spread * c / (K-1)``).
    Partitioning the pool by label therefore induces BOTH class skew and
    box-scale skew per client — the detection analogue of the token
    path's dirichlet/shards/quantity scenarios.

    Returns {"images" (P,S,S,3), "bboxes" list[list[BBox]], "gt_boxes"
    (P,G,4), "gt_cls" (P,G), "gt_valid" (P,G), "labels" (P,)}.
    """
    images = np.empty((n_scenes, size, size, 3), np.float32)
    bboxes: list[list[BBox]] = []
    labels = np.empty(n_scenes, np.int64)
    for i in range(n_scenes):
        dom = int(rng.integers(0, n_classes))
        probs = np.full(n_classes, (1.0 - dominance) / max(n_classes - 1, 1))
        probs[dom] = dominance if n_classes > 1 else 1.0
        base = 0.12 + scale_spread * dom / max(n_classes - 1, 1)
        im, bs = scene_images(
            rng, 1, size, n_classes, max_boxes,
            class_probs=probs, scale_range=(base, base + 0.2),
        )
        images[i] = im[0]
        bboxes.append(bs[0])
        labels[i] = dom
    gt_boxes, gt_cls, gt_valid = boxes_to_arrays(bboxes, max_boxes)
    return {
        "images": images,
        "bboxes": bboxes,
        "gt_boxes": gt_boxes,
        "gt_cls": gt_cls,
        "gt_valid": gt_valid,
        "labels": labels,
    }
