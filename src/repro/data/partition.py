"""Federated data partitioning — horizontal (sample-space) splits (Eq. 1).

HFL requires identical feature/label spaces with disjoint sample ids across
parties. The non-IID scenario suite (FedCV-style; He et al. 2021):

- `dirichlet_partition`  — label skew: per class, proportions ~ Dir(alpha);
- `quantity_skew_partition` — size skew: client sizes ~ LogNormal(0, sigma);
- `class_shard_partition` — pathological label shards (McMahan et al. 2017):
  sort by label, deal each client `shards_per_client` contiguous shards;
- `iid_partition` — the control.

`make_scenario` is the string-keyed dispatcher `launch/train.py` and the
benchmarks use. Every split is a pure function of the passed Generator, so
a fixed seed reproduces the exact partition (pinned in tests/test_data.py).
"""
from __future__ import annotations

import numpy as np

SCENARIOS = ("iid", "dirichlet", "shards", "quantity")


def iid_partition(n_samples: int, n_clients: int, rng: np.random.Generator) -> list[np.ndarray]:
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def _ensure_min(out: list[np.ndarray], min_per_client: int) -> list[np.ndarray]:
    """Donor rebalance to a fixed point: move samples from the largest
    client to the smallest until every client holds >= min_per_client (so
    every client can form a batch). Each move shrinks the total deficit, so
    this terminates whenever the floor is feasible at all."""
    total = sum(len(s) for s in out)
    if min_per_client * len(out) > total:
        raise ValueError(
            f"min_per_client={min_per_client} infeasible: {total} samples "
            f"across {len(out)} clients"
        )
    while True:
        i = int(np.argmin([len(s) for s in out]))
        if len(out[i]) >= min_per_client:
            return out
        donor = int(np.argmax([len(s) for s in out]))
        need = min_per_client - len(out[i])
        take = out[donor][-need:]
        out[donor] = out[donor][:-need]
        out[i] = np.sort(np.concatenate([out[i], take]))


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float, rng: np.random.Generator, min_per_client: int = 1) -> list[np.ndarray]:
    """Label-skewed split: per class, proportions ~ Dir(alpha) over clients."""
    n_classes = int(labels.max()) + 1
    shards: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for shard, part in zip(shards, np.split(idx, cuts)):
            shard.extend(part.tolist())
    return _ensure_min([np.asarray(sorted(s), int) for s in shards], min_per_client)


def quantity_skew_partition(n_samples: int, n_clients: int, rng: np.random.Generator, sigma: float = 1.0, min_per_client: int = 1) -> list[np.ndarray]:
    """Size-skewed IID split: client shares ~ LogNormal(0, sigma), labels IID.

    sigma=0 reduces to `iid_partition`'s equal sizes; sigma~1 gives a
    realistic long-tail where a few clients hold most of the data.
    """
    raw = rng.lognormal(0.0, sigma, n_clients) if sigma > 0 else np.ones(n_clients)
    props = raw / raw.sum()
    cuts = np.clip((np.cumsum(props) * n_samples).astype(int)[:-1], 0, n_samples)
    perm = rng.permutation(n_samples)
    return _ensure_min([np.sort(s) for s in np.split(perm, cuts)], min_per_client)


def class_shard_partition(labels: np.ndarray, n_clients: int, shards_per_client: int, rng: np.random.Generator) -> list[np.ndarray]:
    """Pathological non-IID (McMahan et al. 2017): sort by label, cut into
    n_clients * shards_per_client contiguous shards, deal shards_per_client
    to each client — every client sees only a few classes."""
    order = np.argsort(labels, kind="stable")
    n_shards = n_clients * shards_per_client
    if n_shards > len(labels):
        raise ValueError(
            f"class_shard_partition: {n_shards} shards > {len(labels)} samples"
        )
    shards = np.array_split(order, n_shards)
    deal = rng.permutation(n_shards)
    return [
        np.sort(np.concatenate([shards[deal[c * shards_per_client + j]] for j in range(shards_per_client)]))
        for c in range(n_clients)
    ]


def make_scenario(
    name: str,
    labels: np.ndarray,
    n_clients: int,
    rng: np.random.Generator,
    *,
    alpha: float = 0.5,
    shards_per_client: int = 2,
    sigma: float = 1.0,
) -> list[np.ndarray]:
    """String-keyed scenario dispatch (see SCENARIOS). Deterministic in rng."""
    if name == "iid":
        return iid_partition(len(labels), n_clients, rng)
    if name == "dirichlet":
        return dirichlet_partition(labels, n_clients, alpha, rng)
    if name == "shards":
        return class_shard_partition(labels, n_clients, shards_per_client, rng)
    if name == "quantity":
        return quantity_skew_partition(len(labels), n_clients, rng, sigma)
    raise ValueError(f"unknown partition scenario {name!r}; known: {SCENARIOS}")


def scale_skew_stats(parts: list[np.ndarray], gt_boxes: np.ndarray, gt_valid: np.ndarray) -> dict:
    """Box-scale skew of a partitioned detection scene pool.

    The detection suite ties box scale to the dominant class
    (`data.synthetic.detection_scene_pool`), so a label-skewed
    `make_scenario` split also skews object sizes per client — this is the
    measurement. gt_boxes (P, G, 4) center-format, gt_valid (P, G) 0/1.
    Returns per-client mean sqrt-box-area plus a spread ratio (max/min of
    the client means; 1.0 == no scale skew).
    """
    scale = np.sqrt(np.maximum(gt_boxes[..., 2] * gt_boxes[..., 3], 0.0))  # (P, G)
    means = []
    for p in parts:
        v = gt_valid[p]
        means.append(float((scale[p] * v).sum() / max(v.sum(), 1.0)))
    means_arr = np.asarray(means)
    return {
        "mean_scale": means_arr,
        "spread": float(means_arr.max() / max(means_arr.min(), 1e-9)),
    }


def partition_stats(parts: list[np.ndarray], labels: np.ndarray) -> dict:
    n_classes = int(labels.max()) + 1
    hist = np.stack([np.bincount(labels[p], minlength=n_classes) for p in parts])
    frac = hist / np.maximum(hist.sum(1, keepdims=True), 1)
    uniform = np.full(n_classes, 1.0 / n_classes)
    tv = 0.5 * np.abs(frac - uniform).sum(1)  # total-variation from uniform
    return {"sizes": [len(p) for p in parts], "label_hist": hist, "skew_tv": tv}
