"""Federated data partitioning — horizontal (sample-space) splits (Eq. 1).

HFL requires identical feature/label spaces with disjoint sample ids across
parties. `dirichlet_partition` produces the standard non-IID label-skew
split used to evaluate FedAvg-style systems; `iid_partition` is the control.
"""
from __future__ import annotations

import numpy as np


def iid_partition(n_samples: int, n_clients: int, rng: np.random.Generator) -> list[np.ndarray]:
    perm = rng.permutation(n_samples)
    return [np.sort(s) for s in np.array_split(perm, n_clients)]


def dirichlet_partition(labels: np.ndarray, n_clients: int, alpha: float, rng: np.random.Generator, min_per_client: int = 1) -> list[np.ndarray]:
    """Label-skewed split: per class, proportions ~ Dir(alpha) over clients."""
    n_classes = int(labels.max()) + 1
    shards: list[list[int]] = [[] for _ in range(n_clients)]
    for c in range(n_classes):
        idx = np.nonzero(labels == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_clients)
        cuts = (np.cumsum(props) * len(idx)).astype(int)[:-1]
        for shard, part in zip(shards, np.split(idx, cuts)):
            shard.extend(part.tolist())
    # rebalance empty shards so every client can form a batch
    out = [np.asarray(sorted(s), int) for s in shards]
    for i, s in enumerate(out):
        if len(s) < min_per_client:
            donor = int(np.argmax([len(x) for x in out]))
            take = out[donor][-min_per_client:]
            out[donor] = out[donor][:-min_per_client]
            out[i] = np.sort(np.concatenate([s, take]))
    return out


def partition_stats(parts: list[np.ndarray], labels: np.ndarray) -> dict:
    n_classes = int(labels.max()) + 1
    hist = np.stack([np.bincount(labels[p], minlength=n_classes) for p in parts])
    frac = hist / np.maximum(hist.sum(1, keepdims=True), 1)
    uniform = np.full(n_classes, 1.0 / n_classes)
    tv = 0.5 * np.abs(frac - uniform).sum(1)  # total-variation from uniform
    return {"sizes": [len(p) for p in parts], "label_hist": hist, "skew_tv": tv}
