"""Host-side batching pipeline: modality dispatch + device placement.

`fed_batches(cfg, fed, ...)` yields client-stacked batches (C, E, b, ...)
matching what `core.rounds.build_fed_round` consumes, for any assigned
architecture (text/audio/vlm) or the paper's detector.
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.rounds import FedConfig
from repro.data import darknet, synthetic
from repro.models.yolov3 import ANCHORS


def fed_batches(cfg: ArchConfig, fed: FedConfig, batch: int, seq: int, seed: int = 0, img_size: int = 96):
    C, E = fed.n_clients, fed.local_steps
    if cfg.modality == "audio":
        yield from synthetic.audio_batches(cfg.d_model, cfg.vocab_size, C, E, batch, seq, seed)
    elif cfg.modality == "vlm":
        ni = cfg.n_image_tokens
        rng = np.random.default_rng(seed)
        for tb in synthetic.token_batches(cfg.vocab_size, C, E, batch, max(seq - ni, 8), seed):
            imgs = rng.normal(size=(C, E, batch, ni, cfg.d_model)).astype(np.float32) * 0.1
            yield {"tokens": tb["tokens"], "images": imgs}
    elif cfg.family == "yolo":
        rng = np.random.default_rng(seed)
        grids = [img_size // 8, img_size // 16, img_size // 32]
        while True:
            ims = np.empty((C, E, batch, img_size, img_size, 3), np.float32)
            tgts = None
            acc = [[None] * E for _ in range(C)]
            for c in range(C):
                for e in range(E):
                    im, boxes = synthetic.scene_images(rng, batch, img_size, cfg.vocab_size)
                    ims[c, e] = im
                    acc[c][e] = darknet.build_targets(boxes, grids, cfg.n_heads, cfg.vocab_size, ANCHORS)
            targets = []
            for s in range(3):
                targets.append(
                    {
                        k: np.stack([np.stack([acc[c][e][s][k] for e in range(E)]) for c in range(C)])
                        for k in ("obj", "box", "cls")
                    }
                )
            yield {"images": ims, "targets": targets}
    else:
        yield from synthetic.token_batches(cfg.vocab_size, C, E, batch, seq, seed)
