"""Host-side batching pipeline: modality dispatch + device placement.

`fed_batches(cfg, fed, ...)` yields client-stacked batches (C, E, b, ...)
matching what `core.rounds.build_fed_round` consumes, for any assigned
architecture (text/audio/vlm) or the paper's detector. For text archs,
``partition_name`` swaps the default per-client Markov drift ("stream") for
one of the `data.partition` non-IID scenarios over a labeled sequence pool
(`partitioned_token_batches`).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.rounds import FedConfig
from repro.data import darknet, partition, synthetic
from repro.models.yolov3 import ANCHORS


def partitioned_token_batches(
    vocab: int,
    n_clients: int,
    local_steps: int,
    batch: int,
    seq: int,
    scenario: str = "dirichlet",
    seed: int = 0,
    *,
    alpha: float = 0.5,
    n_sources: int = 8,
    pool_per_source: int = 64,
):
    """Token batches drawn from a partitioned labeled pool.

    A pool of sequences is pre-sampled from `n_sources` distinct Markov
    chains (label = source id), split across clients by the named
    `data.partition` scenario, and each client then draws batches from its
    own index set only — label-skew/quantity-skew federated text data with
    measurable `partition_stats`. Yields {"tokens": (C, E, b, S)}.
    """
    sources = [synthetic.MarkovTokens(vocab, seed=seed + s) for s in range(n_sources)]
    rng = np.random.default_rng(seed + 101)
    seqs = np.concatenate([s.sample(rng, pool_per_source, seq) for s in sources])
    labels = np.repeat(np.arange(n_sources), pool_per_source)
    parts = partition.make_scenario(
        scenario, labels, n_clients, np.random.default_rng(seed + 202), alpha=alpha
    )
    draw = np.random.default_rng(seed + 303)
    while True:
        idx = np.stack(
            [draw.choice(parts[c], size=(local_steps, batch)) for c in range(n_clients)]
        )
        yield {"tokens": seqs[idx].astype(np.int32)}  # (C, E, b, S)


def fed_batches(cfg: ArchConfig, fed: FedConfig, batch: int, seq: int, seed: int = 0, img_size: int = 96, partition_name: str = "stream", alpha: float = 0.5):
    C, E = fed.n_clients, fed.local_steps
    if partition_name != "stream":
        if cfg.modality != "text":
            raise ValueError(
                f"partition scenarios only apply to text archs (got modality="
                f"{cfg.modality!r}); use the default 'stream'"
            )
        yield from partitioned_token_batches(
            cfg.vocab_size, C, E, batch, seq, partition_name, seed, alpha=alpha
        )
        return
    if cfg.modality == "audio":
        yield from synthetic.audio_batches(cfg.d_model, cfg.vocab_size, C, E, batch, seq, seed)
    elif cfg.modality == "vlm":
        ni = cfg.n_image_tokens
        rng = np.random.default_rng(seed)
        for tb in synthetic.token_batches(cfg.vocab_size, C, E, batch, max(seq - ni, 8), seed):
            imgs = rng.normal(size=(C, E, batch, ni, cfg.d_model)).astype(np.float32) * 0.1
            yield {"tokens": tb["tokens"], "images": imgs}
    elif cfg.family == "yolo":
        rng = np.random.default_rng(seed)
        grids = [img_size // 8, img_size // 16, img_size // 32]
        while True:
            ims = np.empty((C, E, batch, img_size, img_size, 3), np.float32)
            tgts = None
            acc = [[None] * E for _ in range(C)]
            for c in range(C):
                for e in range(E):
                    im, boxes = synthetic.scene_images(rng, batch, img_size, cfg.vocab_size)
                    ims[c, e] = im
                    acc[c][e] = darknet.build_targets(boxes, grids, cfg.n_heads, cfg.vocab_size, ANCHORS)
            targets = []
            for s in range(3):
                targets.append(
                    {
                        k: np.stack([np.stack([acc[c][e][s][k] for e in range(E)]) for c in range(C)])
                        for k in ("obj", "box", "cls")
                    }
                )
            yield {"images": ims, "targets": targets}
    else:
        yield from synthetic.token_batches(cfg.vocab_size, C, E, batch, seq, seed)
