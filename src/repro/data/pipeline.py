"""Host-side batching pipeline: modality dispatch + device placement.

`fed_batches(cfg, fed, ...)` yields client-stacked batches (C, E, b, ...)
matching what `core.rounds.build_fed_round` consumes, for any assigned
architecture (text/audio/vlm) or the paper's detector. For text archs,
``partition_name`` swaps the default per-client Markov drift ("stream") for
one of the `data.partition` non-IID scenarios over a labeled sequence pool
(`partitioned_token_batches`).
"""
from __future__ import annotations

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.rounds import FedConfig
from repro.data import darknet, partition, synthetic
from repro.models.yolov3 import ANCHORS, grid_sizes


def partitioned_token_batches(
    vocab: int,
    n_clients: int,
    local_steps: int,
    batch: int,
    seq: int,
    scenario: str = "dirichlet",
    seed: int = 0,
    *,
    alpha: float = 0.5,
    n_sources: int = 8,
    pool_per_source: int = 64,
):
    """Token batches drawn from a partitioned labeled pool.

    A pool of sequences is pre-sampled from `n_sources` distinct Markov
    chains (label = source id), split across clients by the named
    `data.partition` scenario, and each client then draws batches from its
    own index set only — label-skew/quantity-skew federated text data with
    measurable `partition_stats`. Yields {"tokens": (C, E, b, S)}.
    """
    sources = [synthetic.MarkovTokens(vocab, seed=seed + s) for s in range(n_sources)]
    rng = np.random.default_rng(seed + 101)
    seqs = np.concatenate([s.sample(rng, pool_per_source, seq) for s in sources])
    labels = np.repeat(np.arange(n_sources), pool_per_source)
    parts = partition.make_scenario(
        scenario, labels, n_clients, np.random.default_rng(seed + 202), alpha=alpha
    )
    draw = np.random.default_rng(seed + 303)
    while True:
        idx = np.stack(
            [draw.choice(parts[c], size=(local_steps, batch)) for c in range(n_clients)]
        )
        yield {"tokens": seqs[idx].astype(np.int32)}  # (C, E, b, S)


def _scene_targets(pool: dict, idx: np.ndarray, grids: list[int], cfg: ArchConfig):
    """Sampled scene indices (C, E, b) -> (images, per-scale grid targets)."""
    C, E, b = idx.shape
    ims = pool["images"][idx]  # (C, E, b, S, S, 3)
    acc = [
        [darknet.build_targets([pool["bboxes"][i] for i in idx[c, e]], grids, cfg.n_heads, cfg.vocab_size, ANCHORS) for e in range(E)]
        for c in range(C)
    ]
    targets = [
        {
            k: np.stack([np.stack([acc[c][e][s][k] for e in range(E)]) for c in range(C)])
            for k in ("obj", "box", "cls")
        }
        for s in range(len(grids))
    ]
    return ims, targets


def detection_suite(
    cfg: ArchConfig,
    fed: FedConfig,
    batch: int,
    img_size: int = 64,
    scenario: str = "dirichlet",
    seed: int = 0,
    *,
    alpha: float = 0.5,
    pool_scenes: int = 96,
    eval_per_client: int = 4,
    max_boxes: int = 3,
):
    """Partitioned detection data: (train_batches, eval_batch, stats).

    A pool of labeled synthetic scenes (`detection_scene_pool`: dominant
    class + class-tied box scale) is split across clients by the SAME
    `make_scenario` suite the token path uses, so detection gets identical
    non-IID treatment (label skew also skews box scale). ``train_batches``
    yields the {"images", "targets"} structure `core.rounds` consumes;
    ``eval_batch`` is a fixed per-client holdout in the padded-array form
    `core.detection.build_evaluator` takes ((C, Be, ...) leaves), drawn
    once so per-round mAP curves are comparable across rounds.
    """
    C, E = fed.n_clients, fed.local_steps
    pool = synthetic.detection_scene_pool(
        pool_scenes, img_size, cfg.vocab_size, np.random.default_rng(seed), max_boxes=max_boxes
    )
    parts = partition.make_scenario(
        scenario, pool["labels"], C, np.random.default_rng(seed + 1), alpha=alpha
    )
    grids = grid_sizes(cfg, img_size)
    eval_rng = np.random.default_rng(seed + 2)
    # a real holdout: eval scenes leave the client's training pool. Only a
    # pathologically small partition (<= eval_per_client scenes) keeps its
    # pool intact and evals with replacement — leakage beats an empty pool.
    eval_rows, train_parts = [], []
    for c in range(C):
        p = parts[c]
        if len(p) > eval_per_client:
            sel = eval_rng.choice(p, size=eval_per_client, replace=False)
            train_parts.append(np.setdiff1d(p, sel))
        else:
            sel = eval_rng.choice(p, size=eval_per_client, replace=True)
            train_parts.append(p)
        eval_rows.append(sel)
    eval_idx = np.stack(eval_rows)
    eval_batch = {
        "images": pool["images"][eval_idx],
        "gt_boxes": pool["gt_boxes"][eval_idx],
        "gt_cls": pool["gt_cls"][eval_idx],
        "gt_valid": pool["gt_valid"][eval_idx],
    }
    stats = {
        "parts": parts,
        "label": partition.partition_stats(parts, pool["labels"]),
        "scale": partition.scale_skew_stats(parts, pool["gt_boxes"], pool["gt_valid"]),
    }

    def train_batches():
        draw = np.random.default_rng(seed + 3)
        while True:
            idx = np.stack([draw.choice(train_parts[c], size=(E, batch)) for c in range(C)])
            ims, targets = _scene_targets(pool, idx, grids, cfg)
            yield {"images": ims, "targets": targets}

    return train_batches(), eval_batch, stats


def fed_batches(cfg: ArchConfig, fed: FedConfig, batch: int, seq: int, seed: int = 0, img_size: int = 96, partition_name: str = "stream", alpha: float = 0.5):
    C, E = fed.n_clients, fed.local_steps
    if partition_name != "stream":
        if cfg.family == "yolo":
            gen, _, _ = detection_suite(
                cfg, fed, batch, img_size, partition_name, seed, alpha=alpha
            )
            yield from gen
            return
        if cfg.modality != "text":
            raise ValueError(
                f"partition scenarios only apply to text and yolo archs (got "
                f"modality={cfg.modality!r}); use the default 'stream'"
            )
        yield from partitioned_token_batches(
            cfg.vocab_size, C, E, batch, seq, partition_name, seed, alpha=alpha
        )
        return
    if cfg.modality == "audio":
        yield from synthetic.audio_batches(cfg.d_model, cfg.vocab_size, C, E, batch, seq, seed)
    elif cfg.modality == "vlm":
        ni = cfg.n_image_tokens
        rng = np.random.default_rng(seed)
        for tb in synthetic.token_batches(cfg.vocab_size, C, E, batch, max(seq - ni, 8), seed):
            imgs = rng.normal(size=(C, E, batch, ni, cfg.d_model)).astype(np.float32) * 0.1
            yield {"tokens": tb["tokens"], "images": imgs}
    elif cfg.family == "yolo":
        rng = np.random.default_rng(seed)
        grids = grid_sizes(cfg, img_size)
        while True:
            ims = np.empty((C, E, batch, img_size, img_size, 3), np.float32)
            tgts = None
            acc = [[None] * E for _ in range(C)]
            for c in range(C):
                for e in range(E):
                    im, boxes = synthetic.scene_images(rng, batch, img_size, cfg.vocab_size)
                    ims[c, e] = im
                    acc[c][e] = darknet.build_targets(boxes, grids, cfg.n_heads, cfg.vocab_size, ANCHORS)
            targets = []
            for s in range(3):
                targets.append(
                    {
                        k: np.stack([np.stack([acc[c][e][s][k] for e in range(E)]) for c in range(C)])
                        for k in ("obj", "box", "cls")
                    }
                )
            yield {"images": ims, "targets": targets}
    else:
        yield from synthetic.token_batches(cfg.vocab_size, C, E, batch, seq, seed)
