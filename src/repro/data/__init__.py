from repro.data import darknet, partition, pipeline, synthetic

__all__ = ["darknet", "partition", "pipeline", "synthetic"]
