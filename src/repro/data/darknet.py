"""Crowdsourced image annotation — the paper's Darknet format module.

"FedVision adopts the Darknet model format for annotation. Each row
represents information for a bounding box in the following form:
{label x y w h} where label denotes the category, (x, y) the center and
(w, h) the width/height of the bounding box" (all normalized to [0,1]).

Parser/writer + directory mapping (annotation file sits next to its image,
auto-mapped into the training directory layout) + grid-target builder for
the YOLO loss (Eqs 2-4).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np


@dataclasses.dataclass(frozen=True)
class BBox:
    label: int
    x: float  # center, normalized
    y: float
    w: float
    h: float

    def validate(self) -> "BBox":
        if not (0 <= self.x <= 1 and 0 <= self.y <= 1 and 0 < self.w <= 1 and 0 < self.h <= 1):
            raise ValueError(f"bbox out of range: {self}")
        if self.label < 0:
            raise ValueError(f"negative label: {self}")
        return self


def parse_annotation(text: str) -> list[BBox]:
    boxes = []
    for ln, line in enumerate(text.splitlines()):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        parts = line.split()
        if len(parts) != 5:
            raise ValueError(f"line {ln}: expected 'label x y w h', got {line!r}")
        boxes.append(BBox(int(parts[0]), *(float(p) for p in parts[1:])).validate())
    return boxes


def write_annotation(boxes: list[BBox]) -> str:
    return "\n".join(f"{b.label} {b.x:.6f} {b.y:.6f} {b.w:.6f} {b.h:.6f}" for b in boxes)


def map_annotations(image_dir: str | Path, train_dir: str | Path) -> dict[str, list[BBox]]:
    """The platform's auto-mapping: collect <stem>.txt next to images into
    the model-training directory, returning {stem: boxes}."""
    image_dir, train_dir = Path(image_dir), Path(train_dir)
    train_dir.mkdir(parents=True, exist_ok=True)
    out = {}
    for ann in sorted(image_dir.glob("*.txt")):
        boxes = parse_annotation(ann.read_text())
        (train_dir / ann.name).write_text(write_annotation(boxes))
        out[ann.stem] = boxes
    return out


def build_targets(boxes_per_image: list[list[BBox]], grid_sizes: list[int], n_anchors: int, n_classes: int, anchors) -> list[dict]:
    """Grid targets per scale for the Eq. 2-4 loss.

    Returns [{"obj" (B,S,S,A), "box" (B,S,S,A,4), "cls" (B,S,S,A,C)}].
    Each gt box is assigned to the grid cell containing its center at every
    scale, to the anchor with the closest aspect (paper's B boxes per cell).
    """
    B = len(boxes_per_image)
    out = []
    for s_idx, S in enumerate(grid_sizes):
        obj = np.zeros((B, S, S, n_anchors), np.float32)
        box = np.zeros((B, S, S, n_anchors, 4), np.float32)
        cls = np.zeros((B, S, S, n_anchors, n_classes), np.float32)
        anc = np.asarray(anchors[s_idx], np.float32)  # (A, 2)
        for b, boxes in enumerate(boxes_per_image):
            for gt in boxes:
                gx, gy = min(int(gt.x * S), S - 1), min(int(gt.y * S), S - 1)
                # anchor whose (w,h) is closest in log-space
                d = np.sum((np.log(anc) - np.log([[gt.w, gt.h]])) ** 2, axis=1)
                a = int(np.argmin(d))
                obj[b, gy, gx, a] = 1.0
                box[b, gy, gx, a] = [gt.x, gt.y, gt.w, gt.h]
                cls[b, gy, gx, a, gt.label % n_classes] = 1.0
        out.append({"obj": obj, "box": box, "cls": cls})
    return out
