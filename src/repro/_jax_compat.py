"""Guarded compatibility layer for older installed jax (0.4.x).

The codebase is written against the current jax API (`jax.set_mesh`,
`jax.shard_map`, `jax.sharding.AxisType`, `jax.make_mesh(axis_types=...)`).
The container bakes jax 0.4.37, where those live under older names or don't
exist; installing a newer jax is not an option here. Each patch below is
applied ONLY when the attribute is missing, so on a current jax this module
is a no-op. Imported from ``repro/__init__`` so any `repro.*` import makes
the surface uniform.
"""
from __future__ import annotations

import contextlib
import enum
import functools
import inspect

import jax
import jax.sharding


def _patch() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):  # newer jax: explicit-sharding mesh axes
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    # signature inspection only — calling make_mesh here would initialize
    # the backend at import time, before callers set XLA_FLAGS/platforms
    _orig_make_mesh = jax.make_mesh
    accepts_axis_types = "axis_types" in inspect.signature(_orig_make_mesh).parameters
    if not accepts_axis_types:
        @functools.wraps(_orig_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
            return _orig_make_mesh(axis_shapes, axis_names, devices=devices)

        jax.make_mesh = make_mesh

    if not hasattr(jax, "set_mesh"):
        @contextlib.contextmanager
        def set_mesh(mesh):
            # 0.4.x: Mesh is itself the ambient-mesh context manager
            with mesh:
                yield mesh

        jax.set_mesh = set_mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _esm

        def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kwargs):
            return _esm(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=bool(check_vma), **kwargs,
            )

        jax.shard_map = shard_map


_patch()
