"""End-to-end driver: federated training of a ~100M-parameter LM.

The "production-shaped" example: a 12-layer / d_model=640 transformer
(~100M params with the 49k vocab) trained for a few hundred federated
rounds across 4 non-IID clients with Eq. 6 upload compression, scheduler
-driven participation, and COS round checkpoints.

  PYTHONPATH=src python examples/train_100m.py --rounds 200
"""
import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.configs import get_arch
from repro.core.rounds import FedConfig
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.core.server import FLServer
from repro.data.pipeline import fed_batches
from repro.models.params import count_params
from repro.core.rounds import make_template
from repro.optim import adamw


def arch_100m():
    base = get_arch("granite-3-8b")
    return dataclasses.replace(
        base,
        name="granite-100m",
        n_layers=12,
        d_model=640,
        n_heads=8,
        n_kv_heads=4,
        head_dim=0,
        d_ff=1792,
        dtype="float32",
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--store", default="/tmp/fedvision_cos")
    args = ap.parse_args()

    cfg = arch_100m()
    n = count_params(make_template(cfg))
    print(f"arch={cfg.name} params={n/1e6:.1f}M")
    fed = FedConfig(n_clients=args.clients, local_steps=1, aggregation="eq6",
                    topn=4, client_axis="data", data_axis=None)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    store = ObjectStore(args.store)
    t0 = time.time()
    with jax.set_mesh(mesh):
        server = FLServer(
            cfg, fed, adamw(3e-4), store=store, mesh=mesh,
            scheduler=TaskScheduler(args.clients, SchedulerConfig(max_participants=args.clients)),
            checkpoint_every=50, task_id="train100m",
        )
        batches = (
            jax.tree.map(jnp.asarray, b)
            for b in fed_batches(cfg, fed, batch=args.batch, seq=args.seq)
        )
        history = server.fit(batches, args.rounds)
    print(json.dumps({
        "params_M": round(n / 1e6, 1),
        "rounds": len(history),
        "loss_first": round(history[0].loss, 4),
        "loss_last": round(history[-1].loss, 4),
        "wall_min": round((time.time() - t0) / 60, 1),
        "cos_rounds": store.rounds("train100m"),
    }))


if __name__ == "__main__":
    main()
