"""Full-platform demo: Task Manager coordinating two concurrent federated
tasks (an LM and the FedYOLOv3 detector), with scheduler-driven
participation, client drop/reconnect simulation, the Fig.-9-style monitor
view, and secure (pairwise-masked) aggregation shown on the side.

  PYTHONPATH=src python examples/multi_task_platform.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import monitor, secure_agg
from repro.core.client import ClientConfig, FLClient
from repro.core.rounds import FedConfig
from repro.core.server import FLServer
from repro.core.task_manager import FederatedTask, TaskManager
from repro.data.pipeline import fed_batches
from repro.optim import adamw, sgd


def make_server(arch_name, fed, opt, mesh, seed=0):
    cfg = get_arch(arch_name)
    if cfg.family != "yolo":  # fedyolov3 is already CPU-sized
        cfg = cfg.reduced()
    return FLServer(cfg, fed, opt, mesh=mesh, seed=seed, task_id=arch_name)


def main() -> None:
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    fed_lm = FedConfig(n_clients=3, local_steps=1, aggregation="eq6", topn=2, client_axis="data", data_axis=None)
    fed_yolo = FedConfig(n_clients=2, local_steps=1, aggregation="dense", client_axis="data", data_axis=None)

    with jax.set_mesh(mesh):
        lm_server = make_server("qwen3-1.7b", fed_lm, adamw(3e-3), mesh)
        yolo_server = make_server("fedyolov3", fed_yolo, sgd(1e-3), mesh)
        lm_batches = (
            jax.tree.map(jnp.asarray, b)
            for b in fed_batches(lm_server.cfg, fed_lm, batch=2, seq=32)
        )
        yolo_batches = (
            jax.tree.map(jnp.asarray, b)
            for b in fed_batches(yolo_server.cfg, fed_yolo, batch=2, seq=0, img_size=32)
        )

        # clients with reconnect budgets (paper Configuration module)
        clients = [FLClient(ClientConfig(i, max_reconnects=2)) for i in range(3)]

        tm = TaskManager()
        tm.register(FederatedTask("lm", "qwen3-1.7b", 8, lambda r: vars(lm_server.run_round(next(lm_batches)))))
        tm.register(FederatedTask("yolo", "fedyolov3", 6, lambda r: vars(yolo_server.run_round(next(yolo_batches)))))

        passes = 0
        rng = np.random.default_rng(0)
        while tm.runnable():
            # simulate a drop/reconnect each pass
            victim = clients[rng.integers(0, len(clients))]
            if rng.random() < 0.3 and victim.connected:
                alive = victim.drop()
                print(f"client {victim.cfg.client_id} dropped "
                      f"({'will reconnect' if alive else 'out of reconnect budget'})")
            tm.step_all()
            passes += 1
        print(f"\nTaskManager finished both tasks in {passes} fair-share passes\n")
        print(monitor.render_task("lm", lm_server.history, fed_lm.n_clients, upload_bytes_per_round=1.7e6))
        print()
        print(monitor.render_task("yolo", yolo_server.history, fed_yolo.n_clients, upload_bytes_per_round=48e6))

        # secure aggregation sidebar: server only ever sees masked sums
        # (unpacked_params = the flat round state's checkpoint/serve edge)
        from repro.core import rounds as R

        lm_stacked = R.unpacked_params(lm_server.cfg, lm_server.fed, lm_server.state)
        ups = [jax.tree.map(lambda x: x[i], lm_stacked) for i in range(3)]
        sec = secure_agg.secure_fedavg(ups, round_idx=0)
        plain = jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs) / 3, *ups)
        err = max(float(jnp.max(jnp.abs(a - b))) for a, b in zip(jax.tree.leaves(sec), jax.tree.leaves(plain)))
        print(f"\nsecure aggregation: pairwise masks cancel to {err:.2e} (server never saw a raw update)")


if __name__ == "__main__":
    main()
