"""FedYOLOv3 — the paper's headline application, end to end:
train -> evaluate -> serve.

Multiple data owners hold procedurally generated camera scenes annotated in
the paper's Darknet ``{label x y w h}`` format, split non-IID by the same
scenario suite the token path uses (dominant-class label skew also skews
box scale). Each round: the Task Scheduler selects participants (masked
participation — the straggler load model keeps overloaded cameras out),
the selected clients train YOLOv3 locally (Eqs 2-4 loss), upload their
Eq.6 top-n layers through the registry aggregator, and the server
aggregates (Eq. 5) and stores the round model in the COS object store.
Every few rounds `server.evaluate_round` scores the global model on each
client's holdout — global + per-client mAP@0.5 through the Pallas IoU/NMS
kernels — and feeds the per-client quality back into the scheduler's EMA.
The finale serves detections from the final global model the same way
`launch.serve` does.

  PYTHONPATH=src python examples/fed_yolo.py [--rounds 30]
"""
import argparse
import tempfile

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.configs import get_arch
from repro.core import detection, monitor
from repro.core.rounds import FedConfig
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.core.server import FLServer
from repro.data import darknet, synthetic
from repro.data.pipeline import detection_suite
from repro.models import yolov3
from repro.optim import sgd


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clients", type=int, default=3)
    ap.add_argument("--img-size", type=int, default=64)
    ap.add_argument("--eval-every", type=int, default=5)
    args = ap.parse_args()

    cfg = get_arch("fedyolov3")
    fed = FedConfig(n_clients=args.clients, local_steps=1, aggregation="eq6", topn=4,
                    client_axis="data", data_axis=None, participation="masked")
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)

    # --- crowdsourced annotation flow: clients write Darknet rows ---------
    with tempfile.TemporaryDirectory() as tmp:
        rng = np.random.default_rng(0)
        imgs, boxes = synthetic.scene_images(rng, 4, args.img_size, cfg.vocab_size)
        from pathlib import Path

        cam = Path(tmp) / "cam0"
        cam.mkdir()
        for i, bs in enumerate(boxes):
            (cam / f"frame{i}.txt").write_text(darknet.write_annotation(bs))
        mapped = darknet.map_annotations(cam, Path(tmp) / "train")
        print(f"annotation module mapped {len(mapped)} files into the training dir")

        # --- train: non-IID scene pool + scheduler-in-the-loop rounds -----
        gen, eval_batch, stats = detection_suite(
            cfg, fed, batch=2, img_size=args.img_size, scenario="dirichlet"
        )
        print(f"dirichlet scene split: sizes {stats['label']['sizes']}, "
              f"box-scale spread {stats['scale']['spread']:.2f}x across clients")
        store = ObjectStore(Path(tmp) / "cos")
        with jax.set_mesh(mesh):
            server = FLServer(
                cfg, fed, sgd(lr=1e-3), store=store, mesh=mesh,
                scheduler=TaskScheduler(args.clients, SchedulerConfig(
                    max_participants=max(2, args.clients - 1), fairness_rounds=3)),
                checkpoint_every=5, task_id="fedyolo",
            )
            batches = (jax.tree.map(jnp.asarray, b) for b in gen)
            for r in range(args.rounds):
                server.run_round(next(batches))
                if r % args.eval_every == 0 or r == args.rounds - 1:
                    ev = server.evaluate_round(eval_batch)
                    per = " ".join(f"{m:.3f}" for m in ev.per_client_map)
                    print(f"round {r:3d}  loss {server.history[-1].loss:8.3f}  "
                          f"mAP@0.5 {ev.map50:.3f}  per-client [{per}]")
        history = server.history
        print(monitor.render_task("fedyolo", history, args.clients,
                                  eval_history=server.eval_history))

        # --- serve: final global model -> decode + Pallas NMS -------------
        params = server.global_params()
        imgs_t, boxes_t = synthetic.scene_images(np.random.default_rng(7), 4, args.img_size, cfg.vocab_size)
        pred = detection.decode_predictions(cfg, params, jnp.asarray(imgs_t), max_detections=16)
        kept = int(np.asarray(pred["valid"]).sum())
        print(f"serving 4 frames: {kept} detections after NMS "
              f"(top score {float(np.asarray(pred['scores']).max()):.3f})")

        # detection sanity: confidence at object cells > empty cells
        outs = yolov3.forward(params, jnp.asarray(imgs_t), cfg)
        grids = yolov3.grid_sizes(cfg, args.img_size)
        tgts = darknet.build_targets(boxes_t, grids, cfg.n_heads, cfg.vocab_size, yolov3.ANCHORS)
        _, conf, _ = yolov3.decode_boxes(outs[0].astype(jnp.float32), yolov3.ANCHORS[0])
        obj = jnp.asarray(tgts[0]["obj"])
        conf_obj = float((conf * obj).sum() / jnp.maximum(obj.sum(), 1))
        conf_bg = float((conf * (1 - obj)).sum() / (1 - obj).sum())
        print(f"loss {history[0].loss:.3f} -> {history[-1].loss:.3f}; "
              f"mean conf@objects={conf_obj:.3f} vs background={conf_bg:.3f}")
        print(f"COS stored rounds: {store.rounds('fedyolo')}, total {store.total_bytes()/1e6:.2f} MB")
        assert history[-1].loss < history[0].loss


if __name__ == "__main__":
    main()
