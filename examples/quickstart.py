"""Quickstart: federated training of a small LM with the FedVision engine.

Four clients with non-IID token streams train locally; the FL_SERVER
aggregates with the paper's Eq. 6 top-n upload compression each round and
the Yu-2017 scheduler picks participants by quality/load.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core.rounds import FedConfig
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.core.server import FLServer
from repro.data.pipeline import fed_batches
from repro.optim import adamw

ARCH = get_arch("qwen3-1.7b").reduced()
FED = FedConfig(n_clients=4, local_steps=2, aggregation="eq6", topn=2, client_axis="data", data_axis=None)


def main() -> None:
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        server = FLServer(
            ARCH,
            FED,
            adamw(3e-3),
            scheduler=TaskScheduler(4, SchedulerConfig(max_participants=3)),
            mesh=mesh,
        )
        batches = (
            jax.tree.map(jnp.asarray, b) for b in fed_batches(ARCH, FED, batch=4, seq=48)
        )
        history = server.fit(batches, n_rounds=15)
    first, last = history[0].loss, history[-1].loss
    print(f"\nfederated loss {first:.3f} -> {last:.3f} over {len(history)} rounds")
    assert last < first


if __name__ == "__main__":
    main()
