"""Quickstart: federated training of a small LM with the FedVision engine.

Four clients with non-IID token streams train locally; each round the
Yu-2017 Task Scheduler picks participants from quality/load scores (masked
participation — unselected clients skip the round), and the FL_SERVER
aggregates through the registry with the paper's Eq. 6 top-n upload
compression. Any registered aggregation mode works via --agg.

  PYTHONPATH=src python examples/quickstart.py --rounds 5
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import aggregators
from repro.core.rounds import FedConfig
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.core.server import FLServer
from repro.data.pipeline import fed_batches
from repro.optim import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=15)
    ap.add_argument("--agg", default="eq6", choices=[n for n in aggregators.names() if n != "fedsgd"])
    args = ap.parse_args()

    arch = get_arch("qwen3-1.7b").reduced()
    fed = FedConfig(
        n_clients=4,
        local_steps=2,
        aggregation=args.agg,
        topn=2,
        client_axis="data",
        data_axis=None,
        participation="masked",  # scheduler-selected clients train; the rest sit out
        # fedadam's adaptive step is ~server_lr per coordinate — needs a small
        # one (see core/aggregators/server_opt.py); 1.0 is exact FedAvg otherwise
        server_lr=0.02 if args.agg == "fedadam" else 1.0,
    )
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        server = FLServer(
            arch,
            fed,
            adamw(3e-3),
            scheduler=TaskScheduler(4, SchedulerConfig(max_participants=3)),
            mesh=mesh,
        )
        batches = (
            jax.tree.map(jnp.asarray, b) for b in fed_batches(arch, fed, batch=4, seq=48)
        )
        history = server.fit(batches, n_rounds=args.rounds)
    first, last = history[0].loss, history[-1].loss
    mean_part = sum(len(r.participants) for r in history) / len(history)
    print(f"\nfederated loss {first:.3f} -> {last:.3f} over {len(history)} rounds "
          f"({args.agg}, mean participants {mean_part:.1f}/4)")
    assert last < first


if __name__ == "__main__":
    main()
