"""Upload-compression demo: Eq. 6 layer selection + int8 quantization.

Shows, for one federated round of a real model, exactly which layers each
client would upload under Eq. 6 and how many bytes each transport moves —
the mechanism behind the paper's Fig. 8 and the SPIC bandwidth claim.

  PYTHONPATH=src python examples/compression_demo.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import compression as comp
from repro.core import packing
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.data.pipeline import fed_batches
from repro.kernels import ops
from repro.models.params import count_params
from repro.optim import adamw

CFG = get_arch("qwen3-1.7b").reduced()


def main() -> None:
    fed = FedConfig(n_clients=3, local_steps=2, aggregation="eq6", topn=1, client_axis="data", data_axis=None)
    tpl = R.make_template(CFG)
    opt = adamw(3e-3)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        batch = jax.tree.map(jnp.asarray, next(fed_batches(CFG, fed, batch=2, seq=32)))
        before = state["agg"]["prev_sums"]
        state, _ = fr(state, batch, R.uniform_weights(3))
        scores = comp.contribution_scores(before, state["agg"]["prev_sums"])

    nb = comp.n_score_buckets(CFG)
    print(f"{CFG.name}: {nb} layer buckets ({CFG.n_layers} layers + misc)")
    for c in range(3):
        mask = np.asarray(comp.topn_mask(scores[c], fed.topn))
        ranked = np.argsort(-np.asarray(scores[c]))
        print(f"client {c}: v(j)={np.round(np.asarray(scores[c]), 3)} -> uploads buckets {np.nonzero(mask)[0].tolist()} (rank order {ranked.tolist()})")

    n = count_params(tpl)
    full = n * 4
    print(f"\nupload per client per round ({n/1e6:.1f}M params):")
    print(f"  full f32        : {full/1e6:8.2f} MB")
    print(f"  Eq.6 top-{fed.topn}      : {full*comp.compression_ratio(CFG, fed.topn)/1e6:8.2f} MB")
    print(f"  int8 delta      : {n/1e6:8.2f} MB (+{nb*4} B scales)")
    print(f"  Eq.6 + int8     : {n*comp.compression_ratio(CFG, fed.topn)/1e6:8.2f} MB")

    # flat round engine: state["params"] IS the packed (C, N_total) buffer —
    # no per-round pack; the unpack below is the checkpoint/serve edge copy
    w = R.uniform_weights(3)
    spec = packing.build_pack_spec(CFG, tpl)
    packed = state["params"]
    stacked = R.unpacked_params(CFG, fed, state)
    wmask = jax.vmap(lambda s: comp.topn_mask(s, fed.topn))(scores).astype(jnp.float32) * w[:, None]
    num, den = ops.packed_bucket_reduce(packed, wmask, jnp.asarray(packing.bucket_ids(spec)))
    n_leaves = len(jax.tree.leaves(stacked))
    print(f"\nflat engine: {n_leaves} tensors live as one ({packed.shape[0]}, {packed.shape[1]}) "
          f"round-state buffer, 1 Pallas launch (legacy tree path: {n_leaves} launches); "
          f"{int(jnp.sum(den > 0))}/{spec.n_total} elements uploaded this round")

    # legacy per-leaf kernel path, kept as the reference
    flat_mask = jax.tree.map(lambda _: jnp.ones(3), stacked)  # per-leaf demo mask
    agg = ops.fedavg_tree(stacked, w, flat_mask)
    print(f"legacy fedavg_tree aggregated {len(jax.tree.leaves(agg))} tensors "
          f"({sum(x.size for x in jax.tree.leaves(agg))/1e6:.1f}M values)")


if __name__ == "__main__":
    main()
