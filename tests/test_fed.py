"""Federated engine behaviour: Eq.5/Eq.6 semantics, all aggregation modes
train, quant8 tracks dense, FedSGD(E=1) == stacked FedAvg(E=1).

Aggregation now runs through the packed-buffer engine behind the
repro.core.aggregators registry; packed-vs-legacy numerical equivalence
lives in tests/test_aggregators.py."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import compression as comp
from repro.core import fedavg
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()


def toy_batch(fed, b=2, S=16, seed=1):
    rng = np.random.default_rng(seed)
    if fed.aggregation == "fedsgd":
        shape = (fed.local_steps, b * fed.n_clients, S)
    else:
        shape = (fed.n_clients, fed.local_steps, b, S)
    return {"tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, shape), jnp.int32)}


@pytest.mark.parametrize("mode", ["dense", "eq6", "quant8", "static_topn"])
def test_modes_train(mode):
    fed = FedConfig(n_clients=4, local_steps=2, aggregation=mode, topn=2, client_axis="data", data_axis=None)
    opt = sgd(lr=0.05)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        batch = toy_batch(fed)
        w = R.uniform_weights(4)
        losses = []
        for _ in range(5):
            state, m = fr(state, batch, w)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (mode, losses)
    assert int(state["round"]) == 5


def test_quant8_tracks_dense():
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt = sgd(lr=0.05)
    out = {}
    for mode in ["dense", "quant8"]:
        fed = FedConfig(n_clients=4, local_steps=1, aggregation=mode, client_axis="data", data_axis=None)
        with jax.set_mesh(mesh):
            state = R.make_state(CFG, fed, opt, jax.random.key(0))
            fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
            batch = toy_batch(fed)
            for _ in range(3):
                state, m = fr(state, batch, R.uniform_weights(4))
        out[mode] = float(m["loss"])
    assert abs(out["quant8"] - out["dense"]) < 0.05, out


def test_fedsgd_equals_stacked_fedavg_e1():
    """Param-averaging == grad-averaging for E=1 SGD (DESIGN.md §5)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt = sgd(lr=0.05, momentum=0.0)
    C, b, S = 4, 2, 16
    rng = np.random.default_rng(7)
    toks = rng.integers(0, CFG.vocab_size, (C, 1, b, S))
    fed_a = FedConfig(n_clients=C, local_steps=1, aggregation="dense", client_axis="data", data_axis=None)
    fed_s = FedConfig(n_clients=C, local_steps=1, aggregation="fedsgd", client_axis="data", data_axis=None)
    with jax.set_mesh(mesh):
        st_a = R.make_state(CFG, fed_a, opt, jax.random.key(3))
        stacked_a = R.unpacked_params(CFG, fed_a, st_a)  # flat state -> pytree edge
        st_s = {
            "params": jax.tree.map(lambda x: x[0], stacked_a),
            "opt": jax.tree.map(lambda x: x[0], st_a["opt"]),
            "round": jnp.int32(0),
        }
        fr_a = jax.jit(R.build_fed_round(CFG, fed_a, opt, mesh))
        fr_s = jax.jit(R.build_fed_round(CFG, fed_s, opt, mesh))
        st_s["agg"] = {}
        st_a, _ = fr_a(st_a, {"tokens": jnp.asarray(toks, jnp.int32)}, R.uniform_weights(C))
        # fedsgd sees the same tokens as one big batch
        st_s, _ = fr_s(st_s, {"tokens": jnp.asarray(toks.transpose(1, 0, 2, 3).reshape(1, C * b, S), jnp.int32)}, R.uniform_weights(C))
    a0 = jax.tree.leaves(R.unpacked_params(CFG, fed_a, st_a))[0][0]
    s0 = jax.tree.leaves(st_s["params"])[0]
    np.testing.assert_allclose(np.asarray(a0, np.float32), np.asarray(s0, np.float32), rtol=2e-4, atol=2e-5)


def test_eq6_uploads_topn_only():
    """Clients upload exactly topn buckets; non-uploaded layers keep local values."""
    tpl = R.make_template(CFG)
    fed = FedConfig(n_clients=3, local_steps=1, aggregation="eq6", topn=1, client_axis="data")
    opt = sgd()
    state = R.make_state(CFG, fed, opt, jax.random.key(0))
    stacked = R.unpacked_params(CFG, fed, state)  # legacy path wants the pytree
    nb = comp.n_score_buckets(CFG)
    # every client drifts hugely on bucket 0 (-> its top-1 upload) and a
    # little, client-dependently, on bucket 1 (never uploaded)
    big = jnp.zeros(nb).at[0].set(1.0)
    small = jnp.zeros(nb).at[1].set(1.0)

    stacked = jax.vmap(lambda p, c: jax.tree.map(
        lambda x, d: x + d,
        p,
        jax.tree.map(
            lambda ones_b, ones_s: 100.0 * (c + 1) * ones_b + 0.01 * (c + 1) * ones_s,
            comp.apply_layer_mask(CFG, tpl, jax.tree.map(jnp.ones_like, p), big),
            comp.apply_layer_mask(CFG, tpl, jax.tree.map(jnp.ones_like, p), small),
        ),
    ))(stacked, jnp.arange(3.0))
    prev = state["agg"]["prev_sums"]
    new, sums = fedavg.aggregate_eq6(CFG, tpl, stacked, R.uniform_weights(3), prev, topn=1)
    # bucket 0 synced (all uploaded it), bucket 1 still divergent
    new_sums = jax.vmap(lambda p: comp.layer_sums(CFG, tpl, p))(new)
    assert float(jnp.max(jnp.abs(new_sums[:, 0] - new_sums[0, 0]))) < 1e-3
    assert float(jnp.max(jnp.abs(new_sums[:, 1] - new_sums[0, 1]))) > 1e-3
    assert sums.shape == (3, nb)


def test_static_schedule_covers_all_layers():
    nb = comp.n_score_buckets(CFG)
    seen = set()
    for r in range(nb):
        seen.update(fedavg.static_layer_schedule(nb, 1, r))
    assert seen == set(range(nb))


def test_microbatching_matches_full_batch():
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt = sgd(lr=0.05, momentum=0.0)
    rng = np.random.default_rng(11)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 1, 4, 16)), jnp.int32)
    outs = []
    for mb in (1, 4):
        fed = FedConfig(n_clients=2, local_steps=1, aggregation="dense", client_axis="data", data_axis=None, microbatches=mb)
        with jax.set_mesh(mesh):
            st = R.make_state(CFG, fed, opt, jax.random.key(5))
            fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
            st, m = fr(st, {"tokens": toks}, R.uniform_weights(2))
        outs.append(np.asarray(jax.tree.leaves(st["params"])[0], np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-5)
