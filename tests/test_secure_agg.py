"""Secure aggregation: mask cancellation, privacy, FedAvg equivalence."""
import numpy as np
from _hyp import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import secure_agg as sa


def _updates(n, shape=(16,), seed=0):
    rng = np.random.default_rng(seed)
    return [
        {"w": jnp.asarray(rng.normal(size=shape), jnp.float32), "b": {"x": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}}
        for _ in range(n)
    ]


@given(st.integers(2, 6), st.integers(0, 5))
@settings(max_examples=12, deadline=None)
def test_masks_cancel_exactly(n, round_idx):
    ups = _updates(n, seed=round_idx)
    secure = sa.secure_fedavg(ups, round_idx, scale=100.0)
    plain = jax.tree.map(lambda *xs: sum(xs) / n, *ups)
    for a, b in zip(jax.tree.leaves(secure), jax.tree.leaves(plain)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-3)


def test_masked_update_hides_individual():
    """A single masked upload is dominated by mask noise (privacy)."""
    ups = _updates(3)
    masked = sa.mask_update(ups[0], 0, 3, round_idx=0, scale=100.0)
    diff = np.asarray(masked["w"]) - np.asarray(ups[0]["w"])
    assert np.abs(diff).mean() > 10.0  # mask >> signal
    # and correlation with the true update is negligible
    corr = np.corrcoef(np.asarray(masked["w"]), np.asarray(ups[0]["w"]))[0, 1]
    assert abs(corr) < 0.9


def test_pair_seed_symmetric_and_round_dependent():
    assert sa.pair_seed(1, 3, 7) == sa.pair_seed(3, 1, 7)
    assert sa.pair_seed(1, 3, 7) != sa.pair_seed(1, 3, 8)
    assert sa.pair_seed(1, 3, 7, session=1) != sa.pair_seed(1, 3, 7, session=2)


def test_monitor_render():
    from repro.core.monitor import export_json, render_task, sparkline
    from repro.core.server import RoundRecord

    hist = [RoundRecord(i, 5.0 - 0.1 * i, [0.5, 0.5, 0.0], 0.3) for i in range(10)]
    out = render_task("demo", hist, 3, upload_bytes_per_round=2.5e6)
    assert "round 10/10" in out and "2/3 participating" in out and "2.50 MB" in out
    assert len(sparkline([1, 2, 3])) == 3
    import json

    j = json.loads(export_json("demo", hist, 3))
    assert len(j["rounds"]) == 10 and j["rounds"][-1]["participants"] == 2
