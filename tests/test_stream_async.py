"""Streaming async flush (DESIGN.md §13).

Pins the O(buffer_size·N) discipline:
  - with the same seed/timing, StreamingAsyncEngine reproduces the
    buffered engine's event schedule exactly (participants, staleness,
    drops) and its global model to reduction-order tolerance;
  - no state leaf carries the client dimension: the dispatch ring is
    (max_staleness+1, N) and the running accumulator is (N,) — that IS
    the memory claim, as static shapes;
  - drops are counted, never silently lost, and redispatch version-only;
  - build-time validation: stream needs max_staleness>=1, the dense
    reduce, and a stateless local optimizer; BufferedAsyncEngine refuses
    stream=True configs;
  - sgd(momentum=0) is stateless and steps identically to the momentum
    path's first step;
  - FLServer dispatches on fed.stream and serves global_params/monitor
    from the ring.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import explorer, monitor
from repro.core.async_engine import BufferedAsyncEngine, StreamingAsyncEngine
from repro.core.rounds import FedConfig
from repro.core.server import FLServer
from repro.optim import adamw, sgd

CFG = get_arch("qwen3-1.7b").reduced()
C = 4


def _fed(**kw):
    base = dict(n_clients=C, local_steps=1, aggregation="dense",
                client_axis="data", data_axis=None, state_layout="flat",
                mode="async", buffer_size=2, max_staleness=3, stream=True)
    base.update(kw)
    return FedConfig(**base)


def _batch(seed=1):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, (C, 1, 2, 16)), jnp.int32)}


def _opt():
    return sgd(lr=0.05, momentum=0.0)


def test_streaming_matches_buffered_engine():
    eb = BufferedAsyncEngine(CFG, _fed(stream=False), _opt(), seed=0,
                             load_model=explorer.ClientLoadModel(C, seed=0))
    es = StreamingAsyncEngine(CFG, _fed(), _opt(), seed=0,
                              load_model=explorer.ClientLoadModel(C, seed=0))
    batch = _batch()
    for i in range(6):
        rb = eb.step_round(batch)
        rs = es.step_round(batch)
        # identical event plane: the collection loop is shared code
        assert rb.participants == rs.participants
        assert rb.staleness == rs.staleness
        assert rb.dropped == rs.dropped
        assert rb.weights == pytest.approx(rs.weights, abs=1e-7)
        assert rb.loss == pytest.approx(rs.loss, rel=1e-4)
        gb = np.asarray(eb.global_packed_row(), np.float64)
        gs = np.asarray(es.global_packed_row(), np.float64)
        scale = max(np.max(np.abs(gb)), 1e-9)
        # same math, different reduction order (masked C-chain vs cohort sum)
        assert np.max(np.abs(gb - gs)) / scale < 1e-5, i


def test_streaming_state_has_no_client_dimension():
    fed = _fed(max_staleness=2)
    es = StreamingAsyncEngine(CFG, fed, _opt(), seed=0)
    n = es.agg.ctx.spec.n_total
    assert es.state["ring"].shape == (fed.max_staleness + 1, n)
    assert es.state["agg"]["acc"].shape == (n,)
    assert es.state["agg"]["wsum"].shape == ()
    for leaf in jax.tree.leaves(es.state):
        assert not (leaf.ndim and leaf.shape[0] == C), leaf.shape
    # the flush materializes at most min(buffer_size, _cohort) rows at once
    assert min(fed.buffer_size, es._cohort) <= fed.buffer_size


def test_streaming_drop_accounting():
    es = StreamingAsyncEngine(CFG, _fed(buffer_size=1, max_staleness=1), _opt(), seed=3)
    batch = _batch()
    staged_total = 0
    for _ in range(12):
        rec = es.step_round(batch)
        staged_total += len(rec.participants)
        assert all(s <= 1 for s in rec.staleness)
    assert es.completions == staged_total + es.dropped_total
    assert es.dropped_total > 0  # the schedule actually exercised drops


def test_streaming_config_validation():
    with pytest.raises(ValueError, match="max_staleness"):
        StreamingAsyncEngine(CFG, _fed(max_staleness=0), _opt())
    with pytest.raises(ValueError, match="dense"):
        StreamingAsyncEngine(CFG, _fed(aggregation="eq6"), _opt())
    with pytest.raises(ValueError, match="stateless"):
        StreamingAsyncEngine(CFG, _fed(), sgd(lr=0.05))  # momentum state
    with pytest.raises(ValueError, match="stateless"):
        StreamingAsyncEngine(CFG, _fed(), adamw(1e-3))
    with pytest.raises(ValueError, match="stream=True"):
        StreamingAsyncEngine(CFG, _fed(stream=False), _opt())
    with pytest.raises(ValueError, match="StreamingAsyncEngine"):
        BufferedAsyncEngine(CFG, _fed(), _opt())


def test_stateless_sgd_matches_momentum_first_step():
    opt0 = sgd(lr=0.1, momentum=0.0)
    optm = sgd(lr=0.1, momentum=0.9)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    grads = {"w": jnp.asarray([0.5, 0.1, -0.2], jnp.float32)}
    assert opt0.init(params) == {}
    p0, s0 = opt0.update(params, grads, {})
    pm, _ = optm.update(params, grads, optm.init(params))
    # from zero velocity the first momentum step is the plain sgd step
    np.testing.assert_allclose(np.asarray(p0["w"]), np.asarray(pm["w"]), rtol=1e-7)
    assert s0 == {}


def test_server_dispatches_streaming_engine():
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        srv = FLServer(CFG, _fed(), _opt(), mesh=mesh, seed=0)
        assert isinstance(srv.engine, StreamingAsyncEngine)
        batch = _batch()
        rec = srv.run_async(batch)
        assert rec.participants and rec.version == 1
        params = srv.global_params()
        leaves = jax.tree.leaves(params)
        assert leaves and all(l.ndim == 0 or l.shape[0] != C for l in leaves)
        # the ring row round-trips through the one pack/unpack edge
        packed = srv.engine.global_packed_row()
        assert packed.shape == (srv.engine.agg.ctx.spec.n_total,)
        text = monitor.render_task("t", srv.history, C)
        assert "sim clock" in text
