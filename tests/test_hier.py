"""Hierarchical two-level aggregation (DESIGN.md §13).

Pins the PR 6 tentpole invariants:
  - `hier` at G=1 and G=C is bit-for-bit the flat engine (params/opt/agg/
    loss) for EVERY registered stacked base — the degenerate geometries
    delegate to the same program by construction;
  - the genuine two-level path (1 < G < C) with a dense base matches the
    flat dense mean analytically (per-group renormalization telescopes);
  - `grouped_weighted_mean` (ref + Pallas `grouped_reduce`) matches the
    NumPy oracle, including masked-out members and empty groups;
  - build-time geometry validation: hier group divisibility, recursion and
    fedsgd-base rejection, quant8's C % G / G % shards check;
  - the sharded client axis reproduces the unsharded round at 1e-6
    (subprocess: tests run on one CPU device, the sharded round forces 2).
"""
import os
import subprocess
import sys
import types

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import aggregators, packing
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.kernels import pack as pk
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()
TPL = R.make_template(CFG)
SPEC = packing.build_pack_spec(CFG, TPL)
C = 4
STACKED_MODES = [
    ("dense", {}),
    ("eq6", {}),
    ("quant8", {}),
    ("static_topn", {}),
    ("fedavgm", {}),
    ("fedadam", {"server_lr": 0.02}),
    ("trimmed_mean", {"trim_ratio": 0.3}),
]


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _fed(mode, **kw):
    base = dict(n_clients=C, local_steps=1, aggregation=mode, topn=2,
                client_axis="data", data_axis=None, state_layout="flat")
    base.update(kw)
    return FedConfig(**base)


def _toks(seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab_size, (C, 1, 2, 16)), jnp.int32)


def _run(fed, n=2, seed=0):
    opt = sgd(lr=0.05)
    mesh = _mesh()
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(seed))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        for _ in range(n):
            state, m = fr(state, {"tokens": _toks()}, jnp.asarray([0.4, 0.1, 0.3, 0.2], jnp.float32))
    return state, m


_FLAT_CACHE: dict = {}


def _flat(mode, kw):
    key = mode
    if key not in _FLAT_CACHE:
        _FLAT_CACHE[key] = _run(_fed(mode, **kw))
    return _FLAT_CACHE[key]


# ----------------- degenerate geometries == flat, bit for bit ----------------


@pytest.mark.parametrize("mode,kw", STACKED_MODES, ids=[m for m, _ in STACKED_MODES])
@pytest.mark.parametrize("G", [1, C], ids=["G1", "GC"])
def test_hier_degenerate_bitwise_flat(mode, kw, G):
    sf, mf = _flat(mode, kw)
    sh, mh = _run(_fed("hier", group_size=G, hier_base=mode, **kw))
    fl, hl = jax.tree.leaves(sf), jax.tree.leaves(sh)
    assert len(fl) == len(hl)
    for a, b in zip(fl, hl):
        assert a.dtype == b.dtype and a.shape == b.shape
        assert jnp.array_equal(a, b), f"{mode} G={G}: state leaf diverged"
    assert float(mf["loss"]) == float(mh["loss"])


def test_hier_middle_g_dense_matches_flat():
    # per-group renormalization telescopes for the linear dense reduce, so
    # the genuine two-level program agrees with flat to reduction-order ulps
    sf, mf = _flat("dense", {})
    sh, mh = _run(_fed("hier", group_size=2, hier_base="dense"))
    pf = np.asarray(sf["params"], np.float64)
    ph = np.asarray(sh["params"], np.float64)
    scale = max(np.max(np.abs(pf)), 1e-9)
    assert np.max(np.abs(pf - ph)) / scale < 1e-6
    assert abs(float(mf["loss"]) - float(mh["loss"])) < 1e-6


def test_hier_pallas_impl_round_runs():
    s, m = _run(_fed("hier", group_size=2, hier_base="dense", agg_impl="pallas"), n=1)
    sf, _ = _flat("dense", {})
    pf = np.asarray(sf["params"], np.float64)
    # flat cache ran 2 rounds; rerun 1-round flat for the comparison
    s1, _ = _run(_fed("dense"), n=1)
    d = np.abs(np.asarray(s1["params"], np.float64) - np.asarray(s["params"], np.float64))
    assert d.max() / max(np.max(np.abs(pf)), 1e-9) < 1e-5


# ----------------- grouped reduce oracles ------------------------------------


def test_grouped_weighted_mean_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    Cb, N, G = 24, 513, 6
    x = rng.normal(size=(Cb, N)).astype(np.float32)
    w = rng.uniform(0.1, 1.0, Cb).astype(np.float32)
    mask = (rng.uniform(size=Cb) > 0.3).astype(np.float32)
    mask[:G] = 0.0  # group 0 fully masked: zero row, zero den
    rows, den = packing.grouped_weighted_mean(jnp.asarray(x), jnp.asarray(w), G, jnp.asarray(mask))
    wm = (w * mask).reshape(-1, G)
    den_np = wm.sum(axis=1)
    exp = np.einsum("gi,gin->gn", wm / np.maximum(den_np, 1e-12)[:, None], x.reshape(-1, G, N))
    np.testing.assert_allclose(np.asarray(rows), exp, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(den), den_np, rtol=1e-6)
    assert float(den[0]) == 0.0 and float(np.abs(np.asarray(rows)[0]).max()) == 0.0


@pytest.mark.parametrize("G", [1, 4, 8, 32])
def test_grouped_reduce_pallas_matches_ref(G):
    rng = np.random.default_rng(G)
    Cb, N = 32, 2100  # N not a block multiple: exercises padding
    x = jnp.asarray(rng.normal(size=(Cb, N)).astype(np.float32))
    w = jnp.asarray(rng.uniform(0.1, 1.0, Cb).astype(np.float32))
    ref_rows, ref_den = packing.grouped_weighted_mean(x, w, G, impl="ref")
    pal_rows, pal_den = packing.grouped_weighted_mean(x, w, G, impl="pallas")
    np.testing.assert_allclose(np.asarray(pal_rows), np.asarray(ref_rows), rtol=2e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(pal_den), np.asarray(ref_den), rtol=1e-6)


def test_client_block_widens_for_large_c():
    assert pk.client_block(8) == pk.BLOCK_C
    assert pk.client_block(64) == pk.BLOCK_C
    assert pk.client_block(256) > pk.BLOCK_C
    assert pk.client_block(1024) > pk.BLOCK_C


# ----------------- build-time validation -------------------------------------


def test_hier_validation_errors():
    with pytest.raises(ValueError, match="group_size"):
        R.make_aggregator(CFG, _fed("hier", group_size=3))  # 4 % 3 != 0
    with pytest.raises(ValueError, match="recurse"):
        R.make_aggregator(CFG, _fed("hier", group_size=2, hier_base="hier"))
    with pytest.raises(ValueError, match="stacked"):
        R.make_aggregator(CFG, _fed("hier", group_size=2, hier_base="fedsgd"))
    with pytest.raises(ValueError, match="unknown aggregation"):
        R.make_aggregator(CFG, _fed("hier", group_size=2, hier_base="nope"))


def _fake_mesh(shards):
    return types.SimpleNamespace(
        axis_names=("data", "model"), devices=np.zeros((shards, 1))
    )


def test_quant8_group_geometry_validation():
    from repro.core.aggregators.quant import Quant8
    import dataclasses as dc

    agg = R.make_aggregator(CFG, _fed("quant8"))
    # valid: C=4, G=2, 2 shards -> C % G == 0 and G % shards == 0
    Quant8(dc.replace(agg.ctx, fed=_fed("quant8", group_size=2), mesh=_fake_mesh(2)))
    # invalid: G does not divide C
    with pytest.raises(ValueError) as e:
        Quant8(dc.replace(agg.ctx, fed=_fed("quant8", group_size=3), mesh=_fake_mesh(2)))
    assert "n_clients=4" in str(e.value) and "group_size=3" in str(e.value) and "shards=2" in str(e.value)
    # invalid: shards do not divide G
    with pytest.raises(ValueError, match="group_size % shards"):
        Quant8(dc.replace(agg.ctx, fed=_fed("quant8", group_size=2), mesh=_fake_mesh(4)))
    # groupless config keeps the original C % shards check
    with pytest.raises(ValueError, match="divisible"):
        Quant8(dc.replace(agg.ctx, mesh=_fake_mesh(3)))


def test_hier_shard_local_group_validation():
    from repro.core.aggregators.hier import Hier
    import dataclasses as dc

    agg = R.make_aggregator(CFG, _fed("dense"))
    # 4 clients over 4 shards leaves 1 row/shard: group_size=2 straddles
    with pytest.raises(ValueError, match="shard-local"):
        Hier(dc.replace(agg.ctx, fed=_fed("hier", group_size=2), mesh=_fake_mesh(4)))


# ----------------- sharded == unsharded (subprocess: needs 2 devices) --------

_SHARDED_SCRIPT = r"""
import os
import numpy as np
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()
C = 4

def run(n_shards):
    fed = FedConfig(n_clients=C, local_steps=1, aggregation="hier",
                    group_size=2, hier_base="dense", topn=2,
                    client_axis="data", data_axis=None, state_layout="flat")
    opt = sgd(lr=0.05)
    mesh = jax.make_mesh((n_shards, 1), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2,
                         devices=jax.devices()[:n_shards])
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (C, 1, 2, 16)), jnp.int32)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        w = jnp.asarray([0.4, 0.1, 0.3, 0.2], jnp.float32)
        for _ in range(2):
            state, m = fr(state, {"tokens": toks}, w)
    return np.asarray(jax.device_get(state["params"]), np.float64), float(m["loss"])

assert jax.device_count() == 2, jax.device_count()
p1, l1 = run(1)
p2, l2 = run(2)
scale = max(np.max(np.abs(p1)), 1e-9)
print("MAXDIFF", np.max(np.abs(p1 - p2)) / scale, "LOSSDIFF", abs(l1 - l2))
assert np.max(np.abs(p1 - p2)) / scale < 1e-6, np.max(np.abs(p1 - p2)) / scale
assert abs(l1 - l2) < 1e-6
print("SHARDED_OK")
"""


def test_sharded_hier_matches_unsharded():
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=2").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(os.path.dirname(__file__), "..", "src"), env.get("PYTHONPATH", "")]
    )
    out = subprocess.run(
        [sys.executable, "-c", _SHARDED_SCRIPT], env=env,
        capture_output=True, text=True, timeout=420,
    )
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARDED_OK" in out.stdout, out.stdout
