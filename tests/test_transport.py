"""Multi-process federation over a real wire, pinned deterministic against
the in-process engine (DESIGN.md §14).

The tentpole contract: a wire run — real worker processes training over
TCP, landings in wall-clock arrival order — records its arrival schedule,
and replaying that schedule through the SimClock `ArrivalAsyncEngine`
reproduces the global parameters **bit for bit** (dense codec; 1e-5 for
quant8, which in practice is also bitwise because the int8 delta
round-trip is deterministic NumPy). The acceptance test drives C=4 worker
processes over 5 flushes including one forced staleness dropout, then
replays.

Below it, the layers the contract rests on get their own pins:
  - framing: length-prefixed frames survive arbitrary split/coalesced
    reads; corrupt lengths/types fail loudly;
  - codec: dense is bit-lossless, quant8's delta error is bounded by half
    a quantization step per block, dispatches are always dense;
  - arrival engine: staged clients can't be redispatched over, double
    updates are refused, stale landings drop + redispatch *from the true
    global* — global_packed_row() must survive the global_row client
    landing its next trained update mid-window (the buffered engine never
    faces this: its rows only mutate at a flush);
  - FLServer: async checkpoints read the engine's global row, not a
    client's half-trained buffer row, even after drops/redispatches.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.core import rounds as R
from repro.core.async_engine import ArrivalAsyncEngine, build_row_update
from repro.core.explorer import ClientLoadModel, LoadModelConfig
from repro.core.server import FLServer
from repro.core.simclock import SimClock, WallClock
from repro.core.transport import codec as tc
from repro.core.transport import harness, wire
from repro.core.transport import replay as rp
from repro.optim import adamw, sgd

TINY = harness.TINY_OVERRIDES


def _meta(**kw):
    base = dict(overrides=TINY, n_clients=3, buffer_size=2, max_staleness=1,
                seq=8, batch=2)
    base.update(kw)
    return harness.make_meta(**base)


# ------------------------------- framing -------------------------------------

def test_frame_roundtrip_survives_arbitrary_chunking():
    rng = np.random.default_rng(7)
    frames = [
        wire.pack_hello(3),
        wire.pack_dispatch(9, b"\x00" + rng.bytes(37)),
        wire.pack_update(1, 4, 9, 0.5, rng.bytes(113)),
        wire.pack_heartbeat(2),
        wire.pack_bye(),
    ]
    stream = b"".join(frames)
    # feed in adversarial chunk sizes: 1-byte drip, then random splits
    for sizes in ([1] * len(stream), rng.integers(1, 11, len(stream)).tolist()):
        parser = wire.FrameParser()
        got = []
        pos = 0
        for n in sizes:
            got.extend(parser.feed(stream[pos:pos + int(n)]))
            pos += int(n)
            if pos >= len(stream):
                break
        assert parser.pending == 0
        assert [t for t, _ in got] == [wire.HELLO, wire.DISPATCH, wire.UPDATE,
                                       wire.HEARTBEAT, wire.BYE]
    assert wire.parse_hello(got[0][1]) == 3
    v, row = wire.parse_dispatch(got[1][1])
    assert v == 9 and len(row) == 38
    c, seq, ver, loss, buf = wire.parse_update(got[2][1])
    assert (c, seq, ver, loss) == (1, 4, 9, 0.5) and len(buf) == 113
    assert wire.parse_heartbeat(got[3][1]) == 2


def test_frame_parser_rejects_corruption():
    import zlib

    with pytest.raises(ValueError, match="frame length"):
        wire.FrameParser().feed(b"\x00\x00\x00\x00garbage")
    # an unknown type under a VALID crc is a protocol bug, not line noise
    body = b"\x7f"
    bad_type = b"\x00\x00\x00\x01" + zlib.crc32(body).to_bytes(4, "big") + body
    with pytest.raises(ValueError, match="frame type"):
        wire.FrameParser().feed(bad_type)
    with pytest.raises(ValueError, match="protocol version"):
        wire.parse_hello(wire.FrameParser().feed(
            wire.encode_frame(wire.HELLO, b"\x00\x00\x00\x01\x00\x63"))[0][1])


def _example_frames(rng):
    return {
        "hello": wire.pack_hello(3),
        "dispatch": wire.pack_dispatch(9, b"\x00" + rng.bytes(37)),
        "update": wire.pack_update(1, 4, 9, 0.5, rng.bytes(113)),
        "heartbeat": wire.pack_heartbeat(2),
        "bye": wire.pack_bye(),
    }


@pytest.mark.parametrize("ftype", ["hello", "dispatch", "update", "heartbeat", "bye"])
def test_crc_detects_every_corrupted_byte(ftype):
    """DESIGN.md §16: flip ANY single byte of the CRC field or the body —
    the frame must be withheld and counted, never parsed. (Length-prefix
    bytes are framing, not CRC-covered — wire.py documents that a corrupted
    length desynchronizes the stream and the connection is dropped.)"""
    frame = _example_frames(np.random.default_rng(11))[ftype]
    for pos in range(4, len(frame)):
        for flip in (0x01, 0xFF):
            bad = bytes(frame[:pos]) + bytes([frame[pos] ^ flip]) + bytes(frame[pos + 1:])
            parser = wire.FrameParser()
            frames = parser.feed(bad)
            assert frames == [], f"byte {pos}^{flip:#x} parsed through the CRC"
            assert parser.crc_errors == 1
            assert parser.pending == 0  # the damaged frame's bytes are consumed


def test_parser_resumes_after_withheld_frame():
    """A corrupted frame mid-stream is skipped; everything after it still
    parses — the length prefix keeps the stream framed even when the CRC
    rejects the content."""
    good1, bad, good2 = wire.pack_hello(1), wire.pack_heartbeat(2), wire.pack_bye()
    bad = bytes(bad[:9]) + bytes([bad[9] ^ 0xFF]) + bytes(bad[10:])
    parser = wire.FrameParser()
    got = []
    stream = good1 + bad + good2
    for i in range(len(stream)):  # 1-byte drip straddling the damage
        got.extend(parser.feed(stream[i:i + 1]))
    assert [t for t, _ in got] == [wire.HELLO, wire.BYE]
    assert parser.crc_errors == 1
    assert wire.parse_hello(got[0][1]) == 1


# -------------------------------- codec --------------------------------------

def test_dense_codec_bit_lossless():
    rng = np.random.default_rng(0)
    for dtype in (np.float32, np.float16, np.float64):
        row = rng.normal(size=257).astype(dtype)
        out = tc.decode_row(tc.encode_dense(row))
        assert out.dtype == dtype
        np.testing.assert_array_equal(out, row)


def test_quant8_delta_error_bounded_by_half_step():
    rng = np.random.default_rng(1)
    base = rng.normal(size=1000).astype(np.float32)
    delta = (rng.normal(size=1000) * 1e-3).astype(np.float32)
    for block in (32, 256, 1024):
        buf = tc.encode_update(base + delta, base, "quant8", block)
        landed = tc.decode_update(buf, base)
        err = np.abs(landed - (base + delta))
        nb = -(-1000 // block)
        padded = np.zeros(nb * block, np.float32)
        padded[:1000] = delta
        step = np.maximum(np.abs(padded.reshape(nb, block)).max(axis=1), 1e-12) / 127.0
        # half a quantization step, plus one f32-addition ulp of the base
        # (landed = fl(base + dq) vs fl(base + delta) round differently)
        bound = np.repeat(step / 2 * 1.001, block)[:1000] + 2.4e-7 * np.abs(base) + 1e-9
        assert (err <= bound).all()
        # the round-trip is deterministic NumPy: same bytes every time
        assert tc.encode_update(base + delta, base, "quant8", block) == buf


def test_dispatch_rows_always_dense():
    row = np.linspace(-1, 1, 64, dtype=np.float32)
    for codec in ("dense", "quant8"):
        buf = tc.encode_row(row, codec)
        assert buf[0] == tc.DENSE
        np.testing.assert_array_equal(tc.decode_row(buf), row)
    with pytest.raises(ValueError, match="unknown wire codec"):
        tc.encode_row(row, "zstd")


def test_payload_bytes_analytic_matches_encoding():
    row = np.ones(3000, np.float32)
    assert len(tc.encode_dense(row)) == tc.payload_bytes(3000, "dense")
    buf = tc.encode_update(row, np.zeros(3000, np.float32), "quant8", 256)
    assert len(buf) == tc.payload_bytes(3000, "quant8", 256)
    # the wire's uplink cut: quant8 ~4x smaller at the default block
    assert tc.payload_bytes(1 << 20, "quant8") < tc.payload_bytes(1 << 20, "dense") / 3.8


# --------------------------- arrival engine ----------------------------------

def test_arrival_engine_validates_config():
    meta = _meta()
    fed = rp.build_fed(meta)
    cfg = rp.build_cfg(meta)
    with pytest.raises(ValueError, match="stateless"):
        ArrivalAsyncEngine(cfg, fed, adamw(1e-3))
    with pytest.raises(ValueError, match="mode"):
        ArrivalAsyncEngine(cfg, dataclasses.replace(fed, mode="sync"), sgd(0.05, momentum=0.0))
    with pytest.raises(ValueError, match="buffer_size"):
        ArrivalAsyncEngine(cfg, dataclasses.replace(fed, buffer_size=99), sgd(0.05, momentum=0.0))
    with pytest.raises(ValueError, match="stream"):
        ArrivalAsyncEngine(cfg, dataclasses.replace(fed, stream=True, aggregation="dense"),
                           sgd(0.05, momentum=0.0))


def test_arrival_engine_protocol_guards():
    eng = rp.make_engine(_meta())
    base = eng.dispatch_row(0)
    eng.land(0, base + 1.0)
    with pytest.raises(RuntimeError, match="staged"):
        eng.dispatch(0)  # would overwrite the landed update
    with pytest.raises(RuntimeError, match="already staged"):
        eng.land(0, base + 2.0)  # one update per dispatch


def test_global_row_survives_midwindow_landing_and_drop_redispatch():
    """THE regression for the mid-window staleness hazard: after a flush,
    global_row points at a client's row — but in the arrival engine that
    client's NEXT trained update can land mid-window. The global must not
    change, and a dropped client's redispatch must copy the true global,
    not the neighbouring client's half-trained row."""
    eng = rp.make_engine(_meta())  # C=3, buffer 2, max_staleness 1
    base = eng.dispatch_row(0)
    eng.land(0, base + 1.0)
    rec = eng.land(1, base + 2.0)
    assert rec.flush is not None and rec.flush.participants == [0, 1]
    g1 = np.asarray(eng.global_packed_row(), np.float32).copy()
    assert eng.global_row == 0
    # client 0's next trained update lands mid-window onto row global_row
    eng.land(0, base + 50.0)
    np.testing.assert_array_equal(np.asarray(eng.global_packed_row()), g1)
    # second flush: versions move to 2 while client 2 still holds v0
    rec2 = eng.land(1, base + 7.0)
    assert rec2.flush is not None and rec2.flush.participants == [0, 1]
    assert eng.staged() == ()  # the mid-window landing flushed, not lost
    g2 = np.asarray(eng.global_packed_row(), np.float32).copy()
    assert not np.array_equal(g2, g1)  # flush 2 really moved the global
    res = eng.land(2, base + 9.0)  # staleness 2 > max_staleness 1
    assert res.dropped and res.staleness == 2 and eng.dropped_total == 1
    # the redispatch wrote the true global into row 2 — bit for bit
    np.testing.assert_array_equal(eng.dispatch_row(2), g2)


def test_flush_discount_matches_buffered_formula():
    """The arrival flush must use the exact discount arithmetic of
    BufferedAsyncEngine._do_flush: w = mask/|staged| then (1+s)^-alpha,
    renormalized by the reducer. Landing rows crafted so the aggregate is
    checkable against the NumPy oracle."""
    meta = _meta(n_clients=4, buffer_size=2, max_staleness=0, staleness_alpha=0.5)
    eng = rp.make_engine(meta)
    base = eng.dispatch_row(0).astype(np.float64)
    eng.land(0, np.float32(base + 1.0))
    rec = eng.land(1, np.float32(base + 3.0))
    w = np.array([1.0, 1.0]) / 2.0  # both staleness 0: discount = 1
    want = base + (w[0] * 1.0 + w[1] * 3.0) / w.sum()
    got = np.asarray(eng.global_packed_row(), np.float64)
    np.testing.assert_allclose(got, want, rtol=1e-6)
    assert rec.flush.weights[0] == pytest.approx(0.5)
    assert rec.flush.weights[1] == pytest.approx(0.5)


def test_wallclock_sync_and_peek():
    c = WallClock()
    t1 = c.sync()
    assert c.peek() >= t1 >= 0.0
    before = c.now()
    assert c.peek() >= before and c.now() == before  # peek never advances
    assert c.sync() >= before


# ----------------------------- schedules -------------------------------------

def test_schedule_json_roundtrip(tmp_path):
    sched = rp.ArrivalSchedule(
        meta=_meta(),
        events=[
            rp.WireEvent(kind="dispatch", t=0.0, client=0, version=0),
            rp.WireEvent(kind="land", t=0.5, client=0, version=0, seq=0, flush=0),
            rp.WireEvent(kind="land", t=0.9, client=1, version=0, seq=0, dropped=True),
        ],
    )
    sched.save(tmp_path / "s.json")
    back = rp.ArrivalSchedule.load(tmp_path / "s.json")
    assert back.meta == sched.meta and back.events == sched.events
    assert back.n_flushes == 1 and back.n_dropped == 1


# ---------------------- FLServer checkpoint regression ------------------------

def test_async_checkpoints_read_engine_global_after_drops(tmp_path):
    """Satellite: async-mode checkpoints must store the engine's global
    row — global_params() reads global_packed_row(), never a fixed buffer
    row — including after staleness drops and redispatches."""
    cfg = rp.build_cfg(_meta())
    fed = R.FedConfig(n_clients=4, local_steps=1, aggregation="dense",
                      client_axis="data", data_axis=None, mode="async",
                      buffer_size=2, max_staleness=1, staleness_alpha=0.5)
    lm = ClientLoadModel(4, seed=0, config=LoadModelConfig(
        straggler_frac=0.0, base_spread=0.0, jitter=0.0, spike_prob=0.0))
    # 0 and 3 run ~3x slower (33s vs 11s/round): they complete 2+ versions
    # stale within the 6-round horizon, so the staleness gate really fires
    lm.baseline = lm.loads = np.array([0.7, 0.1, 0.1, 0.7])
    store = ObjectStore(tmp_path)
    srv = FLServer(cfg, fed, sgd(0.05), store=store, checkpoint_every=1,
                   task_id="wire-ckpt", load_model=lm)
    rng = np.random.default_rng(0)
    batches = iter(
        {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 1, 2, 8)), jnp.int32)}
        for _ in range(6)
    )
    srv.fit(batches, 6, log=None)
    assert srv.engine.dropped_total >= 1  # the scenario really exercised drops
    restored = store.restore_into("wire-ckpt", srv.global_params())
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(srv.global_params())):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # negative control: the stored global is NOT just buffer row 0 — row 0
    # belongs to a (slow, often stale) client
    packed_row0 = srv.state["params"][0]
    assert not np.array_equal(
        np.asarray(srv.engine.global_packed_row()), np.asarray(packed_row0)
    ) or srv.engine.global_row == 0


# ------------------------- THE acceptance test --------------------------------

@pytest.mark.parametrize("wire_codec", ["dense", "quant8", "quant4"])
def test_wire_run_replays_deterministically(wire_codec, tmp_path):
    """C=4 real worker processes over TCP, 5 flushes, one forced staleness
    dropout (a straggler trained against a version the fast clients have
    long flushed past). The recorded schedule, replayed through the
    SimClock engine, must reproduce the wire run's global parameters bit
    for bit (dense) / to 1e-5 (quant8/quant4 — both codecs round
    deterministically, so the replay re-encodes the identical bytes)."""
    meta = _meta(n_clients=4, buffer_size=2, max_staleness=1,
                 wire_codec=wire_codec, quant_block=512)
    res = harness.wire_run(
        meta, 5,
        worker_groups=[
            {"client_ids": [0, 1, 2], "extra": ["--max-updates", "3"]},
            {"client_ids": [3], "extra": ["--train-delay", "4.0", "--max-updates", "2"]},
        ],
        deadline_s=150.0,
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 5 and len(res.history) == 5
    assert res.dropped_total >= 1, "the straggler's stale update must drop"
    assert res.schedule.n_dropped == res.dropped_total
    assert res.stats.protocol_errors == 0

    # the schedule survives the CI-artifact round trip
    path = tmp_path / f"{wire_codec}.schedule.json"
    res.schedule.save(path)
    sched = rp.ArrivalSchedule.load(path)

    eng = rp.replay(sched)
    replayed = np.asarray(eng.global_packed_row(), np.float32)
    assert len(eng.history) == 5
    assert eng.dropped_total == res.dropped_total
    if wire_codec == "dense":
        np.testing.assert_array_equal(replayed, res.global_row)
    else:
        np.testing.assert_allclose(replayed, res.global_row, atol=1e-5, rtol=0)
    # flush-by-flush agreement, not just the endpoint
    for wrec, rrec in zip(res.history, eng.history):
        assert wrec.participants == rrec.participants
        assert wrec.staleness == rrec.staleness
        np.testing.assert_allclose(wrec.loss, rrec.loss, rtol=1e-5)

    if wire_codec == "dense":
        # the pin has teeth: corrupting the record must be caught
        bad = rp.ArrivalSchedule.from_json(sched.to_json())
        lands = [i for i, e in enumerate(bad.events) if e.kind == "land"]
        bad.events[lands[-1]] = dataclasses.replace(
            bad.events[lands[-1]], dropped=not bad.events[lands[-1]].dropped
        )
        with pytest.raises(rp.ReplayMismatch):
            rp.replay(bad)


def test_worker_and_replay_share_one_row_update_program():
    """Determinism by construction: the worker's jit and the replay's jit
    are the same build_row_update program, so one dispatch row + one batch
    give bitwise-identical trained rows across separate jit instances."""
    meta = _meta(n_clients=2)
    cfg, fed = rp.build_cfg(meta), rp.build_fed(meta)
    opt = rp.build_optimizer(meta)
    upd_a = build_row_update(cfg, fed, opt)
    upd_b = build_row_update(cfg, fed, opt)
    eng = rp.make_engine(meta)
    row = jnp.asarray(eng.dispatch_row(0))
    batch = rp.synth_client_batch(cfg, meta, 0, 0)
    ra, la = upd_a(row, batch)
    rb, lb = upd_b(row, batch)
    np.testing.assert_array_equal(np.asarray(ra), np.asarray(rb))
    assert float(la) == float(lb)
    assert not np.array_equal(np.asarray(ra), np.asarray(row))  # it really trained
