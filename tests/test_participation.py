"""Scheduler-in-the-loop partial participation engine (DESIGN.md §8).

Pins the load-bearing equivalence: with every client selected at uniform
weight, the masked packed path reproduces the PR 1 packed path bit-for-bit
on all four seed modes — and compact (static-K gather) agrees with masked
on partial selections. Plus scheduler fairness, the participation mask
kernel operand, and the straggler load model.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import rounds as R
from repro.core.explorer import ClientLoadModel, LoadModelConfig
from repro.core.rounds import FedConfig
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.kernels import ops, ref
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()
SEED_MODES = ["dense", "eq6", "quant8", "static_topn"]
C = 4


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _fed(mode, **kw):
    base = dict(n_clients=C, local_steps=1, aggregation=mode, topn=2,
                client_axis="data", data_axis=None)
    base.update(kw)
    return FedConfig(**base)


def _toks(seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab_size, (C, 1, 2, 16)), jnp.int32)


def _one_round(fed, part, mesh, seed=0):
    opt = sgd(lr=0.05)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(seed))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        state, metrics = fr(state, {"tokens": _toks()}, part)
    return state, metrics


def _assert_trees_equal(a, b, exact=True):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        if exact:
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        else:
            np.testing.assert_allclose(
                np.asarray(x, np.float32), np.asarray(y, np.float32), rtol=1e-6, atol=1e-7
            )


# ---------------- full mask == PR 1 packed path, bit for bit -----------------

@pytest.mark.parametrize("mode", SEED_MODES)
def test_full_mask_masked_path_bitwise_equals_pr1(mode):
    mesh = _mesh()
    st_legacy, m_legacy = _one_round(_fed(mode), R.uniform_weights(C), mesh)
    fed_m = _fed(mode, participation="masked")
    part = R.participation_input(fed_m, np.ones(C), np.full(C, 1.0 / C))
    st_masked, m_masked = _one_round(fed_m, part, mesh)
    _assert_trees_equal(st_legacy["params"], st_masked["params"])
    _assert_trees_equal(st_legacy["agg"], st_masked["agg"])
    assert float(m_legacy["loss"]) == float(m_masked["loss"])


def test_full_budget_compact_matches_full():
    mesh = _mesh()
    st_full, _ = _one_round(_fed("dense"), R.uniform_weights(C), mesh)
    fed_c = _fed("dense", participation="compact", max_participants=C)
    part = R.participation_input(fed_c, np.ones(C), np.full(C, 1.0 / C), np.arange(C))
    st_compact, _ = _one_round(fed_c, part, mesh)
    _assert_trees_equal(st_full["params"], st_compact["params"], exact=False)


@pytest.mark.parametrize("mode", SEED_MODES)
def test_masked_and_compact_agree_on_partial_selection(mode):
    mesh = _mesh()
    mask = np.array([1.0, 0.0, 1.0, 0.0], np.float32)
    w = mask / mask.sum()
    fed_m = _fed(mode, participation="masked")
    fed_c = _fed(mode, participation="compact", max_participants=2)
    st_m, mm = _one_round(fed_m, R.participation_input(fed_m, mask, w), mesh)
    st_c, mc = _one_round(fed_c, R.participation_input(fed_c, mask, w, np.array([0, 2])), mesh)
    _assert_trees_equal(st_m["params"], st_c["params"], exact=False)
    np.testing.assert_allclose(
        np.asarray(mm["client_loss"]), np.asarray(mc["client_loss"]), rtol=1e-6
    )
    # unselected clients trained nothing: their loss slots stay zero
    assert float(mm["client_loss"][1]) == 0.0 and float(mm["client_loss"][3]) == 0.0


def test_masked_partial_excludes_unselected_from_aggregate():
    """The dense global under a partial mask is the weighted mean of the
    *selected* clients' trained params only."""
    mesh = _mesh()
    mask = np.array([1.0, 1.0, 0.0, 0.0], np.float32)
    w = mask / mask.sum()
    fed_m = _fed("dense", participation="masked")
    st, _ = _one_round(fed_m, R.participation_input(fed_m, mask, w), mesh)
    # an all-clients run from the same init, restricted to clients {0,1}:
    # the masked global must not depend on clients 2,3 at all — rerun with a
    # different batch for the unselected clients and demand identity
    toks2 = np.array(_toks())
    toks2[2:] = np.asarray(_toks(seed=99))[2:]
    opt = sgd(lr=0.05)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed_m, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed_m, opt, mesh))
        st2, _ = fr(state, {"tokens": jnp.asarray(toks2)}, R.participation_input(fed_m, mask, w))
    _assert_trees_equal(st["params"], st2["params"])


# ---------------------------- validation -------------------------------------

def test_participation_validation():
    with pytest.raises(ValueError, match="full|masked|compact"):
        R.build_fed_round(CFG, _fed("dense", participation="nope"), sgd())
    with pytest.raises(ValueError, match="fedsgd"):
        R.build_fed_round(CFG, _fed("fedsgd", participation="masked"), sgd())
    with pytest.raises(ValueError, match="max_participants"):
        R.build_fed_round(CFG, _fed("dense", participation="compact", max_participants=C + 1), sgd())
    fed_c = _fed("dense", participation="compact", max_participants=2)
    with pytest.raises(ValueError, match="idx"):
        R.participation_input(fed_c, np.ones(C), np.full(C, 0.25))
    with pytest.raises(ValueError, match="exactly K"):
        R.participation_input(fed_c, np.ones(C), np.full(C, 0.25), np.arange(3))
    # distinctness: gather/scatter by idx must be invertible (and the flat
    # engine's K == C fast path treats idx as a permutation)
    with pytest.raises(ValueError, match="duplicate"):
        R.participation_input(fed_c, np.ones(C), np.full(C, 0.25), np.array([1, 1]))


# ------------------------- kernel mask operand --------------------------------

@pytest.mark.parametrize("C_,N,B", [(4, 3000, 3), (3, 277, 5)])
def test_packed_bucket_reduce_mask_operand(C_, N, B):
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(C_, N)), jnp.float32)
    wm = jnp.asarray(rng.random((C_, B)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, B, N), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, C_), jnp.float32)
    num_k, den_k = ops.packed_bucket_reduce(x, wm, ids, mask, block_n=256)
    num_r, den_r = ref.packed_bucket_reduce(x, wm, ids, mask)
    np.testing.assert_allclose(np.asarray(num_k), np.asarray(num_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(den_k), np.asarray(den_r), rtol=1e-5, atol=1e-5)
    # folding the mask into wmask is the same reduction
    num_f, den_f = ref.packed_bucket_reduce(x, wm * mask[:, None], ids)
    np.testing.assert_allclose(np.asarray(num_r), np.asarray(num_f), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(den_r), np.asarray(den_f), rtol=1e-6)


def test_trimmed_mean_masked_ignores_unselected_outlier():
    from repro.core import aggregators, packing

    tpl = R.make_template(CFG)
    spec = packing.build_pack_spec(CFG, tpl)
    state = R.make_state(CFG, _fed("dense"), sgd(), jax.random.key(0))
    packed = state["params"]  # the flat round state IS the packed buffer
    packed = packed + jnp.asarray(np.random.default_rng(3).normal(size=packed.shape) * 0.01, packed.dtype)
    poisoned = packed.at[3].set(1e6)  # Byzantine *unselected* client
    mask = jnp.asarray([1.0, 1.0, 1.0, 0.0])
    ctx = aggregators.AggContext(cfg=CFG, fed=_fed("trimmed_mean", trim_ratio=0.34),
                                 template=tpl, spec=spec, mesh=None)
    agg = aggregators.get("trimmed_mean")(ctx)
    out_clean, _ = agg.aggregate(packed, R.uniform_weights(C), {}, mask)
    out_pois, _ = agg.aggregate(poisoned, R.uniform_weights(C), {}, mask)
    np.testing.assert_array_equal(np.asarray(out_clean[0]), np.asarray(out_pois[0]))
    # and the masked trim still drops a *selected* outlier
    pois_sel = packed.at[0].set(1e6)
    out_sel, _ = agg.aggregate(pois_sel, R.uniform_weights(C), {}, mask)
    assert float(jnp.max(jnp.abs(out_sel[1]))) < 1e3


# --------------------------- scheduler fairness -------------------------------

def test_fairness_floor_readmits_within_fairness_rounds():
    fr = 3
    s = TaskScheduler(4, SchedulerConfig(max_participants=1, fairness_rounds=fr))
    s.quality = np.array([10.0, 0.0, 0.0, 0.0])  # client 0 always wins on score
    starved_round = None
    for r in range(fr + 1):
        sel = s.participation(np.zeros(4))
        if r > 0 and sel["mask"][1] > 0:
            starved_round = r
            break
    assert starved_round is not None and starved_round <= fr, starved_round


def test_compact_budget_is_exact_and_fairness_preempts():
    s = TaskScheduler(6, SchedulerConfig(max_participants=2, fairness_rounds=2))
    s.quality = np.array([10.0, 9.0, 0.0, 0.0, 0.0, 0.0])
    seen = set()
    for _ in range(6):
        sel = s.participation(np.zeros(6), k_static=2)
        assert sel["idx"].shape == (2,)
        assert sel["mask"].sum() == 2
        assert set(np.nonzero(sel["mask"])[0]) == set(sel["idx"].tolist())
        np.testing.assert_allclose(sel["weights"].sum(), 1.0, rtol=1e-6)
        seen.update(sel["idx"].tolist())
    # the fairness floor preempted the two high-quality clients often enough
    # that every client participated at least once
    assert seen == set(range(6))


def test_scheduler_select_backcompat():
    s = TaskScheduler(4, SchedulerConfig(max_participants=2, fairness_rounds=100))
    for c in range(4):
        s.report_quality(c, 1.0)
        s.report_quality(c, 0.5)
    w = s.select(np.array([0.9, 0.1, 0.8, 0.2]))
    assert w[1] > 0 and w[3] > 0 and w[0] == 0 and w[2] == 0


# ----------------------------- load model -------------------------------------

def test_load_model_deterministic_and_bounded():
    a = ClientLoadModel(8, seed=3)
    b = ClientLoadModel(8, seed=3)
    for _ in range(5):
        la, lb = a.step(), b.step()
        np.testing.assert_array_equal(la, lb)
        assert (la >= 0).all() and (la <= 1).all()


def test_load_model_stragglers_run_hot():
    m = ClientLoadModel(16, seed=0, config=LoadModelConfig(straggler_frac=0.25, spike_prob=0.0))
    loads = np.mean([m.step() for _ in range(20)], axis=0)
    strag = np.zeros(16, bool)
    strag[m.stragglers] = True
    assert loads[strag].mean() > loads[~strag].mean() + 0.2


# --------------------------- server end to end --------------------------------

def test_server_compact_end_to_end():
    from repro.core.server import FLServer
    from repro.data.pipeline import fed_batches

    fed = _fed("dense", participation="compact", max_participants=2,
               local_steps=1)
    mesh = _mesh()
    with jax.set_mesh(mesh):
        server = FLServer(
            CFG, fed, sgd(lr=0.05),
            scheduler=TaskScheduler(C, SchedulerConfig(max_participants=2, fairness_rounds=2)),
            mesh=mesh,
        )
        batches = (jax.tree.map(jnp.asarray, b) for b in fed_batches(CFG, fed, batch=2, seq=16))
        history = server.fit(batches, 4, log=None)
    assert all(len(r.participants) == 2 for r in history)
    assert all(np.isfinite(r.loss) for r in history)
    # quality EMA only ever updated for clients that actually participated
    seen = set(c for r in history for c in r.participants)
    untouched = [c for c in range(C) if c not in seen]
    assert all(np.isnan(server.scheduler.last_loss[c]) for c in untouched)
