"""Platform components: scheduler (Yu 2017), explorer, task manager, COS."""
import numpy as np
import pytest
from _hyp import given, settings, st

import jax
import jax.numpy as jnp

from repro.checkpoint import ObjectStore
from repro.core.explorer import monitor, simulated_loads
from repro.core.scheduler import SchedulerConfig, TaskScheduler
from repro.core.task_manager import FederatedTask, TaskManager, TaskStatus


# ----------------------------- scheduler -----------------------------------

def test_scheduler_prefers_low_load():
    s = TaskScheduler(4, SchedulerConfig(max_participants=2, fairness_rounds=100))
    for c in range(4):
        s.report_quality(c, 1.0)
        s.report_quality(c, 0.5)  # identical qualities
    w = s.select(np.array([0.9, 0.1, 0.8, 0.2]))
    assert w[1] > 0 and w[3] > 0 and w[0] == 0 and w[2] == 0
    assert abs(w.sum() - 1.0) < 1e-9


def test_scheduler_prefers_quality():
    s = TaskScheduler(3, SchedulerConfig(max_participants=1, beta=0.0, fairness_rounds=100))
    s.report_quality(0, 5.0); s.report_quality(0, 4.9)   # small improvement
    s.report_quality(1, 5.0); s.report_quality(1, 1.0)   # big improvement
    s.report_quality(2, 5.0); s.report_quality(2, 5.0)   # none
    w = s.select(np.zeros(3))
    assert w[1] == 1.0


def test_scheduler_fairness_floor():
    s = TaskScheduler(3, SchedulerConfig(max_participants=1, fairness_rounds=2))
    s.quality = np.array([1.0, 0.0, 0.0])
    for _ in range(3):
        w = s.select(np.zeros(3))
    # after 2 idle rounds clients 1,2 force-join
    assert w[1] > 0 and w[2] > 0


@given(st.integers(2, 12), st.integers(1, 12))
@settings(max_examples=20, deadline=None)
def test_scheduler_invariants(n, k):
    s = TaskScheduler(n, SchedulerConfig(max_participants=min(k, n)))
    rng = np.random.default_rng(n * 31 + k)
    for _ in range(5):
        w = s.select(rng.random(n))
        assert w.shape == (n,)
        assert abs(w.sum() - 1.0) < 1e-9
        assert (w >= 0).all()


def test_scheduler_fairness_floor_identical_quality():
    """Degenerate quality signal (every EMA identical): the fairness floor
    must still rotate starved clients in — ties cannot starve anyone."""
    n = 5
    s = TaskScheduler(n, SchedulerConfig(max_participants=2, fairness_rounds=3))
    # identical quality EMAs (all zero) and identical loads every round
    seen = np.zeros(n, int)
    for _ in range(12):
        sel = s.participation(np.zeros(n))
        seen += (sel["mask"] > 0).astype(int)
        assert abs(sel["weights"].sum() - 1.0) < 1e-9
        assert s.idle_rounds.max() <= s.cfg.fairness_rounds  # floor honored
    assert (seen > 0).all()  # every client participated at least once


def test_scheduler_eval_quality_feeds_ema():
    """report_eval (per-client mAP from server.evaluate_round) moves the
    same quality EMA report_quality does — improving clients rank higher."""
    s = TaskScheduler(2, SchedulerConfig(max_participants=1, beta=0.0, fairness_rounds=100))
    s.report_eval(0, 0.10); s.report_eval(0, 0.50)   # climbing mAP
    s.report_eval(1, 0.40); s.report_eval(1, 0.40)   # plateaued
    assert s.quality[0] > s.quality[1]
    w = s.select(np.zeros(2))
    assert w[0] == 1.0 and abs(w.sum() - 1.0) < 1e-9


@pytest.mark.parametrize("k_static", [1, 4])  # K == 1 and K == C
def test_scheduler_static_k_extremes(k_static):
    """Compact-mode contract at the edges: exactly K indices every round,
    weights sum to 1 over exactly K participants, and the mask matches idx."""
    n = 4
    s = TaskScheduler(n, SchedulerConfig(max_participants=k_static, fairness_rounds=2))
    rng = np.random.default_rng(3)
    seen = np.zeros(n, int)
    for _ in range(10):
        sel = s.participation(rng.random(n), k_static=k_static)
        assert sel["idx"].shape == (k_static,)
        assert len(set(sel["idx"].tolist())) == k_static  # no duplicate slots
        assert sel["mask"].sum() == k_static
        np.testing.assert_array_equal(np.nonzero(sel["mask"])[0], np.sort(sel["idx"]))
        assert abs(sel["weights"].sum() - 1.0) < 1e-9
        assert (sel["weights"][sel["idx"]] > 0).all()
        seen += (sel["mask"] > 0).astype(int)
    if k_static == n:
        assert (seen == 10).all()  # K == C: everyone, every round
    else:
        assert (seen > 0).all()  # K == 1: fairness floor still rotates all


# ----------------------------- explorer ------------------------------------

def test_explorer_monitor_reads_proc():
    r = monitor(0.01)
    assert 0.0 <= r.cpu_frac <= 1.0
    assert 0.0 <= r.mem_frac <= 1.0
    assert r.load1 >= 0


def test_simulated_loads_range():
    loads = simulated_loads(8, np.random.default_rng(0))
    assert loads.shape == (8,) and (loads >= 0).all() and (loads <= 1).all()


# ----------------------------- task manager --------------------------------

def test_task_manager_runs_to_completion():
    tm = TaskManager()
    calls = {"a": 0, "b": 0}

    def mk(tid, total):
        def run(r):
            calls[tid] += 1
            return {"round": r}

        return FederatedTask(tid, "qwen3-1.7b", total, run)

    tm.register(mk("a", 3))
    tm.register(mk("b", 5))
    tm.run_to_completion()
    assert calls == {"a": 3, "b": 5}
    assert all(t.status == TaskStatus.DONE for t in tm.tasks.values())


def test_task_manager_isolates_failures():
    tm = TaskManager()

    def boom(r):
        raise RuntimeError("client died")

    tm.register(FederatedTask("bad", "x", 2, boom))
    tm.register(FederatedTask("good", "x", 1, lambda r: {}))
    tm.run_to_completion()
    assert tm.tasks["bad"].status == TaskStatus.FAILED
    assert tm.tasks["good"].status == TaskStatus.DONE


def test_task_manager_rejects_duplicates():
    tm = TaskManager()
    tm.register(FederatedTask("t", "x", 1, lambda r: {}))
    with pytest.raises(ValueError):
        tm.register(FederatedTask("t", "x", 1, lambda r: {}))


# ----------------------------- object store (COS) --------------------------

def test_object_store_roundtrip(tmp_path):
    store = ObjectStore(tmp_path)
    params = {"w": jnp.arange(6.0).reshape(2, 3), "b": {"x": jnp.ones(4)}}
    store.put_model("task", 0, params, {"loss": 1.0})
    store.put_model("task", 1, jax.tree.map(lambda x: x * 2, params))
    assert store.rounds("task") == [0, 1]
    back = store.restore_into("task", params, round_idx=1)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(params["w"]) * 2)
    latest = store.restore_into("task", params)  # newest round
    np.testing.assert_allclose(np.asarray(latest["b"]["x"]), 2.0)


def test_object_store_dedup_and_gc(tmp_path):
    store = ObjectStore(tmp_path)
    params = {"w": jnp.ones(10)}
    k1 = store.put_model("t", 0, params)
    k2 = store.put_model("t", 1, params)  # identical content -> same blob
    assert k1 == k2
    for r in range(2, 8):
        store.put_model("t", r, {"w": jnp.full(10, float(r))})
    removed = store.gc(keep=2)
    assert store.rounds("t") == [6, 7]
    assert removed > 0
    # persistence across reopen
    store2 = ObjectStore(tmp_path)
    assert store2.rounds("t") == [6, 7]
