"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.models.mamba2 import ssd_chunked

RNG = np.random.default_rng(42)


def _arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


@pytest.mark.parametrize("C,N", [(2, 128), (4, 3000), (8, 1024), (3, 17)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedavg_kernel(C, N, dtype):
    x = _arr((C, N), dtype)
    w = jnp.asarray(RNG.dirichlet([1.0] * C), jnp.float32)
    m = jnp.asarray(RNG.integers(0, 2, C), jnp.float32)
    if float(jnp.sum(m)) == 0:
        m = m.at[0].set(1.0)
    got = ops.fedavg_masked_mean(x, w, m, block_n=256)
    want = ref.fedavg_masked_mean(x, w, m)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("N,block", [(1024, 256), (5000, 1024), (256, 256), (77, 64)])
def test_quant_roundtrip(N, block):
    x = _arr((N,))
    q, s = ops.quantize(x, block=block)
    back = ops.dequantize(q, s, block=block)
    pad = (-N) % block
    qr, sr = ref.quantize_blocks(jnp.pad(x, (0, pad)), block)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr)[:N])
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    # max error bounded by half a quantization step per block
    err = np.abs(np.asarray(back) - np.asarray(x))
    step = np.repeat(np.asarray(s), block)[:N]
    assert (err <= 0.51 * step + 1e-9).all()


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64), (True, 128)])
@pytest.mark.parametrize("B,H,Hkv,S,hd", [(1, 2, 1, 256, 64), (2, 4, 2, 128, 32), (1, 8, 8, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(causal, window, B, H, Hkv, S, hd, dtype):
    q = _arr((B, H, S, hd), dtype)
    k = _arr((B, Hkv, S, hd), dtype)
    v = _arr((B, Hkv, S, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, window=window, block_q=64, block_k=64)
    want = ref.flash_attention(q, k, v, causal=causal, window=window)
    tol = 2e-4 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,S,H,P,N,Q", [(1, 32, 2, 8, 4, 8), (2, 64, 3, 16, 8, 16), (1, 128, 1, 64, 16, 32)])
def test_ssd_scan(B, S, H, P, N, Q):
    xdt = _arr((B, S, H, P), scale=0.1)
    dA = -jnp.abs(_arr((B, S, H), scale=0.1))
    Bm = _arr((B, S, N))
    Cm = _arr((B, S, N))
    y_k, st_k = ops.ssd_full(xdt, dA, Bm, Cm, chunk=Q)
    y_r, st_r = ssd_chunked(xdt, dA, Bm, Cm, Q)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_r), rtol=2e-4, atol=2e-4)


def test_ssd_kernel_single_chunk_matches_ref_chunk():
    Q, H, P, N = 16, 2, 8, 4
    xdt = _arr((1, Q, H, P), scale=0.1)
    dA = -jnp.abs(_arr((1, Q, H), scale=0.1))
    Bm = _arr((1, Q, N))
    Cm = _arr((1, Q, N))
    y, st, dec, ec = ops.ssd_chunk_scan(xdt, dA, Bm, Cm, chunk=Q)
    y_r, st_r, dec_r = ref.ssd_chunk(xdt[0], dA[0], Bm[0], Cm[0])
    np.testing.assert_allclose(np.asarray(y[0]), np.asarray(y_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st[0, 0]), np.asarray(st_r), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(dec[0, 0]), np.asarray(dec_r), rtol=2e-4, atol=2e-4)


def test_fedavg_tree_and_quant_tree():
    tree = {"a": _arr((3, 4, 5)), "b": {"c": _arr((3, 7))}}
    w = jnp.asarray([0.5, 0.25, 0.25], jnp.float32)
    masks = {"a": jnp.ones(3), "b": {"c": jnp.asarray([1.0, 1.0, 0.0])}}
    out = ops.fedavg_tree(tree, w, masks)
    want_a = ref.fedavg_masked_mean(tree["a"].reshape(3, -1), w, masks["a"]).reshape(4, 5)
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(want_a), rtol=1e-5, atol=1e-6)
    qt = ops.quantize_tree(tree)
    back = ops.dequantize_tree(qt, tree)
    assert back["a"].shape == (3, 4, 5)


def test_pallas_attention_impl_in_model():
    """attention_impl='pallas' routes through the flash kernel and matches
    the reference path, forward AND gradients."""
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.models import params as P
    from repro.models import transformer as T

    base = get_arch("qwen3-1.7b").reduced()
    cfg_ref = dataclasses.replace(base, n_layers=2)
    cfg_pal = dataclasses.replace(cfg_ref, attention_impl="pallas")
    tpl = T.template(cfg_ref)
    params = P.init_params(tpl, jax.random.key(0), jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg_ref.vocab_size, (1, 128)), jnp.int32)
    batch = {"tokens": toks}
    l_ref, g_ref = jax.value_and_grad(lambda p: T.loss_fn(cfg_ref, p, batch)[0])(params)
    l_pal, g_pal = jax.value_and_grad(lambda p: T.loss_fn(cfg_pal, p, batch)[0])(params)
    np.testing.assert_allclose(float(l_ref), float(l_pal), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)


def test_pallas_ssd_impl_in_model():
    """ssm_impl='pallas' routes mamba2 through the SSD kernel: fwd + grads."""
    import dataclasses

    import jax

    from repro.configs import get_arch
    from repro.models import params as P
    from repro.models import transformer as T

    base = get_arch("mamba2-1.3b").reduced()
    cfg_ref = base
    cfg_pal = dataclasses.replace(base, ssm_impl="pallas")
    tpl = T.template(cfg_ref)
    params = P.init_params(tpl, jax.random.key(0), jnp.float32)
    toks = jnp.asarray(RNG.integers(0, cfg_ref.vocab_size, (1, 32)), jnp.int32)
    batch = {"tokens": toks}
    l_ref, g_ref = jax.value_and_grad(lambda p: T.loss_fn(cfg_ref, p, batch)[0])(params)
    l_pal, g_pal = jax.value_and_grad(lambda p: T.loss_fn(cfg_pal, p, batch)[0])(params)
    np.testing.assert_allclose(float(l_ref), float(l_pal), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_pal)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=5e-3, atol=5e-4)
