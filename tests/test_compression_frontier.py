"""Communication frontier (DESIGN.md §15): topk_ef / quant4 / secure.

Every numerical path lands with its NumPy oracle: the shared fmix32 PRNG,
4-bit blockwise quantization (nearest + stochastic), nibble packing, top-k
selection, and pairwise uint32 masking are all pinned BIT-FOR-BIT against
`kernels/ref.py` across the jnp twins (`core/packing.py`) and the Pallas
kernels (`kernels/quant4.py`, `kernels/mask.py`).

The three dense-equivalence pins the PR hangs on:
  - topk_ef at k == N_total reproduces `dense` bit-for-bit (and EF stays 0);
  - quant4 with quant4_mode="skip" statically routes through `dense`;
  - secure masking ON == OFF bit-for-bit — the pairwise masks cancel
    EXACTLY in the modular uint32 sum, never approximately.

Plus the EF telescoping property (uploaded + residual == compensated delta,
bitwise, under adversarial weight/mask sequences), stochastic-rounding
unbiasedness over fixed key batches, and a subprocess regression proving
`secure_agg.pair_seed` no longer depends on PYTHONHASHSEED.
"""
import os
import subprocess
import sys

import numpy as np
import pytest
from _hyp import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import aggregators, packing
from repro.core import rounds as R
from repro.core import secure_agg
from repro.core.rounds import FedConfig
from repro.core.transport import codec
from repro.kernels import mask as kmask
from repro.kernels import quant4 as kq
from repro.kernels import ref

CFG = get_arch("qwen3-1.7b").reduced()
TPL = R.make_template(CFG)
RNG = np.random.default_rng(11)

# tiny synthetic spec (the frontier contracts are shape-independent): 4
# clients over a 64-element 4-bucket buffer, quant blocks of 16
_C, _N, _B, _BLK = 4, 64, 4, 16
_SPEC = packing.PackSpec(
    _N, _B,
    tuple(
        packing.LeafSlot(f"leaf{i}", (_N // _B,), i * (_N // _B), _N // _B, i, 1)
        for i in range(_B)
    ),
)


def _fed(mode, **kw):
    base = dict(n_clients=_C, local_steps=1, aggregation=mode, topn=2,
                client_axis="data", data_axis=None, quant_block=_BLK)
    base.update(kw)
    return FedConfig(**base)


def _agg(name, **kw):
    ctx = aggregators.AggContext(cfg=CFG, fed=_fed(name, **kw), template=TPL,
                                 spec=_SPEC, mesh=None)
    return aggregators.get(name)(ctx)


def _inputs(seed=0, scale=0.05):
    rng = np.random.default_rng(seed)
    base = jnp.asarray(rng.normal(size=(_C, _N)), jnp.float32)
    packed = base + jnp.asarray(rng.normal(size=(_C, _N)) * scale, jnp.float32)
    w = rng.uniform(0.1, 1.0, _C)
    w = jnp.asarray(w / w.sum(), jnp.float32)
    return packed, base, w


# ------------------------- shared PRNG oracles -------------------------------

def test_round_key_matches_oracle():
    for seed in (0, 1, 7, 2**31 - 1):
        for r in (0, 1, 5, 1000):
            got = np.asarray(packing.round_key(seed, jnp.int32(r)))
            exp = ref.round_key_np(seed, r)
            assert got == exp, (seed, r)


def test_counter_uniform_matches_oracle_and_range():
    key = ref.round_key_np(3, 4)
    c = np.arange(5)[:, None]
    n = np.arange(200)[None, :]
    exp = ref.counter_uniform_np(key, c, n)
    got = np.asarray(packing.counter_uniform(
        jnp.uint32(int(key)), jnp.asarray(c, jnp.int32), jnp.asarray(n, jnp.int32)
    ))
    np.testing.assert_array_equal(got, exp)
    assert exp.min() >= 0.0 and exp.max() < 1.0
    # the stream must actually move across clients and elements
    assert len(np.unique(exp)) > 900


# ------------------------------- quant4 --------------------------------------

@pytest.mark.parametrize("mode", ["nearest", "stochastic"])
def test_quant4_dequant_rows_matches_oracle_bitwise(mode):
    x = RNG.normal(size=(3, 100)).astype(np.float32)
    key = ref.round_key_np(9, 2)
    got = np.asarray(packing.quant4_dequant_rows_ref(
        jnp.asarray(x), _BLK, key=jnp.uint32(int(key)), mode=mode
    ))
    for c in range(3):
        q, s = ref.quant4_blocks_np(x[c], _BLK, mode=mode, key=key, c=c)
        exp = ref.dequant4_blocks_np(q, s, _BLK)[:100]
        np.testing.assert_array_equal(got[c], exp, err_msg=f"row {c}")


@pytest.mark.parametrize("mode", ["nearest", "stochastic"])
def test_quant4_reduce_ref_and_pallas_match_oracle(mode):
    delta = RNG.normal(size=(_C, 3000)).astype(np.float32) * 0.01
    w = RNG.dirichlet([1.0] * _C).astype(np.float32)
    key = ref.round_key_np(1, 3)
    exp = ref.quant4_reduce_np(delta, w, _BLK, mode=mode, key=key)
    got_ref = np.asarray(packing.quant4_mean_ref(
        jnp.asarray(delta), jnp.asarray(w), _BLK, key=jnp.uint32(int(key)), mode=mode
    ))
    np.testing.assert_array_equal(got_ref, exp)  # jnp twin is bit-exact
    got_pl = np.asarray(kq.quant4_reduce(
        jnp.asarray(delta), jnp.asarray(w), jnp.uint32(int(key)), mode=mode, block=_BLK
    ))
    # Pallas accumulates per client block: reduction-order ulps only
    np.testing.assert_allclose(got_pl, exp, atol=4e-6, rtol=1e-6)


def test_quant4_nearest_half_step_bound():
    x = RNG.normal(size=2000).astype(np.float32)
    q, s = ref.quant4_blocks_np(x, _BLK, mode="nearest")
    back = ref.dequant4_blocks_np(q, s, _BLK)[:2000]
    step = np.repeat(s, _BLK)[:2000]
    assert np.all(np.abs(back - x) <= step / 2 * 1.0001)


def test_quant4_stochastic_one_step_bound_and_zero_padding():
    x = RNG.normal(size=1000).astype(np.float32)
    key = ref.round_key_np(0, 0)
    q, s = ref.quant4_blocks_np(x, _BLK, mode="stochastic", key=key)
    back = ref.dequant4_blocks_np(q, s, _BLK)
    step = np.repeat(s, _BLK)
    assert np.all(np.abs(back[:1000] - x) <= step[:1000] * 1.0001)
    assert np.all(q.reshape(-1)[1000:] == 0), "padding must quantize to exactly 0"


def test_quant4_stochastic_mean_unbiased_over_keys():
    """E_u[clip(floor(x/s + u))] == x/s: averaging the SAME values over many
    per-round keys must converge on the unquantized input."""
    x = RNG.uniform(-1, 1, 256).astype(np.float32)
    acc = np.zeros(256, np.float64)
    n_keys = 512
    for r in range(n_keys):
        key = ref.round_key_np(42, r)
        q, s = ref.quant4_blocks_np(x, _BLK, mode="stochastic", key=key)
        acc += ref.dequant4_blocks_np(q, s, _BLK)[:256]
    mean = acc / n_keys
    step = np.repeat(ref.quant4_blocks_np(x, _BLK)[1], _BLK)[:256]
    # CLT: the per-key error is U(-step/2-ish); the mean shrinks ~1/sqrt(K)
    assert np.abs(mean - x).max() < step.max() * 5 / np.sqrt(n_keys)


def test_nibble_roundtrip_and_codec_pin():
    q = RNG.integers(-7, 8, 999).astype(np.int8)
    buf = ref.pack_nibbles_np(q)
    assert buf.nbytes == 500
    np.testing.assert_array_equal(ref.unpack_nibbles_np(buf, 999), q)
    # the wire codec's nibble primitives are the same bytes
    assert codec.pack_nibbles(q) == buf.tobytes()
    np.testing.assert_array_equal(codec.unpack_nibbles(buf.tobytes(), 999), q)


def test_codec_quant4_pinned_to_oracle():
    x = RNG.normal(size=777).astype(np.float32)
    q_c, s_c = codec.quantize4_blocks(x, _BLK)
    q_r, s_r = ref.quant4_blocks_np(x, _BLK, mode="nearest")
    np.testing.assert_array_equal(q_c.reshape(-1), q_r)
    np.testing.assert_array_equal(s_c, s_r)


def test_quant4_aggregator_deterministic_and_advances_round():
    packed, base, w = _inputs(1)
    agg = _agg("quant4", quant4_mode="stochastic")
    st0 = agg.init_state(jnp.broadcast_to(base[0][None], packed.shape))
    out1, st1 = agg.aggregate(packed, w, st0)
    out1b, _ = agg.aggregate(packed, w, st0)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out1b))
    assert int(st1["round"]) == int(st0["round"]) + 1
    np.testing.assert_array_equal(np.asarray(st1["base"]), np.asarray(out1[0]))
    # a later round keys a different stream: same inputs, different rounding
    out2, _ = agg.aggregate(packed, w, st1)
    assert not np.array_equal(np.asarray(out1), np.asarray(out2))


# ------------------------------- topk_ef -------------------------------------

def _topk_sel(packed, ef, base, k):
    """Re-derive the selection exactly as sparse.TopKEF does."""
    acc = packed.astype(jnp.float32) + ef - base[None, :]
    if k >= acc.shape[1]:
        return acc, jnp.ones(acc.shape, bool)
    thresh = jax.lax.top_k(jnp.abs(acc), k)[0][:, -1]
    return acc, jnp.abs(acc) >= thresh[:, None]


def test_topk_ef_full_k_equals_dense_bitwise():
    packed, base, w = _inputs(2)
    ef_agg = _agg("topk_ef", topk_frac=1.0)
    st0 = ef_agg.init_state(jnp.broadcast_to(base[0][None], packed.shape))
    out, st1 = ef_agg.aggregate(packed, w, st0)
    dense_out, _ = _agg("dense").aggregate(packed, w, {})
    np.testing.assert_array_equal(np.asarray(out), np.asarray(dense_out))
    assert np.all(np.asarray(st1["ef"]) == 0.0), "k==N uploads everything; EF must stay 0"


@given(st.integers(0, 2**30), st.integers(1, 2**_C - 1))
@settings(max_examples=10, deadline=None)
def test_topk_ef_telescoping_invariant(seed, mask_bits):
    """selected + residual == compensated delta, EXACTLY (disjoint-support
    where split), under adversarial weights and participation masks; masked
    rows carry their residual through bit-for-bit."""
    rng = np.random.default_rng(seed)
    agg = _agg("topk_ef", topk_frac=0.25)
    k = int(0.25 * _N)
    mask_np = np.asarray([(mask_bits >> c) & 1 for c in range(_C)], np.float32)
    mask = jnp.asarray(mask_np)
    packed, base0, _ = _inputs(seed)
    state = agg.init_state(jnp.broadcast_to(base0[0][None], packed.shape))
    for step in range(3):
        w = rng.uniform(0.0, 1.0, _C)  # adversarial: near-zero weights allowed
        w = jnp.asarray((w + 1e-6) / (w + 1e-6).sum(), jnp.float32)
        packed = jnp.asarray(
            np.asarray(packed) + rng.normal(size=(_C, _N)).astype(np.float32) * 0.03
        )
        base = state["base"].astype(jnp.float32)
        ef_prev = state["ef"]
        acc, sel = _topk_sel(packed, ef_prev, base, k)
        out, state = agg.aggregate(packed, w, state, mask)
        ef_new = np.asarray(state["ef"])
        # masked rows: residual retained bitwise
        for c in range(_C):
            if mask_np[c] == 0:
                np.testing.assert_array_equal(ef_new[c], np.asarray(ef_prev)[c])
            else:
                # participants: residual is the unselected part, bitwise
                np.testing.assert_array_equal(
                    ef_new[c], np.asarray(jnp.where(sel, 0.0, acc))[c]
                )
                # telescoping: uploaded + residual == compensated delta, bitwise
                up = np.asarray(jnp.where(sel, acc, 0.0))[c]
                total = np.asarray(jnp.where(sel, acc, 0.0) + jnp.where(sel, 0.0, acc))[c]
                np.testing.assert_array_equal(total, np.asarray(acc)[c])
                assert np.count_nonzero(up) <= k * 2  # ties may widen slightly


def test_topk_ef_dropped_client_residual_retention():
    """A straggler masked out for two rounds re-joins with its residual
    intact and then uploads it (async redispatch semantics: the mask is
    exactly what the buffered engine passes for missing clients)."""
    packed, base, w = _inputs(5)
    agg = _agg("topk_ef", topk_frac=0.1)
    state = agg.init_state(jnp.broadcast_to(base[0][None], packed.shape))
    # round 1: everyone lands; client 2 banks a nonzero residual
    _, state = agg.aggregate(packed, w, state)
    ef1 = np.asarray(state["ef"])[2]
    assert np.any(ef1 != 0.0)
    # rounds 2-3: client 2 keeps training but its updates never land — the
    # residual rides along bit-for-bit, untouched by everyone else's rounds
    drop2 = jnp.asarray([1.0, 1.0, 0.0, 1.0], jnp.float32)
    packed2 = packed.at[2].add(0.02)
    _, state = agg.aggregate(packed2, w, state, drop2)
    np.testing.assert_array_equal(np.asarray(state["ef"])[2], ef1)
    _, state = agg.aggregate(packed2, w, state, drop2)
    np.testing.assert_array_equal(np.asarray(state["ef"])[2], ef1)
    # round 4: client 2 lands again; the banked residual is consumed
    _, state = agg.aggregate(packed2, w, state)
    assert not np.array_equal(np.asarray(state["ef"])[2], ef1)


def test_topk_ef_quant4_composition_residual_is_exact_complement():
    """With topk_quant='quant4' the EF row absorbs sparsification AND
    quantization error: residual == compensated - dequant(upload), bitwise."""
    packed, base, w = _inputs(7)
    agg = _agg("topk_ef", topk_frac=0.25, topk_quant="quant4", quant4_mode="nearest")
    state = agg.init_state(jnp.broadcast_to(base[0][None], packed.shape))
    k = int(0.25 * _N)
    acc, sel = _topk_sel(packed, state["ef"], state["base"].astype(jnp.float32), k)
    key = packing.round_key(0, state["round"])
    vq = packing.quant4_dequant_rows_ref(
        jnp.where(sel, acc, 0.0), _BLK, key=key, mode="nearest"
    )
    out, st1 = agg.aggregate(packed, w, state)
    np.testing.assert_array_equal(np.asarray(st1["ef"]), np.asarray(acc - vq))


# -------------------------------- secure -------------------------------------

@pytest.mark.parametrize("C", [2, 3, 8])
def test_secure_sum_masks_cancel_exactly(C):
    """Masked modular sum == unmasked sum BIT-FOR-BIT, across the NumPy
    oracle, the jnp twin, and the Pallas masked-sum kernel."""
    rng = np.random.default_rng(C)
    q = rng.integers(-127, 128, (C, 500)).astype(np.int32)
    part = np.ones(C, np.float32)
    rk = ref.round_key_np(5, 1)
    s_plain = ref.secure_sum_np(q, part, rk, use_masks=False)
    s_masked = ref.secure_sum_np(q, part, rk, use_masks=True)
    np.testing.assert_array_equal(s_masked, s_plain)
    np.testing.assert_array_equal(s_plain, q.sum(axis=0))
    # jnp twin
    qj = jnp.asarray(q)
    pj = jnp.asarray(part)
    rkj = jnp.uint32(int(rk))
    for use in (False, True):
        got = np.asarray(packing.secure_sum_ref(qj, pj, rkj, use_masks=use))
        np.testing.assert_array_equal(got, s_plain)
    # Pallas path: sum the masked uint32 rows, bitcast back
    rows = jax.lax.bitcast_convert_type(qj, jnp.uint32) + packing.secure_client_masks(rkj, pj, 500)
    total = kmask.masked_u32_sum(rows, pj)
    np.testing.assert_array_equal(
        np.asarray(jax.lax.bitcast_convert_type(total, jnp.int32)), s_plain
    )


def test_secure_sum_partial_participation_cancels():
    """A dropped client contributes no row AND activates no pair: the
    survivors' masks still cancel exactly and its junk row never leaks."""
    rng = np.random.default_rng(0)
    q = rng.integers(-127, 128, (4, 300)).astype(np.int32)
    part = np.asarray([1, 0, 1, 1], np.float32)
    rk = ref.round_key_np(2, 9)
    s_masked = ref.secure_sum_np(q, part, rk, use_masks=True)
    np.testing.assert_array_equal(s_masked, q[[0, 2, 3]].sum(axis=0))
    got = np.asarray(packing.secure_sum_ref(
        jnp.asarray(q), jnp.asarray(part), jnp.uint32(int(rk)), use_masks=True
    ))
    np.testing.assert_array_equal(got, s_masked)


def test_secure_masks_look_like_noise_but_are_symmetric():
    rk = ref.round_key_np(0, 0)
    assert ref.pair_key_np(rk, 1, 3) == ref.pair_key_np(rk, 3, 1)
    assert ref.pair_key_np(rk, 1, 3) != ref.pair_key_np(rk, 1, 2)
    m = ref.pair_mask_np(rk, 0, 1, 4096)
    # a full-range uint32 stream: both halves of the range populated
    assert (m > 2**31).mean() > 0.4 and (m <= 2**31).mean() > 0.4


@pytest.mark.parametrize("domain", ["int8", "int4"])
def test_secure_aggregator_masked_equals_unmasked_bitwise(domain):
    packed, base, w = _inputs(3)
    st_b = jnp.broadcast_to(base[0][None], packed.shape)
    on = _agg("secure", secure_domain=domain, secure_mask=True)
    off = _agg("secure", secure_domain=domain, secure_mask=False)
    out_on, _ = on.aggregate(packed, w, on.init_state(st_b))
    out_off, _ = off.aggregate(packed, w, off.init_state(st_b))
    np.testing.assert_array_equal(np.asarray(out_on), np.asarray(out_off))
    # and the quantized sum tracks dense within the shared-scale step
    dense_out, _ = _agg("dense").aggregate(packed, w, {})
    step = float(jnp.max(jnp.abs(packed - base[0][None]))) / (127.0 if domain == "int8" else 7.0)
    assert float(jnp.max(jnp.abs(out_on - dense_out))) <= _C * step


def test_secure_aggregator_masked_equals_unmasked_under_dropout():
    packed, base, w = _inputs(4)
    st_b = jnp.broadcast_to(base[0][None], packed.shape)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0], jnp.float32)
    on = _agg("secure", secure_mask=True)
    off = _agg("secure", secure_mask=False)
    out_on, _ = on.aggregate(packed, w, on.init_state(st_b), mask)
    out_off, _ = off.aggregate(packed, w, off.init_state(st_b), mask)
    np.testing.assert_array_equal(np.asarray(out_on), np.asarray(out_off))


def test_secure_pallas_impl_matches_ref_bitwise():
    packed, base, w = _inputs(6)
    st_b = jnp.broadcast_to(base[0][None], packed.shape)
    outs = {}
    for impl in ("ref", "pallas"):
        agg = _agg("secure", agg_impl=impl)
        outs[impl], _ = agg.aggregate(packed, w, agg.init_state(st_b))
    # integer sums: the kernel and the jnp sum are the SAME modular ring
    np.testing.assert_array_equal(np.asarray(outs["ref"]), np.asarray(outs["pallas"]))


# --------------------- pair_seed: PYTHONHASHSEED regression ------------------

_SEED_SNIPPET = (
    "from repro.core import secure_agg;"
    "print([secure_agg.pair_seed(i, j, r, session=5)"
    " for i in range(3) for j in range(3) if i != j for r in (0, 7)])"
)


def test_pair_seed_stable_across_hash_seeds():
    """Two interpreters with different PYTHONHASHSEED must derive the SAME
    pair seeds — the old `hash()`-based mixing was salted per process, so
    worker processes would mask with different streams and nothing cancels."""
    outs = []
    for hs in ("1", "2"):
        env = dict(os.environ, PYTHONHASHSEED=hs,
                   PYTHONPATH=os.pathsep.join(filter(None, ["src", os.environ.get("PYTHONPATH", "")])))
        r = subprocess.run([sys.executable, "-c", _SEED_SNIPPET], env=env,
                           capture_output=True, text=True, timeout=120,
                           cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        assert r.returncode == 0, r.stderr
        outs.append(r.stdout.strip())
    assert outs[0] == outs[1]
    # and both match the in-process value AND the NumPy oracle
    expected = [secure_agg.pair_seed(i, j, r, session=5)
                for i in range(3) for j in range(3) if i != j for r in (0, 7)]
    assert outs[0] == str(expected)
    for i in range(3):
        for j in range(3):
            if i != j:
                assert secure_agg.pair_seed(i, j, 3, session=5) == int(
                    ref.pair_seed_np(i, j, 3, session=5)
                )
                assert secure_agg.pair_seed(i, j, 3, session=5) == secure_agg.pair_seed(j, i, 3, session=5)


# ----------------------- build-time validation + dry-run ---------------------

class _FakeMesh:
    axis_names = ("data", "model")
    devices = np.zeros((2, 1))


def test_frontier_validation_errors():
    with pytest.raises(ValueError, match="topk_frac"):
        _agg("topk_ef", topk_frac=0.0)
    with pytest.raises(ValueError, match="topk_quant"):
        _agg("topk_ef", topk_quant="int8")
    with pytest.raises(ValueError, match="quant4_mode"):
        _agg("quant4", quant4_mode="round")
    with pytest.raises(ValueError, match="secure_domain"):
        _agg("secure", secure_domain="int16")
    with pytest.raises(ValueError, match="O\\(C\\^2\\)"):
        _agg("secure", n_clients=33)
    for name in ("quant4", "secure"):
        with pytest.raises(ValueError, match="mesh axis"):
            aggregators.get(name)(aggregators.AggContext(
                cfg=CFG, fed=_fed(name), template=TPL, spec=_SPEC, mesh=_FakeMesh()
            ))


def test_frontier_init_state_is_eval_shape_safe():
    """state_template dry-runs init_state on abstract values — the frontier
    states (EF rows, round counters) must build without materializing."""
    for name in ("topk_ef", "quant4", "secure"):
        agg = _agg(name)
        abstract = jax.eval_shape(
            agg.init_state, jax.ShapeDtypeStruct((_C, _N), jnp.float32)
        )
        real = agg.init_state(jnp.zeros((_C, _N), jnp.float32))
        assert jax.tree.structure(abstract) == jax.tree.structure(real)
        for a, r in zip(jax.tree.leaves(abstract), jax.tree.leaves(real)):
            assert a.shape == r.shape and a.dtype == r.dtype


# --------------------------- end-to-end training -----------------------------

@pytest.mark.parametrize(
    "mode,kw",
    [
        ("topk_ef", {"topk_frac": 0.2}),
        ("topk_ef", {"topk_frac": 0.2, "topk_quant": "quant4"}),
        ("quant4", {"quant4_mode": "stochastic"}),
        ("secure", {}),
    ],
)
def test_frontier_modes_train(mode, kw):
    from repro.optim import sgd

    fed = FedConfig(n_clients=4, local_steps=2, aggregation=mode, topn=2,
                    client_axis="data", data_axis=None, quant_block=256, **kw)
    opt = sgd(lr=0.05)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    rng = np.random.default_rng(3)
    batch = {"tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, (4, 2, 2, 16)), jnp.int32)}
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        w = R.uniform_weights(4)
        losses = []
        for _ in range(5):
            state, m = fr(state, batch, w)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (mode, kw, losses)
    assert int(state["round"]) == 5
