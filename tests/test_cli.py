"""CLI integration: the launchers run end-to-end as subprocesses."""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
ENV = {**os.environ, "PYTHONPATH": str(ROOT / "src")}


def _run(args, timeout=420):
    return subprocess.run(
        [sys.executable, "-m", *args], env=ENV, cwd=ROOT, capture_output=True, text=True, timeout=timeout
    )


def test_train_cli_runs_and_converges():
    r = _run(["repro.launch.train", "--arch", "qwen3-1.7b", "--rounds", "3", "--clients", "2", "--batch", "2", "--seq", "32"])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["rounds"] == 3 and out["final_loss"] > 0


def test_train_cli_print_plan():
    r = _run(["repro.launch.train", "--arch", "zamba2-2.7b", "--print-plan"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "multipod" in r.stdout and "clients=" in r.stdout


def test_serve_cli_generates():
    r = _run(["repro.launch.serve", "--arch", "qwen3-1.7b", "--batch", "2", "--prompt-len", "16", "--new-tokens", "4"])
    assert r.returncode == 0, r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert len(out["generated"]) == 4


def test_serve_cli_rejects_encoder_only():
    r = _run(["repro.launch.serve", "--arch", "hubert-xlarge"])
    assert r.returncode != 0
    assert "encoder-only" in (r.stdout + r.stderr)
