"""Flat-state round engine (DESIGN.md §11).

Pins the tentpole invariants:
  - the flat engine (packed (C, N_total) round state, slot-view training,
    in-place write-back) reproduces the PR 3 tree engine bit-for-bit for
    EVERY registered stacked aggregator under full, masked and compact
    participation;
  - slot views are reshape-of-slice only (no copy primitives in the jaxpr)
    and round-trip pack/write_slots exactly, including 0-d and misc-bucket
    leaves;
  - `jit_fed_round` donates the state: the lowering carries the aliasing
    attribute and the caller's old packed buffer is actually consumed;
  - the re-tiled reducers (merged-run fused chains, bucket-tiled Pallas
    kernel, fused quant8 transport) match the element-wise oracles.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import aggregators, packing
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.kernels import ref
from repro.kernels import pack as pk
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()
TPL = R.make_template(CFG)
SPEC = packing.build_pack_spec(CFG, TPL)
C = 4
STACKED_MODES = [
    ("dense", {}),
    ("eq6", {}),
    ("quant8", {}),
    ("static_topn", {}),
    ("fedavgm", {}),
    ("fedadam", {"server_lr": 0.02}),
    ("trimmed_mean", {"trim_ratio": 0.3}),
]


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)


def _fed(mode, layout, **kw):
    base = dict(n_clients=C, local_steps=1, aggregation=mode, topn=2,
                client_axis="data", data_axis=None, state_layout=layout)
    base.update(kw)
    return FedConfig(**base)


def _toks(seed=1):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, CFG.vocab_size, (C, 1, 2, 16)), jnp.int32)


def _part(fed):
    if fed.participation == "masked":
        mask = np.array([1.0, 0.0, 1.0, 1.0], np.float32)
        w = np.array([0.5, 0.0, 0.3, 0.2], np.float32)
        return R.participation_input(fed, mask, w)
    if fed.participation == "compact":
        mask = np.array([1.0, 0.0, 1.0, 1.0], np.float32)
        w = np.array([0.5, 0.0, 0.3, 0.2], np.float32)
        return R.participation_input(fed, mask, w, np.array([0, 2, 3]))
    return jnp.asarray([0.4, 0.1, 0.3, 0.2], jnp.float32)


def _run_rounds(fed, n=2, seed=0):
    opt = sgd(lr=0.05)
    mesh = _mesh()
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(seed))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        part = _part(fed)
        for _ in range(n):
            state, m = fr(state, {"tokens": _toks()}, part)
    return state, m


def _packed_of(fed, state):
    p = state["params"]
    return np.asarray(p if isinstance(p, jax.Array) else packing.pack(SPEC, p))


# ----------------- flat engine == tree engine, bit for bit -------------------

@pytest.mark.parametrize("participation", ["full", "masked", "compact"])
@pytest.mark.parametrize("mode,kw", STACKED_MODES, ids=[m for m, _ in STACKED_MODES])
def test_flat_round_bitwise_equals_tree_round(mode, kw, participation):
    pkw = dict(kw)
    if participation == "compact":
        pkw.update(participation="compact", max_participants=3)
    elif participation == "masked":
        pkw.update(participation="masked")
    st_tree, m_tree = _run_rounds(_fed(mode, "tree", **pkw))
    st_flat, m_flat = _run_rounds(_fed(mode, "flat", **pkw))
    if participation == "full":
        # the documented claim: full-participation flat round == PR 3 round
        # bit for bit (params, opt moments, loss)
        assert_state = lambda x, y: np.testing.assert_array_equal(x, y)
        assert float(m_tree["loss"]) == float(m_flat["loss"])
    else:
        # partial participation changes the program around the reducer chain
        # (cond gates / row gathers), and LLVM FMA-contracts the fused
        # multiply-add chain differently per compiled program — a 1-2 ulp
        # effect (see kernels/detect.py's max(.,0) note) that round 2's
        # gradients amplify to ~5e-7 in the momentum buffers; pin to 1e-6.
        assert_state = lambda x, y: np.testing.assert_allclose(x, y, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(m_tree["loss"]), float(m_flat["loss"]), rtol=1e-6)
    assert_state(_packed_of(_fed(mode, "tree", **pkw), st_tree),
                 _packed_of(_fed(mode, "flat", **pkw), st_flat))
    for x, y in zip(jax.tree.leaves(st_tree["opt"]), jax.tree.leaves(st_flat["opt"])):
        assert_state(np.asarray(x), np.asarray(y))
    # cross-round float accumulators (eq6 prev_sums etc.) reduce over ~1e5
    # elements; XLA tiles those sums differently per compiled program, so
    # they get a tight relative tolerance instead of bit equality
    for x, y in zip(jax.tree.leaves(st_tree["agg"]), jax.tree.leaves(st_flat["agg"])):
        np.testing.assert_allclose(
            np.asarray(x, np.float32), np.asarray(y, np.float32), rtol=1e-5, atol=3e-5
        )


def test_flat_state_is_the_packed_buffer():
    fed = _fed("dense", "flat")
    state = R.make_state(CFG, fed, sgd(), jax.random.key(0))
    assert isinstance(state["params"], jax.Array)
    assert state["params"].shape == (C, SPEC.n_total)
    # and the edge unpack reproduces the tree layout's initial params
    tree_state = R.make_state(CFG, _fed("dense", "tree"), sgd(), jax.random.key(0))
    flat_unpacked = R.unpacked_params(CFG, fed, state)
    for x, y in zip(jax.tree.leaves(tree_state["params"]), jax.tree.leaves(flat_unpacked)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_state_layout_validated():
    with pytest.raises(ValueError, match="state_layout"):
        R.make_state(CFG, _fed("dense", "nope"), sgd(), jax.random.key(0))
    with pytest.raises(ValueError, match="state_layout"):
        R.build_fed_round(CFG, _fed("dense", "nope"), sgd())


def test_flat_state_template_matches_make_state():
    """Dry-run abstract state mirrors the real flat state, per mode."""
    opt = sgd()
    for mode, kw in STACKED_MODES:
        fed = _fed(mode, "flat", **kw)
        real = R.make_state(CFG, fed, opt, jax.random.key(0))
        abstract = R.state_template(CFG, fed, opt, jnp.float32)
        assert jax.tree.structure(real) == jax.tree.structure(abstract), mode
        for r, a in zip(jax.tree.leaves(real), jax.tree.leaves(abstract)):
            assert r.shape == a.shape and r.dtype == a.dtype, mode
        specs = R.state_pspecs(CFG, fed, opt)
        assert jax.tree.structure(abstract) == jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        ), mode


# ------------------------- slot views / write-back ---------------------------

_VIEW_SPEC = packing.PackSpec(
    23, 3,
    (
        packing.LeafSlot("a", (3, 5), 0, 15, 0, 1),
        packing.LeafSlot("b", (), 15, 1, 2, 1),  # 0-d leaf, misc bucket
        packing.LeafSlot("c", (7,), 16, 7, 2, 1),  # shares the misc bucket
    ),
)


def _view_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(C, 3, 5)), jnp.float32),
        "b": jnp.asarray(rng.normal(size=(C,)), jnp.float32),
        "c": jnp.asarray(rng.normal(size=(C, 7)), jnp.float32),
    }


def test_unpack_views_roundtrip_bitwise():
    t = _view_tree()
    packed = packing.pack(_VIEW_SPEC, t)
    views = packing.unpack_views(_VIEW_SPEC, packed, t)
    assert jax.tree.structure(views) == jax.tree.structure(t)
    for k in t:
        np.testing.assert_array_equal(np.asarray(views[k]), np.asarray(t[k]))
        assert views[k].dtype == packed.dtype


def test_unpack_views_is_copy_free():
    """The view reconstruction lowers to slice+reshape ONLY — no concat, no
    gather, no conversion: nothing that materializes a second buffer."""
    packed = jax.ShapeDtypeStruct((C, _VIEW_SPEC.n_total), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda p: packing.unpack_views(_VIEW_SPEC, p, _view_tree()))(packed)
    prims = {eqn.primitive.name for eqn in jaxpr.jaxpr.eqns}
    assert prims <= {"slice", "reshape", "squeeze"}, prims


def test_write_slots_inverts_views_and_matches_pack():
    t = _view_tree(3)
    packed = packing.pack(_VIEW_SPEC, t)
    # write into a zero buffer == pack (every element covered exactly once)
    np.testing.assert_array_equal(
        np.asarray(packing.write_slots(_VIEW_SPEC, jnp.zeros_like(packed), t)),
        np.asarray(packed),
    )
    # overwrite semantics: writing different leaves replaces every slot
    t2 = _view_tree(4)
    np.testing.assert_array_equal(
        np.asarray(packing.write_slots(_VIEW_SPEC, packed, t2)),
        np.asarray(packing.pack(_VIEW_SPEC, t2)),
    )


def test_unpack_views_real_spec_matches_unpack():
    state = R.make_state(CFG, _fed("dense", "flat"), sgd(), jax.random.key(2))
    views = packing.unpack_views(SPEC, state["params"], TPL)
    edge = R.unpacked_params(CFG, _fed("dense", "flat"), state)
    for v, e in zip(jax.tree.leaves(views), jax.tree.leaves(edge)):
        np.testing.assert_array_equal(np.asarray(v), np.asarray(e))


# ------------------------------- donation ------------------------------------

def test_jit_fed_round_lowers_with_donated_state():
    fed = _fed("dense", "flat")
    opt = sgd(lr=0.05)
    state = R.make_state(CFG, fed, opt, jax.random.key(0))
    fr = R.jit_fed_round(R.build_fed_round(CFG, fed, opt))
    txt = fr.lower(state, {"tokens": _toks()}, R.uniform_weights(C)).as_text()
    assert ("tf.aliasing_output" in txt) or ("jax.buffer_donor" in txt)


def test_jit_fed_round_donation_survives_aliasing_modes():
    """quant8's agg state carries the dispatched model; were it the SAME
    (C, N) buffer as state["params"] (as the tree-era design had it), the
    donated jit would die with 'Attempt to donate the same buffer twice' on
    round 2. The (N,) dispatch-row base keeps every donated leaf distinct."""
    fed = _fed("quant8", "flat")
    opt = sgd(lr=0.05)
    state = R.make_state(CFG, fed, opt, jax.random.key(0))
    fr = R.jit_fed_round(R.build_fed_round(CFG, fed, opt))
    for _ in range(3):  # round 2+ feeds aggregate's outputs back in, donated
        state, m = fr(state, {"tokens": _toks()}, R.uniform_weights(C))
    assert np.isfinite(float(m["loss"]))
    assert state["agg"]["base"].shape == (SPEC.n_total,)


def test_jit_fed_round_consumes_the_old_state():
    """No second copy of the packed state survives the round: the donated
    input buffer is deleted once the jitted round returns."""
    fed = _fed("dense", "flat")
    opt = sgd(lr=0.05)
    state = R.make_state(CFG, fed, opt, jax.random.key(0))
    old_packed = state["params"]
    fr = R.jit_fed_round(R.build_fed_round(CFG, fed, opt))
    state, _ = fr(state, {"tokens": _toks()}, R.uniform_weights(C))
    assert old_packed.is_deleted()
    assert not state["params"].is_deleted()
    # and the new state is immediately consumable for the next round
    state, m = fr(state, {"tokens": _toks()}, R.uniform_weights(C))
    assert np.isfinite(float(m["loss"]))


# ----------------------- re-tiled reducers vs oracles ------------------------

def _random_spec():
    """Non-uniform layout: a 2-bucket stack, a second stack revisiting the
    same buckets (no run merge), and two misc tensors sharing a bucket."""
    slots = (
        packing.LeafSlot("s1", (2, 6), 0, 12, 0, 2),
        packing.LeafSlot("s2", (2, 3), 12, 6, 0, 2),
        packing.LeafSlot("m1", (5,), 18, 5, 2, 1),
        packing.LeafSlot("m2", (4,), 23, 4, 2, 1),
    )
    return packing.PackSpec(27, 3, slots)


def test_merged_runs_reconstruct_bucket_ids():
    for spec in (SPEC, _random_spec(), _VIEW_SPEC):
        ids = np.empty(spec.n_total, np.int32)
        covered = 0
        for col0, b0, nb, per in packing.merged_runs(spec):
            ids[col0 : col0 + nb * per] = np.repeat(np.arange(b0, b0 + nb), per)
            covered += nb * per
        assert covered == spec.n_total
        np.testing.assert_array_equal(ids, packing.bucket_ids(spec))


@pytest.mark.parametrize("use_mask", [False, True])
def test_masked_bucket_mean_fused_chain_matches_oracle(use_mask):
    spec = _random_spec()
    rng = np.random.default_rng(11)
    p = jnp.asarray(rng.normal(size=(C, spec.n_total)), jnp.float32)
    wm = jnp.asarray(rng.random((C, spec.n_buckets)), jnp.float32)
    mask = jnp.asarray([1.0, 0.0, 1.0, 1.0]) if use_mask else None
    g, den_b = packing.masked_bucket_mean(p, wm, spec, mask)
    ids = jnp.asarray(packing.bucket_ids(spec))
    num_r, den_r = ref.packed_bucket_reduce(p, wm, ids, mask)
    assert den_b.shape == (spec.n_buckets,)  # per-bucket, expanded lazily
    np.testing.assert_allclose(
        np.asarray(packing.expand_bucket_vec(spec, den_b)), np.asarray(den_r), rtol=1e-6
    )
    np.testing.assert_allclose(
        np.asarray(g), np.asarray(num_r) / np.maximum(np.asarray(den_r), 1e-12),
        rtol=1e-5, atol=1e-6,
    )


def test_masked_bucket_mean_large_client_fallback():
    """C > CHAIN_MAX_CLIENTS takes the contraction path — same numbers."""
    spec = _random_spec()
    Cbig = packing.CHAIN_MAX_CLIENTS + 4
    rng = np.random.default_rng(12)
    p = jnp.asarray(rng.normal(size=(Cbig, spec.n_total)), jnp.float32)
    wm = jnp.asarray(rng.random((Cbig, spec.n_buckets)), jnp.float32)
    g, den = packing.masked_bucket_mean(p, wm, spec)
    ids = jnp.asarray(packing.bucket_ids(spec))
    num_r, den_r = ref.packed_bucket_reduce(p, wm, ids)
    np.testing.assert_allclose(
        np.asarray(g), np.asarray(num_r) / np.maximum(np.asarray(den_r), 1e-12),
        rtol=1e-5, atol=1e-6,
    )
    w = jnp.asarray(rng.dirichlet(np.ones(Cbig)), jnp.float32)
    got = packing.weighted_mean(p, w)
    want = jnp.einsum("c,cn->n", w, p) / jnp.sum(w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_packed_bucket_reduce_bucket_tile():
    """Tight bucket tiling == full-width one-hot on a sorted-id spec."""
    spec = SPEC
    rng = np.random.default_rng(5)
    p = jnp.asarray(rng.normal(size=(C, spec.n_total)), jnp.float32)
    wm = jnp.asarray(rng.random((C, spec.n_buckets)), jnp.float32)
    ids = jnp.asarray(packing.bucket_ids(spec))
    tile = packing.bucket_tile_bound(spec)
    assert tile <= spec.n_buckets + 1
    num_t, den_t = pk.packed_bucket_reduce(p, wm, ids, bucket_tile=tile)
    num_f, den_f = pk.packed_bucket_reduce(p, wm, ids, bucket_tile=None)
    np.testing.assert_allclose(np.asarray(num_t), np.asarray(num_f), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(den_t), np.asarray(den_f), rtol=1e-6, atol=1e-7)


def test_packed_bucket_reduce_client_blocks():
    """2-D grid accumulation over client blocks == single-block result."""
    rng = np.random.default_rng(6)
    Cn, N, B = 7, 700, 3  # C not divisible by the client block
    p = jnp.asarray(rng.normal(size=(Cn, N)), jnp.float32)
    wm = jnp.asarray(rng.random((Cn, B)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, B, N), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, Cn), jnp.float32)
    num_r, den_r = ref.packed_bucket_reduce(p, wm, ids, mask)
    for bc in (2, 3, 16):
        num_k, den_k = pk.packed_bucket_reduce(p, wm, ids, mask, block_n=256, block_c=bc)
        np.testing.assert_allclose(np.asarray(num_k), np.asarray(num_r), rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(den_k), np.asarray(den_r), rtol=1e-5, atol=1e-5)


# --------------------------- fused quant8 transport --------------------------

def test_quant8_mean_ref_matches_unfused_composition():
    rng = np.random.default_rng(9)
    delta = jnp.asarray(rng.normal(size=(C, 2500)), jnp.float32)
    w = jnp.asarray(rng.dirichlet(np.ones(C)), jnp.float32)
    q, s = packing.quantize_rows_ref(delta, 256)
    d = packing.dequantize_rows_ref(q, s, 256)
    want = np.einsum("c,cn->n", np.asarray(w), np.asarray(d))
    np.testing.assert_allclose(
        np.asarray(packing.quant8_mean_ref(delta, w, 256)), want, rtol=1e-6, atol=1e-7
    )
    np.testing.assert_allclose(
        np.asarray(packing.dequant_reduce_ref(q, s, w, 256)), want, rtol=1e-6, atol=1e-7
    )


def test_quant8_reduce_kernel_one_launch_matches_ref():
    rng = np.random.default_rng(10)
    delta = jnp.asarray(rng.normal(size=(6, 2500)), jnp.float32)
    w = jnp.asarray(rng.dirichlet(np.ones(6)), jnp.float32)
    got = pk.quant8_reduce(delta, w, block=256, block_n=512, block_c=4)
    want = packing.quant8_mean_ref(delta, w, 256)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)


def test_quantize_rows_blocked_grid_matches_ref():
    """Re-tiled (client-block x N-block) quant kernels == row refs at odd
    shapes (C and N both off the block sizes)."""
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.normal(size=(5, 3333)), jnp.float32)
    q_k, s_k = pk.quantize_rows(x, block=128, block_n=512, block_c=2)
    q_r, s_r = packing.quantize_rows_ref(x, 128)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    back = pk.dequantize_rows(q_k, s_k, block=128, block_n=512, block_c=2)
    np.testing.assert_allclose(
        np.asarray(back), np.asarray(packing.dequantize_rows_ref(q_r, s_r, 128)),
        rtol=1e-6, atol=1e-7,
    )


def test_quant8_aggregator_meshless_fused_path_matches_mesh_transport():
    """The collective-free fused path and the shard_map int8 transport are
    the same quantizer: identical outputs on a 1-shard mesh."""
    rng = np.random.default_rng(14)
    packed = jnp.asarray(rng.normal(size=(C, 512)), jnp.float32)
    base = jnp.asarray(rng.normal(size=(512,)) * 0.1, jnp.float32)  # (N,) dispatch row
    w = jnp.asarray(rng.dirichlet(np.ones(C)), jnp.float32)
    spec = packing.PackSpec(512, 2, (packing.LeafSlot("x", (512,), 0, 512, 0, 1),))
    fed = _fed("quant8", "flat", quant_block=128)
    ctx_none = aggregators.AggContext(cfg=CFG, fed=fed, template=TPL, spec=spec, mesh=None)
    out_none, _ = aggregators.get("quant8")(ctx_none).aggregate(packed, w, {"base": base})
    mesh = _mesh()
    with jax.set_mesh(mesh):
        ctx_mesh = aggregators.AggContext(cfg=CFG, fed=fed, template=TPL, spec=spec, mesh=mesh)
        out_mesh, _ = aggregators.get("quant8")(ctx_mesh).aggregate(packed, w, {"base": base})
    np.testing.assert_allclose(np.asarray(out_none), np.asarray(out_mesh), rtol=1e-6, atol=1e-7)
