"""Property tests for the packed-buffer reduction tiling at large C
(DESIGN.md §11/§13 satellite of PR 6).

Random slot layouts pin the invariants the fused reducers and the Pallas
bucket kernel rely on:
  - `merged_runs` tiles [0, n_total) exactly and reproduces the per-element
    bucket id map (`bucket(col0 + i) == b0 + i // per` inside each run);
  - `bucket_tile_bound` really bounds the distinct buckets any
    block_n-aligned window touches (the kernel's static tile width);
  - `weighted_mean` / `grouped_weighted_mean` agree with the NumPy oracle
    on BOTH sides of the CHAIN_MAX_CLIENTS cutover — the fused chain and
    the contraction are interchangeable numerics, so retuning the cutover
    can never change results beyond reduction-order ulps.

PR 7 adds the wire-codec properties (DESIGN.md §14): random rows pushed
through the FULL uplink pipeline — ``encode_update`` -> frame -> adversarial
TCP chunking (split and coalesced reads) -> ``FrameParser`` ->
``parse_update`` -> ``decode_update`` — must come back identical (dense,
bitwise) or within the quantizer's half-step bound, because the
replay-determinism contract replays recorded schedules through exactly this
round-trip. PR 8 widens the loop over `codec.CODECS` to the frontier codecs
(DESIGN.md §15): quant4 under its amax/7 half-step bound, and topk under
"half the global int8 step OR untouched (decodes to base)".
"""
import numpy as np

import jax.numpy as jnp

from _hyp import given, settings, st

from repro.core import packing
from repro.core.packing import CHAIN_MAX_CLIENTS, LeafSlot, PackSpec
from repro.core.transport import codec, wire


def _spec_from_layout(widths, kinds):
    """Random slot layout -> a consistent PackSpec. kinds[i] selects a
    misc slot (one bucket) or a scan-stacked slot (one bucket per row)."""
    slots = []
    off = 0
    boff = 0
    for w, k in zip(widths, kinds):
        if k:  # stacked: nb rows of `w` elements, one bucket each
            nb = 1 + (w % 3)
            size = nb * w
        else:  # misc tensor: one bucket
            nb = 1
            size = w
        slots.append(LeafSlot(f"s{off}", (size,), off, size, boff, nb))
        off += size
        boff += nb
    return PackSpec(n_total=off, n_buckets=boff, slots=tuple(slots))


@settings(max_examples=30, deadline=None)
@given(
    widths=st.lists(st.integers(1, 64), min_size=1, max_size=12),
    kind_seed=st.integers(0, 2**30),
)
def test_merged_runs_cover_and_reconstruct_bucket_ids(widths, kind_seed):
    rng = np.random.default_rng(kind_seed)
    spec = _spec_from_layout(widths, rng.integers(0, 2, len(widths)))
    runs = packing.merged_runs(spec)
    ids = packing.bucket_ids(spec)
    # exact disjoint coverage in offset order
    pos = 0
    rebuilt = np.empty(spec.n_total, np.int32)
    for col0, b0, nb, per in runs:
        assert col0 == pos, "runs must tile the buffer contiguously"
        assert per >= 1 and nb >= 1
        span = nb * per
        rebuilt[col0 : col0 + span] = b0 + np.arange(span) // per
        pos += span
    assert pos == spec.n_total
    np.testing.assert_array_equal(rebuilt, ids)
    # expand_bucket_vec is the same map applied to data
    vec = jnp.asarray(rng.normal(size=spec.n_buckets).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(packing.expand_bucket_vec(spec, vec)), np.asarray(vec)[rebuilt]
    )


@settings(max_examples=30, deadline=None)
@given(
    widths=st.lists(st.integers(1, 64), min_size=1, max_size=12),
    kind_seed=st.integers(0, 2**30),
    block_n=st.integers(4, 96),
)
def test_bucket_tile_bound_bounds_every_window(widths, kind_seed, block_n):
    rng = np.random.default_rng(kind_seed)
    spec = _spec_from_layout(widths, rng.integers(0, 2, len(widths)))
    bound = packing.bucket_tile_bound(spec, block_n)
    ids = packing.bucket_ids(spec)
    pad = (-len(ids)) % block_n
    padded = np.concatenate([ids, np.full(pad, spec.n_buckets, np.int32)])
    for w in padded.reshape(-1, block_n):
        assert len(np.unique(w)) <= bound
        # the kernel's tile is a contiguous [min, min+bound) id window
        assert w.max() - w.min() < bound


@settings(max_examples=12, deadline=None)
@given(
    c_off=st.integers(-4, 4),
    n=st.integers(1, 300),
    seed=st.integers(0, 2**30),
)
def test_weighted_mean_agrees_across_chain_cutover(c_off, n, seed):
    # C straddles CHAIN_MAX_CLIENTS: below -> fused chain, above -> einsum.
    # Both must match the f64 oracle, so the cutover is numerics-neutral.
    C = CHAIN_MAX_CLIENTS + c_off
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(C, n)).astype(np.float32)
    w = rng.uniform(0.1, 1.0, C).astype(np.float32)
    mask = (rng.uniform(size=C) > 0.2).astype(np.float32)
    if mask.sum() == 0:
        mask[0] = 1.0
    got = np.asarray(packing.weighted_mean(jnp.asarray(x), jnp.asarray(w), jnp.asarray(mask)))
    wm = (w * mask).astype(np.float64)
    exp = (wm @ x.astype(np.float64)) / wm.sum()
    np.testing.assert_allclose(got, exp, rtol=2e-5, atol=2e-6)


@settings(max_examples=8, deadline=None)
@given(
    g_off=st.integers(-2, 2),
    ngroups=st.integers(1, 3),
    n=st.integers(1, 120),
    seed=st.integers(0, 2**30),
)
def test_grouped_mean_agrees_across_chain_cutover(g_off, ngroups, n, seed):
    G = CHAIN_MAX_CLIENTS + g_off  # inner chain vs batched contraction
    C = ngroups * G
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(C, n)).astype(np.float32)
    w = rng.uniform(0.1, 1.0, C).astype(np.float32)
    rows, den = packing.grouped_weighted_mean(jnp.asarray(x), jnp.asarray(w), G)
    wg = w.astype(np.float64).reshape(ngroups, G)
    den_np = wg.sum(axis=1)
    exp = np.einsum(
        "gi,gin->gn", wg / den_np[:, None], x.astype(np.float64).reshape(ngroups, G, n)
    )
    np.testing.assert_allclose(np.asarray(rows), exp, rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(den), den_np, rtol=1e-6)


# --------------------------- wire codec (§14) --------------------------------

def _chunked(stream: bytes, rng, style: int):
    """Adversarial TCP read patterns: 1-byte drip, random small splits
    (frames arrive split), or huge reads (frames arrive coalesced)."""
    if style == 0:
        sizes = [1] * len(stream)
    elif style == 1:
        sizes = rng.integers(1, 17, len(stream)).tolist()
    else:
        sizes = rng.integers(len(stream) // 2 + 1, len(stream) + 1, 4).tolist()
    pos = 0
    for n in sizes:
        if pos >= len(stream):
            return
        yield stream[pos : pos + int(n)]
        pos += int(n)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 3000),
    block=st.integers(1, 600),
    seed=st.integers(0, 2**30),
    style=st.integers(0, 2),
)
def test_wire_update_roundtrip_through_frames_and_codec(n, block, seed, style):
    """encode_update -> frame -> chunked feed -> parse -> decode_update is
    the identity (dense) / half-step-bounded (quant8) for random rows."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=n).astype(np.float32) * rng.uniform(0.01, 10)
    trained = (base + rng.normal(size=n).astype(np.float32) * 0.05).astype(np.float32)
    for name in codec.CODECS:
        buf = codec.encode_update(trained, base, name, block)
        stream = wire.pack_update(7, 3, 41, 0.25, buf)
        parser = wire.FrameParser()
        got = []
        for chunk in _chunked(stream, rng, style):
            got.extend(parser.feed(chunk))
        assert parser.pending == 0 and len(got) == 1
        ftype, payload = got[0]
        assert ftype == wire.UPDATE
        c, seq, ver, loss, out = wire.parse_update(payload)
        assert (c, seq, ver, loss) == (7, 3, 41, 0.25)
        decoded = codec.decode_update(out, base)
        delta = trained - base
        if name == "dense":
            np.testing.assert_array_equal(decoded, trained)
        elif name == "topk":
            # selected values: int8-quantized over the compacted k-vector,
            # so half the GLOBAL step bounds them; unselected decode to base
            bound = (
                np.abs(delta).max() / 127.0 / 2 * 1.001
                + 2.4e-7 * np.abs(base) + 1e-9
            )
            err = np.abs(decoded - trained)
            assert np.all((err <= bound) | (decoded == base))
            k = max(1, min(n, int(-(-codec.TOPK_FRAC * n // 1))))
            assert int(np.sum(decoded != base)) <= k
        else:
            qmax = 127.0 if name == "quant8" else 7.0
            nb = -(-n // block)
            pad = np.zeros(nb * block, np.float32)
            pad[:n] = delta
            step = np.abs(pad).reshape(nb, block).max(axis=1) / qmax
            # half the quant step per block, plus one f32-addition ulp
            bound = np.repeat(step / 2 * 1.001, block)[:n] + 2.4e-7 * np.abs(base) + 1e-9
            assert np.all(np.abs(decoded - trained) <= bound)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 2000),
    seed=st.integers(0, 2**30),
    style=st.integers(0, 2),
)
def test_wire_dispatch_roundtrip_is_bitwise(n, seed, style):
    """Dispatch rows (always dense) survive framing + chunking bit-for-bit —
    the worker must train on EXACTLY the server's row."""
    rng = np.random.default_rng(seed)
    row = rng.normal(size=n).astype(np.float32)
    stream = wire.pack_dispatch(int(rng.integers(0, 2**40)), codec.encode_row(row, "dense"))
    parser = wire.FrameParser()
    got = []
    for chunk in _chunked(stream, rng, style):
        got.extend(parser.feed(chunk))
    assert len(got) == 1 and got[0][0] == wire.DISPATCH
    _v, out = wire.parse_dispatch(got[0][1])
    np.testing.assert_array_equal(codec.decode_row(out), row)


@settings(max_examples=12, deadline=None)
@given(
    nframes=st.integers(2, 8),
    seed=st.integers(0, 2**30),
    style=st.integers(0, 2),
)
def test_mixed_frame_stream_roundtrip(nframes, seed, style):
    """A whole conversation's worth of mixed frames survives any chunking
    in order, with payloads intact."""
    rng = np.random.default_rng(seed)
    frames = []
    for _ in range(nframes):
        k = int(rng.integers(0, 4))
        if k == 0:
            frames.append((wire.HELLO, wire.pack_hello(int(rng.integers(0, 100)))))
        elif k == 1:
            frames.append((wire.HEARTBEAT, wire.pack_heartbeat(int(rng.integers(0, 100)))))
        elif k == 2:
            frames.append((wire.DISPATCH, wire.pack_dispatch(
                int(rng.integers(0, 1000)), b"\x00" + rng.bytes(int(rng.integers(1, 200))))))
        else:
            frames.append((wire.UPDATE, wire.pack_update(
                int(rng.integers(0, 100)), int(rng.integers(0, 50)),
                int(rng.integers(0, 1000)), 0.5, rng.bytes(int(rng.integers(1, 200))))))
    stream = b"".join(f for _, f in frames)
    parser = wire.FrameParser()
    got = []
    for chunk in _chunked(stream, rng, style):
        got.extend(parser.feed(chunk))
    assert parser.pending == 0
    assert [t for t, _ in got] == [t for t, _ in frames]
    # each parsed payload is the original frame minus the len|crc|type prefix
    for (ftype, full), (_, payload) in zip(frames, got):
        assert full[wire.HEADER_BYTES + 1:] == payload


# ----------------------- CRC frame header (§16) -------------------------------

@settings(max_examples=30, deadline=None)
@given(
    ftype=st.sampled_from([wire.HELLO, wire.DISPATCH, wire.UPDATE,
                           wire.HEARTBEAT, wire.BYE]),
    payload=st.binary(min_size=0, max_size=400),
    seed=st.integers(0, 2**30),
    style=st.integers(0, 2),
)
def test_extended_header_roundtrips_any_payload(ftype, payload, seed, style):
    """encode_frame -> adversarial chunking -> FrameParser is the identity
    for ANY payload bytes under the len|crc32|type header — the parser never
    interprets payloads, so framing is payload-agnostic."""
    frame = wire.encode_frame(ftype, payload)
    assert len(frame) == wire.HEADER_BYTES + 1 + len(payload)
    parser = wire.FrameParser()
    got = []
    for chunk in _chunked(frame, np.random.default_rng(seed), style):
        got.extend(parser.feed(chunk))
    assert got == [(ftype, payload)]
    assert parser.pending == 0 and parser.crc_errors == 0


@settings(max_examples=40, deadline=None)
@given(
    payload=st.binary(min_size=0, max_size=200),
    pos_seed=st.integers(0, 2**30),
    flip=st.integers(1, 255),
)
def test_any_single_byte_flip_past_the_length_is_withheld(payload, pos_seed, flip):
    """Every possible single-byte corruption of the crc/type/payload region
    is caught by the CRC check: the frame is withheld + counted, never
    delivered damaged, and the stream stays framed for the next frame."""
    frame = bytearray(wire.encode_frame(wire.UPDATE, payload))
    # the length word stays honest (a fault that lies about length is a
    # desync, tested separately); everything after it is fair game
    pos = 4 + pos_seed % (len(frame) - 4)
    frame[pos] ^= flip
    parser = wire.FrameParser()
    got = parser.feed(bytes(frame) + wire.pack_bye())
    assert parser.crc_errors == 1
    assert [t for t, _ in got] == [wire.BYE]
    assert parser.pending == 0
