"""Eq. 6 scoring/masking + int8 quantization properties (hypothesis)."""
import numpy as np
from _hyp import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import compression as comp
from repro.core import rounds as R

CFG = get_arch("qwen3-1.7b").reduced()
TPL = R.make_template(CFG)


def _ones_params():
    from repro.models.params import is_info

    return jax.tree.map(lambda i: jnp.ones(i.shape), TPL, is_leaf=is_info)


def test_layer_sums_shape_and_linearity():
    p1 = _ones_params()
    s1 = comp.layer_sums(CFG, TPL, p1)
    assert s1.shape == (comp.n_score_buckets(CFG),)
    s2 = comp.layer_sums(CFG, TPL, jax.tree.map(lambda x: 2 * x, p1))
    np.testing.assert_allclose(np.asarray(s2), 2 * np.asarray(s1), rtol=1e-6)
    # every parameter is counted exactly once
    from repro.models.params import count_params

    assert float(s1.sum()) == count_params(TPL)


@given(st.integers(1, 10))
@settings(max_examples=10, deadline=None)
def test_topn_mask_selects_n(n):
    scores = jnp.asarray(np.random.default_rng(n).normal(size=17) ** 2)
    mask = comp.topn_mask(scores, n)
    assert int(mask.sum()) >= min(n, 17)  # ties may add extras
    kept = np.asarray(scores)[np.asarray(mask)]
    dropped = np.asarray(scores)[~np.asarray(mask)]
    if dropped.size and kept.size:
        assert kept.min() >= dropped.max()


def test_apply_layer_mask_zeroes_unselected():
    params = _ones_params()
    nb = comp.n_score_buckets(CFG)
    mask = jnp.zeros(nb).at[0].set(1.0)  # only layer 0 survives
    out = comp.apply_layer_mask(CFG, TPL, params, mask)
    sums = comp.layer_sums(CFG, TPL, out)
    assert float(sums[0]) > 0
    np.testing.assert_allclose(np.asarray(sums[1:]), 0.0, atol=1e-6)


def test_contribution_scores_eq6():
    prev = jnp.asarray([1.0, -2.0, 3.0])
    new = jnp.asarray([1.5, -2.0, -3.0])
    np.testing.assert_allclose(np.asarray(comp.contribution_scores(prev, new)), [0.5, 0.0, 6.0])


@given(st.lists(st.floats(-100, 100, allow_nan=False), min_size=1, max_size=300))
@settings(max_examples=25, deadline=None)
def test_quant_roundtrip_error_bound(xs):
    x = jnp.asarray(xs, jnp.float32)
    q, s = comp.quantize(x)
    back = comp.dequantize(q, s)
    step = float(s)
    assert np.abs(np.asarray(back) - np.asarray(x)).max() <= 0.51 * step + 1e-9


def test_compression_ratio():
    assert comp.compression_ratio(CFG, comp.n_score_buckets(CFG)) == 1.0
    assert 0 < comp.compression_ratio(CFG, 1) < 0.5
