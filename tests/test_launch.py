"""Launch-layer units that don't need the 512-device fleet: plan matrix
coverage, input_specs shapes, HLO analyzer trip-count handling, roofline."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, SHAPES, get_arch, get_shape, shape_applicable
from repro.launch import hlo_analysis, roofline, specs


def test_matrix_is_10x4():
    assert len(ASSIGNED) == 10
    assert len(SHAPES) == 4
    pairs = [(a.name, s.name) for a in ASSIGNED for s in SHAPES.values()]
    assert len(pairs) == 40


def test_skip_matrix():
    skips = {
        (a.name, s.name)
        for a in ASSIGNED
        for s in SHAPES.values()
        if not shape_applicable(a, s)[0]
    }
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    for dense in ["granite-3-8b", "qwen3-1.7b", "minitron-8b", "llava-next-34b", "grok-1-314b", "granite-moe-1b-a400m"]:
        assert (dense, "long_500k") in skips
    # sub-quadratic archs run long_500k
    for ok in ["mamba2-1.3b", "zamba2-2.7b", "gemma3-27b"]:
        assert (ok, "long_500k") not in skips
    assert len(skips) == 8


@pytest.mark.parametrize("multi", [False, True])
@pytest.mark.parametrize("arch", [a.name for a in ASSIGNED])
def test_plans_and_input_specs_build(arch, multi):
    for shape in SHAPES.values():
        ok, _ = shape_applicable(get_arch(arch), shape)
        if not ok:
            continue
        plan = specs.make_plan(arch, shape.name, multi)
        args, pspecs_ = specs.input_specs(plan)
        # structures must match so jit in_shardings align
        assert jax.tree.structure(args) == jax.tree.structure(
            pspecs_, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        if shape.kind == "train":
            state = args[0]
            # every stacked leaf carries the client dim
            if plan.kind == "train":
                C = plan.fed.n_clients
                for leaf in jax.tree.leaves(state["params"]):
                    assert leaf.shape[0] == C
        if shape.kind == "decode":
            params, cache, tokens, pos = args
            assert tokens.shape == (shape.global_batch, 1)


def test_hlo_analyzer_counts_scan_trips():
    D, L = 64, 6

    def scanned(x, ws):
        def body(h, w):
            return jnp.tanh(h @ w), None

        h, _ = jax.lax.scan(body, x, ws)
        return h

    x = jnp.ones((8, D))
    ws = jnp.ones((L, D, D))
    txt = jax.jit(scanned).lower(x, ws).compile().as_text()
    costs = hlo_analysis.analyze(txt)
    want = 2 * 8 * D * D * L
    np.testing.assert_allclose(costs.flops, want, rtol=1e-6)


def test_hlo_analyzer_collectives():
    mesh = jax.make_mesh((1,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
    # single-device: no collectives expected
    with jax.set_mesh(mesh):
        txt = jax.jit(lambda x: x * 2).lower(jnp.ones(8)).compile().as_text()
    costs = hlo_analysis.analyze(txt)
    assert not costs.coll_bytes


def test_roofline_terms_and_dominance():
    arch = get_arch("qwen3-1.7b")
    shape = get_shape("train_4k")
    rl = roofline.terms(1e15, 1e12, {"all-reduce": 1e11}, 256, arch, shape)
    assert rl.compute_s > 0 and rl.memory_s > 0 and rl.collective_s > 0
    assert rl.dominant in ("compute", "memory", "collective")
    # all-reduce counts 2x
    np.testing.assert_allclose(rl.collective_s, 2 * 1e11 / 50e9)
    assert rl.model_flops == 6.0 * roofline.active_params(arch) * 256 * 4096


def test_moe_active_params_smaller_than_total():
    from repro.core.rounds import make_template
    from repro.models.params import count_params

    grok = get_arch("grok-1-314b")
    assert roofline.active_params(grok) < count_params(make_template(grok))


def test_default_topn():
    assert specs.default_topn(get_arch("granite-3-8b")) == 10


def test_cross_pod_classifier():
    assert hlo_analysis.crosses_boundary("replica_groups={{0,256},{1,257}}, x", 256)
    assert not hlo_analysis.crosses_boundary("replica_groups={{0,1},{256,257}}, x", 256)
    # iota format: [256,2]<=[512] -> consecutive pairs, all within one pod
    assert not hlo_analysis.crosses_boundary("replica_groups=[256,2]<=[512], y", 256)
    # [2,256]<=[512] transposed pairs device i with i+256 -> crosses
    assert hlo_analysis.crosses_boundary("replica_groups=[256,2]<=[2,256]T(1,0), y", 256)


def test_variant_plans_build():
    for variant in ["moe_sort", "moe_ep", "moe_sort_ep"]:
        plan = specs.make_plan("granite-moe-1b-a400m", "train_4k", True, variant=variant)
        args, ps = specs.input_specs(plan)
        assert jax.tree.structure(args) == jax.tree.structure(
            ps, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        )
        if "ep" in variant:
            assert plan.rules["expert"] == "model" and plan.rules["ffn"] is None
        if "sort" in variant:
            assert plan.arch.moe_impl == "sort"
    plan = specs.make_plan("gemma3-27b", "train_4k", False, variant="zero1")
    assert plan.rules["embed"] is None and plan.opt_rules["embed"] == "data"
    plan = specs.make_plan("qwen3-1.7b", "train_4k", False, variant="micro2")
    assert plan.fed.microbatches == 2


def test_roofline_cross_pod_term():
    arch = get_arch("qwen3-1.7b")
    shape = get_shape("train_4k")
    rl = roofline.terms(1e12, 1e12, {"all-gather": 1e9}, 512, arch, shape, cross_pod_bytes={"all-gather": 5e8})
    np.testing.assert_allclose(rl.cross_pod_s, 5e8 / 25e9)
    assert rl.cross_pod_bytes == 5e8
