"""Detection evaluation engine (DESIGN.md §10): Pallas IoU/NMS kernels
pinned bit-for-bit against the NumPy oracles in interpret mode, greedy
matching + mAP on hand-computed fixtures, and the jitted federated
evaluator's per-client/global wiring."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import detection
from repro.kernels import ops, ref
from repro.models import yolov3

RNG = np.random.default_rng(11)
CFG = get_arch("fedyolov3").reduced()


def _boxes(*shape, lo=0.02, hi=0.5):
    xy = RNG.uniform(0.1, 0.9, shape + (2,)).astype(np.float32)
    wh = RNG.uniform(lo, hi, shape + (2,)).astype(np.float32)
    return np.concatenate([xy, wh], -1)


# ------------------------- pairwise IoU goldens -----------------------------

@pytest.mark.parametrize("B,N,M", [(1, 5, 7), (3, 130, 70), (2, 64, 9)])
@pytest.mark.parametrize("giou", [False, True])
def test_pairwise_iou_bit_for_bit(B, N, M, giou):
    """Tiled kernel == NumPy oracle bitwise, padding and batching included."""
    a, b = _boxes(B, N), _boxes(B, M)
    k = ops.pairwise_iou(jnp.asarray(a), jnp.asarray(b), giou=giou, block_n=64, block_m=64)
    np.testing.assert_array_equal(np.asarray(k), ref.pairwise_iou_np(a, b, giou=giou))


def test_pairwise_iou_degenerate_bit_for_bit():
    """Zero-area and negative-w/h boxes score 0 against everything — in the
    kernel AND the oracle, bitwise."""
    a = _boxes(6)
    a[0, 2:] = 0.0  # zero area
    a[1, 2] = -0.2  # negative width (collapses to zero area)
    k = np.asarray(ops.pairwise_iou(jnp.asarray(a), jnp.asarray(a)))
    r = ref.pairwise_iou_np(a, a)
    np.testing.assert_array_equal(k, r)
    assert k[0, 0] == 0.0 and k[1, 1] == 0.0  # degenerate self-IoU is 0
    np.testing.assert_allclose(np.diag(k)[2:], 1.0)  # proper boxes: identity
    assert (k[0] == 0.0).all() and (k[1] == 0.0).all()


def test_pairwise_iou_matches_model_iou():
    """kernels.detect and models.yolov3 share one IoU definition: the loss
    path's broadcasting iou gives the same matrix as the kernel."""
    a, b = _boxes(24), _boxes(17)
    k = np.asarray(ops.pairwise_iou(jnp.asarray(a), jnp.asarray(b)))
    m = np.asarray(yolov3.pairwise_iou(jnp.asarray(a), jnp.asarray(b)))
    np.testing.assert_allclose(k, m, rtol=1e-6, atol=1e-7)


def test_model_iou_broadcasts_batched():
    """The satellite fix: iou broadcasts over batched box arrays."""
    a, b = _boxes(4, 8), _boxes(4, 8)
    elem = np.asarray(yolov3.iou(jnp.asarray(a), jnp.asarray(b)))
    assert elem.shape == (4, 8)
    pair = np.asarray(yolov3.pairwise_iou(jnp.asarray(a), jnp.asarray(b)))
    assert pair.shape == (4, 8, 8)
    # the pairwise diagonal is the element-wise result
    np.testing.assert_allclose(np.diagonal(pair, axis1=1, axis2=2), elem, rtol=1e-6)


def test_giou_bounds_and_bit_for_bit():
    a, b = _boxes(40), _boxes(40)
    gi = np.asarray(ops.pairwise_iou(jnp.asarray(a), jnp.asarray(b), giou=True))
    io = np.asarray(ops.pairwise_iou(jnp.asarray(a), jnp.asarray(b)))
    assert (gi <= io + 1e-6).all() and (gi >= -1.0 - 1e-6).all()
    np.testing.assert_array_equal(gi, ref.pairwise_iou_np(a, b, giou=True))


# ------------------------------ NMS goldens ---------------------------------

@pytest.mark.parametrize("B,N", [(1, 16), (2, 64), (3, 200)])
def test_nms_bit_for_bit_random(B, N):
    bx = _boxes(B, N)
    sc = RNG.uniform(0, 1, (B, N)).astype(np.float32)
    for mk in (0, 8):
        k = ops.nms(jnp.asarray(bx), jnp.asarray(sc), iou_thresh=0.4, score_thresh=0.1, max_keep=mk)
        np.testing.assert_array_equal(np.asarray(k), ref.nms_np(bx, sc, 0.4, 0.1, mk))


def test_nms_score_ties_stable():
    """Equal scores break by original index (stable sort) — deterministic
    in kernel and oracle alike: of N identical tied boxes, index 0 wins."""
    bx = np.tile(np.asarray([[0.5, 0.5, 0.2, 0.2]], np.float32), (6, 1))
    sc = np.full(6, 0.9, np.float32)
    k = np.asarray(ops.nms(jnp.asarray(bx), jnp.asarray(sc), iou_thresh=0.5))
    np.testing.assert_array_equal(k, ref.nms_np(bx, sc, 0.5))
    np.testing.assert_array_equal(k, [1, 0, 0, 0, 0, 0])


def test_nms_all_suppressed():
    """One cluster of near-identical boxes -> single survivor; a score
    threshold above every score -> empty keep mask."""
    base = np.asarray([0.5, 0.5, 0.3, 0.3], np.float32)
    bx = base[None] + RNG.uniform(-0.01, 0.01, (8, 4)).astype(np.float32)
    sc = RNG.uniform(0.5, 0.9, 8).astype(np.float32)
    k = np.asarray(ops.nms(jnp.asarray(bx), jnp.asarray(sc), iou_thresh=0.5))
    np.testing.assert_array_equal(k, ref.nms_np(bx, sc, 0.5))
    assert k.sum() == 1.0 and k[np.argmax(sc)] == 1.0
    none = np.asarray(ops.nms(jnp.asarray(bx), jnp.asarray(sc), score_thresh=0.95))
    np.testing.assert_array_equal(none, np.zeros(8, np.float32))
    np.testing.assert_array_equal(none, ref.nms_np(bx, sc, 0.5, 0.95))


def test_nms_more_survivors_than_max_keep():
    """> max_keep disjoint boxes: exactly max_keep survive, highest scores
    first, shapes unchanged (fixed-size contract — masked, never sliced)."""
    n, mk = 12, 5
    bx = np.stack([
        np.linspace(0.05, 0.95, n), np.full(n, 0.5), np.full(n, 0.04), np.full(n, 0.04),
    ], -1).astype(np.float32)  # pairwise-disjoint strip
    sc = RNG.permutation(np.linspace(0.2, 0.9, n)).astype(np.float32)
    k = np.asarray(ops.nms(jnp.asarray(bx), jnp.asarray(sc), iou_thresh=0.5, max_keep=mk))
    np.testing.assert_array_equal(k, ref.nms_np(bx, sc, 0.5, 0.0, mk))
    assert k.shape == (n,) and k.sum() == mk
    assert set(np.nonzero(k)[0]) == set(np.argsort(-sc)[:mk])  # top-mk by score


def test_nms_kept_boxes_are_an_antichain():
    """No two kept boxes overlap above the threshold, and every dropped
    valid box overlaps some kept, higher-ranked box."""
    bx = _boxes(64)
    sc = RNG.uniform(0.2, 1.0, 64).astype(np.float32)
    thresh = 0.4
    k = np.asarray(ops.nms(jnp.asarray(bx), jnp.asarray(sc), iou_thresh=thresh))
    iou = ref.pairwise_iou_np(bx, bx)
    kept = np.nonzero(k)[0]
    for i in kept:
        for j in kept:
            assert i == j or iou[i, j] <= thresh
    order = np.argsort(-sc, kind="stable")
    rank = {int(b): r for r, b in enumerate(order)}
    for d in np.nonzero(1 - k)[0]:
        assert any(iou[d, j] > thresh and rank[int(j)] < rank[int(d)] for j in kept)


# ------------------------- matching + AP fixtures ---------------------------

def _pred(boxes, scores, cls=None, valid=None):
    boxes = jnp.asarray(boxes, jnp.float32)
    B, K = boxes.shape[:2]
    return {
        "boxes": boxes,
        "scores": jnp.asarray(scores, jnp.float32),
        "cls": jnp.zeros((B, K), jnp.int32) if cls is None else jnp.asarray(cls, jnp.int32),
        "valid": jnp.ones((B, K), jnp.float32) if valid is None else jnp.asarray(valid, jnp.float32),
    }


def test_match_greedy_one_gt_one_tp():
    """Two detections on one GT: only the higher-scored one is a TP."""
    gt = jnp.asarray([[[0.3, 0.3, 0.2, 0.2]]], jnp.float32)
    pred = _pred([[[0.3, 0.3, 0.2, 0.2], [0.31, 0.3, 0.2, 0.2]]], [[0.9, 0.8]])
    tp = detection.match_detections(pred, gt, jnp.zeros((1, 1), jnp.int32), jnp.ones((1, 1), jnp.float32))
    np.testing.assert_array_equal(np.asarray(tp), [[1.0, 0.0]])


def test_match_is_class_aware():
    gt = jnp.asarray([[[0.3, 0.3, 0.2, 0.2]]], jnp.float32)
    pred = _pred([[[0.3, 0.3, 0.2, 0.2]]], [[0.9]], cls=[[1]])  # wrong class
    tp = detection.match_detections(pred, gt, jnp.zeros((1, 1), jnp.int32), jnp.ones((1, 1), jnp.float32))
    np.testing.assert_array_equal(np.asarray(tp), [[0.0]])


def test_map_hand_computed_fixture():
    """2 GTs, dets TP(.9) / duplicate-FP(.8) / TP(.7):
    PR points (.5, 1), (.5, .5), (1, 2/3) -> all-point AP = 5/6."""
    gt_boxes = jnp.asarray([[[0.2, 0.2, 0.1, 0.1], [0.7, 0.7, 0.1, 0.1]]], jnp.float32)
    gt_cls = jnp.zeros((1, 2), jnp.int32)
    gt_valid = jnp.ones((1, 2), jnp.float32)
    pred = _pred(
        [[[0.2, 0.2, 0.1, 0.1], [0.2, 0.2, 0.1, 0.1], [0.7, 0.7, 0.1, 0.1]]],
        [[0.9, 0.8, 0.7]],
    )
    out = detection.evaluate_detections(pred, gt_boxes, gt_cls, gt_valid, n_classes=1)
    np.testing.assert_allclose(float(out["map"]), 5.0 / 6.0, rtol=1e-6)
    # NMS-invalidated duplicate no longer counts as FP -> perfect AP
    pred["valid"] = jnp.asarray([[1.0, 0.0, 1.0]], jnp.float32)
    out2 = detection.evaluate_detections(pred, gt_boxes, gt_cls, gt_valid, n_classes=1)
    np.testing.assert_allclose(float(out2["map"]), 1.0, rtol=1e-6)


def test_map_averages_only_present_classes():
    """A class with zero GT anywhere contributes nothing to mAP (no fake 0)."""
    gt_boxes = jnp.asarray([[[0.2, 0.2, 0.1, 0.1]]], jnp.float32)
    gt_cls = jnp.zeros((1, 1), jnp.int32)
    gt_valid = jnp.ones((1, 1), jnp.float32)
    pred = _pred([[[0.2, 0.2, 0.1, 0.1]]], [[0.9]])
    out = detection.evaluate_detections(pred, gt_boxes, gt_cls, gt_valid, n_classes=3)
    np.testing.assert_allclose(float(out["map"]), 1.0, rtol=1e-6)


def test_evaluator_per_client_and_global():
    """build_evaluator: ONE jitted call -> per-client vector + pooled
    global, shapes fixed by (C, B) alone, everything in [0, 1]."""
    from repro.models import params as P

    params = P.init_params(yolov3.template(CFG), jax.random.key(0), jnp.float32)
    C, B = 2, 2
    imgs = jnp.asarray(RNG.normal(0, 0.05, (C, B, 32, 32, 3)), jnp.float32)
    batch = {
        "images": imgs,
        "gt_boxes": jnp.asarray(_boxes(C, B, 3), jnp.float32),
        "gt_cls": jnp.zeros((C, B, 3), jnp.int32),
        "gt_valid": jnp.ones((C, B, 3), jnp.float32),
    }
    ev = detection.build_evaluator(CFG, max_detections=16)
    out = ev(params, batch)
    assert out["per_client_map"].shape == (C,)
    assert out["per_client_ap"].shape == (C, CFG.vocab_size)
    for v in [float(out["map"]), *map(float, out["per_client_map"])]:
        assert np.isfinite(v) and 0.0 <= v <= 1.0


def test_decode_predictions_fixed_shapes():
    """Fixed K detection slots with a validity mask; scores descending."""
    from repro.models import params as P

    params = P.init_params(yolov3.template(CFG), jax.random.key(1), jnp.float32)
    imgs = jnp.asarray(RNG.normal(0, 0.05, (2, 32, 32, 3)), jnp.float32)
    pred = detection.decode_predictions(CFG, params, imgs, max_detections=24)
    assert pred["boxes"].shape == (2, 24, 4)
    assert pred["scores"].shape == pred["cls"].shape == pred["valid"].shape == (2, 24)
    s = np.asarray(pred["scores"])
    assert (np.diff(s, axis=1) <= 1e-6).all()  # top-k order preserved
    v = np.asarray(pred["valid"])
    assert set(np.unique(v)).issubset({0.0, 1.0})
