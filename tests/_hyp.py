"""hypothesis, or a deterministic fallback when it is not installed.

Test modules import `given` / `settings` / `st` from here instead of from
`hypothesis` directly. With the real package present this module is a pure
re-export. Without it, `@given` degrades to a fixed number of deterministic
example draws per strategy (seeded rng per example index), which keeps the
property tests meaningful as smoke tests and — more importantly — keeps the
suite collectable in containers where hypothesis isn't baked in.

Only the strategy combinators this repo uses are implemented: integers,
floats, lists, builds, sampled_from, binary.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import numpy as _np

    HAVE_HYPOTHESIS = False
    _N_EXAMPLES = 8  # fixed draws per test when falling back

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, allow_nan=False, allow_infinity=False, **_kw):
            return _Strategy(lambda r: float(r.uniform(min_value, max_value)))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(
                lambda r: [
                    elements.example(r)
                    for _ in range(int(r.integers(min_size, max_size + 1)))
                ]
            )

        @staticmethod
        def sampled_from(options):
            opts = list(options)
            return _Strategy(lambda r: opts[int(r.integers(0, len(opts)))])

        @staticmethod
        def binary(min_size=0, max_size=10):
            return _Strategy(
                lambda r: bytes(r.integers(0, 256, int(r.integers(min_size, max_size + 1)), dtype=_np.uint8))
            )

        @staticmethod
        def builds(target, **kwargs):
            return _Strategy(
                lambda r: target(**{k: v.example(r) for k, v in kwargs.items()})
            )

    st = _Strategies()

    def settings(**_kwargs):  # max_examples/deadline knobs are meaningless here
        return lambda f: f

    def given(*strategies, **kw_strategies):
        def decorate(f):
            # zero-arg wrapper: pytest must not mistake strategy params for
            # fixtures, so the original signature is deliberately hidden
            def run():
                for i in range(_N_EXAMPLES):
                    rng = _np.random.default_rng(1000 + i)
                    args = [s.example(rng) for s in strategies]
                    kwargs = {k: s.example(rng) for k, s in kw_strategies.items()}
                    f(*args, **kwargs)

            run.__name__ = f.__name__
            run.__module__ = f.__module__
            run.__doc__ = f.__doc__
            return run

        return decorate


strategies = st

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st", "strategies"]
