"""Packed aggregation engine: registry surface, packed-vs-legacy numerical
equivalence on the four seed modes, Pallas packed kernels vs oracles,
convergence smoke tests for the new modes (fedavgm / fedadam /
trimmed_mean), and hypothesis properties of the PR 2 participation-mask
operand (all-ones == None; masked-out rows can hold anything)."""
import dataclasses

import numpy as np
import pytest
from _hyp import given, settings, st

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import aggregators, fedavg, packing
from repro.core import compression as comp
from repro.core import rounds as R
from repro.core.rounds import FedConfig
from repro.kernels import ops, ref
from repro.optim import sgd

CFG = get_arch("qwen3-1.7b").reduced()
TPL = R.make_template(CFG)
SPEC = packing.build_pack_spec(CFG, TPL)
RNG = np.random.default_rng(7)


def _fed(mode, **kw):
    base = dict(n_clients=4, local_steps=1, aggregation=mode, topn=2, client_axis="data", data_axis=None)
    base.update(kw)
    return FedConfig(**base)


def _ctx(mode, mesh=None, **kw):
    return aggregators.AggContext(cfg=CFG, fed=_fed(mode, **kw), template=TPL, spec=SPEC, mesh=mesh)


def _stacked_and_base():
    # tree layout: these tests exercise aggregators against the legacy
    # per-leaf path, so they want a materialized client-stacked pytree
    state = R.make_state(CFG, _fed("dense", state_layout="tree"), sgd(), jax.random.key(0))
    base = state["params"]
    stacked = jax.tree.map(
        lambda x: x + jnp.asarray(RNG.normal(size=x.shape) * 0.01, x.dtype), base
    )
    return stacked, base


def _maxdiff(a, b):
    return max(
        float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


# ----------------------------- registry -------------------------------------

def test_registry_has_all_modes():
    have = set(aggregators.names())
    assert {"dense", "eq6", "quant8", "static_topn", "fedavgm", "fedadam", "trimmed_mean", "fedsgd", "topk_ef", "quant4", "secure"} <= have


def test_unknown_mode_fails_at_build_with_names():
    with pytest.raises(ValueError, match="registered"):
        R.build_fed_round(CFG, _fed("nope"), sgd())


class _FakeMesh:
    """Shape-only stand-in: a 2-shard client axis on a 1-device container.

    Both validation paths read only mesh.axis_names / mesh.devices.shape and
    must raise before any collective touches real devices."""

    axis_names = ("data", "model")
    devices = np.zeros((2, 1))


def test_quant8_divisibility_validated_at_build():
    # registry path (packed engine)
    with pytest.raises(ValueError, match="divisible"):
        aggregators.get("quant8")(_ctx("quant8", mesh=_FakeMesh(), n_clients=3))
    # legacy tree path raises the same way instead of mis-sizing scales
    stacked, base = _stacked_and_base()
    three = jax.tree.map(lambda x: x[:3], stacked)
    with pytest.raises(ValueError, match="divisible"):
        fedavg.aggregate_quant8(three, jax.tree.map(lambda x: x[:3], base),
                                R.uniform_weights(3), _FakeMesh(), "data",
                                R.stacked_pspecs(TPL, "data"))


def test_trimmed_mean_ratio_validated():
    with pytest.raises(ValueError, match="trim"):
        aggregators.get("trimmed_mean")(_ctx("trimmed_mean", trim_ratio=0.5))
    # floor(ratio*C) == 0 would silently be a plain mean — rejected too
    with pytest.raises(ValueError, match="Byzantine"):
        aggregators.get("trimmed_mean")(_ctx("trimmed_mean", trim_ratio=0.2))


def test_packed_pspec_uses_model_axis_when_divisible():
    from jax.sharding import PartitionSpec as P

    spec16 = packing.PackSpec(1600, 2, (packing.LeafSlot("x", (1600,), 0, 1600, 0, 1),))
    spec17 = packing.PackSpec(17, 2, (packing.LeafSlot("x", (17,), 0, 17, 0, 1),))
    sizes = {"data": 16, "model": 16}
    assert packing.packed_pspec(spec16, "data", axis_sizes=sizes) == P("data", "model")
    assert packing.packed_pspec(spec17, "data", axis_sizes=sizes) == P("data", None)


def test_no_mode_branching_left_in_rounds():
    import inspect

    src = inspect.getsource(R.build_fed_round)
    assert 'fed.aggregation ==' not in src and 'elif' not in src


# ------------------- packed engine == legacy tree path ----------------------

def test_packed_dense_matches_legacy():
    stacked, _ = _stacked_and_base()
    w = jnp.asarray(RNG.dirichlet([1.0] * 4), jnp.float32)
    packed = packing.pack(SPEC, stacked)
    out, _ = aggregators.get("dense")(_ctx("dense")).aggregate(packed, w, {})
    assert _maxdiff(fedavg.aggregate_dense(stacked, w), packing.unpack(SPEC, out, stacked)) < 1e-5


def test_packed_eq6_matches_legacy():
    stacked, base = _stacked_and_base()
    w = jnp.asarray(RNG.dirichlet([1.0] * 4), jnp.float32)
    prev = jax.vmap(lambda p: comp.layer_sums(CFG, TPL, p))(base)
    legacy, legacy_sums = fedavg.aggregate_eq6(CFG, TPL, stacked, w, prev, topn=2)
    agg = aggregators.get("eq6")(_ctx("eq6"))
    st0 = agg.init_state(packing.pack(SPEC, base))
    np.testing.assert_allclose(np.asarray(st0["prev_sums"]), np.asarray(prev), rtol=1e-5, atol=1e-3)
    out, st1 = agg.aggregate(packing.pack(SPEC, stacked), w, st0)
    assert _maxdiff(legacy, packing.unpack(SPEC, out, stacked)) < 1e-5
    np.testing.assert_allclose(np.asarray(st1["prev_sums"]), np.asarray(legacy_sums), rtol=1e-5, atol=1e-3)


def test_packed_static_topn_matches_legacy():
    stacked, _ = _stacked_and_base()
    w = jnp.asarray(RNG.dirichlet([1.0] * 4), jnp.float32)
    sched = fedavg.static_layer_schedule(comp.n_score_buckets(CFG), 2, 0)
    legacy = fedavg.aggregate_static_topn(CFG, TPL, stacked, w, sched)
    out, _ = aggregators.get("static_topn")(_ctx("static_topn")).aggregate(
        packing.pack(SPEC, stacked), w, {}
    )
    assert _maxdiff(legacy, packing.unpack(SPEC, out, stacked)) < 1e-5


def test_packed_quant8_matches_legacy_within_quant_step():
    stacked, base = _stacked_and_base()
    w = R.uniform_weights(4)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        legacy = fedavg.aggregate_quant8(stacked, base, w, mesh, "data", R.stacked_pspecs(TPL, "data"))
        agg = aggregators.get("quant8")(_ctx("quant8", mesh=mesh))
        pb = packing.pack(SPEC, base)
        out, st = agg.aggregate(packing.pack(SPEC, stacked), w, {"base": pb[0]})
    # scale granularities differ (per-row-block vs per-leaf-shard): both are
    # within one max quantization step of each other
    step = float(jnp.max(jnp.abs(packing.pack(SPEC, stacked) - pb))) / 127.0
    assert _maxdiff(legacy, packing.unpack(SPEC, out, stacked)) < 2 * step + 1e-7
    # next round's dispatch = row 0 of the output (base is the (N,) row)
    np.testing.assert_array_equal(np.asarray(st["base"]), np.asarray(out[0]))


def test_pack_unpack_roundtrip_and_layout():
    stacked, _ = _stacked_and_base()
    packed = packing.pack(SPEC, stacked)
    assert packed.shape == (4, SPEC.n_total)
    assert _maxdiff(stacked, packing.unpack(SPEC, packed, stacked)) == 0.0
    ids = packing.bucket_ids(SPEC)
    assert ids.shape == (SPEC.n_total,) and ids.max() == SPEC.n_buckets - 1
    # slot-wise bucket sums == legacy per-leaf layer sums
    sums = packing.bucket_sums(SPEC, packed)
    legacy = jax.vmap(lambda p: comp.layer_sums(CFG, TPL, p))(stacked)
    np.testing.assert_allclose(np.asarray(sums), np.asarray(legacy), rtol=1e-5, atol=1e-3)


# --------------------------- Pallas kernels ---------------------------------

@pytest.mark.parametrize("C,N,B", [(4, 3000, 3), (3, 1024, 5), (2, 77, 2)])
def test_packed_bucket_reduce_kernel(C, N, B):
    x = jnp.asarray(RNG.normal(size=(C, N)), jnp.float32)
    wm = jnp.asarray(RNG.random((C, B)), jnp.float32)
    ids = jnp.asarray(RNG.integers(0, B, N), jnp.int32)
    num_k, den_k = ops.packed_bucket_reduce(x, wm, ids, block_n=256)
    num_r, den_r = ref.packed_bucket_reduce(x, wm, ids)
    np.testing.assert_allclose(np.asarray(num_k), np.asarray(num_r), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(den_k), np.asarray(den_r), rtol=1e-5, atol=1e-5)


def test_quantize_rows_kernel_matches_ref():
    x = jnp.asarray(RNG.normal(size=(3, 2500)), jnp.float32)
    q_k, s_k = ops.quantize_rows(x, block=256)
    q_r, s_r = packing.quantize_rows_ref(x, 256)
    np.testing.assert_array_equal(np.asarray(q_k), np.asarray(q_r))
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-6)
    back = ops.dequantize_rows(q_k, s_k, block=256)
    np.testing.assert_allclose(
        np.asarray(back), np.asarray(packing.dequantize_rows_ref(q_r, s_r, 256)), rtol=1e-6, atol=1e-7
    )


@pytest.mark.parametrize("mode,tol", [("eq6", 1e-5), ("quant8", 1e-6)])
def test_agg_impl_pallas_matches_ref_in_round(mode, tol):
    """FedConfig.agg_impl='pallas' routes the round through the packed
    kernels (bucket reduce for eq6, row-block quant for quant8) and matches
    the jnp reference engine."""
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    opt = sgd(lr=0.05)
    toks = jnp.asarray(RNG.integers(0, CFG.vocab_size, (4, 1, 2, 16)), jnp.int32)
    outs = {}
    for impl in ("ref", "pallas"):
        fed = _fed(mode, agg_impl=impl)
        with jax.set_mesh(mesh):
            state = R.make_state(CFG, fed, opt, jax.random.key(2))
            fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
            state, _ = fr(state, {"tokens": toks}, R.uniform_weights(4))
        outs[impl] = state["params"]
    assert _maxdiff(outs["ref"], outs["pallas"]) < tol


# -------------- participation-mask properties (all aggregators) -------------

# tiny synthetic spec: the mask contract is shape-independent, so the
# property sweep runs on a 4-bucket 64-element buffer instead of a model
_PROP_C, _PROP_N, _PROP_B = 4, 64, 4
_PROP_SPEC = packing.PackSpec(
    _PROP_N, _PROP_B,
    tuple(
        packing.LeafSlot(f"leaf{i}", (_PROP_N // _PROP_B,), i * (_PROP_N // _PROP_B), _PROP_N // _PROP_B, i, 1)
        for i in range(_PROP_B)
    ),
)
_PROP_KW = {"trimmed_mean": {"trim_ratio": 0.25}}


def _prop_agg(name):
    fed = _fed(name, topn=2, **_PROP_KW.get(name, {}))
    ctx = aggregators.AggContext(cfg=CFG, fed=fed, template=TPL, spec=_PROP_SPEC, mesh=None)
    return aggregators.get(name)(ctx)


def _prop_inputs(rng, weights):
    packed = jnp.asarray(rng.normal(size=(_PROP_C, _PROP_N)), jnp.float32)
    base = jnp.asarray(rng.normal(size=(_PROP_C, _PROP_N)) * 0.1, jnp.float32)
    w = np.asarray(weights, np.float64)
    w = jnp.asarray(w / w.sum(), jnp.float32)
    return packed, base, w


def test_fedsgd_has_no_mask_surface():
    """The one non-stacked mode: a single shared copy, nothing to mask."""
    cls = aggregators.get("fedsgd")
    assert not cls.stacked
    with pytest.raises(RuntimeError, match="shared model"):
        cls(aggregators.AggContext(cfg=CFG, fed=_fed("fedsgd"), template=TPL, spec=_PROP_SPEC)).aggregate(None, None, {})


@given(st.lists(st.floats(0.05, 1.0), min_size=_PROP_C, max_size=_PROP_C), st.integers(0, 2**31 - 1))
@settings(max_examples=8, deadline=None)
def test_mask_all_ones_equals_none(wlist, seed):
    """Contract (aggregators/base.py): aggregate(mask=all-ones) must be
    numerically identical to aggregate(mask=None), for EVERY stacked mode."""
    for name in aggregators.names():
        if not aggregators.get(name).stacked:
            continue
        agg = _prop_agg(name)
        packed, base, w = _prop_inputs(np.random.default_rng(seed), wlist)
        st0 = agg.init_state(base)
        out_none, _ = agg.aggregate(packed, w, st0)
        out_ones, _ = agg.aggregate(packed, w, st0, jnp.ones((_PROP_C,), jnp.float32))
        np.testing.assert_allclose(
            np.asarray(out_ones), np.asarray(out_none), rtol=1e-6, atol=1e-7,
            err_msg=f"mode={name}",
        )


@given(
    st.integers(1, 2 ** _PROP_C - 2),  # >=1 participant AND >=1 masked-out
    st.floats(1.0, 1e4),
)
@settings(max_examples=8, deadline=None)
def test_masked_rows_cannot_influence_participants(mask_bits, junk_scale):
    """Mask-0 rows are clients that did not train: whatever garbage their
    buffer rows hold (scaled up to 1e4 — a Byzantine straggler), every
    participant's output row is unchanged, for every stacked mode."""
    mask_np = np.asarray([(mask_bits >> c) & 1 for c in range(_PROP_C)], np.float32)
    mask = jnp.asarray(mask_np)
    part = mask_np[:, None]
    for name in aggregators.names():
        if not aggregators.get(name).stacked:
            continue
        agg = _prop_agg(name)
        rng = np.random.default_rng(mask_bits * 31 + int(junk_scale))
        packed, base, w = _prop_inputs(rng, [0.4, 0.3, 0.2, 0.1])
        st0 = agg.init_state(base)
        out_clean, _ = agg.aggregate(packed, w, st0, mask)
        junk = jnp.asarray(rng.normal(size=(_PROP_C, _PROP_N)) * junk_scale, jnp.float32)
        packed_junk = jnp.where(mask[:, None] > 0, packed, junk)
        out_junk, _ = agg.aggregate(packed_junk, w, st0, mask)
        np.testing.assert_allclose(
            np.asarray(out_junk) * part, np.asarray(out_clean) * part,
            rtol=1e-6, atol=1e-7, err_msg=f"mode={name}",
        )


# ------------------ new modes: convergence smoke tests ----------------------

def _toy_batch(fed, b=2, S=16, seed=3):
    rng = np.random.default_rng(seed)
    shape = (fed.n_clients, fed.local_steps, b, S)
    return {"tokens": jnp.asarray(rng.integers(0, CFG.vocab_size, shape), jnp.int32)}


@pytest.mark.parametrize(
    "mode,kw",
    [
        ("fedavgm", {}),
        ("fedadam", {"server_lr": 0.02}),
        ("trimmed_mean", {"trim_ratio": 0.25}),
    ],
)
def test_new_modes_train(mode, kw):
    fed = _fed(mode, local_steps=2, **kw)
    opt = sgd(lr=0.05)
    mesh = jax.make_mesh((1, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,) * 2)
    with jax.set_mesh(mesh):
        state = R.make_state(CFG, fed, opt, jax.random.key(0))
        fr = jax.jit(R.build_fed_round(CFG, fed, opt, mesh))
        batch = _toy_batch(fed)
        w = R.uniform_weights(fed.n_clients)
        losses = []
        for _ in range(5):
            state, m = fr(state, batch, w)
            losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], (mode, losses)
    assert int(state["round"]) == 5


def test_fedavgm_first_round_equals_dense():
    """Zero-initialized momentum + server_lr=1: round 1 is exactly FedAvg."""
    stacked, base = _stacked_and_base()
    w = R.uniform_weights(4)
    packed = packing.pack(SPEC, stacked)
    agg = aggregators.get("fedavgm")(_ctx("fedavgm"))
    out, _ = agg.aggregate(packed, w, agg.init_state(packing.pack(SPEC, base)))
    dense_out, _ = aggregators.get("dense")(_ctx("dense")).aggregate(packed, w, {})
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense_out), rtol=1e-5, atol=1e-6)


def test_trimmed_mean_ignores_outlier_client():
    stacked, _ = _stacked_and_base()
    packed = packing.pack(SPEC, stacked)
    poisoned = packed.at[0].set(1e6)  # Byzantine client
    agg = aggregators.get("trimmed_mean")(_ctx("trimmed_mean", trim_ratio=0.25))
    out, _ = agg.aggregate(poisoned, R.uniform_weights(4), {})
    clean_mid = jnp.sort(packed.astype(jnp.float32), axis=0)[1:3].mean(axis=0)
    assert float(jnp.max(jnp.abs(out[1] - clean_mid))) < 1.0  # no 1e6 leakage


def test_state_template_matches_make_state():
    """Dry-run abstract state must mirror the real state tree, per mode."""
    opt = sgd()
    for mode, kw in [("dense", {}), ("eq6", {}), ("quant8", {}), ("fedavgm", {}), ("fedadam", {}), ("trimmed_mean", {"trim_ratio": 0.25}), ("topk_ef", {}), ("quant4", {}), ("secure", {})]:
        fed = _fed(mode, **kw)
        real = R.make_state(CFG, fed, opt, jax.random.key(0))
        abstract = R.state_template(CFG, fed, opt, jnp.float32)
        assert jax.tree.structure(real) == jax.tree.structure(abstract), mode
        for r, a in zip(jax.tree.leaves(real), jax.tree.leaves(abstract)):
            assert r.shape == a.shape and r.dtype == a.dtype, mode
        specs = R.state_pspecs(CFG, fed, opt)
        assert jax.tree.structure(abstract) == jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)
        ), mode
