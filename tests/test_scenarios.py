"""Fault scenarios on the real wire, each pinned against the SimClock
replay (DESIGN.md §14).

Every scenario here runs twice: once as real worker subprocesses over TCP
(`harness.wire_run`), once as a SimClock replay of the recorded arrival
schedule — and the two must agree bit for bit on the final global (dense
codec), with `rp.replay` additionally cross-checking every recorded
dispatch version, drop decision, and flush boundary along the way.

The scenarios are the failure modes the transport exists to survive:
  - a client process hard-crashes mid-round (after one upload, before its
    next) — the survivors keep flushing;
  - a straggler trains against a version the fast clients flushed past —
    its update drops at the staleness gate and it redispatches;
  - a client exits and a NEW process reconnects with the same id (a fresh
    HELLO is the reconnect path) and resumes contributing;
  - the landing loop falls behind a bounded queue — readers block and the
    overflow is counted as backpressure, never buffered unboundedly;
  - a crashed client goes silent past heartbeat_timeout_s and the
    liveness machine logs the ALIVE -> DEAD transition.

Workers are deliberately choreographed with --max-updates / --train-delay
/ --crash-after so the interesting ordering is forced, not hoped for.
"""
import json
import threading
import time

import numpy as np

from repro.core.transport import harness
from repro.core.transport import replay as rp
from repro.launch.worker import CRASH_EXIT_CODE

TINY = harness.TINY_OVERRIDES


def _meta(**kw):
    base = dict(overrides=TINY, seq=8, batch=2)
    base.update(kw)
    return harness.make_meta(**base)


def _pin_replay(res):
    """The scenario's correctness spine: the recorded schedule re-derives
    identically in-process (rp.replay raises on any divergent decision)
    and lands on the same global bit for bit (dense codec)."""
    eng = rp.replay(res.schedule)
    np.testing.assert_array_equal(
        np.asarray(eng.global_packed_row(), np.float32), res.global_row
    )
    assert len(eng.history) == len(res.history)
    assert eng.dropped_total == res.dropped_total
    return eng


def test_client_crash_midround_survivors_keep_flushing():
    meta = _meta(n_clients=3, buffer_size=2, max_staleness=0)
    captured = {}

    def hooks(server, workers):
        captured["workers"] = workers

    res = harness.wire_run(
        meta, 3,
        worker_groups=[
            {"client_ids": [0, 1]},  # survivors, no limits
            {"client_ids": [2], "extra": ["--crash-after", "1"]},
        ],
        deadline_s=120.0,
        hooks=hooks,
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 3
    # the crasher died the hard way (os._exit, no BYE) after one upload
    assert captured["workers"][1].returncode == CRASH_EXIT_CODE
    crash_lands = [e for e in res.schedule.events if e.kind == "land" and e.client == 2]
    assert len(crash_lands) == 1
    _pin_replay(res)


def test_straggler_drops_past_max_staleness_and_recovers():
    # buffer_size=1: every landing flushes, so versions advance with the
    # fast client alone. The straggler's first update arrives 2 versions
    # stale -> dropped + redispatched; its retrained update then lands
    # fresh and completes the final flush.
    meta = _meta(n_clients=2, buffer_size=1, max_staleness=1)
    res = harness.wire_run(
        meta, 3,
        worker_groups=[
            {"client_ids": [0], "extra": ["--max-updates", "2"]},
            {"client_ids": [1], "extra": ["--train-delay", "4.0", "--max-updates", "2"]},
        ],
        deadline_s=120.0,
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 3
    assert res.dropped_total == 1 and res.schedule.n_dropped == 1
    drops = [e for e in res.schedule.events if e.kind == "land" and e.dropped]
    assert drops[0].client == 1
    # after the drop, client 1 landed again and that landing flushed
    later = [e for e in res.schedule.events if e.kind == "land"
             and e.client == 1 and not e.dropped]
    assert later and later[-1].flush >= 0
    eng = _pin_replay(res)
    assert eng.history[-1].participants == [1]


def test_reconnect_with_same_id_resumes_contributing(tmp_path):
    meta = _meta(n_clients=2, buffer_size=2, max_staleness=0)
    meta_path = tmp_path / "meta.json"
    meta_path.write_text(json.dumps(meta))

    def hooks(server, workers):
        def late_join():
            # the fresh HELLO may race the first process's (jit-slow) single
            # upload: the new process can then hold a dispatch the first
            # flush supersedes, so its first upload may be refused at the
            # version-echo gate — budget TWO updates so it retrains from the
            # flush redispatch and still contributes exactly once
            time.sleep(4.0)
            workers.append(
                harness.spawn_worker(str(meta_path), server.host, server.port,
                                     [0], ["--max-updates", "2"])
            )
        threading.Thread(target=late_join, daemon=True).start()

    res = harness.wire_run(
        meta, 2,
        worker_groups=[
            {"client_ids": [0], "extra": ["--max-updates", "1"]},
            {"client_ids": [1]},
        ],
        deadline_s=120.0,
        hooks=hooks,
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 2
    assert res.stats.reconnects >= 1
    # the reconnected client really contributed: client 0 landed exactly
    # twice (once per process) — every flush here needs both clients, and
    # a superseded/refused upload is never recorded as a land
    lands0 = [e for e in res.schedule.events if e.kind == "land" and e.client == 0]
    assert len(lands0) == 2
    # client 0 was dispatched at least once via HELLO (flush-boundary
    # redispatches are implicit in both engines, so a deferred reconnect
    # records no extra dispatch event)
    dispatches0 = [e for e in res.schedule.events
                   if e.kind == "dispatch" and e.client == 0]
    assert len(dispatches0) >= 1
    _pin_replay(res)


def test_bounded_queue_applies_backpressure():
    # queue_cap=1 + a deliberately slow landing loop + 4 clients in one
    # process: their HELLOs (and later their post-jit uploads) arrive
    # within milliseconds of each other, so while the loop dawdles 0.2s
    # over the first item the rest MUST find the queue full — readers
    # block (counted as backpressure) and the run still completes:
    # backpressure, not loss. Heartbeats never enqueue, so they can't
    # fill the queue for us.
    meta = _meta(n_clients=4, buffer_size=2, max_staleness=2,
                 queue_cap=1)
    res = harness.wire_run(meta, 2, deadline_s=120.0, land_delay_s=0.2)
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 2
    assert res.stats.backpressure_blocks >= 1
    assert res.stats.queue_high_water <= meta["queue_cap"]
    _pin_replay(res)


def test_heartbeat_timeout_marks_crashed_client_dead():
    meta = _meta(n_clients=2, buffer_size=1, max_staleness=0,
                 heartbeat_s=0.1, heartbeat_timeout_s=0.6)
    res = harness.wire_run(
        meta, 8,
        worker_groups=[
            {"client_ids": [0], "extra": ["--train-delay", "0.3"]},
            {"client_ids": [1], "extra": ["--crash-after", "1"]},
        ],
        deadline_s=120.0,
    )
    assert not res.stats.deadline_hit, (res.stats, res.worker_stderr)
    assert res.stats.flushes == 8
    transitions = [(c, s) for _, c, s in res.liveness_log]
    assert (1, "alive") in transitions, res.liveness_log
    assert (1, "dead") in transitions, res.liveness_log
    # the survivor stayed alive throughout
    assert (0, "dead") not in transitions
    _pin_replay(res)
